"""Serving demo — continuous batching with per-workload TTQ self-calibration
and a block-paged quantized KV cache.

Submits a staggered stream of requests to the TTQEngine; the engine prefillls
each prompt in full precision (stats tap on), aggregates the activation
statistics of the *live* workload, requantizes, and decodes 4-bit over an
int8 **paged** KV pool (``kv_dtype="int8"`` codes + per-(head, token)
scales in ``(num_blocks, Hkv, block_size, ·)`` pools indexed by per-slot
block tables — DESIGN.md §8; on CPU the paged flash-decoding kernel runs in
Pallas interpret mode, so this demo exercises the exact production code
path).  Half the requests share a system prompt: after the first admission
its blocks sit in the prefix trie and later arrivals prefill only their
tails.  Prints a timeline of admissions / requantizations / completions and
a throughput + pool-metrics summary.

    PYTHONPATH=src python examples/serve_ttq.py
"""
import sys
import time

sys.path.insert(0, ".")

import jax
import numpy as np

from repro.core import ttq_policy
from repro.models import ModelConfig, lm
from repro.serving import EngineConfig, TTQEngine


def main():
    cfg = ModelConfig(name="serve-demo", family="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
                      vocab=256)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = TTQEngine(
        cfg, params,
        ttq_policy(bits=4, group_size=32, rank=8, kv_dtype="int8"),
        # decode_chunk=2: each engine step fuses 2 decode tokens on device
        # (lm.decode_many) — one host sync per block instead of per token.
        # kv_paged: slot caches become shared block pools + block tables;
        # requests reserve only the blocks their prompt+budget can touch.
        EngineConfig(max_slots=4, max_len=96, recalibrate_every=2,
                     decode_chunk=2, kv_paged=True, kv_block_size=16),
    )
    kv = eng.kvcfg
    cache_rows = cfg.n_layers * cfg.n_kv_heads
    print(f"kv-cache: {kv.dtype}, {kv.bytes_per_token_head(cfg.hd):.0f} B "
          f"per (head, token) row x {cache_rows} rows/token "
          f"(bf16 would be {2 * cfg.hd} B/row); paged pool "
          f"{eng.num_blocks} blocks x {kv.block_size} tokens/layer")
    rng = np.random.default_rng(0)
    system = list(rng.integers(1, 256, size=16))   # one shareable block
    arrivals = [(i, (system if i % 2 else [])
                 + list(rng.integers(1, 256, size=rng.integers(4, 24))),
                 int(rng.integers(8, 20))) for i in range(10)]
    t0 = time.time()
    submitted = 0
    steps = 0
    while submitted < len(arrivals) or eng.queue or any(eng.slot_req):
        # stagger: two new requests every 4 engine steps
        if steps % 4 == 0 and submitted < len(arrivals):
            for _ in range(2):
                if submitted < len(arrivals):
                    _, prompt, n = arrivals[submitted]
                    rid = eng.submit(prompt, max_new=n)
                    print(f"[step {steps:3d}] submit rid={rid} "
                          f"promptlen={len(prompt)} max_new={n}")
                    submitted += 1
        nq = eng.n_requants
        if not eng.step():
            continue
        if eng.n_requants != nq:
            print(f"[step {steps:3d}] online requantization "
                  f"#{eng.n_requants} (aggregated workload stats)")
        for rid, req in list(eng.finished.items()):
            if getattr(req, "_printed", False):
                continue
            req._printed = True
            print(f"[step {steps:3d}] done rid={rid} tokens={len(req.out)}")
        steps += 1
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in eng.finished.values())
    print(f"\n{len(eng.finished)} requests, {total_tokens} tokens, "
          f"{steps} engine steps, {dt:.1f}s wall "
          f"({total_tokens/dt:.1f} tok/s on 1 CPU core — see "
          f"benchmarks/bench_runtime.py for the v5e roofline projection), "
          f"{eng.host_syncs/max(total_tokens,1):.2f} host syncs/token")
    print(f"requantizations: {eng.n_requants}")
    print(f"kv-pool: peak utilization {eng.kv_pool_utilization:.2f}, "
          f"prefix hit rate {eng.prefix_hit_rate:.2f} (shared system "
          f"prompt prefilled once), preemptions {eng.preemptions}")


if __name__ == "__main__":
    main()
