"""Quickstart — TTQ in 60 seconds.

Builds a small LM, compares RTN / AWQ / TTQ weight-approximation quality,
then runs the full lifecycle through the unified ``repro.quant`` API:
``QuantizedModel``  — calibrate(stats) → requantize() → decode_params —
with a mixed-precision policy override, and finally the serving engine with
a quantized KV cache (everything below runs on the CPU fallback paths:
interpret-mode Pallas + jnp oracles).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (AWQConfig, KVCacheConfig, QuantConfig,
                        activation_diag, awq_qdq, qdq, svd_factors,
                        ttq_lowrank_qdq)
from repro.core.awq import awq_loss
from repro.core.ttq import QuantizedTensor
from repro.models import ModelConfig, lm
from repro.quant import QuantizedModel, override, registered_methods, ttq_policy
from repro.serving import EngineConfig, TTQEngine


def main():
    cfg = ModelConfig(name="quickstart", family="dense", n_layers=3,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab=256)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.name}, {sum(p.size for p in jax.tree.leaves(params)):,} params")
    print(f"registered quantizers: {', '.join(registered_methods())}")

    # --- 1. layer-level: the quantization science -------------------------
    W = params["stack"][0]["u0"]["mlp"]["wg"][0].astype(jnp.float32)
    key = jax.random.PRNGKey(1)
    chan = jnp.exp(jax.random.normal(key, (cfg.d_model,)) * 1.5)
    X = jax.random.normal(jax.random.PRNGKey(2), (512, cfg.d_model)) * chan
    Cd = jnp.mean(X ** 2, axis=0)
    qcfg = QuantConfig(bits=3, group_size=32, layout="row")
    D = activation_diag(X)
    B, A = svd_factors(W, 16)
    print("\nactivation-aware loss ‖(W−Ŵ)diag(C)^½‖² at 3-bit, g=32:")
    print(f"  RTN        : {float(awq_loss(W, qdq(W, qcfg), Cd)):.1f}")
    print(f"  AWQ/TTQ    : {float(awq_loss(W, awq_qdq(W, D, qcfg), Cd)):.1f}")
    print(f"  TTQ + r16  : {float(awq_loss(W, ttq_lowrank_qdq(W, B, A, D, qcfg), Cd)):.1f}")

    # --- 2. model-level: the QuantizedModel facade ------------------------
    # mixed precision as policy: MLPs 3-bit g=64, attention 4-bit g=32
    policy = ttq_policy(bits=3, group_size=64, rank=8).with_overrides(
        override("*.mix.*", bits=4, group_size=32))
    qm = QuantizedModel(params, policy)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 16), 0, cfg.vocab)
    _, _, stats = lm.prefill(cfg, params, {"tokens": toks}, max_len=32)
    qm.calibrate(stats, tokens=toks.size).requantize()
    mix = qm.qparams["stack"][0]["u0"]["mix"]["wq"]
    mlp = qm.qparams["stack"][0]["u0"]["mlp"]["wg"]
    assert isinstance(mix, QuantizedTensor) and isinstance(mlp, QuantizedTensor)
    print(f"\nQuantizedModel (session count={qm.session.count:.0f}): "
          f"attention {mix.bits}-bit g={mix.group_size}, "
          f"MLP {mlp.bits}-bit g={mlp.group_size}")
    lg, _, _ = lm.forward(cfg, qm.decode_params, {"tokens": toks})
    print(f"quantized forward: logits {tuple(lg.shape)}, "
          f"finite={bool(jnp.isfinite(lg).all())}")

    # --- 3. system-level: the serving lifecycle ---------------------------
    # int4 weights AND an int8 KV cache: kv_dtype switches the engine's slot
    # caches to codes + per-(head, token) scales, decoded on the fly by the
    # fused dequant-attention kernel (interpret mode on CPU)
    eng = TTQEngine(cfg, params,
                    ttq_policy(bits=4, group_size=32, rank=8,
                               kvcache=KVCacheConfig(dtype="int8")),
                    EngineConfig(max_slots=2, max_len=64))
    rids = [eng.submit([7, 3, 9, 1], max_new=8),
            eng.submit([100, 42, 5], max_new=8)]
    outs = eng.run_all()
    print("\nTTQ engine (4-bit weights, int8 KV cache, per-prompt calibration):")
    for rid in rids:
        print(f"  request {rid}: {outs[rid]}")
    print(f"  online requantizations: {eng.n_requants}")
    kstate = eng.state["stack"][0]["u0"]
    print(f"  slot cache leaves: k_q {kstate['k_q'].dtype} "
          f"{tuple(kstate['k_q'].shape)}, k_s {kstate['k_s'].dtype} "
          f"({eng.kvcfg.bytes_per_token_head(cfg.hd):.0f} B vs "
          f"{2 * cfg.hd} B bf16 per head-token row)")


if __name__ == "__main__":
    main()
