"""Domain-shift experiment (paper Fig. 1 / Table 3 core claim).

Trains a small LM on two domains, then quantizes with:
  * AWQ calibrated on each of three calibration domains (offline, static)
  * TTQ with zero calibration (online, per-batch)
and evaluates perplexity on in-domain + shifted eval sets.  AWQ's quality
moves with the calibration choice; TTQ tracks the best of them without any
calibration data.

    PYTHONPATH=src python examples/domain_shift.py
"""
import sys
sys.path.insert(0, ".")

from benchmarks.common import (CALIB_DOMAINS, EVAL_DOMAINS, collect_stats,
                               eval_batches, perplexity, quantize_with,
                               trained_model, ttq_perplexity)

BITS, G = 3, 32


def main():
    cfg, params = trained_model()
    evs = {d: eval_batches(d, n=2) for d in EVAL_DOMAINS}
    print(f"fp baseline ppl: " + ", ".join(
        f"dom{d}={perplexity(cfg, params, evs[d]):.1f}" for d in EVAL_DOMAINS))
    for c in CALIB_DOMAINS:
        calib = collect_stats(cfg, params, eval_batches(c, n=2, seed0=555))
        qp = quantize_with(cfg, params, "awq", BITS, G, calib=calib)
        print(f"AWQ calib-dom{c} ppl: " + ", ".join(
            f"dom{d}={perplexity(cfg, qp, evs[d]):.1f}" for d in EVAL_DOMAINS))
    print("TTQ (zero calib) ppl: " + ", ".join(
        f"dom{d}={ttq_perplexity(cfg, params, evs[d], BITS, G, rank=16):.1f}"
        for d in EVAL_DOMAINS))


if __name__ == "__main__":
    main()
