"""End-to-end driver: train an LM for a few hundred steps, then serve it
through the full TTQ stack (online quantization + quantized decode) and
report perplexity under RTN / AWQ / TTQ at 3- and 4-bit.

Presets:
    --preset cpu   (default)  ~3M params  — runs in minutes on this container
    --preset 100m             ~100M params (d=768, L=12, 32k vocab) — the
                              "train ~100M for a few hundred steps" target on
                              real hardware; identical code path.

    PYTHONPATH=src python examples/train_ttq_lm.py [--steps 300]
"""
import argparse
import sys

sys.path.insert(0, ".")

import jax

from repro.data import DataConfig, token_stream
from repro.models import ModelConfig, lm
from repro.training import TrainConfig, Trainer

PRESETS = {
    "cpu": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
                vocab=256, seq=64, batch=16),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2304, vocab=32768, seq=1024, batch=32),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="results/train_ttq_lm")
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = ModelConfig(name=f"ttq-lm-{args.preset}", family="dense",
                      n_layers=p["n_layers"], d_model=p["d_model"],
                      n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"],
                      d_ff=p["d_ff"], vocab=p["vocab"])
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps")
    dc = DataConfig(vocab=p["vocab"], seq_len=p["seq"], batch=p["batch"],
                    seed=11)
    tc = TrainConfig(n_microbatches=2, remat=True, total_steps=args.steps,
                     warmup=max(10, args.steps // 10),
                     checkpoint_every=max(50, args.steps // 4),
                     checkpoint_dir=args.ckpt)
    tr = Trainer(cfg, tc, token_stream(dc, 0))
    tr.restore_if_available()
    log = tr.run(max(0, args.steps - tr.step))
    if log:
        print(f"loss: {log[0]['loss']:.3f} → {log[-1]['loss']:.3f}")
    params = tr.params

    # quantized-quality report on held-out data
    from benchmarks import common as C
    C.BENCH_CFG, C.BENCH_DC = cfg, dc   # reuse the eval helpers on this model
    ev = C.eval_batches(0, n=2, seq=p["seq"], batch=4)
    base = C.perplexity(cfg, params, ev)
    print(f"\nheld-out ppl fp: {base:.2f}")
    calib = C.collect_stats(cfg, params, C.eval_batches(1, n=2, seq=p["seq"],
                                                        batch=4, seed0=321))
    for bits in (4, 3):
        rtn = C.perplexity(cfg, C.quantize_with(cfg, params, "rtn", bits, 32), ev)
        awq = C.perplexity(cfg, C.quantize_with(cfg, params, "awq", bits, 32,
                                                calib=calib), ev)
        ttq = C.ttq_perplexity(cfg, params, ev, bits, 32, rank=16)
        print(f"{bits}-bit g=32  RTN {rtn:.2f} | AWQ(shifted calib) {awq:.2f} "
              f"| TTQ(r=16, zero calib) {ttq:.2f}")


if __name__ == "__main__":
    main()
