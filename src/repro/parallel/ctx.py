"""Parallelism context threaded through model forwards."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Optional[jax.sharding.Mesh] = None
    data_axes: Tuple[str, ...] = ("data",)     # batch axes (('pod','data') multi-pod)
    model_axis: str = "model"
    moe_impl: str = "a2a"                      # 'a2a' (shard_map EP) | 'dense'
    seq_axis: Optional[str] = None             # SP: shard sequence on this axis

    @property
    def dp(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
