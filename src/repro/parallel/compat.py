"""JAX version compatibility.

``jax.shard_map`` became a top-level API (with the ``check_vma`` kwarg) after
the experimental period; older versions (≤0.4.x, like the pinned toolchain
here) expose ``jax.experimental.shard_map.shard_map`` with the same semantics
under the ``check_rep`` kwarg.  Call sites import :func:`shard_map` from here
and always use the new-style ``check_vma`` name.
"""
from __future__ import annotations

import jax

def axis_size(name) -> int:
    """``jax.lax.axis_size`` (new API) / ``psum(1, name)`` (old) inside a
    mapped context."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
