from .compat import shard_map
from .ctx import ParallelCtx
from .rules import param_sharding, shard_params, state_sharding

__all__ = ["ParallelCtx", "param_sharding", "shard_map", "shard_params",
           "state_sharding"]
