from .ctx import ParallelCtx
from .rules import param_sharding, shard_params, state_sharding

__all__ = ["ParallelCtx", "param_sharding", "shard_params", "state_sharding"]
