"""Logical sharding rules — param-path patterns → PartitionSpec.

Megatron-style TP on the ``model`` axis, EP for MoE experts, replication for
small tensors; decode-state sharding for serving. Rules are matched on the
flattened param path (joined with '.'), first match wins.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp

from .ctx import ParallelCtx

P = jax.sharding.PartitionSpec

# (regex on path, spec builder(ndim, model_axis) -> PartitionSpec)
_RULES = [
    # embeddings / head: vocab-parallel
    (r"(^|\.)embed$",        lambda m: P(m, None)),
    (r"(^|\.)lm_head$",      lambda m: P(m, None)),
    (r"(^|\.)pos_embed$",    lambda m: P(None, None)),
    # attention — heads on model
    (r"\.(mix|xattn)\.(wq|wk|wv)$",  lambda m: P(m, None)),
    (r"\.(mix|xattn)\.wo$",          lambda m: P(None, m)),
    (r"\.mix\.(qnorm|knorm)\.",      lambda m: P(None)),
    # MLA
    (r"\.mix\.wkv_a$",       lambda m: P(None, None)),
    (r"\.mix\.wkv_b$",       lambda m: P(m, None)),
    # RG-LRU / SSD — recurrent width on model
    (r"\.mix\.(w_branch|w_in|w_z|w_x)$", lambda m: P(m, None)),
    (r"\.mix\.(w_out)$",     lambda m: P(None, m)),
    (r"\.mix\.w_gate_[ax]$", lambda m: P(m, None, None)),   # block-diag blocks
    (r"\.mix\.conv_[wxBC]$", lambda m: P(None, None)),
    (r"\.mix\.(w_B|w_C|w_dt)$", lambda m: P(None, None)),
    (r"\.mix\.(A_log|Dskip|dt_bias|log_lambda)$", lambda m: P(None)),
    # dense MLP — hidden on model
    (r"\.mlp\.(wg|wu|w1)$",  lambda m: P(m, None)),
    (r"\.mlp\.(wd|w2)$",     lambda m: P(None, m)),
    # MoE — experts on model (EP); shared expert TP'd like dense MLP
    (r"\.mlp\.experts\.(wg|wu|wd)$", lambda m: P(m, None, None)),
    (r"\.mlp\.router$",      lambda m: P(None, None)),
    (r"\.mlp\.shared\.(wg|wu)$", lambda m: P(m, None)),
    (r"\.mlp\.shared\.wd$",  lambda m: P(None, m)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def spec_for_path(path_str: str, leaf_ndim: int, model_axis: str = "model",
                  stacked: bool = True) -> P:
    """Sharding spec for one param. ``stacked``: leading layer-repeat dim."""
    for pat, builder in _RULES:
        if re.search(pat, path_str):
            spec = builder(model_axis)
            base = len(spec)
            if stacked and leaf_ndim == base + 1:
                return P(None, *spec)
            if leaf_ndim == base:
                return spec
            # pad/trim to rank
            if leaf_ndim > base:
                return P(*([None] * (leaf_ndim - base)), *spec)
            return P(*list(spec)[:leaf_ndim])
    return P(*([None] * leaf_ndim))                     # replicate by default


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, (tuple, list)):
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axes]


def divisible_spec(spec: P, shape, mesh) -> P:
    """Drop spec axes that don't divide the corresponding dim (e.g. MQA's
    single KV head can't shard over 16-way model) — GSPMD-legal everywhere."""
    out = []
    for i, ax in enumerate(spec):
        n = _axis_size(mesh, ax)
        out.append(ax if (n > 1 and shape[i] % n == 0) or n == 1 else None)
    out += [None] * (len(shape) - len(out))
    return P(*out)


# QuantizedTensor children order: (wint, packed, scale, zero, dinv, B, A)
_QT_FIELDS = ("wint", "packed", "scale", "zero", "dinv", "B", "A")


def _qt_child_specs(base: P, model_axis: str):
    """Derive per-child specs for a QuantizedTensor from its 2-D weight spec.

    base = (row, col) of the dequantized weight; wint/packed/scale/zero share
    it (packed/scale cols are d/8, d/g slices of the same layout); dinv lives
    on the input dim (col); B on rows, A on cols.
    """
    row, col = (list(base) + [None, None])[:2]
    return {
        "wint": P(row, col), "packed": P(row, col), "scale": P(row, col),
        "zero": P(row, col), "dinv": P(col), "B": P(row, None), "A": P(None, col),
    }


def qt_specs(path_str: str, shapes, model_axis: str = "model", mesh=None):
    """Per-child PartitionSpecs for a QuantizedTensor at ``path_str``.

    ``shapes``: dict child-name → shape (or None for absent children, e.g.
    wint after packing, B/A without low-rank).  Pure spec logic — ``mesh``
    only needs a ``.shape`` mapping for the divisibility fallback, so
    property tests can drive this without real devices.
    """
    lead = 1 if ("stack" in path_str) else 0
    ref = shapes.get("wint") or shapes.get("packed")
    extra = len(ref) - 2 - lead              # e.g. expert dim
    base = spec_for_path(path_str, 2, model_axis, stacked=False)
    child = _qt_child_specs(base, model_axis)
    # experts: leading expert dim sharded on model (EP) → override TP
    if extra > 0:
        lead_spec = [None] * lead + [model_axis] + [None] * (extra - 1)
        child = {k: P(*lead_spec, None, None) if k != "dinv"
                 else P(*lead_spec, None) for k in child}
    else:
        lead_spec = [None] * lead
        child = {k: P(*lead_spec, *v) for k, v in child.items()}
    if mesh is not None:
        child = {k: (divisible_spec(v, shapes[k], mesh) if shapes.get(k)
                     else v) for k, v in child.items()}
    return child


def qt_sharding(path_str: str, qt, pctx: ParallelCtx):
    """QuantizedTensor of NamedShardings (None for absent children) for the
    packed tensor at ``path_str`` — the public per-tensor entry used by the
    shard-local requant path (quant/api.py) and ``param_sharding``."""
    from repro.core.ttq import QuantizedTensor
    shapes = {n: (getattr(qt, n).shape if getattr(qt, n) is not None else None)
              for n in _QT_FIELDS}
    child = qt_specs(path_str, shapes, pctx.model_axis, pctx.mesh)
    vals = [jax.sharding.NamedSharding(pctx.mesh, child[n])
            if shapes[n] is not None else None for n in _QT_FIELDS]
    return QuantizedTensor(*vals, qt.bits, qt.group_size,
                           qt.out_features, qt.in_features)


def constrain_qt(path_str: str, qt, pctx: ParallelCtx):
    """``with_sharding_constraint`` on every child of ``qt`` (trace-time use:
    pins requant outputs to the serving layout so each weight shard is
    quantized in place, never gathered)."""
    from repro.core.ttq import QuantizedTensor
    sh = qt_sharding(path_str, qt, pctx)
    vals = [jax.lax.with_sharding_constraint(getattr(qt, n), getattr(sh, n))
            if getattr(qt, n) is not None else None for n in _QT_FIELDS]
    return QuantizedTensor(*vals, qt.bits, qt.group_size,
                           qt.out_features, qt.in_features)


def param_sharding(params, pctx: ParallelCtx):
    """Pytree of NamedSharding matching ``params`` (layer-scanned leaves get a
    leading replicated dim; QuantizedTensor nodes get per-child derived specs;
    non-divisible dims fall back to replication)."""
    from repro.core.ttq import QuantizedTensor
    mesh = pctx.mesh

    def per_leaf(path, leaf):
        if isinstance(leaf, QuantizedTensor):
            return qt_sharding(_path_str(path), leaf, pctx)
        ps = _path_str(path)
        in_stack = "stack" in ps
        spec = spec_for_path(ps, leaf.ndim, pctx.model_axis, stacked=in_stack)
        spec = divisible_spec(spec, leaf.shape, mesh)
        return jax.sharding.NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(
        per_leaf, params, is_leaf=lambda x: isinstance(x, QuantizedTensor))


def shard_params(params, pctx: ParallelCtx):
    shardings = param_sharding(params, pctx)
    return jax.tree.map(jax.device_put, params, shardings)


def state_sharding(state, pctx: ParallelCtx, batch_axes=None, seq_axis=None,
                   paged: bool = False):
    """Decode/KV state: batch dim on data axes, head/width dims on model.

    Heuristic on rank: (B, Hkv, S, hd)→(dp, m, None|seq, None);
    (B, S, r)→(dp, None|seq, None); (B, dr)→(dp, m); (B, H, p, n)→(dp, m, None, None);
    (B, W, ch)→(dp, None, m); leading run-stacked dims get None.
    ``seq_axis``: shard the KV sequence dim (long-context, batch ≤ data size).
    ``paged``: KV leaves are slot-free block pools (NB, Hkv, bs, ·) — shard
    the KV-head dim only (never the block-pool dim: the block allocator's
    physical indices are global), per-slot block tables stay replicated.
    """
    mesh, m = pctx.mesh, pctx.model_axis
    dp = pctx.dp if batch_axes is None else batch_axes

    def per_leaf(path, leaf):
        ps = _path_str(path)
        nd = leaf.ndim
        lead = 1 if re.match(r"stack\.\d+\.", ps) or ".u" in ps else 0
        core = nd - lead
        if "enc_out" in ps:
            spec = P(dp, None, None)
        elif paged and re.search(r"\.(k|v)(_q|_s)?$", ps) and core == 4:
            # pool (NB, Hkv, bs, hd|groups): KV heads on model; no data axis
            # (every device addresses the full pool by physical block id)
            spec = P(None, m, None, None)
        elif re.search(r"\.(k|v|xk|xv)(_q|_s)?$", ps) and core == 4:
            # GQA w/ Hkv < tp: heads can't shard over model — fall back to
            # sharding the cache sequence dim (flash-decoding style; the
            # grouped attention einsum turns it into tiny psum/pmax combines).
            # §Perf iteration 2.  Baseline (opt 0) replicates instead.
            from repro.models.common import opt_level
            hkv = leaf.shape[lead + 1]
            msize = _axis_size(mesh, m)
            if hkv % msize == 0 or opt_level() < 1:
                spec = P(dp, m, seq_axis, None)
            else:
                spec = P(dp, None, m if seq_axis is None else seq_axis, None)
        elif re.search(r"\.(latent|k_rope)$", ps) and core == 3:
            spec = P(dp, seq_axis, None)
        elif re.search(r"\.h$", ps) and core == 2:
            spec = P(dp, m)
        elif re.search(r"\.h$", ps) and core == 4:
            spec = P(dp, m, None, None)
        elif re.search(r"\.conv", ps) and core == 3:
            spec = P(dp, None, m)
        else:
            spec = P(*([None] * core))
        if lead:
            spec = P(None, *spec)
        spec = divisible_spec(spec, leaf.shape, mesh)
        return jax.sharding.NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(per_leaf, state)
