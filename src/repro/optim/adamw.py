"""AdamW from scratch (no optax in this container).

Mixed precision: params live in bf16; the optimizer keeps f32 master copies
and f32 (m, v).  With ZeRO-1 the (master, m, v) leaves are additionally
sharded over the data axes (parallel/rules + training/trainer wire that up).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(grads, opt_state, cfg: AdamWConfig, params=None, lr_t=None):
    """Returns (new_params [cast to the dtype of ``params``], state, metrics)."""
    step = opt_state["step"] + 1
    lr = cfg.lr if lr_t is None else lr_t
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, mst, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        mst = mst - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * mst)
        return mst, m, v

    out = jax.tree.map(upd, grads, opt_state["master"], opt_state["m"],
                       opt_state["v"])
    master = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    ref = params if params is not None else opt_state["master"]
    new_params = jax.tree.map(lambda mst, p: mst.astype(p.dtype), master, ref)
    new_state = {"step": step, "master": master, "m": m, "v": v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
