"""LR schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup: int, peak: float):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    return peak * jnp.minimum(1.0, (s + 1.0) / max(warmup, 1))


def cosine_schedule(step, warmup: int, total: int, peak: float,
                    floor_frac: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = peak * jnp.minimum(1.0, (s + 1.0) / max(warmup, 1))
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)
