"""int8 gradient compression with error feedback — for DP all-reduce traffic.

Used inside a ``shard_map`` over the data axes (training/trainer.py builds the
compressed-DP step variant): each device quantizes its local gradient shard to
int8 with a per-tensor scale, psums the int8 payload (4× fewer bytes on the
wire), dequantizes, and keeps the quantization residual in an error-feedback
buffer so the bias vanishes over steps (Karimireddy et al.-style EF).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_state_init(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, axis_names, err_state):
    """psum int8-compressed grads over ``axis_names``; returns (grads, new_err).

    Call inside shard_map.  The per-tensor scale is agreed collectively
    (pmax — scalar, negligible wire bytes) so every device quantizes onto the
    SAME grid; the int8 payload is then exactly summable.  Quantization
    residuals stay in the local error-feedback buffer.
    """
    def per_leaf(g, err):
        gf = g.astype(jnp.float32) + err
        s = jax.lax.pmax(jnp.abs(gf).max(), axis_names) / 127.0
        s = jnp.maximum(s, 1e-12)
        q = jnp.clip(jnp.round(gf / s), -127, 127)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_names)
        deq = qsum.astype(jnp.float32) * s
        new_err = gf - q * s                          # local residual
        return deq, new_err

    out = jax.tree.map(per_leaf, grads, err_state)
    deq = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, err
