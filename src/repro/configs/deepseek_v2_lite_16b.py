"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + 64 routed experts top-6
with 2 shared experts.

27L d_model=2048 16H d_ff_expert=1408 vocab=102400
[arXiv:2405.04434; hf]
"""
from repro.models.config import MLACfg, ModelConfig, MoECfg


def config():
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
        n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab=102400,
        act="silu", mlp="glu", norm="rms", pos="rope",
        mla=MLACfg(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                   v_head_dim=128),
        moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                   capacity_factor=1.25),
        source="arXiv:2405.04434",
    )


def smoke():
    return ModelConfig(
        name="deepseek-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=32, vocab=512,
        act="silu", mlp="glu", norm="rms", pos="rope",
        mla=MLACfg(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                   v_head_dim=16),
        moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32, n_shared=2,
                   capacity_factor=2.0),
    )
