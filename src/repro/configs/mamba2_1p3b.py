"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

48L d_model=2048 ssm_state=128 vocab=50280
[arXiv:2405.21060; unverified]
"""
from repro.models.config import ModelConfig, SSMCfg


def config():
    return ModelConfig(
        name="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
        norm="rms", pos="rope",
        ssm=SSMCfg(d_state=128, head_dim=64, expand=2, chunk=256,
                   conv_width=4, n_groups=1),
        subquadratic=True, source="arXiv:2405.21060",
    )


def smoke():
    return ModelConfig(
        name="mamba2-smoke", family="ssm", n_layers=3, d_model=64,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=512, norm="rms",
        ssm=SSMCfg(d_state=16, head_dim=16, expand=2, chunk=8,
                   conv_width=4, n_groups=1),
        subquadratic=True,
    )
