"""granite-34b [dense] — code model, 88 layers, MQA (plain GELU MLP — a gated MLP at these dims gives 47B; the published 34B matches 2·D·F, gpt_bigcode lineage).

88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152
[arXiv:2405.04324; hf]
"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="granite-34b", family="dense", n_layers=88, d_model=6144,
        n_heads=48, n_kv_heads=1, head_dim=128, d_ff=24576, vocab=49152,
        act="gelu", mlp="plain", norm="layer", pos="rope",
        source="arXiv:2405.04324",
    )


def smoke():
    return ModelConfig(
        name="granite-smoke", family="dense", n_layers=4, d_model=96,
        n_heads=6, n_kv_heads=1, head_dim=16, d_ff=256, vocab=512,
        act="silu", mlp="glu", norm="rms", pos="rope",
    )
