"""minitron-4b [dense] — pruned nemotron (relu MLP, GQA kv=8).

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000
[arXiv:2407.14679; hf]
"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="minitron-4b", family="dense", n_layers=32, d_model=3072,
        n_heads=24, n_kv_heads=8, head_dim=128, d_ff=9216, vocab=256000,
        act="relu", mlp="plain", norm="layer", pos="rope",
        source="arXiv:2407.14679",
    )


def smoke():
    return ModelConfig(
        name="minitron-smoke", family="dense", n_layers=3, d_model=96,
        n_heads=6, n_kv_heads=2, head_dim=16, d_ff=192, vocab=512,
        act="relu", mlp="plain", norm="layer", pos="rope",
    )
