"""Assigned architecture configs (exact specs from the public pool) + shapes.

Each ``<arch>.py`` exposes ``config()`` (the full published config) and
``smoke()`` (a reduced same-family config for CPU smoke tests).  ``get(name)``
resolves either.  ``SHAPES`` defines the per-arch input-shape set; skip rules
(long_500k needs sub-quadratic attention) are enforced by ``cells()``.
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "recurrentgemma_9b", "minitron_4b", "starcoder2_15b", "gemma_7b",
    "granite_34b", "whisper_medium", "deepseek_v2_lite_16b",
    "llama4_scout_17b_a16e", "chameleon_34b", "mamba2_1p3b",
]

# shape_name: (seq_len, global_batch, step_kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get(name: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.smoke() if smoke else mod.config()


def skip_reason(cfg, shape_name: str):
    if shape_name == "long_500k" and not cfg.subquadratic:
        return "pure full-attention arch — long_500k needs sub-quadratic attention"
    return None


def cells(include_skipped: bool = False):
    """All (arch_id, shape_name) dry-run cells, with skip annotations."""
    out = []
    for a in ARCH_IDS:
        cfg = get(a)
        for s in SHAPES:
            r = skip_reason(cfg, s)
            if r is None or include_skipped:
                out.append((a, s, r))
    return out
