"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 pattern.

38L d_model=4096 16H (GQA kv=1/MQA) d_ff=12288 vocab=256000
[arXiv:2402.19427; unverified]
"""
from repro.models.config import HybridCfg, ModelConfig


def config():
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
        n_heads=16, n_kv_heads=1, head_dim=256, d_ff=12288, vocab=256000,
        act="gelu", mlp="glu", norm="rms", pos="rope",
        hybrid=HybridCfg(pattern=("rec", "rec", "attn"), window=2048,
                         d_rnn=4096, conv_width=4),
        subquadratic=True, source="arXiv:2402.19427",
    )


def smoke():
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid", n_layers=6, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab=512,
        act="gelu", mlp="glu", norm="rms", pos="rope",
        hybrid=HybridCfg(pattern=("rec", "rec", "attn"), window=16, d_rnn=64,
                         conv_width=4),
        subquadratic=True,
    )
