"""whisper-medium [audio] — enc-dec; conv frontend is a stub (the spec'd
``input_specs`` provides precomputed (B, 1500, d_model) frame embeddings).

24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865
[arXiv:2212.04356; unverified]
"""
from repro.models.config import EncDecCfg, ModelConfig


def config():
    return ModelConfig(
        name="whisper-medium", family="encdec", n_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096, vocab=51865,
        act="gelu", mlp="plain", norm="layer", pos="learned",
        tie_embeddings=True, max_seq=32768,
        encdec=EncDecCfg(n_enc_layers=24, n_frames=1500),
        source="arXiv:2212.04356",
    )


def smoke():
    return ModelConfig(
        name="whisper-smoke", family="encdec", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=512,
        act="gelu", mlp="plain", norm="layer", pos="learned", max_seq=128,
        encdec=EncDecCfg(n_enc_layers=2, n_frames=12),
    )
