"""starcoder2-15b [dense] — GQA kv=4, RoPE, plain GELU MLP.

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152
[arXiv:2402.19173; hf]
"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="starcoder2-15b", family="dense", n_layers=40, d_model=6144,
        n_heads=48, n_kv_heads=4, head_dim=128, d_ff=24576, vocab=49152,
        act="gelu", mlp="plain", norm="layer", pos="rope",
        source="arXiv:2402.19173",
    )


def smoke():
    return ModelConfig(
        name="starcoder2-smoke", family="dense", n_layers=3, d_model=96,
        n_heads=6, n_kv_heads=2, head_dim=16, d_ff=256, vocab=512,
        act="gelu", mlp="plain", norm="layer", pos="rope",
    )
