"""llama4-scout-17b-16e [moe] — 16 experts top-1 + shared expert, early
fusion (text backbone per spec).

48L d_model=5120 40H (GQA kv=8) d_ff_expert=8192 vocab=202048
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.models.config import ModelConfig, MoECfg


def config():
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=202048,
        act="silu", mlp="glu", norm="rms", pos="rope",
        moe=MoECfg(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1,
                   capacity_factor=1.25),
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def smoke():
    return ModelConfig(
        name="llama4-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64, vocab=512,
        act="silu", mlp="glu", norm="rms", pos="rope",
        moe=MoECfg(n_experts=4, top_k=1, d_ff_expert=64, n_shared=1,
                   capacity_factor=2.0),
    )
