"""gemma-7b [dense] — GeGLU, head_dim=256, MHA (kv=16).

28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000
[arXiv:2403.08295; hf]
"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="gemma-7b", family="dense", n_layers=28, d_model=3072,
        n_heads=16, n_kv_heads=16, head_dim=256, d_ff=24576, vocab=256000,
        act="gelu", mlp="glu", norm="rms", pos="rope",
        source="arXiv:2403.08295",
    )


def smoke():
    return ModelConfig(
        name="gemma-smoke", family="dense", n_layers=3, d_model=96,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256, vocab=512,
        act="gelu", mlp="glu", norm="rms", pos="rope",
    )
