"""chameleon-34b [vlm] — early-fusion; VQ image tokens share the vocab, so
the backbone consumes plain token ids (qk-norm stabilized).

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536
[arXiv:2405.09818; unverified]
"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
        n_heads=64, n_kv_heads=8, head_dim=128, d_ff=22016, vocab=65536,
        act="silu", mlp="glu", norm="rms", pos="rope", qk_norm=True,
        source="arXiv:2405.09818",
    )


def smoke():
    return ModelConfig(
        name="chameleon-smoke", family="vlm", n_layers=3, d_model=96,
        n_heads=6, n_kv_heads=2, head_dim=16, d_ff=192, vocab=512,
        act="silu", mlp="glu", norm="rms", pos="rope", qk_norm=True,
    )
