"""Scheduler — the host half of the serving engine: requests and policy.

Owns everything that is bookkeeping rather than device math: the FIFO queue,
the slot table, admission planning (free slots are filled in submission
order, then the round's admissions are grouped by padded prompt bucket so
each group is ONE batched prefill dispatch), and the requantization cadence.

Cadence is a policy, not a side effect of admission (the paper's Fig. 1b
lifecycle): with ``EngineConfig.recalibrate_tokens > 0`` the engine
requantizes once the token budget (prefill + generated tokens since the last
requant) is exhausted *and* fresh statistics have arrived; otherwise it
falls back to the per-admission counter (``recalibrate_every``).

No jax arrays live here — the device side is :class:`~repro.serving.runner.
DeviceRunner` and the two are composed by :class:`~repro.serving.engine.
TTQEngine`.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Dict, List, Optional


def pick_decode_chunk(slots: int) -> int:
    """Default fused-decode chunk per slot count (EXPERIMENTS.md §Perf
    iteration 7).  At 1 slot fused decode at K=8 measured *slower* than
    per-token on short generation budgets (fixed-K steps are wasted past
    EOS/budget — the PR-3 snapshot: 165 vs 724 tok/s at max_new=16), and
    there is no batching to amortize, so stay per-token; from 2 slots up
    the dispatch amortization dominates for every measured budget and K=8
    sits past the crossover (`bench_engine.py` sweeps K and reports it)."""
    return 1 if slots <= 1 else 8


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    frames: Any = None              # encdec stub modality input


class GenResult(list):
    """A request's generated tokens.  Compares and prints as a plain list;
    ``unfinished`` marks a partial output (the engine stopped at
    ``max_iters`` with the request still queued or mid-generation)."""

    def __init__(self, tokens=(), unfinished: bool = False):
        super().__init__(tokens)
        self.unfinished = unfinished


@dataclasses.dataclass
class AdmissionGroup:
    """One bucketed prefill dispatch: requests padded to a shared length."""
    bucket: int
    slots: List[int] = dataclasses.field(default_factory=list)
    requests: List[Request] = dataclasses.field(default_factory=list)

    @property
    def tokens(self) -> float:
        return float(len(self.requests) * self.bucket)


class Scheduler:
    def __init__(self, ecfg, exact_buckets: bool = False):
        self.ecfg = ecfg
        # recurrent state would absorb pad tokens — prefill at exact length
        self.exact_buckets = exact_buckets
        self.queue: deque = deque()
        self.slot_req: List[Optional[Request]] = [None] * ecfg.max_slots
        self.finished: Dict[int, Request] = {}
        self._rid = itertools.count()
        self.admits_since_cal = 0
        self.tokens_since_cal = 0.0
        self._fresh_stats = False

    # ---------------------------------------------------------------- intake

    @property
    def max_prompt_len(self) -> int:
        """Longest admissible prompt: the cache must hold it and (for
        bucketed families) the largest bucket must fit it."""
        if self.exact_buckets:
            return self.ecfg.max_len
        return min(max(self.ecfg.prompt_buckets), self.ecfg.max_len)

    def submit(self, prompt, max_new: int = 16, frames=None) -> int:
        prompt = list(prompt)
        limit = self.max_prompt_len
        if len(prompt) > limit:
            detail = f"max_len={self.ecfg.max_len}"
            if not self.exact_buckets:
                detail += (f", largest prompt bucket "
                           f"{max(self.ecfg.prompt_buckets)}")
            raise ValueError(
                f"prompt of {len(prompt)} tokens exceeds the engine's "
                f"admissible length {limit} ({detail}); raise max_len / "
                f"prompt_buckets or truncate the prompt")
        rid = next(self._rid)
        self.queue.append(Request(rid, prompt, max_new, frames=frames))
        return rid

    # ------------------------------------------------------------- admission

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def bucket(self, n: int) -> int:
        if self.exact_buckets:
            return n
        for b in self.ecfg.prompt_buckets:
            if n <= b:
                return min(b, self.ecfg.max_len)
        return min(self.ecfg.prompt_buckets[-1], self.ecfg.max_len)

    def plan_admissions(self) -> List[AdmissionGroup]:
        """Pop queued requests into free slots in FIFO order, then group the
        round's admissions by bucket — each group is one prefill dispatch."""
        picked = []
        for slot in self.free_slots():
            if not self.queue:
                break
            picked.append((slot, self.queue.popleft()))
        groups: Dict[int, AdmissionGroup] = {}
        for slot, req in picked:
            g = groups.setdefault(self.bucket(len(req.prompt)),
                                  AdmissionGroup(self.bucket(len(req.prompt))))
            g.slots.append(slot)
            g.requests.append(req)
            self.slot_req[slot] = req
        return list(groups.values())

    # -------------------------------------------------------- requant cadence

    def note_admitted(self, n: int, tokens: float):
        """n requests prefilled (fresh statistics folded into the session)."""
        self.admits_since_cal += n
        self.tokens_since_cal += tokens
        self._fresh_stats = True

    def note_decoded(self, tokens: int):
        self.tokens_since_cal += tokens

    def should_requant(self) -> bool:
        if self.ecfg.recalibrate_tokens > 0:
            return (self._fresh_stats
                    and self.tokens_since_cal >= self.ecfg.recalibrate_tokens)
        return self.admits_since_cal >= self.ecfg.recalibrate_every

    def note_requant(self):
        self.admits_since_cal = 0
        self.tokens_since_cal = 0.0
        self._fresh_stats = False

    # --------------------------------------------------------------- results

    def finish(self, slot: int):
        req = self.slot_req[slot]
        req.done = True
        self.finished[req.rid] = req
        self.slot_req[slot] = None

    def record_block(self, tokens, valid, done) -> int:
        """Fold one decode block's host copies into per-request outputs.

        ``tokens``/``valid``: (B, K) host arrays; ``done``: (B,) final flags.
        Returns the number of accepted tokens (token-budget cadence)."""
        accepted = 0
        K = tokens.shape[1]
        for slot in self.active_slots():
            req = self.slot_req[slot]
            for k in range(K):
                if valid[slot, k]:
                    req.out.append(int(tokens[slot, k]))
                    accepted += 1
            if done[slot]:
                self.finish(slot)
        self.note_decoded(accepted)
        return accepted

    def results(self, include_partials: bool = True) -> Dict[int, GenResult]:
        """Finished outputs, plus (by default) in-flight/queued partials
        flagged ``unfinished=True`` — nothing submitted is silently dropped."""
        out = {rid: GenResult(req.out) for rid, req in self.finished.items()}
        if include_partials:
            pending = [r for r in self.slot_req if r is not None]
            pending += list(self.queue)
            for req in pending:
                out[req.rid] = GenResult(req.out, unfinished=True)
        return out
