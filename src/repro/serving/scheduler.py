"""Scheduler — the host half of the serving engine: requests and policy.

Owns everything that is bookkeeping rather than device math: the request
queue, the slot table, admission planning (free slots are filled in
priority/deadline order, then the round's admissions are grouped by padded
prompt bucket so each group is ONE batched prefill dispatch), the chunked
prefill ledger, and the requantization cadence.

SLO scheduling (DESIGN.md §13): requests carry a priority class (lower =
more urgent) and an optional deadline; admission picks by
``(priority, absolute deadline, submission order)`` — earliest-deadline-
first within a class, FIFO when neither priority nor deadlines are set.
Preemption (pool pressure) victims are picked from the *least* important
class first, and a request never evicts a more important one.  Long
prompts are ingested in fixed-size chunks (``EngineConfig.prefill_chunk``)
interleaved with decode rounds under a per-round padded-token budget
(``prefill_budget``) so a 4k-token arrival cannot monopolize a dispatch
round and blow up running streams' inter-token latency.

Cadence is a policy, not a side effect of admission (the paper's Fig. 1b
lifecycle): with ``EngineConfig.recalibrate_tokens > 0`` the engine
requantizes once the token budget (prefill + generated tokens since the last
requant) is exhausted *and* fresh statistics have arrived; otherwise it
falls back to the per-admission counter (``recalibrate_every``).

No jax arrays live here — the device side is :class:`~repro.serving.runner.
DeviceRunner` and the two are composed by :class:`~repro.serving.engine.
TTQEngine`.  That array-free contract (tracecheck TC402/TC405) is also what
makes the scheduler mesh-oblivious: on a sharded engine (DESIGN.md §10) the
same queue/slot/cadence decisions drive every device — admission groups,
block budgets and requant cadence are global properties, and the runner
replays them against the sharded state.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Callable, Dict, List, Optional


class QueueFull(RuntimeError):
    """``submit`` rejected: the intake queue is at ``max_queue`` capacity.

    The synchronous engine surfaces this to the caller (shed load / retry
    later); the async front end (:class:`~repro.serving.server.TTQServer`)
    holds its own admission semaphore so coroutines *await* instead."""


def pick_decode_chunk(slots: int, speculate_k: int = 0) -> int:
    """Default fused-decode chunk per slot count (EXPERIMENTS.md §Perf
    iteration 7).  At 1 slot fused decode at K=8 measured *slower* than
    per-token on short generation budgets (fixed-K steps are wasted past
    EOS/budget — the PR-3 snapshot: 165 vs 724 tok/s at max_new=16), and
    there is no batching to amortize, so stay per-token; from 2 slots up
    the dispatch amortization dominates for every measured budget and K=8
    sits past the crossover (`bench_engine.py` sweeps K and reports it).

    With self-speculative decoding (DESIGN.md §11) the chunk counts
    *windows*, and each window emits up to ``speculate_k + 1`` tokens per
    lane — the effective tokens/dispatch is ``chunk × (W+1) × acceptance``.
    To keep the wasted-work exposure past EOS/budget comparable to the
    non-speculative tuning above, divide the chunk by the per-window token
    ceiling (floor 1); the 1-slot case stays per-window for the same
    crossover reason it stays per-token without speculation."""
    base = 1 if slots <= 1 else 8
    if speculate_k <= 0:
        return base
    return max(1, base // (speculate_k + 1))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list                    # grows on preemption: orig + generated
    max_new: int                    # ORIGINAL budget; remaining = max_new - len(out)
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    frames: Any = None              # encdec stub modality input
    cancelled: bool = False
    blocks: list = dataclasses.field(default_factory=list)  # paged: owned blocks
    prefix_len: int = 0             # paged: cached-prefix tokens this admission
    admit_seq: int = -1             # admission order (preemption victim pick)
    orig_len: int = 0               # submitted prompt length (pre-preemption)
    # ---- isolation / deadlines (DESIGN.md §12) ----
    deadline_s: float = 0.0         # wall budget from submit (0 = none)
    submit_t: float = 0.0           # engine-clock submission time
    error: str = ""                 # terminal failure reason ("" = none)
    attempts: int = 0               # decode-fault retries consumed
    not_before: int = 0             # planning round gating a retry (backoff)
    # ---- SLO / streaming (DESIGN.md §13) ----
    priority: int = 0               # class: lower = more urgent
    prefilled: int = 0              # chunked prefill: tokens resident on device
    tok_times: list = dataclasses.field(default_factory=list)  # emit stamps

    def __post_init__(self):
        if not self.orig_len:
            self.orig_len = len(self.prompt)

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.out)


class GenResult(list):
    """A request's generated tokens.  Compares and prints as a plain list;
    ``unfinished`` marks a partial output (the engine stopped at
    ``max_iters`` with the request still queued or mid-generation, the
    request was cancelled — ``cancelled`` distinguishes that — or it failed
    terminally, in which case ``error`` carries the reason: "deadline",
    "non-finite logits", "admission retries exhausted")."""

    def __init__(self, tokens=(), unfinished: bool = False,
                 cancelled: bool = False, error: str = ""):
        super().__init__(tokens)
        self.unfinished = unfinished
        self.cancelled = cancelled
        self.error = error


@dataclasses.dataclass
class AdmissionGroup:
    """One bucketed prefill dispatch: requests padded to a shared length.

    Paged prefix-cache hits carry a nonzero ``prefix_len`` — the bucket then
    pads the prompt *tail* and the prefill attends to the cached prefix."""
    bucket: int
    prefix_len: int = 0
    slots: List[int] = dataclasses.field(default_factory=list)
    requests: List[Request] = dataclasses.field(default_factory=list)

    @property
    def tokens(self) -> float:
        return float(len(self.requests) * self.bucket)


@dataclasses.dataclass
class ChunkPlan:
    """One chunked-prefill dispatch for one mid-ingestion request: write
    prompt rows ``[start, start + length)`` into the slot's cache (padded to
    ``prefill_chunk``).  The ``final`` chunk runs the admission epilogue —
    sample the first token and arm the lane for decode."""
    slot: int
    req: Request
    start: int                      # tokens already resident (prefix + chunks)
    length: int                     # real tokens this chunk (<= prefill_chunk)
    final: bool


class Scheduler:
    def __init__(self, ecfg, exact_buckets: bool = False, kvcfg=None,
                 num_blocks: int = 0):
        self.ecfg = ecfg
        # recurrent state would absorb pad tokens — prefill at exact length
        self.exact_buckets = exact_buckets
        self.queue: deque = deque()
        self.slot_req: List[Optional[Request]] = [None] * ecfg.max_slots
        self.finished: Dict[int, Request] = {}
        self._rid = itertools.count()
        self.admits_since_cal = 0
        self.tokens_since_cal = 0.0
        self._fresh_stats = False
        # paged pool bookkeeping (DESIGN.md §8)
        self.allocator = None
        if kvcfg is not None and getattr(kvcfg, "paged", False):
            from .blocks import BlockAllocator
            self.allocator = BlockAllocator(
                num_blocks, kvcfg.block_size,
                prefix_cache=getattr(ecfg, "prefix_cache", True))
        self._admit_seq = itertools.count()
        self.prefill_tokens = 0.0       # padded tokens dispatched to prefill
        self.preemptions = 0
        self.pending_releases: List[int] = []   # slots to sink on device
        self._recent_victims: set = set()       # no re-preemption until decode
        # isolation / robustness counters (DESIGN.md §12).  The guard knobs
        # (retry budget, admission-attempt cap) apply regardless of
        # EngineConfig.guards — the flag gates *detection* machinery, not
        # plain bookkeeping like capping a retry loop.
        self.gcfg = getattr(ecfg, "guard_cfg", None)
        self.lane_faults = 0            # decode lanes failed on bad logits
        self.deadline_expirations = 0
        self.admission_failures = 0     # requests failed at the attempt cap
        self._round = 0                 # planning rounds (retry backoff unit)
        self._starve: Dict[int, int] = {}   # rid → idle-starved rounds
        # SLO / streaming (DESIGN.md §13)
        self.prefilling: Dict[int, Request] = {}  # slot → mid-chunked-prefill
        self.prefill_chunks = 0         # chunk dispatches (telemetry)
        self.queue_rejections = 0       # submits bounced off max_queue
        self.on_token: Optional[Callable] = None    # (rid, tok, now)
        self.on_finish: Optional[Callable] = None   # (rid, req)

    # ---------------------------------------------------------------- intake

    @property
    def max_prompt_len(self) -> int:
        """Longest admissible prompt: the cache must hold it and (for
        bucketed families) the largest bucket must fit it.  Chunked prefill
        lifts the bucket limit — any prompt the cache holds can be ingested
        chunk by chunk."""
        if self.exact_buckets or getattr(self.ecfg, "prefill_chunk", 0) > 0:
            return self.ecfg.max_len
        return min(max(self.ecfg.prompt_buckets), self.ecfg.max_len)

    def submit(self, prompt, max_new: int = 16, frames=None,
               deadline_s: Optional[float] = None, now: float = 0.0,
               priority: int = 0) -> int:
        prompt = list(prompt)
        limit = self.max_prompt_len
        if len(prompt) > limit:
            detail = f"max_len={self.ecfg.max_len}"
            if limit != self.ecfg.max_len:
                detail += (f", largest prompt bucket "
                           f"{max(self.ecfg.prompt_buckets)}")
            raise ValueError(
                f"prompt of {len(prompt)} tokens exceeds the engine's "
                f"admissible length {limit} ({detail}); raise max_len / "
                f"prompt_buckets or truncate the prompt")
        mq = getattr(self.ecfg, "max_queue", 0)
        if mq and len(self.queue) >= mq:
            self.queue_rejections += 1
            raise QueueFull(
                f"intake queue at capacity (max_queue={mq}); shed load or "
                f"retry after the engine drains")
        if self.allocator is not None:
            need = self.allocator.blocks_needed(len(prompt), max_new,
                                                self.ecfg.max_len)
            if need > self.allocator.capacity:
                raise ValueError(
                    f"request needs {need} KV blocks but the pool holds "
                    f"{self.allocator.capacity}; raise kv_pool_blocks or "
                    f"shrink the prompt/max_new")
        rid = next(self._rid)
        dl = float(getattr(self.ecfg, "deadline_s", 0.0)
                   if deadline_s is None else deadline_s)
        self.queue.append(Request(rid, prompt, max_new, frames=frames,
                                  deadline_s=dl, submit_t=float(now),
                                  priority=int(priority)))
        return rid

    # ------------------------------------------------------------- streaming

    def emit(self, req: Request, tok: int, now: float = 0.0):
        """Land one generated token: append to the request's output, stamp
        the emission time (TTFT/ITL metrics) and fire the streaming
        callback.  Every token-producing path funnels through here so
        ``len(out) == len(tok_times)`` holds everywhere."""
        req.out.append(int(tok))
        req.tok_times.append(float(now))
        if self.on_token is not None:
            self.on_token(req.rid, int(tok), float(now))

    def _land(self, req: Request):
        """Terminal landing: the request is finished (done, failed,
        cancelled, expired) — record it and fire the completion callback."""
        self.finished[req.rid] = req
        if self.on_finish is not None:
            self.on_finish(req.rid, req)

    # ------------------------------------------------------------- admission

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def decode_slots(self) -> List[int]:
        """Slots with an armed decode lane — active minus mid-chunked-
        prefill (those are parked ``done`` on device until their final
        chunk lands)."""
        return [s for s in self.active_slots() if s not in self.prefilling]

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def bucket(self, n: int) -> int:
        if self.exact_buckets:
            return n
        for b in self.ecfg.prompt_buckets:
            if n <= b:
                return min(b, self.ecfg.max_len)
        # beyond the largest bucket: only reachable by preemption-resumed
        # prompts (submit() rejects external ones) — pad to max_len
        return self.ecfg.max_len

    # ----------------------------------------------- isolation (DESIGN.md §12)

    def _evict(self, slot: int, req: Request, finished: bool):
        """Shared failure-path eviction: clear the slot, free (paged)
        blocks, queue the device release; optionally land in finished."""
        self.slot_req[slot] = None
        self.prefilling.pop(slot, None)
        if self.allocator is not None:
            self.allocator.free_request(req.blocks)
            req.blocks = []
        self.pending_releases.append(slot)
        if finished:
            self._land(req)

    def fail_lane(self, slot: int, reason: str):
        """A decode lane went bad (non-finite logits): fail ONLY this
        request — slot recycled, blocks released, the rest of the batch
        untouched.  Within the retry budget the request requeues from its
        original prompt with exponential backoff in planning rounds (the
        fault may be load-coupled — give the batch time to drain); past it
        the request finishes with ``error=reason``."""
        req = self.slot_req[slot]
        self.lane_faults += 1
        max_retries = self.gcfg.max_retries if self.gcfg is not None else 0
        if req.attempts < max_retries:
            req.attempts += 1
            self._evict(slot, req, finished=False)
            req.prompt = list(req.prompt[:req.orig_len])
            req.out = []
            req.tok_times = []
            req.prefix_len = 0
            req.prefilled = 0
            req.not_before = self._round + (1 << req.attempts)
            self.queue.append(req)
        else:
            req.error = reason
            self._evict(slot, req, finished=True)

    def expire_deadlines(self, now: float):
        """Fail queued and running requests past their ``deadline_s`` (no
        retry — the clock that expired them keeps running).  Running
        requests keep their partial output."""
        for req in [r for r in self.queue
                    if r.deadline_s > 0 and now - r.submit_t > r.deadline_s]:
            self.queue.remove(req)
            req.error = "deadline"
            self._land(req)
            self.deadline_expirations += 1
        for slot, req in enumerate(self.slot_req):
            if (req is not None and req.deadline_s > 0
                    and now - req.submit_t > req.deadline_s):
                req.error = "deadline"
                self._evict(slot, req, finished=True)
                self.deadline_expirations += 1

    def has_deferred_work(self) -> bool:
        """Queued work the engine must keep stepping for even though no
        lane is active: retries whose backoff round has not arrived, and
        requests waiting out a *transient* pool starvation (idle lanes +
        an allocation that keeps failing — e.g. injected exhaustion).
        Both are bounded: backoff by the retry budget, starvation by the
        admission-attempt cap — so ``run_all`` can never spin forever."""
        return (any(r.not_before > self._round for r in self.queue)
                or any(r.rid in self._starve for r in self.queue))

    # ------------------------------------------------------------ preemption

    def _pick_victim(self, exclude, limit_priority: int = 0
                     ) -> Optional[int]:
        """Class-based eviction: the least important running class loses
        first (highest priority number), youngest admission within it (FIFO
        keeps older work running; its resume re-prefill is cheap anyway
        because its own prompt blocks stay in the prefix cache).  A request
        never evicts a lane *more* important than itself
        (``victim.priority >= limit_priority``) — equal-class preemption
        stays allowed so a full pool of peers behaves exactly as before
        priorities existed."""
        cands = [(self.slot_req[s].priority, self.slot_req[s].admit_seq, s)
                 for s in self.active_slots()
                 if s not in exclude
                 and self.slot_req[s].priority >= limit_priority]
        return max(cands)[2] if cands else None

    def _preempt(self, slot: int) -> Request:
        """Evict a running slot: free its blocks and fold the generated
        tokens into the prompt — a later re-prefill resumes the greedy
        stream exactly (per-token quantization makes re-prefilled rows
        identical to the evicted ones; the resume footprint stays constant:
        ``len(prompt) + remaining == orig_len + max_new``).  The caller
        requeues the returned request once the round's planning is done."""
        req = self.slot_req[slot]
        self.allocator.free_request(req.blocks)
        req.blocks = []
        req.prompt = list(req.prompt[:req.orig_len]) + list(req.out)
        req.prefilled = 0               # mid-chunked-prefill victims restart
        self.slot_req[slot] = None
        self.prefilling.pop(slot, None)
        self.pending_releases.append(slot)
        self.preemptions += 1
        self._recent_victims.add(req.rid)
        return req

    def plan_admissions(self) -> List[AdmissionGroup]:
        """Pop queued requests into free slots in FIFO order, then group the
        round's admissions by (bucket, prefix_len) — each group is one
        prefill dispatch.

        Paged: each admission reserves its blocks upfront (prompt +
        generation budget, minus prefix-cache hits).  On pool exhaustion a
        running slot is preempted (blocks freed, its slot handed to the
        admission) instead of stalling; victims are held out of the queue
        until planning ends, then requeued at the front — they resume via
        re-prefill (their own blocks stay prefix-cached), never in the same
        round they were evicted.

        The MemoryError→preempt→retry loop is bounded per request per round
        (``guard_cfg.max_admission_attempts``, lifted to at least
        ``max_slots + 1`` so a legitimate chain that preempts every running
        slot still fits): a pathological allocation — one that keeps
        raising after its victims freed their blocks — fails the request
        cleanly (``error="admission retries exhausted"``) instead of
        spinning planning forever.  Requests whose retry backoff round has
        not arrived (``not_before``) are skipped, not popped.

        SLO ordering (DESIGN.md §13): the next admission is the eligible
        request minimizing ``(priority, absolute deadline, rid)`` —
        priority classes strictly dominate, earliest deadline first within
        a class, FIFO among undeadlined peers.  Eviction honours the same
        classes via :meth:`_pick_victim`.  Requests whose prompt tail
        exceeds ``prefill_chunk`` claim their slot and blocks here but skip
        the group dispatch — they enter the ``prefilling`` ledger and are
        ingested chunk-by-chunk by :meth:`plan_prefill_chunks`; their lane
        is parked on device (queued slot release) until the final chunk
        arms it, and their fresh blocks enter the prefix trie only as the
        rows land (``allocate(register=False)``)."""
        self._round += 1
        cap = self.gcfg.max_admission_attempts if self.gcfg is not None else 8
        cap = max(cap, self.ecfg.max_slots + 1)
        attempts: Dict[int, int] = {}
        picked: List[tuple] = []
        victims: List[Request] = []
        free = self.free_slots()
        while free:
            req = min((r for r in self.queue
                       if r.not_before <= self._round),
                      key=self._sel_key, default=None)
            if req is None:
                break
            if self.allocator is not None:
                try:
                    req.blocks, req.prefix_len = self.allocator.allocate(
                        req.prompt, req.remaining, self.ecfg.max_len,
                        register=not self._maybe_chunked(req))
                except MemoryError:
                    attempts[req.rid] = attempts.get(req.rid, 0) + 1
                    if attempts[req.rid] >= cap:
                        self.queue.remove(req)
                        self._starve.pop(req.rid, None)
                        req.error = "admission retries exhausted"
                        self._land(req)
                        self.admission_failures += 1
                        continue            # next eligible request
                    victim = self._pick_victim(
                        exclude={s for s, _ in picked},
                        limit_priority=req.priority)
                    # a fresh victim may not preempt in turn until decode
                    # has progressed — breaks admit-round ping-pong cycles
                    if victim is None or req.rid in self._recent_victims:
                        if not self.active_slots() and not picked:
                            # idle starvation: the pool is short with no
                            # lane running to free it (transient theft or
                            # a leak).  Wait a bounded number of rounds —
                            # has_deferred_work() keeps the engine
                            # stepping — then fail the request cleanly.
                            n = self._starve.get(req.rid, 0) + 1
                            self._starve[req.rid] = n
                            if n >= cap:
                                self.queue.remove(req)
                                self._starve.pop(req.rid, None)
                                req.error = "admission retries exhausted"
                                self._land(req)
                                self.admission_failures += 1
                        break               # nothing evictable — wait
                    victims.append(self._preempt(victim))
                    free = self.free_slots()
                    continue                # retry with the freed blocks
            self._starve.pop(req.rid, None)
            self.queue.remove(req)
            req.admit_seq = next(self._admit_seq)
            slot = free.pop(0)
            self.slot_req[slot] = req       # claimed now: a preemption later
            picked.append((slot, req))      # in this round must not free it
            if self._chunked(req):
                req.prefilled = req.prefix_len
                self.prefilling[slot] = req
                self.pending_releases.append(slot)  # park the lane on device
            elif self.allocator is not None and self._maybe_chunked(req):
                # prefix hits shrank the tail under one chunk — classic
                # dispatch after all; hook the deferred registrations now
                # (identical to allocate(register=True) semantics)
                self.allocator.register_blocks(req.prompt, req.blocks,
                                               len(req.prompt))
        for req in reversed(victims):       # oldest victim resumes first
            self.queue.appendleft(req)
        groups: Dict[tuple, AdmissionGroup] = {}
        for slot, req in picked:
            if slot in self.prefilling:     # chunk-ingested, no group
                continue
            tail = len(req.prompt) - req.prefix_len
            key = (self.bucket(tail), req.prefix_len)
            g = groups.setdefault(key, AdmissionGroup(*key))
            g.slots.append(slot)
            g.requests.append(req)
        # dispatch order = ascending prefix_len: a same-round prefix hit on
        # a sibling's freshly registered blocks always reads blocks the
        # *writer* prefills, and along one hash chain the reader's match
        # necessarily extends past the writer's own prefix — reader
        # prefix_len > writer prefix_len.  Sorting is therefore a
        # topological order of same-round dependencies: every group's
        # gather dispatches after the scatters it reads (equal prefix_len
        # ⇒ no dependency).  Without it a reader could share a group
        # created before its writer's and gather still-zero pool blocks.
        return sorted(groups.values(), key=lambda g: g.prefix_len)

    @staticmethod
    def _sel_key(req: Request):
        """Admission order: priority class, then earliest absolute
        deadline, then submission (rid) — plain FIFO when neither knob is
        used."""
        dl = (req.submit_t + req.deadline_s if req.deadline_s > 0
              else float("inf"))
        return (req.priority, dl, req.rid)

    def _maybe_chunked(self, req: Request) -> bool:
        """Could this request need chunked ingestion?  Decided before the
        prefix match — used to defer trie registration."""
        c = getattr(self.ecfg, "prefill_chunk", 0)
        return c > 0 and len(req.prompt) > c

    def _chunked(self, req: Request) -> bool:
        """Chunked ingestion needed: the un-cached prompt tail exceeds one
        chunk (prefix hits may have shrunk it under the threshold)."""
        c = getattr(self.ecfg, "prefill_chunk", 0)
        return c > 0 and (len(req.prompt) - req.prefix_len) > c

    # ------------------------------------------------------- chunked prefill

    def plan_prefill_chunks(self) -> List[ChunkPlan]:
        """The round's chunk dispatches, most urgent request first, capped
        at ``prefill_budget`` padded tokens (default: one chunk per round —
        decode runs between every pair of chunks).  Always yields at least
        one chunk when ingestion is pending, so a sub-chunk budget cannot
        stall a prompt forever.  Plans are speculative until the engine
        lands them via :meth:`note_chunk`."""
        if not self.prefilling:
            return []
        chunk = self.ecfg.prefill_chunk
        budget = getattr(self.ecfg, "prefill_budget", 0) or chunk
        plans: List[ChunkPlan] = []
        spent = 0
        for slot, req in sorted(self.prefilling.items(),
                                key=lambda kv: self._sel_key(kv[1])):
            plen, prog = len(req.prompt), req.prefilled
            while prog < plen and (spent < budget or not plans):
                n = min(chunk, plen - prog)
                plans.append(ChunkPlan(slot, req, prog, n,
                                       final=prog + n >= plen))
                prog += n
                spent += chunk          # budget counts padded tokens
            if spent >= budget:
                break
        return plans

    def note_chunk(self, plan: ChunkPlan, tokens: float):
        """One chunk landed on device: advance the resident-token mark,
        expose the freshly written full blocks to the prefix trie, and fold
        the (padded) chunk into the requant cadence.  The final chunk
        counts as the admission and un-parks the ledger entry — the engine
        arms the lane and emits the first token."""
        req = plan.req
        req.prefilled = plan.start + plan.length
        if self.allocator is not None:
            self.allocator.register_blocks(req.prompt, req.blocks,
                                           req.prefilled)
        self.prefill_chunks += 1
        self.note_admitted(1 if plan.final else 0, tokens)
        if plan.final:
            self.prefilling.pop(plan.slot, None)

    # -------------------------------------------------------- requant cadence

    def note_admitted(self, n: int, tokens: float):
        """n requests prefilled (fresh statistics folded into the session)."""
        self.admits_since_cal += n
        self.tokens_since_cal += tokens
        self.prefill_tokens += tokens
        self._fresh_stats = True

    def note_decoded(self, tokens: int):
        self.tokens_since_cal += tokens
        self._recent_victims.clear()    # decode progressed — preemption rearmed

    def should_requant(self) -> bool:
        if self.ecfg.recalibrate_tokens > 0:
            return (self._fresh_stats
                    and self.tokens_since_cal >= self.ecfg.recalibrate_tokens)
        return self.admits_since_cal >= self.ecfg.recalibrate_every

    def note_requant(self):
        self.admits_since_cal = 0
        self.tokens_since_cal = 0.0
        self._fresh_stats = False

    # --------------------------------------------------------------- results

    def finish(self, slot: int):
        req = self.slot_req[slot]
        req.done = True
        self.slot_req[slot] = None
        if self.allocator is not None:
            self.allocator.free_request(req.blocks)
            req.blocks = []
            self.pending_releases.append(slot)
        self._land(req)

    def cancel(self, rid: int) -> bool:
        """Abort a queued or running request: its slot and (paged) blocks
        free immediately — including blocks partially written by chunked
        prefill — and the partial output lands in ``finished`` as
        ``cancelled`` (``results()`` flags it unfinished).  Returns False
        for unknown/already-finished rids."""
        for req in list(self.queue):
            if req.rid == rid:
                self.queue.remove(req)
                req.cancelled = True
                self._land(req)
                return True
        for slot, req in enumerate(self.slot_req):
            if req is not None and req.rid == rid:
                req.cancelled = True
                self.slot_req[slot] = None
                self.prefilling.pop(slot, None)
                if self.allocator is not None:
                    self.allocator.free_request(req.blocks)
                    req.blocks = []
                self.pending_releases.append(slot)
                self._land(req)
                return True
        return False

    def record_block(self, tokens, valid, done, fault=None,
                     now: float = 0.0) -> int:
        """Fold one decode block's host copies into per-request outputs.

        ``tokens``/``valid``: (B, K) host arrays; ``done``: (B,) final
        flags; ``fault``: optional (B,) lane-fault flags from the guarded
        decode (DESIGN.md §12) — a faulted lane's block is discarded
        wholesale (its logits are suspect from the start of the block) and
        the request fails alone via :meth:`fail_lane`.  Mid-chunked-prefill
        slots are skipped: their lanes are parked ``done`` on device, which
        must not be mistaken for EOS.
        Returns the number of accepted tokens (token-budget cadence)."""
        accepted = 0
        K = tokens.shape[1]
        for slot in self.active_slots():
            if slot in self.prefilling:
                continue
            req = self.slot_req[slot]
            if fault is not None and fault[slot]:
                self.fail_lane(slot, "non-finite logits")
                continue
            for k in range(K):
                if valid[slot, k]:
                    self.emit(req, int(tokens[slot, k]), now)
                    accepted += 1
            if done[slot]:
                self.finish(slot)
        self.note_decoded(accepted)
        return accepted

    def results(self, include_partials: bool = True) -> Dict[int, GenResult]:
        """Finished outputs, plus (by default) in-flight/queued partials
        flagged ``unfinished=True`` — nothing submitted is silently dropped.
        Cancelled requests report their partial output with both flags."""
        out = {rid: GenResult(req.out,
                              unfinished=req.cancelled or bool(req.error),
                              cancelled=req.cancelled, error=req.error)
               for rid, req in self.finished.items()}
        if include_partials:
            pending = [r for r in self.slot_req if r is not None]
            pending += list(self.queue)
            for req in pending:
                out[req.rid] = GenResult(req.out, unfinished=True)
        return out
