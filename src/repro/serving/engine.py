"""TTQEngine — continuous-batching serving with online test-time quantization.

The paper's lifecycle (Fig. 1b) as a slot-based engine:

  submit → [queue] → admit: PREFILL in full precision with the stats tap on
                            (Σ_t x² per linear input feature, additive)
                     → aggregate stats across active prompts
                     → (re)QUANTIZE: D = f(stats); W_int,S,Z = G[(W−BA)∘D]
                       — one fused device program per weight family
                       (FusedRequantPlan), double-buffered so decode keeps
                       serving the previous tree until the swap, and
                       delta-gated (``requant_threshold``): only layers
                       whose D drifted re-quantize
                     → DECODE with the quantized weights in fused K-step
                       blocks; with ``policy.kernel.use_pallas`` (or
                       ``EngineConfig.use_kernels``) every packed-weight
                       matmul dispatches the Pallas ttq_gemm (in-kernel
                       unpack + dequant + D⁻¹ prologue)

The engine is a thin facade over three parts (DESIGN.md §"Serving
architecture"):

* :class:`~repro.serving.scheduler.Scheduler` — host policy: FIFO queue,
  slot admission (bucketed groups → one batched prefill dispatch each),
  requantization cadence (per-admission or token-budget);
* :class:`~repro.serving.runner.DeviceRunner` — jitted device execution:
  batched prefill and ``lm.decode_many`` (a ``lax.scan`` over
  ``decode_chunk`` decode steps with on-device sampling / EOS / budget /
  capacity masking — one host transfer per K tokens per batch, not one per
  token per slot);
* :class:`repro.quant.QuantizedModel` — TTQ state: stats session (decay),
  low-rank factors computed once, the quantized tree.

Per-prompt calibration (the paper's setting) is the ``max_slots=1`` case;
with batched serving the engine self-calibrates on the aggregate of the
*current* prompts — the statistics are additive sufficient statistics, so
this is the natural generalization (DESIGN.md §"CalibrationSession").

Per-slot positions everywhere → true continuous batching: a new request can
be admitted while other slots are mid-generation (at decode-chunk
boundaries).  The slot caches' memory layout is policy-driven
(``policy.kvcache`` / ``EngineConfig.kv_dtype``): bf16, or int8 /
packed-int4 codes + per-(head, token) f32 scales (DESIGN.md §"KV-cache
layout").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

from repro.core import QuantPolicy
from repro.models.config import ModelConfig
from repro.quant import CalibrationSession, GuardConfig, QuantizedModel
from repro.quant import guards as _guards

from .runner import DeviceRunner
from .scheduler import GenResult, Request, Scheduler, pick_decode_chunk


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4
    max_len: int = 256
    decode_chunk: int = 1           # K: fused decode steps per host sync;
                                    # 0 → auto via pick_decode_chunk(slots)
                                    # (serve.py defaults to auto; the config
                                    # default stays 1 = per-token, the seed
                                    # semantics)
    recalibrate_every: int = 1      # re-quantize after every N admissions
    recalibrate_tokens: int = 0     # >0: token-budget cadence instead
    stats_halflife: int = 0         # >0: exponential decay of stats (updates)
    temperature: float = 0.0
    eos_token: int = -1             # -1 → run to max_new
    prompt_buckets: tuple = (16, 32, 64, 128, 256)
    kv_dtype: str = ""              # "" → policy.kvcache; else bf16|int8|int4
    use_kernels: Optional[bool] = None  # None → policy.kernel.use_pallas.
                                    # Flips ONLY the decode GEMM dispatch
                                    # (bitwise-identical math either way);
                                    # the Pallas ttq_quantize kernel is a
                                    # *policy* choice (policy.kernel) because
                                    # it changes the quantization function
                                    # itself (±1 code ties vs jnp)
    requant_threshold: float = -1.0  # ≥0 → delta-gated requantization
    double_buffer: bool = False     # readiness-gated requant swap (decode
                                    # keeps the old tree until the new one
                                    # is device-ready; tokens become
                                    # device-timing-dependent — opt-in)
    # ---- paged KV pool (DESIGN.md §8) ----
    kv_paged: Optional[bool] = None  # None → policy.kvcache.paged
    kv_block_size: int = 0          # tokens per pool block; 0 → policy
    kv_pool_blocks: int = 0         # physical blocks per layer incl. the
                                    # sink; 0 → capacity-equivalent auto
                                    # (max_slots·max_len/block_size + 1 —
                                    # no preemption ever needed); smaller
                                    # budgets oversubscribe and preempt
    prefix_cache: bool = True       # share quantized prompt-prefix blocks
    # ---- self-speculative decoding (DESIGN.md §11) ----
    speculate_k: int = 0            # W: drafted tokens per verify window
                                    # (0 = off).  Greedy only — auto-off
                                    # when temperature > 0 (rejection-
                                    # sampling acceptance is future work).
                                    # decode_chunk then counts WINDOWS per
                                    # dispatch (auto shrinks it so tokens/
                                    # dispatch stays comparable).
    # ---- robustness layer (DESIGN.md §12) ----
    guards: bool = True             # calibration validation, requant health
                                    # gate, decode fault isolation and the
                                    # degradation ladder.  Off = the exact
                                    # pre-guard engine (decode program
                                    # included — detection costs one
                                    # isfinite reduction per step)
    guard_cfg: GuardConfig = GuardConfig()  # knobs (frozen, shareable)
    deadline_s: float = 0.0         # default per-request wall budget from
                                    # submit (0 = none; submit() overrides
                                    # per request)
    # ---- streaming & SLO scheduling (DESIGN.md §13) ----
    prefill_chunk: int = 0          # >0: ingest prompt tails longer than
                                    # this in fixed-size chunks interleaved
                                    # with decode rounds (plain-attn
                                    # families; paged pools need it to
                                    # divide by block_size).  Also lifts
                                    # the bucket cap on prompt length.
    prefill_budget: int = 0         # padded prefill tokens dispatched per
                                    # engine round (0 → one chunk/round);
                                    # bounds how much a long ingestion can
                                    # stretch running streams' ITL
    max_queue: int = 0              # >0: submit() raises QueueFull at this
                                    # queue depth (the async front end
                                    # awaits instead); 0 = unbounded


class TTQEngine:
    def __init__(self, cfg: ModelConfig, params, policy: QuantPolicy,
                 ecfg: EngineConfig = EngineConfig(), pctx=None, key=None,
                 draft_policy: Optional[QuantPolicy] = None, faults=None):
        if ecfg.speculate_k > 0 and ecfg.temperature > 0.0:
            # greedy acceptance would bias sampled streams — auto-off until
            # rejection-sampling acceptance lands (DESIGN.md §11)
            ecfg = dataclasses.replace(ecfg, speculate_k=0)
        if ecfg.speculate_k > 0:
            from repro.models.stack import stack_spec
            kinds = {k for ks, _ in stack_spec(cfg) for k in ks}
            if kinds != {"attn"}:
                raise ValueError(
                    f"speculate_k needs a plain-attention family, got "
                    f"{sorted(kinds)} (windowed/latent/recurrent decode "
                    f"states cannot roll back rejected drafts — "
                    f"DESIGN.md §11)")
        if ecfg.prefill_chunk > 0:
            from repro.models.stack import stack_spec
            kinds = {k for ks, _ in stack_spec(cfg) for k in ks}
            if kinds != {"attn"}:
                raise ValueError(
                    f"prefill_chunk needs a plain-attention family, got "
                    f"{sorted(kinds)} (chunked ingestion gathers and "
                    f"extends one per-layer k/v context per chunk — "
                    f"DESIGN.md §13)")
        if ecfg.decode_chunk <= 0:
            ecfg = dataclasses.replace(
                ecfg, decode_chunk=pick_decode_chunk(ecfg.max_slots,
                                                     ecfg.speculate_k))
        self.cfg, self.params, self.policy, self.ecfg = cfg, params, policy, ecfg
        # self-speculative draft tree: the default draft is the policy's
        # uniform low-bit variant; with a NO_QUANT verify policy pass an
        # enabled draft_policy for draft-only quantization (the quantized
        # model speculates for its fp self — see EXPERIMENTS.md)
        self.draft_policy = None
        if ecfg.speculate_k > 0:
            self.draft_policy = (draft_policy if draft_policy is not None
                                 else policy.draft_variant())
        self.pctx = pctx
        # KV-cache memory layout: policy-driven, EngineConfig.kv_dtype wins
        # when set.  Static across the engine's lifetime — every slot cache,
        # the prefill write and the decode read share one layout.
        self.kvcfg = policy.kvcache
        if ecfg.kv_dtype:
            self.kvcfg = dataclasses.replace(self.kvcfg, dtype=ecfg.kv_dtype)
        if ecfg.kv_paged is not None:
            self.kvcfg = dataclasses.replace(self.kvcfg, paged=ecfg.kv_paged)
        if ecfg.kv_block_size:
            self.kvcfg = dataclasses.replace(self.kvcfg,
                                             block_size=ecfg.kv_block_size)
        # paged pool geometry: blocks per layer, block 0 reserved as sink.
        # The auto budget is capacity-equivalent to the dense slab (every
        # slot can hold max_len), so the default never preempts; shrink
        # kv_pool_blocks to oversubscribe (DESIGN.md §8).
        self.num_blocks = 0
        if self.kvcfg.paged:
            if ecfg.max_len % self.kvcfg.block_size:
                raise ValueError(
                    f"max_len={ecfg.max_len} must divide by "
                    f"kv block_size={self.kvcfg.block_size}")
            per_slot = ecfg.max_len // self.kvcfg.block_size
            self.num_blocks = (ecfg.kv_pool_blocks
                               or ecfg.max_slots * per_slot + 1)
            if (ecfg.prefill_chunk > 0
                    and ecfg.prefill_chunk % self.kvcfg.block_size):
                raise ValueError(
                    f"prefill_chunk={ecfg.prefill_chunk} must divide by kv "
                    f"block_size={self.kvcfg.block_size}: chunk boundaries "
                    f"must align with pool blocks so the prefix gather "
                    f"reads whole written blocks")
        # weight-kernel dispatch: policy-driven, EngineConfig.use_kernels
        # wins when set.  Static too — it is baked into the jitted decode.
        # The override is decode-only by design: the GEMM paths are bitwise
        # identical, so flipping it never changes tokens, while the fused
        # requant's Pallas ttq_quantize (a different rounding fusion — ±1
        # code ties) stays governed by the policy the QuantizedModel holds.
        self.kncfg = policy.kernel
        if ecfg.use_kernels is not None:
            self.kncfg = dataclasses.replace(self.kncfg,
                                             use_pallas=ecfg.use_kernels)
        # runner first: with a mesh, the fp parameter tree is committed to
        # its sharded layout through the runner (the one component allowed
        # to allocate device memory — TC402/TC405) BEFORE the quant model
        # captures it, so every requant reads already-local weight shards
        self.runner = DeviceRunner(cfg, ecfg, self.kvcfg, kncfg=self.kncfg,
                                   pctx=pctx, key=key,
                                   num_blocks=self.num_blocks)
        self.params = params = self.runner.place_params(params)
        # robustness layer (DESIGN.md §12): one GuardConfig drives the
        # session's update validation, the model's requant health gate, the
        # scheduler's retry budget and the degradation ladder below.  The
        # session/model guards are strictly opt-in at their constructors,
        # so direct QuantizedModel users are untouched.
        guard = ecfg.guard_cfg if ecfg.guards else None
        self.qmodel = QuantizedModel(
            params, policy,
            session=CalibrationSession(halflife=ecfg.stats_halflife,
                                       guard=guard),
            double_buffer=ecfg.double_buffer, pctx=pctx,
            draft_policy=self.draft_policy, health_gate=guard)
        self.scheduler = Scheduler(
            ecfg, exact_buckets=cfg.family in ("hybrid", "ssm"),
            kvcfg=self.kvcfg, num_blocks=self.num_blocks)
        self.requant_wall_s = 0.0       # dispatch time spent requantizing
        # fault injection (serving/faults.py): deterministic, seeded faults
        # at named sites; the injector may supply a virtual clock so
        # deadline scenarios replay bit-for-bit
        self.faults = faults
        self._clock = time.monotonic
        if faults is not None:
            if getattr(faults, "clock", None) is not None:
                self._clock = faults.clock
            if getattr(faults, "requant_hook", None) is not None:
                self.qmodel._fault_hook = faults.requant_hook
        # graceful-degradation ladder under sustained KV-pool pressure:
        # 0 = normal, 1 = speculation off, 2 = K=1 decode chunks,
        # 3 = cached prefix blocks dropped — all before preemption bites
        self.degrade_level = 0
        self.degrade_events = 0

    # ------------------------------------------------------------------- TTQ

    def _requantize(self):
        thr = self.ecfg.requant_threshold
        t0 = time.perf_counter()
        tree = self.qmodel.requantize(threshold=thr if thr >= 0 else None)
        self.requant_wall_s += time.perf_counter() - t0
        if tree is not None:
            self.scheduler.note_requant()

    # back-compat views of the parts' state (tests/benchmarks/examples)
    @property
    def decode_params(self):
        return self.qmodel.decode_params

    @property
    def draft_params(self):
        """The speculation draft tree (None when speculation is off)."""
        if self.ecfg.speculate_k <= 0:
            return None
        return self.qmodel.draft_params

    @property
    def spec_acceptance_rate(self) -> float:
        """Accepted drafts / drafted tokens across all speculation windows
        (EXPERIMENTS.md §"Self-speculative methodology")."""
        r = self.runner
        return r.spec_accepted / r.spec_drafted if r.spec_drafted else 0.0

    @property
    def spec_windows(self) -> int:
        return self.runner.spec_windows

    @property
    def qparams(self):
        return self.qmodel.qparams

    @property
    def n_requants(self):
        return self.qmodel.n_requants

    @property
    def lowrank_tree(self):
        return self.qmodel.lowrank_tree

    @property
    def layers_requantized(self):
        """Total leaf quantizations dispatched across all requants."""
        return self.qmodel.total_requant_layers

    @property
    def layers_skipped(self):
        """Total leaf quantizations the delta gate skipped (QT reused)."""
        return self.qmodel.total_skipped_layers

    @property
    def agg_stats(self):
        return self.qmodel.session.stats

    @property
    def stat_count(self):
        return self.qmodel.session.count

    @property
    def admits_since_cal(self):
        return self.scheduler.admits_since_cal

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def slot_req(self):
        return self.scheduler.slot_req

    @property
    def finished(self):
        return self.scheduler.finished

    @property
    def state(self):
        return self.runner.state

    @property
    def pos(self):
        return self.runner.pos

    @property
    def cur_tok(self):
        return self.runner.cur_tok

    @property
    def host_syncs(self):
        return self.runner.host_syncs

    @property
    def compiled_programs(self) -> int:
        """XLA programs resident across the engine's jit caches (decode,
        bucketed prefill, prefix gather, fused requant families).  Bounded
        by construction: decode compiles once, prefill once per
        (bucket, prefix_len, group_size) shape, requant once per family —
        tests/test_runtime_guards.py pins the bound and benchmarks gate on
        a zero steady-state delta (DESIGN.md §"Static analysis & runtime
        invariants")."""
        return (self.runner.compiled_programs
                + self.qmodel.compiled_programs
                + _guards.compiled_programs())

    # ------------------------------------------------- paged-pool metrics

    @property
    def allocator(self):
        """The paged pool's :class:`~repro.serving.blocks.BlockAllocator`
        (None on the dense slab)."""
        return self.scheduler.allocator

    @property
    def kv_pool_utilization(self) -> float:
        """Peak fraction of allocatable pool blocks ever in use."""
        a = self.allocator
        return a.peak_in_use / max(a.capacity, 1) if a else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        a = self.allocator
        return a.prefix_hit_rate() if a else 0.0

    @property
    def preemptions(self) -> int:
        return self.scheduler.preemptions

    @property
    def prefill_tokens(self) -> float:
        """Padded tokens dispatched to prefill (prefix hits shrink this)."""
        return self.scheduler.prefill_tokens

    # ------------------------------------- streaming / SLO telemetry (§13)

    @property
    def queue_depth(self) -> int:
        """Requests waiting in the intake queue right now."""
        return len(self.scheduler.queue)

    @property
    def queue_rejections(self) -> int:
        """Submits bounced off the ``max_queue`` capacity bound."""
        return self.scheduler.queue_rejections

    @property
    def prefill_chunks(self) -> int:
        """Chunked-prefill dispatches issued (0 with chunking off)."""
        return self.scheduler.prefill_chunks

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p99 time-to-first-token and inter-token latency (seconds,
        engine clock) over every request that has emitted tokens — finished
        and in-flight.  ``serve.py``'s summary and ``bench_serve_slo.py``
        both report from this one implementation, so the batch harness and
        the async server share a latency vocabulary."""
        reqs = list(self.scheduler.finished.values())
        reqs += [r for r in self.scheduler.slot_req if r is not None]
        ttfts, itls = [], []
        for r in reqs:
            ts = r.tok_times
            if not ts:
                continue
            ttfts.append(ts[0] - r.submit_t)
            itls += [b - a for a, b in zip(ts, ts[1:])]

        def pct(xs, q):
            if not xs:
                return 0.0
            s = sorted(xs)
            return s[min(len(s) - 1, int(round(q * (len(s) - 1))))]

        return {"ttft_p50": pct(ttfts, 0.50), "ttft_p99": pct(ttfts, 0.99),
                "itl_p50": pct(itls, 0.50), "itl_p99": pct(itls, 0.99),
                "n_streams": len(ttfts), "n_itl": len(itls)}

    # -------------------------------------------- robustness telemetry (§12)

    @property
    def calib_rejections(self) -> int:
        """Calibration updates the session's guard quarantined (never
        folded into the running statistics)."""
        return self.qmodel.session.n_rejected

    @property
    def quarantine(self):
        """The session's bounded quarantine log (QuarantineRecord deque)."""
        return self.qmodel.session.quarantine

    @property
    def requant_rejections(self) -> int:
        """Candidate quantized trees the health gate refused to swap in."""
        return self.qmodel.requant_rejections

    @property
    def lane_faults(self) -> int:
        return self.scheduler.lane_faults

    @property
    def deadline_expirations(self) -> int:
        return self.scheduler.deadline_expirations

    @property
    def admission_failures(self) -> int:
        """Requests failed after exhausting the bounded admission-retry
        budget (``guard_cfg.max_admission_attempts``)."""
        return self.scheduler.admission_failures

    # --------------------------------------------------------------- serving

    def submit(self, prompt, max_new: int = 16, frames=None,
               deadline_s=None, priority: int = 0) -> int:
        """Queue a request; rejects prompts the engine cannot admit, and
        raises :class:`~repro.serving.scheduler.QueueFull` at
        ``EngineConfig.max_queue`` depth.

        ``deadline_s`` (seconds from now, 0 = none) bounds the request's
        wall-clock lifetime: expired requests — queued or running — are
        failed with ``error == "deadline"`` instead of occupying a lane
        forever.  Defaults to ``EngineConfig.deadline_s``.  ``priority``
        (lower = more urgent) picks the SLO class: admission order,
        eviction order and chunked-ingestion order all honour it
        (DESIGN.md §13)."""
        return self.scheduler.submit(prompt, max_new, frames=frames,
                                     deadline_s=deadline_s,
                                     now=self._clock(), priority=priority)

    def set_stream_callbacks(self, on_token=None, on_finish=None):
        """Install streaming callbacks: ``on_token(rid, tok, t)`` fires for
        every emitted token (first token included), ``on_finish(rid, req)``
        once per terminal landing (done, failed, cancelled, expired).
        Callbacks run on the engine-driving thread and must be cheap and
        device-free — the async server forwards into the event loop via
        ``call_soon_threadsafe`` (tracecheck TC407)."""
        self.scheduler.on_token = on_token
        self.scheduler.on_finish = on_finish

    def cancel(self, rid: int) -> bool:
        """Abort a queued or running request immediately: its slot and
        (paged) pool blocks free right away and its partial output is
        returned by ``results()`` flagged ``cancelled``.  Returns False if
        the rid is unknown or already finished."""
        ok = self.scheduler.cancel(rid)
        self._flush_releases()
        return ok

    def _flush_releases(self):
        """Deactivate slots the scheduler freed (finish / preempt / cancel)
        on device *before* their blocks can be reallocated."""
        slots = self.scheduler.pending_releases
        if slots:
            self.runner.release_slots(slots)
            self.scheduler.pending_releases = []

    def admit(self):
        """Admit queued requests into free slots: one batched prefill per
        bucket group, calibrate on its stats, requantize per cadence.

        Loops until the queue or the free slots run out: a request that
        finishes *at admission* (budget of 1, EOS or capacity on its first
        token) frees its slot immediately, and the next planning round hands
        that slot to the next queued request instead of stranding it."""
        while True:
            groups = self.scheduler.plan_admissions()
            self._flush_releases()   # preempted slots → sink before prefill
            if not groups:
                break
            for group in groups:
                # encdec frames ride each Request; the runner stages them
                # on device (the facade never allocates arrays)
                first, fin, stats = self.runner.admit_group(self.params,
                                                            group)
                rids = tuple(r.rid for r in group.requests)
                tokens = group.tokens
                if self.faults is not None:
                    stats, tokens = self.faults.calib_site(stats, tokens,
                                                           rids)
                if stats is not None:    # a "drop" fault skips the fold
                    self.qmodel.calibrate(stats, tokens=tokens,
                                          provenance=rids)
                self.scheduler.note_admitted(len(group.requests), group.tokens)
                now = self._clock()
                for i, (slot, req) in enumerate(zip(group.slots,
                                                    group.requests)):
                    self.scheduler.emit(req, int(first[i]), now)
                    if fin[i]:
                        self.scheduler.finish(slot)
        self._flush_releases()       # requests finished at admission
        if self.scheduler.should_requant():
            self._requantize()

    def _run_chunks(self):
        """Dispatch this round's chunked-prefill plans (DESIGN.md §13):
        at most ``prefill_budget`` padded tokens, most urgent ingestion
        first.  Each chunk folds its calibration statistics into the
        session — additive sufficient statistics, so the requant cadence
        sees the whole prompt across chunks exactly as it would from one
        monolithic prefill.  The final chunk arms the lane and emits the
        request's first token."""
        plans = self.scheduler.plan_prefill_chunks()
        for plan in plans:
            first, fin, stats = self.runner.prefill_chunk(self.params, plan)
            rids = (plan.req.rid,)
            tokens = float(self.ecfg.prefill_chunk)
            if self.faults is not None:
                stats, tokens = self.faults.calib_site(stats, tokens, rids)
            if stats is not None:        # a "drop" fault skips the fold
                self.qmodel.calibrate(stats, tokens=tokens, provenance=rids)
            self.scheduler.note_chunk(plan, float(self.ecfg.prefill_chunk))
            if plan.final:
                self.scheduler.emit(plan.req, int(first[0]), self._clock())
                if fin[0]:
                    self.scheduler.finish(plan.slot)
        if plans:
            self._flush_releases()   # finished-at-final-chunk slots → sink

    def _update_ladder(self):
        """Graceful-degradation ladder under KV-pool pressure (paged pool
        only).  Pressure = fraction of pool blocks currently allocated;
        above ``guard_cfg.degrade_pressure`` the engine climbs one rung,
        below ``recover_pressure`` it steps back down (hysteresis keeps it
        from flapping):

          0  normal service
          1  speculation off (draft tree unused — verify program only)
          2  decode chunk shrunk to K=1 (separate small jit, compiled
             lazily once)
          3  cached prefix blocks evicted back to the plain free list

        Each rung climbed bumps ``degrade_events``."""
        a = self.allocator
        gcfg = self.scheduler.gcfg
        if a is None or gcfg is None or not self.ecfg.guards:
            return
        pressure = 1.0 - len(a.free) / max(a.capacity, 1)
        if pressure >= gcfg.degrade_pressure and self.degrade_level < 3:
            self.degrade_level += 1
            self.degrade_events += 1
            if self.degrade_level >= 3:
                a.drop_cached()
        elif pressure <= gcfg.recover_pressure and self.degrade_level > 0:
            self.degrade_level -= 1

    def step(self) -> bool:
        """One engine iteration: expire deadlines, admit waiting requests,
        decode one fused block of ``decode_chunk`` tokens per active slot.

        Returns True while the engine still has work to drive — including
        rounds where every runnable request is waiting out a retry backoff
        (no decode dispatched, but ``run_all`` must keep stepping)."""
        now = self._clock()
        if self.faults is not None:
            self.faults.on_step(self)
        self.scheduler.expire_deadlines(now)
        self._flush_releases()       # deadline-evicted slots → sink
        self.admit()
        self._run_chunks()           # budgeted chunked-prefill dispatches
        self._update_ladder()
        if not self.scheduler.decode_slots():
            # mid-chunked-prefill lanes are work even though nothing decodes
            return (bool(self.scheduler.prefilling)
                    or self.scheduler.has_deferred_work())
        draft = None if self.degrade_level >= 1 else self.draft_params
        if self.faults is not None and self.runner.detect_faults:
            slots = self.faults.decode_site(self.scheduler.slot_req,
                                            self.scheduler._round)
            self.runner.set_poison(slots)
        toks, valid, done, fault = self.runner.decode_block(
            self.decode_params, draft, small_chunk=self.degrade_level >= 2)
        self.scheduler.record_block(toks, valid, done, fault=fault,
                                    now=self._clock())
        self._flush_releases()       # freed blocks must not be written again
        if self.scheduler.should_requant():
            self._requantize()
        return True

    def run_all(self, max_iters: int = 10_000) -> Dict[int, GenResult]:
        """Drive until all submitted requests finish; returns {rid: tokens}.

        Hitting ``max_iters`` no longer drops in-flight work: partial
        outputs are returned with ``result.unfinished == True``."""
        it = 0
        while self.scheduler.has_work() and it < max_iters:
            if not self.step():
                break
            it += 1
        return self.scheduler.results()
