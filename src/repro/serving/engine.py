"""TTQEngine — continuous-batching serving with online test-time quantization.

The paper's lifecycle (Fig. 1b) as a slot-based engine:

  submit → [queue] → admit: PREFILL in full precision with the stats tap on
                            (Σ_t x² per linear input feature, additive)
                     → aggregate stats across active prompts
                     → (re)QUANTIZE: D = f(stats); W_int,S,Z = G[(W−BA)∘D]
                     → DECODE loop over all active slots with the quantized
                       weights (4-bit packed path hits the Pallas ttq_gemm)

Per-prompt calibration (the paper's setting) is the ``max_slots=1`` case; with
batched serving the engine self-calibrates on the aggregate of the *current*
prompts — the statistics are additive sufficient statistics, so this is the
natural generalization (DESIGN.md §"CalibrationSession").  Quantization state
(stats accumulation/decay, low-rank factors computed once, the quantized
tree) is owned by :class:`repro.quant.QuantizedModel`; the engine only
drives the lifecycle.

Per-slot positions everywhere → true continuous batching: a new request can be
admitted while other slots are mid-generation.

The slot caches' memory layout is policy-driven (``policy.kvcache`` /
``EngineConfig.kv_dtype``): bf16, or int8 / packed-int4 codes with
per-(head, token) f32 scales written at prefill and per-decode-step append
and read by the fused Pallas dequant-attention kernel (DESIGN.md §"KV-cache
layout", EXPERIMENTS.md §Roofline for the traffic numbers).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import QuantPolicy
from repro.models import lm
from repro.models.config import ModelConfig
from repro.quant import QuantizedModel
from repro.quant.api import _path_str

from .sampling import sample


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4
    max_len: int = 256
    recalibrate_every: int = 1      # re-quantize after every N admissions
    stats_halflife: int = 0         # >0: exponential decay of stats (admissions)
    temperature: float = 0.0
    eos_token: int = -1             # -1 → run to max_new
    prompt_buckets: tuple = (16, 32, 64, 128, 256)
    kv_dtype: str = ""              # "" → policy.kvcache; else bf16|int8|int4


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    frames: Any = None              # encdec stub modality input


def _write_slot(batched, single, slot: int):
    """Write a B=1 state into slot ``slot`` of the batched decode state."""
    def per(path, bl, sl):
        ps = _path_str(path)
        if ps.startswith("stack"):
            # leaves (R, B, ...) ← (R, 1, ...)
            idx = (slice(None), slice(slot, slot + 1))
        else:
            idx = (slice(slot, slot + 1),)
        return bl.at[idx].set(sl.astype(bl.dtype))

    return jax.tree_util.tree_map_with_path(per, batched, single)


class TTQEngine:
    def __init__(self, cfg: ModelConfig, params, policy: QuantPolicy,
                 ecfg: EngineConfig = EngineConfig(), pctx=None, key=None):
        self.cfg, self.params, self.policy, self.ecfg = cfg, params, policy, ecfg
        self.pctx = pctx
        self.key = key if key is not None else jax.random.PRNGKey(0)
        # KV-cache memory layout: policy-driven, EngineConfig.kv_dtype wins
        # when set.  Static across the engine's lifetime — every slot cache,
        # the prefill write and the decode read share one layout.
        self.kvcfg = policy.kvcache
        if ecfg.kv_dtype:
            self.kvcfg = dataclasses.replace(self.kvcfg, dtype=ecfg.kv_dtype)
        B, ML = ecfg.max_slots, ecfg.max_len
        self.state = lm.init_decode_state(cfg, B, ML, kvcfg=self.kvcfg)
        self.pos = jnp.zeros((B,), jnp.int32)
        self.cur_tok = jnp.zeros((B, 1), jnp.int32)
        self.slot_req: List[Optional[Request]] = [None] * B
        self.queue: deque = deque()
        self.finished: Dict[int, Request] = {}
        self._rid = itertools.count()
        # TTQ state: session + low-rank factors + quantized tree, all owned
        # by the facade (factors are computed once, here — requantization
        # reuses them, no per-requant SVD).
        self.qmodel = QuantizedModel(params, policy,
                                     halflife=ecfg.stats_halflife)
        self.admits_since_cal = 0
        self._decode_jit = jax.jit(partial(lm.decode_step, cfg, pctx=pctx,
                                           kvcfg=self.kvcfg))
        self._prefill_jit = jax.jit(partial(lm.prefill, cfg, pctx=pctx,
                                            collect_stats=True,
                                            full_logits=True,
                                            kvcfg=self.kvcfg),
                                    static_argnames=("max_len",))

    # ------------------------------------------------------------------ TTQ

    def _requantize(self):
        if self.qmodel.requantize() is not None:
            self.admits_since_cal = 0

    # back-compat views of the facade's state (tests/benchmarks use these)
    @property
    def decode_params(self):
        return self.qmodel.decode_params

    @property
    def qparams(self):
        return self.qmodel.qparams

    @property
    def n_requants(self):
        return self.qmodel.n_requants

    @property
    def lowrank_tree(self):
        return self.qmodel.lowrank_tree

    @property
    def agg_stats(self):
        return self.qmodel.session.stats

    @property
    def stat_count(self):
        return self.qmodel.session.count

    # -------------------------------------------------------------- serving

    def submit(self, prompt, max_new: int = 16, frames=None) -> int:
        rid = next(self._rid)
        self.queue.append(Request(rid, list(prompt), max_new, frames=frames))
        return rid

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _bucket(self, n: int) -> int:
        for b in self.ecfg.prompt_buckets:
            if n <= b:
                return b
        return self.ecfg.prompt_buckets[-1]

    def _admit_one(self, slot: int, req: Request):
        plen = len(req.prompt)
        if self.cfg.family in ("hybrid", "ssm"):
            # recurrent state would absorb pad tokens — use exact length
            bucket = plen
        else:
            bucket = min(self._bucket(plen), self.ecfg.max_len)
        # right-pad: causal masking keeps real tokens clean; pad positions
        # beyond the prompt end are never attended at decode (ki ≤ pos mask)
        toks = jnp.zeros((1, bucket), jnp.int32)
        toks = toks.at[0, :plen].set(jnp.asarray(req.prompt))
        batch = {"tokens": toks}
        if self.cfg.family == "encdec":
            batch["frames"] = req.frames[None] if req.frames.ndim == 2 else req.frames
        logits, sstate, stats = self._prefill_jit(
            self.params, batch, max_len=self.ecfg.max_len)
        last_logits = logits[:, plen - 1]
        self.qmodel.calibrate(stats, tokens=float(bucket))
        self.state = _write_slot(self.state, sstate, slot)
        self.key, sk = jax.random.split(self.key)
        nxt = sample(last_logits, sk, self.ecfg.temperature)
        req.out.append(int(nxt[0]))
        self.cur_tok = self.cur_tok.at[slot, 0].set(nxt[0])
        self.pos = self.pos.at[slot].set(plen)   # decode overwrites pads
        self.slot_req[slot] = req
        self.admits_since_cal += 1
        if self.admits_since_cal >= self.ecfg.recalibrate_every:
            self._requantize()

    def admit(self):
        for slot in self._free_slots():
            if not self.queue:
                break
            self._admit_one(slot, self.queue.popleft())

    def step(self):
        """One engine iteration: admit waiting requests, decode one token."""
        self.admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        logits, self.state = self._decode_jit(self.decode_params, self.state,
                                              self.cur_tok, self.pos)
        self.key, sk = jax.random.split(self.key)
        nxt = sample(logits, sk, self.ecfg.temperature)
        self.pos = jnp.clip(self.pos + 1, 0, self.ecfg.max_len - 1)
        self.cur_tok = nxt[:, None]
        for i in active:
            req = self.slot_req[i]
            tok = int(nxt[i])
            req.out.append(tok)
            if len(req.out) >= req.max_new or tok == self.ecfg.eos_token:
                req.done = True
                self.finished[req.rid] = req
                self.slot_req[i] = None
        return True

    def run_all(self, max_iters: int = 10_000) -> Dict[int, list]:
        """Drive until all submitted requests finish; returns {rid: tokens}."""
        it = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and it < max_iters:
            if not self.step():
                break
            it += 1
        return {rid: req.out for rid, req in self.finished.items()}
