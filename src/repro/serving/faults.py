"""Deterministic fault injection for the serving stack (DESIGN.md §12).

This module is the engine's *designated fault boundary*: the one place in
``serving/`` allowed to hold broad exception handlers (tracecheck TC406
exempts it by name), because a bug in the injection harness itself must
never take down the serving run it is probing.

Faults are declarative: a :class:`Fault` names a **site** (a seam the
engine already exposes), a site-local trigger index ``at``, a ``kind`` and
a repeat ``count``.  The :class:`FaultInjector` holds a list of them plus
an optional :class:`VirtualClock`, and the engine calls its hooks at fixed
points of the serving loop — so a given (faults, seed, workload) triple
replays bit-for-bit, and ``benchmarks/bench_robustness.py`` can assert the
recovery-equality gate: *unaffected requests produce bitwise-identical
greedy tokens to a fault-free run*.

Sites and kinds:

==================  ====================================================
``calib.stats``     corrupt the admission-time calibration update before
                    it reaches ``CalibrationSession.update``.  Kinds:
                    ``nan`` / ``inf`` (non-finite stats), ``outlier``
                    (scale by ``magnitude``), ``bad-tokens`` (zero token
                    count), ``drop`` (skip the fold entirely — the clean
                    twin used as the equality baseline).
``requant.tree``    corrupt the candidate quantized tree between the
                    fused requant dispatch and the health gate (float
                    leaves → NaN).  Exercises retry-then-rollback.
``pool.steal``      steal up to ``magnitude`` free KV-pool blocks for
                    ``count`` engine steps (admission sees a full pool →
                    bounded retries / preemption), then return them.
``decode.logits``   poison the decode logits of the lane running request
                    ``rid`` (all lanes when ``rid < 0``) for ``count``
                    decode blocks — the runner's fault detector must fail
                    only that lane.
``clock.skew``      jump the virtual clock forward by ``magnitude``
                    seconds at engine step ``at`` (deadline scenarios).
==================  ====================================================

No device placement happens here: stats/tree corruption is arithmetic on
arrays the engine already owns, and lane poisoning only *selects slots* —
the :class:`~repro.serving.runner.DeviceRunner` owns the device-side mask.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["Fault", "FaultInjector", "VirtualClock", "demo_injector"]


@dataclasses.dataclass(frozen=True)
class Fault:
    """One declarative fault: fire at site-local index ``at`` (each site
    keeps its own event counter), ``count`` consecutive times."""
    site: str                 # calib.stats | requant.tree | pool.steal |
                              # decode.logits | clock.skew
    at: int = 0               # site-local trigger index
    kind: str = ""            # site-specific (see module docstring)
    rid: int = -1             # decode.logits: target request (-1 = all)
    magnitude: float = 1e6    # outlier factor / blocks stolen / skew sec
    count: int = 1            # consecutive triggers


class VirtualClock:
    """A monotonic clock the test harness owns.  The engine reads it via
    ``FaultInjector.clock`` so deadline expiry replays deterministically;
    ``tick`` advances it by a fixed step per engine iteration."""

    def __init__(self, start: float = 0.0, tick: float = 0.0):
        self.now = float(start)
        self.tick = float(tick)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float):
        self.now += float(dt)


def _nan_floats(tree):
    """NaN-corrupt every floating leaf of a pytree (ints — packed codes,
    block tables — keep dtype and value)."""
    def leaf(x):
        if hasattr(x, "dtype") and np.issubdtype(x.dtype, np.floating):
            return x * float("nan")
        return x
    return jax.tree.map(leaf, tree)


class FaultInjector:
    """Replays a fault list against the engine's injection sites.

    The engine wires the hooks itself when constructed with
    ``TTQEngine(..., faults=injector)``: ``on_step`` runs at the top of
    every :meth:`~repro.serving.engine.TTQEngine.step`, ``calib_site``
    intercepts each admission-group stats fold, ``requant_hook`` each
    candidate quantized tree, and ``decode_site`` picks the lanes to
    poison before each decode block.  ``fired`` logs every injection as
    ``(site, index, detail)`` so benchmarks can reconcile *injected*
    against *detected* counts exactly.
    """

    def __init__(self, faults, clock: Optional[VirtualClock] = None):
        self.faults: List[Fault] = list(faults)
        self.clock = clock
        self.fired: List[Tuple[str, int, str]] = []
        self.errors: List[str] = []          # harness bugs, never re-raised
        self._step_n = 0
        self._calib_n = 0
        self._requant_n = 0
        self._decode_n = 0
        self._decode_fired: Dict[int, int] = {}
        self._stolen: List[Tuple[int, object, List[int]]] = []

    # ------------------------------------------------------------ plumbing

    def _active(self, site: str, n: int) -> Optional[Fault]:
        for f in self.faults:
            if f.site == site and f.at <= n < f.at + f.count:
                return f
        return None

    def _log(self, f: Fault, n: int, detail: str = ""):
        self.fired.append((f.site, n, detail or f.kind or str(f.rid)))

    # ------------------------------------------------------- engine hooks

    def on_step(self, engine):
        """Step-indexed sites: clock skew and pool-block theft.  This is
        the fault boundary proper — a harness bug is recorded and
        swallowed so it cannot crash the serving loop it is probing."""
        n = self._step_n
        self._step_n += 1
        try:
            if self.clock is not None and self.clock.tick:
                self.clock.advance(self.clock.tick)
            f = self._active("clock.skew", n)
            if f is not None and self.clock is not None:
                self.clock.advance(f.magnitude)
                self._log(f, n, f"+{f.magnitude}s")
            self._pool_site(engine, n)
        except Exception as e:          # tracecheck: ok[TC406]
            self.errors.append(f"on_step[{n}]: {e!r}")

    def _pool_site(self, engine, n: int):
        a = getattr(engine, "allocator", None)
        if a is None:
            return
        # return blocks whose theft window closed (before new theft so a
        # back-to-back fault pair sees a consistent pool)
        keep = []
        for until, alloc, blocks in self._stolen:
            if n >= until:
                alloc.free.extend(blocks)
            else:
                keep.append((until, alloc, blocks))
        self._stolen = keep
        f = self._active("pool.steal", n)
        if f is not None and n == f.at:      # steal once per fault window
            take = min(int(f.magnitude), len(a.free))
            blocks = [a.free.pop() for _ in range(take)]
            self._stolen.append((f.at + f.count, a, blocks))
            self._log(f, n, f"stole {take} blocks")

    def calib_site(self, stats, tokens: int, rids: Tuple[int, ...]):
        """Intercept one admission group's calibration fold; returns the
        (possibly corrupted) ``(stats, tokens)`` — stats ``None`` means
        the engine skips the fold (the clean-drop twin)."""
        n = self._calib_n
        self._calib_n += 1
        f = self._active("calib.stats", n)
        if f is None or stats is None:
            return stats, tokens
        self._log(f, n, f"{f.kind} rids={list(rids)}")
        if f.kind == "drop":
            return None, tokens
        if f.kind == "nan":
            return _nan_floats(stats), tokens
        if f.kind == "inf":
            return jax.tree.map(lambda x: x * float("inf"), stats), tokens
        if f.kind == "outlier":
            return jax.tree.map(lambda x: x * f.magnitude, stats), tokens
        if f.kind == "bad-tokens":
            return stats, 0
        return stats, tokens

    def requant_hook(self, tree):
        """Corrupt a candidate quantized tree (float leaves → NaN) before
        the health gate sees it.  Called once per fused-requant dispatch;
        with ``count=1`` the gate's in-step retry rebuilds a clean tree."""
        n = self._requant_n
        self._requant_n += 1
        f = self._active("requant.tree", n)
        if f is None:
            return tree
        self._log(f, n, f.kind or "nan-scale")
        return _nan_floats(tree)

    def decode_site(self, slot_req, round_: int = 0) -> List[int]:
        """Pick the slots to poison for the next decode block: lanes whose
        request matches a live ``decode.logits`` fault.  Fires at most
        ``count`` blocks per fault, and only once the target is actually
        running — so the trigger is deterministic without the harness
        having to predict admission timing."""
        n = self._decode_n
        self._decode_n += 1
        slots: List[int] = []
        for f in self.faults:
            if f.site != "decode.logits" or n < f.at:
                continue
            done = self._decode_fired.get(id(f), 0)
            if done >= f.count:
                continue
            hit = [s for s, r in enumerate(slot_req)
                   if r is not None and (f.rid < 0 or r.rid == f.rid)]
            if not hit:
                continue
            self._decode_fired[id(f)] = done + 1
            self._log(f, n, f"slots={hit}")
            slots.extend(hit)
        return sorted(set(slots))


def demo_injector(name: str) -> FaultInjector:
    """Named single-fault injectors for ``launch/serve.py --inject`` and
    quick interactive probing.  Benchmarks build their own fault lists."""
    recipes = {
        "nan-stats": [Fault("calib.stats", at=1, kind="nan")],
        "outlier-stats": [Fault("calib.stats", at=1, kind="outlier",
                                magnitude=1e6)],
        "bad-requant": [Fault("requant.tree", at=0, kind="nan-scale")],
        "pool-steal": [Fault("pool.steal", at=2, magnitude=4, count=3)],
        "poison-lane": [Fault("decode.logits", at=0, rid=0)],
    }
    if name not in recipes:
        raise ValueError(f"unknown fault recipe {name!r}; "
                         f"choose from {sorted(recipes)}")
    return FaultInjector(recipes[name])
