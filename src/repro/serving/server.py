"""TTQServer — asyncio streaming front end over :class:`TTQEngine`.

Turns the batch-driven engine into a live service (DESIGN.md §13): clients
``await server.generate(...)`` and receive tokens as the engine emits them,
instead of waiting for ``run_all`` to return.

Threading contract (tracecheck TC407): the engine is single-threaded device
code — every engine call (``submit``, ``step``, ``cancel``) happens on ONE
dedicated worker thread that this server owns.  The asyncio side only
touches queues, futures and semaphores:

* **submit** — a coroutine enqueues a command and awaits a future; the
  worker performs the actual ``engine.submit`` and resolves the future with
  the rid (or the typed rejection).
* **stream** — the engine's ``on_token`` / ``on_finish`` callbacks (fired
  on the worker thread inside ``step``) forward events into the consumer's
  ``asyncio.Queue`` via ``loop.call_soon_threadsafe`` — the one documented
  thread-safe entry point into a running event loop.
* **backpressure** — an ``asyncio.Semaphore`` sized to the engine's
  ``max_queue`` (held from submit to completion) makes coroutines *await*
  at capacity instead of seeing :class:`QueueFull`; the engine-level bound
  stays armed underneath as the hard stop for non-server submitters.
* **disconnect** — a consumer that abandons ``generate`` (task cancelled,
  generator closed) triggers ``cancel(rid)`` on the worker thread; the
  scheduler releases the slot and any partially chunk-ingested blocks
  immediately (mid-prefill cancellation, DESIGN.md §13).

Fault-retried lanes (DESIGN.md §12) restart their stream from scratch —
``on_token`` re-emits from the first token; consumers that need exactly-
once delivery should key on (rid, index).
"""
from __future__ import annotations

import asyncio
import queue as _queue
import threading
from typing import Optional

from .scheduler import GenResult, QueueFull  # noqa: F401  (re-export)


class RequestFailed(RuntimeError):
    """A streamed request landed with a terminal error (deadline, lane
    fault past the retry budget, admission retries exhausted).  Carries the
    partial :class:`GenResult` as ``.result``."""

    def __init__(self, rid: int, result: GenResult):
        super().__init__(f"request {rid} failed: {result.error}")
        self.rid = rid
        self.result = result


class TTQServer:
    """Async streaming wrapper over one :class:`TTQEngine`.

    Usage::

        async with TTQServer(engine) as server:
            async for tok in server.generate(prompt, max_new=32):
                ...

    The server owns the engine for its lifetime: it installs the streaming
    callbacks and drives ``engine.step()`` from its worker thread whenever
    work is pending.  ``stop()`` (or leaving the ``async with``) drains
    in-flight work, then parks the worker.
    """

    def __init__(self, engine, max_concurrent: int = 0,
                 poll_s: float = 0.005):
        self.engine = engine
        # hold-to-completion semaphore: never lets more requests coexist
        # than the engine queue bound admits, so server submits cannot
        # bounce off QueueFull
        self._limit = max_concurrent or getattr(engine.ecfg, "max_queue", 0) \
            or 16
        self.poll_s = poll_s
        self.error: Optional[BaseException] = None   # worker crash, if any
        self._running = False
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._sem: Optional[asyncio.Semaphore] = None
        self._cmds: _queue.Queue = _queue.Queue()
        self._streams: dict = {}        # rid → consumer asyncio.Queue
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._wake = threading.Event()

    # ------------------------------------------------------------ lifecycle

    async def start(self):
        if self._running:
            raise RuntimeError("server already started")
        self._loop = asyncio.get_running_loop()
        self._sem = asyncio.Semaphore(self._limit)
        self._stop_evt.clear()
        self.error = None
        self._thread = threading.Thread(target=self._run, name="ttq-engine",
                                        daemon=True)
        self._running = True
        self._thread.start()

    async def stop(self):
        """Drain in-flight work, then stop the worker thread."""
        if not self._running:
            return
        self._stop_evt.set()
        self._wake.set()
        await self._loop.run_in_executor(None, self._thread.join)
        self._running = False

    async def __aenter__(self):
        await self.start()
        return self

    async def __aexit__(self, *exc):
        await self.stop()

    # -------------------------------------------------------------- serving

    async def generate(self, prompt, max_new: int = 16, priority: int = 0,
                       deadline_s=None):
        """Async generator of tokens, yielded as the engine emits them.

        Awaits at the server's concurrency bound (backpressure) before
        submitting.  Abandoning the generator cancels the request on the
        engine — slot and partially written KV blocks free immediately.
        Raises :class:`RequestFailed` if the request lands with a terminal
        error; a cancellation just ends the stream."""
        rid, q, done = None, None, False
        await self._acquire()
        try:
            rid, q = await self._open(prompt, max_new, priority, deadline_s)
            while True:
                ev = await q.get()
                if isinstance(ev, GenResult):
                    done = True
                    if ev.error:
                        raise RequestFailed(rid, ev)
                    return
                yield ev
        finally:
            self._close(rid, done)

    async def complete(self, prompt, max_new: int = 16, priority: int = 0,
                       deadline_s=None) -> GenResult:
        """Await a whole generation; returns its :class:`GenResult` (error
        results return rather than raise — inspect ``.error``)."""
        rid, done = None, False
        await self._acquire()
        try:
            rid, q = await self._open(prompt, max_new, priority, deadline_s)
            while True:
                ev = await q.get()
                if isinstance(ev, GenResult):
                    done = True
                    return ev
        finally:
            self._close(rid, done)

    # ----------------------------------------------------- stream plumbing

    async def _acquire(self):
        if not self._running:
            raise RuntimeError("server not started")
        await self._sem.acquire()

    async def _open(self, prompt, max_new, priority, deadline_s):
        """Hand the submit to the worker; await the rid."""
        fut = self._loop.create_future()
        q: asyncio.Queue = asyncio.Queue()
        self._cmds.put(("submit", list(prompt),
                        dict(max_new=max_new, priority=priority,
                             deadline_s=deadline_s), fut, q))
        self._wake.set()
        return await fut, q

    def _close(self, rid, done: bool):
        """Stream teardown: cancel on the worker if the consumer left
        early, release the admission slot either way."""
        if rid is not None and not done:
            self._cmds.put(("cancel", rid))
            self._wake.set()
        self._sem.release()

    # -------------------------------------------- worker thread (TC407 side)

    def _run(self):
        """The engine-driving loop: drain commands, step while work is
        pending, park on the wake event otherwise.  The ONLY thread that
        touches the engine after ``start()``."""
        eng = self.engine
        eng.set_stream_callbacks(self._on_token, self._on_finish)
        try:
            while True:
                self._drain_cmds()
                sched = eng.scheduler
                if sched.has_work() or sched.has_deferred_work():
                    eng.step()
                elif self._stop_evt.is_set():
                    break
                else:
                    self._wake.wait(self.poll_s)
                    self._wake.clear()
        except BaseException as e:   # tracecheck: ok[TC406] worker crash
            #   boundary: land the failure in every open stream instead of
            #   killing a daemon thread silently
            self._crash(e)
        finally:
            eng.set_stream_callbacks(None, None)

    def _drain_cmds(self):
        while True:
            try:
                cmd = self._cmds.get_nowait()
            except _queue.Empty:
                return
            if cmd[0] == "submit":
                _, prompt, kw, fut, q = cmd
                try:
                    rid = self.engine.submit(prompt, **kw)
                except (QueueFull, ValueError) as e:
                    self._call_soon(self._resolve, fut, None, e)
                    continue
                self._streams[rid] = q
                self._call_soon(self._resolve, fut, rid, None)
            elif cmd[0] == "cancel":
                self.engine.cancel(cmd[1])

    def _on_token(self, rid, tok, t):
        q = self._streams.get(rid)
        if q is not None:
            self._call_soon(q.put_nowait, int(tok))

    def _on_finish(self, rid, req):
        q = self._streams.pop(rid, None)
        if q is not None:
            res = GenResult(req.out,
                            unfinished=req.cancelled or bool(req.error),
                            cancelled=req.cancelled, error=req.error)
            self._call_soon(q.put_nowait, res)

    def _crash(self, e: BaseException):
        self.error = e
        for rid in list(self._streams):
            q = self._streams.pop(rid, None)
            if q is not None:
                res = GenResult((), unfinished=True,
                                error=f"engine worker crashed: {e!r}")
                self._call_soon(q.put_nowait, res)

    def _call_soon(self, fn, *args):
        try:
            self._loop.call_soon_threadsafe(fn, *args)
        except RuntimeError:        # loop already closed (shutdown race)
            pass

    def _resolve(self, fut, val, err):
        if fut.done():              # consumer gave up while we submitted
            if err is None and val is not None:
                # the submit won the race — don't orphan a running request
                self._streams.pop(val, None)
                self._cmds.put(("cancel", val))
                self._wake.set()
            return
        if err is not None:
            fut.set_exception(err)
        else:
            fut.set_result(val)
