from .blocks import BlockAllocator
from .engine import EngineConfig, TTQEngine
from .faults import Fault, FaultInjector, VirtualClock, demo_injector
from .runner import DeviceRunner
from .sampling import sample
from .scheduler import GenResult, Request, Scheduler, pick_decode_chunk

__all__ = ["BlockAllocator", "DeviceRunner", "EngineConfig", "Fault",
           "FaultInjector", "GenResult", "Request", "Scheduler", "TTQEngine",
           "VirtualClock", "demo_injector", "pick_decode_chunk", "sample"]
