from .blocks import BlockAllocator
from .engine import EngineConfig, TTQEngine
from .faults import Fault, FaultInjector, VirtualClock, demo_injector
from .runner import DeviceRunner
from .sampling import sample
from .scheduler import (GenResult, QueueFull, Request, Scheduler,
                        pick_decode_chunk)
from .server import RequestFailed, TTQServer

__all__ = ["BlockAllocator", "DeviceRunner", "EngineConfig", "Fault",
           "FaultInjector", "GenResult", "QueueFull", "Request",
           "RequestFailed", "Scheduler", "TTQEngine", "TTQServer",
           "VirtualClock", "demo_injector", "pick_decode_chunk", "sample"]
