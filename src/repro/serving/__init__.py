from .engine import EngineConfig, Request, TTQEngine
from .sampling import sample

__all__ = ["EngineConfig", "Request", "TTQEngine", "sample"]
