from .blocks import BlockAllocator
from .engine import EngineConfig, TTQEngine
from .runner import DeviceRunner
from .sampling import sample
from .scheduler import GenResult, Request, Scheduler, pick_decode_chunk

__all__ = ["BlockAllocator", "DeviceRunner", "EngineConfig", "GenResult",
           "Request", "Scheduler", "TTQEngine", "pick_decode_chunk",
           "sample"]
