from .engine import EngineConfig, TTQEngine
from .runner import DeviceRunner
from .sampling import sample
from .scheduler import GenResult, Request, Scheduler

__all__ = ["DeviceRunner", "EngineConfig", "GenResult", "Request",
           "Scheduler", "TTQEngine", "sample"]
