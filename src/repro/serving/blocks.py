"""Block allocator + prefix trie for the paged KV cache (host bookkeeping).

The device side is a per-layer (num_blocks, Hkv, block_size, ·) pool indexed
through per-slot block tables (DESIGN.md §8); this module owns which physical
block holds what:

* **free list** — physical blocks 1..NB-1 (block 0 is the reserved sink for
  done-lane and padding writes; it is never allocated and never read by a
  live slot's masked attention);
* **prefix trie** — full prompt-prefix blocks keyed by a rolling hash chain
  ``h_i = hash(h_{i-1}, tokens[i·bs:(i+1)·bs])``, so a lookup walks the
  longest shared prefix block-by-block.  Hits share the physical block
  (ref-counted); blocks whose refcount drops to zero stay *cached* (LRU) and
  are reclaimed only under pressure — prefix reuse survives the first
  request's lifetime;
* **accounting** — prefix hit/miss counts, peak utilization, per-request
  block ownership (the leak check's ground truth).

Mesh interplay (DESIGN.md §10): physical block ids are *global* — a sharded
pool splits the KV-head dim, never the block dim — so this allocator, the
prefix trie and preemption run identically on every mesh shape; per-slot
block tables are replicated and the ids handed out here index every
device's local pool shard.

Allocation is **upfront**: a request reserves every block its prompt plus
generation budget can touch (``ceil(min(plen + max_new, max_len) / bs)``),
so decode never allocates and the block table is read-only on device between
admissions.  When the free+cached supply cannot cover an admission the
scheduler preempts a running slot (frees its blocks, requeues the request)
rather than stalling — see :meth:`Scheduler.plan_admissions`.

Sharing is safe by construction: only *full* blocks strictly before the
prompt's last token enter the trie, decode writes start at ``pos = plen``,
and the block containing ``plen`` is always privately allocated — a shared
block is never written after registration, so copy-on-write reduces to
"the first divergent block is a fresh allocation" (no copies needed).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Tuple

SINK = 0            # physical block 0: write sink, never allocated


def chain_hashes(tokens, block_size: int, n_blocks: int) -> List[int]:
    """Rolling hash chain over the first ``n_blocks`` full blocks."""
    out, h = [], 0
    for i in range(n_blocks):
        blk = tuple(tokens[i * block_size:(i + 1) * block_size])
        h = hash((h, blk))
        out.append(h)
    return out


class BlockAllocator:
    def __init__(self, num_blocks: int, block_size: int,
                 prefix_cache: bool = True):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the sink)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        self.free: List[int] = list(range(num_blocks - 1, SINK, -1))  # pop() ↑
        self.ref: Dict[int, int] = {}                # block -> refcount (>0)
        self.trie: Dict[int, int] = {}               # chain hash -> block
        self.block_hash: Dict[int, int] = {}         # block -> its chain hash
        self.cached: "OrderedDict[int, None]" = OrderedDict()  # ref==0, LRU
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.peak_in_use = 0

    # ------------------------------------------------------------- capacity

    @property
    def capacity(self) -> int:
        """Allocatable blocks (sink excluded)."""
        return self.num_blocks - 1

    @property
    def in_use(self) -> int:
        return len(self.ref)

    def available(self) -> int:
        """Blocks obtainable right now: free + reclaimable cached."""
        return len(self.free) + len(self.cached)

    # ------------------------------------------------------------ low level

    def _take(self) -> int:
        if self.free:
            blk = self.free.pop()
        elif self.cached:
            blk, _ = self.cached.popitem(last=False)     # LRU cached block
            h = self.block_hash.pop(blk)
            del self.trie[h]
        else:
            raise MemoryError("KV pool exhausted")
        self.ref[blk] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return blk

    def _retain(self, blk: int):
        if blk in self.cached:                            # revive cached
            del self.cached[blk]
            self.ref[blk] = 1
        else:
            self.ref[blk] += 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)

    def _release(self, blk: int):
        self.ref[blk] -= 1
        if self.ref[blk] > 0:
            return
        del self.ref[blk]
        if self.prefix_cache and blk in self.block_hash:
            self.cached[blk] = None                       # keep for reuse
        else:
            self.free.append(blk)

    def drop_cached(self) -> int:
        """Evict every unreferenced cached prefix block back to the plain
        free list (degradation-ladder rung 3: trade prefix reuse for
        allocatable headroom).  Live shared blocks are untouched.  Returns
        the number of blocks reclaimed."""
        n = 0
        while self.cached:
            blk, _ = self.cached.popitem(last=False)
            h = self.block_hash.pop(blk)
            del self.trie[h]
            self.free.append(blk)
            n += 1
        return n

    # ------------------------------------------------------------ admission

    def match_prefix(self, prompt) -> Tuple[List[int], List[int]]:
        """Longest cached prefix of ``prompt``: (physical blocks, hashes).

        Walks full blocks strictly before the last prompt token (the block
        holding position ``plen`` must stay private — decode writes there).
        Pure lookup: hit/miss accounting happens on successful
        :meth:`allocate` only, so a preemption retry does not double-count."""
        n = self._shareable_blocks(len(prompt))
        hashes = chain_hashes(prompt, self.block_size, n)
        if not self.prefix_cache:
            return [], hashes
        blocks: List[int] = []
        for h in hashes:
            blk = self.trie.get(h)
            if blk is None:
                break
            blocks.append(blk)
        return blocks, hashes

    def _shareable_blocks(self, plen: int) -> int:
        """Full blocks strictly before the prompt's last token."""
        return max(plen - 1, 0) // self.block_size

    def blocks_needed(self, plen: int, max_new: int, max_len: int) -> int:
        span = min(plen + max_new, max_len)
        return -(-span // self.block_size)

    def allocate(self, prompt, max_new: int, max_len: int,
                 register: bool = True) -> Tuple[List[int], int]:
        """Reserve the request's blocks.  Returns (physical blocks in logical
        order, prefix_len in tokens).  Shared prefix blocks are ref-retained;
        the remainder freshly allocated; freshly-prefilled shareable blocks
        are registered in the trie.  Raises MemoryError when the pool cannot
        cover the request (caller preempts and retries).

        ``register=False`` defers trie registration of the fresh shareable
        blocks (chunked prefill: the rows are written over several rounds, so
        another request must not prefix-match a block before its tokens land
        — the caller registers written blocks incrementally via
        :meth:`register_blocks`)."""
        shared, hashes = self.match_prefix(prompt)
        need = self.blocks_needed(len(prompt), max_new, max_len)
        # exact capacity check: reviving a shared block that currently sits
        # in the cached pool consumes one unit of "available" too
        shared_cached = sum(1 for b in shared if b in self.cached)
        if need - len(shared) > self.available() - shared_cached:
            raise MemoryError("KV pool exhausted")
        self.prefix_hits += len(shared)
        self.prefix_misses += len(hashes) - len(shared)
        blocks = []
        try:
            for blk in shared:
                self._retain(blk)
                blocks.append(blk)
            for i in range(len(shared), need):
                blk = self._take()
                if register and self.prefix_cache and i < len(hashes):
                    self._hook(hashes[i], blk)
                blocks.append(blk)
        except MemoryError:
            self.free_request(blocks)      # atomic: no partial reservations
            self.prefix_hits -= len(shared)
            self.prefix_misses -= len(hashes) - len(shared)
            raise
        return blocks, len(shared) * self.block_size

    def _hook(self, h: int, blk: int):
        """Enter ``blk`` into the trie under chain hash ``h``.

        A previous block may still map to ``h`` even though the trie walk
        broke earlier in the chain (its predecessor was evicted) — unhook
        it, or its later reclaim would delete THIS block's live trie entry
        out from under us."""
        old = self.trie.get(h)
        if old is not None and old != blk:
            del self.block_hash[old]
            if old in self.cached:                         # demote to plain free
                del self.cached[old]
                self.free.append(old)
        self.trie[h] = blk
        self.block_hash[blk] = h

    def register_blocks(self, prompt, blocks: List[int], written: int):
        """Register the shareable prefix blocks of ``prompt`` whose tokens
        have all been written (``written`` = tokens resident in the cache so
        far).  Incremental counterpart of the registration that
        ``allocate(register=True)`` does upfront: chunked prefill calls this
        after each chunk lands, so the trie only ever points at rows that
        exist on device.  Idempotent — already-registered (shared) blocks
        are skipped."""
        if not self.prefix_cache:
            return
        n = min(self._shareable_blocks(len(prompt)),
                written // self.block_size, len(blocks))
        hashes = chain_hashes(prompt, self.block_size, n)
        for i in range(n):
            blk = blocks[i]
            if self.block_hash.get(blk) == hashes[i]:      # already hooked
                continue
            self._hook(hashes[i], blk)

    def free_request(self, blocks: List[int]):
        """Release a finished/preempted/cancelled request's blocks."""
        for blk in blocks:
            self._release(blk)

    # ------------------------------------------------------------- metrics

    def prefix_hit_rate(self) -> float:
        tot = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / tot if tot else 0.0

    def assert_quiescent(self):
        """Leak check: with no requests in flight every block is free or
        cached, and refcounts are empty."""
        assert not self.ref, f"leaked blocks with refs: {sorted(self.ref)}"
        assert len(self.free) + len(self.cached) == self.capacity, (
            f"block leak: {len(self.free)} free + {len(self.cached)} cached "
            f"!= {self.capacity}")
