"""DeviceRunner — the jitted device half of the serving engine.

Owns the batched decode state (slot caches, positions, per-slot done flags
and generation budgets) plus the two compiled programs:

* a bucketed batched prefill — one dispatch per admission group with the
  stats tap on, instead of B=1 sequential prefills;
* ``lm.decode_many`` — a ``lax.scan`` over ``decode_chunk`` decode steps
  with on-device sampling / EOS / budget / capacity masking, so the host
  sees ONE blocking transfer per chunk (a (B, K) token block + flags)
  instead of one per token per slot.  The engine's ``KernelConfig``
  (``kncfg``) is baked into this program as a static arg: with
  ``use_pallas=True`` every packed-weight matmul inside the scan dispatches
  the fused Pallas ``ttq_gemm``.

With a **paged** ``KVCacheConfig`` (DESIGN.md §8) the slot caches become
per-layer block pools plus a per-slot ``block_table``; admission scatters
the prefill's compact k/v into the slots' physical blocks (prefix-cache
hits prefill only the prompt *tail*, gathering the cached prefix from the
pool), and ``release_slots`` points finished/preempted slots at the sink
block 0 so their done-lane writes can never corrupt reallocated blocks.

``host_syncs`` counts blocking device→host transfers — the number
``benchmarks/bench_engine.py`` reports per generated token.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.quant.api import _path_str

from .blocks import SINK
from .sampling import sample


def _write_slots(batched, src, slots):
    """Write the rows of a batch-``n`` prefill state into slots ``slots`` of
    the batched decode state (stack leaves carry (R, B, ...); other leaves
    (B, ...)) — codes and scales alike for quantized cache layouts."""
    idx = jnp.asarray(slots, jnp.int32)

    def per(path, bl, sl):
        if _path_str(path).startswith("stack"):
            return bl.at[:, idx].set(sl.astype(bl.dtype))
        return bl.at[idx].set(sl.astype(bl.dtype))

    return jax.tree_util.tree_map_with_path(per, batched, src)


def _write_paged(pools, compact, phys, block_size: int):
    """Scatter a compact prefill state into the paged pools.

    pools: per-run {'u0': {leaf: (R, NB, Hkv, bs, D·)}};
    compact: same structure with (R, n, Hkv, Sb, D·) leaves (Sb = the
    group's padded tail bucket); phys: (n, nbw) int32 physical block per
    logical write block — pad blocks beyond the prompt point at the sink.
    """
    bs = block_size
    nbw = phys.shape[1]

    def per(pool, cl):
        R, n, Hkv, Sb, D = cl.shape
        pad = nbw * bs - Sb
        if pad:
            cl = jnp.pad(cl, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        blk = cl.reshape(R, n, Hkv, nbw, bs, D).transpose(0, 1, 3, 2, 4, 5)
        return pool.at[:, phys].set(blk.astype(pool.dtype))

    return jax.tree.map(per, pools, compact)


def _write_rows(batched, compact, slot: int, start: int, max_len: int):
    """Write a compact chunk state into rows ``[start, start + L)`` of one
    slot of the dense batched slab (chunked prefill, DESIGN.md §13).

    batched: per-run {'u0': {leaf: (R, B, Hkv, ML, ·)}}; compact: same
    structure with (R, 1, Hkv, C, ·) leaves.  ``L = min(C, max_len -
    start)`` — the final chunk's pad columns may overhang the slab; the
    dropped overhang holds pad garbage by construction.  Eager
    ``dynamic_update_slice`` with host-static offsets: no advanced-index
    normalization, no h2d."""
    def per(bl, cl):
        L = min(cl.shape[3], max_len - start)
        return jax.lax.dynamic_update_slice(
            bl, cl[:, :, :, :L].astype(bl.dtype), (0, slot, 0, start, 0))

    return jax.tree.map(per, batched, compact)


def _gather_pool(pool, ptab):
    """pool (R, NB, Hkv, bs, D·) + ptab (n, nbp) → (R, n, Hkv, nbp·bs, D·):
    the oracle's per-slot gather, vmapped over the leading layer dim so the
    two layouts can never drift apart."""
    from repro.kernels.ref import gather_paged_kv

    return jax.vmap(lambda p: gather_paged_kv(p, ptab))(pool)


class DeviceRunner:
    def __init__(self, cfg, ecfg, kvcfg, *, kncfg=None, pctx=None, key=None,
                 num_blocks: int = 0):
        self.cfg, self.ecfg, self.kvcfg, self.pctx = cfg, ecfg, kvcfg, pctx
        self.kncfg = kncfg                      # KernelConfig: packed-weight
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.paged = kvcfg is not None and kvcfg.paged
        self.num_blocks = num_blocks
        B, ML = ecfg.max_slots, ecfg.max_len
        K = max(1, ecfg.decode_chunk)           # 0 = auto, resolved upstream
        self.state = lm.init_decode_state(cfg, B, ML, kvcfg=kvcfg,
                                          num_blocks=num_blocks)
        self.pos = jnp.zeros((B,), jnp.int32)
        self.cur_tok = jnp.zeros((B, 1), jnp.int32)
        self.done = jnp.ones((B,), bool)        # empty slot = done lane
        self.remaining = jnp.zeros((B,), jnp.int32)
        self.host_syncs = 0                     # blocking device→host copies
        # fault isolation (DESIGN.md §12): with guards on, decode checks
        # per-step logit finiteness on device and reports a per-slot fault
        # mask; the poison lane is the deterministic injection site
        # (serving/faults.py) — all-False outside fault-injection runs
        self.detect_faults = bool(getattr(ecfg, "guards", False))
        self._poison = jnp.zeros((B,), bool) if self.detect_faults else None
        # device-resident constants so steady-state lane updates stay free of
        # implicit host→device transfers (jax.transfer_guard("disallow")
        # clean — see tests/test_runtime_guards.py)
        self._zero = jnp.asarray(0, jnp.int32)
        self._sink = jnp.asarray(SINK, jnp.int32)
        self._maxlen = jnp.asarray(ML, jnp.int32)
        # mesh serving: commit the decode state to its canonical layout (KV
        # heads on the model axis; paged pools shard heads, never blocks) and
        # the scalar lanes replicated.  The shardings are cached so admission
        # epilogues can re-pin — the decode jit must only ever see ONE
        # input-sharding signature (DESIGN.md §"Mesh-sharded serving").
        if pctx is not None and pctx.mesh is not None:
            from repro.parallel.rules import state_sharding
            self._state_shardings = state_sharding(self.state, pctx,
                                                   paged=self.paged)
            self._rep = jax.sharding.NamedSharding(
                pctx.mesh, jax.sharding.PartitionSpec())
            self.state = jax.tree.map(jax.device_put, self.state,
                                      self._state_shardings)
            self._zero = jax.device_put(self._zero, self._rep)
            self._sink = jax.device_put(self._sink, self._rep)
            self._maxlen = jax.device_put(self._maxlen, self._rep)
            if self._poison is not None:
                self._poison = jax.device_put(self._poison, self._rep)
        else:
            self._state_shardings = None
            self._rep = None
        self._repin()
        out_kw = {}
        if self._state_shardings is not None:
            rep = self._rep
            ys = (rep, rep, rep) if self.detect_faults else (rep, rep)
            out_kw["out_shardings"] = (ys,
                                       (self._state_shardings,
                                        rep, rep, rep, rep, rep))
        self._out_kw = out_kw
        self._decode_jit = jax.jit(partial(
            lm.decode_many, cfg, pctx=pctx, kvcfg=kvcfg, kcfg=kncfg,
            K=K, max_len=ML, detect_faults=self.detect_faults,
            temperature=ecfg.temperature, eos_token=ecfg.eos_token), **out_kw)
        # degradation ladder rung 2 (DESIGN.md §12): a K=1 decode program,
        # built lazily on the first degradation — small chunks bound the
        # wasted-work exposure when the pool is starving
        self._decode_small = None
        # self-speculative decode (DESIGN.md §11): K draft/verify windows of
        # W drafted tokens per dispatch; one program alongside decode_many —
        # the engine picks per block by passing (or not) a draft tree
        self._spec_jit = None
        W = getattr(ecfg, "speculate_k", 0)
        if W > 0:
            self._spec_jit = jax.jit(partial(
                lm.speculate_many, cfg, pctx=pctx, kvcfg=kvcfg, kcfg=kncfg,
                K=K, W=W, max_len=ML, detect_faults=self.detect_faults,
                eos_token=ecfg.eos_token), **out_kw)
        # acceptance telemetry (host math over the per-chunk token block)
        self.spec_windows = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self._prefill_jit = jax.jit(partial(lm.prefill, cfg, pctx=pctx,
                                            collect_stats=True,
                                            full_logits=True, kvcfg=kvcfg),
                                    static_argnames=("max_len",
                                                     "compact_state"))

    def place_params(self, params):
        """Device placement for a parameter tree (fp at engine init, or a
        freshly quantized tree): mesh-sharded per ``parallel/rules.py`` when
        a mesh is active, otherwise untouched (jax default placement).  Lives
        on the runner because device allocation belongs to the runner
        (tracecheck TC402/TC405)."""
        if self.pctx is None or self.pctx.mesh is None:
            return params
        from repro.parallel.rules import shard_params
        return shard_params(params, self.pctx)

    def _repin(self):
        """Pin the slot lanes (and, after admission writes, the decode state)
        back to their canonical shardings.  Explicit ``device_put`` — legal
        under ``jax.transfer_guard("disallow")`` and a no-op when the layout
        already matches — so eager admission scatters can never drift the
        decode jit's input shardings into a recompile ping-pong."""
        if self._state_shardings is None:
            return
        self.state = jax.tree.map(jax.device_put, self.state,
                                  self._state_shardings)
        self.pos = jax.device_put(self.pos, self._rep)
        self.cur_tok = jax.device_put(self.cur_tok, self._rep)
        self.done = jax.device_put(self.done, self._rep)
        self.remaining = jax.device_put(self.remaining, self._rep)
        self.key = jax.device_put(self.key, self._rep)
        if self._poison is not None:
            self._poison = jax.device_put(self._poison, self._rep)

    @property
    def compiled_programs(self) -> int:
        """Programs resident in this runner's jit caches: the fused decode,
        the batched prefill (one entry per admission bucket shape), and the
        module-level prefix gather.  The engine's ``compiled_programs``
        facade adds the requant plan; benchmarks gate on the steady-state
        delta being zero."""
        n = (self._decode_jit._cache_size()
             + self._prefill_jit._cache_size()
             + _gather_prefix._cache_size()
             + _gather_dense_prefix._cache_size())
        if self._spec_jit is not None:
            n += self._spec_jit._cache_size()
        if self._decode_small is not None:
            n += self._decode_small._cache_size()
        return n

    def set_poison(self, slots):
        """Arm the decode-logits fault-injection site: lanes in ``slots``
        get NaN logits on every step of the next decode block
        (``lm.decode_many``'s ``poison`` input — DESIGN.md §12).  Only
        callable with guards on (the fault-detecting decode program); the
        mask crosses via one explicit ``device_put``, so injection runs
        stay transfer-guard clean."""
        if self._poison is None:
            raise RuntimeError("fault injection needs EngineConfig.guards")
        mask_h = np.zeros((self.ecfg.max_slots,), bool)
        mask_h[list(slots)] = True
        self._poison = jax.device_put(mask_h) if self._rep is None \
            else jax.device_put(mask_h, self._rep)

    # -------------------------------------------------------------- admission

    def _assemble(self, reqs, bucket: int, prefix_len: int):
        """Host-side token assembly: one transfer, tail tokens only."""
        toks_h = np.zeros((len(reqs), bucket), np.int32)
        for i, req in enumerate(reqs):
            tail = req.prompt[prefix_len:]
            toks_h[i, :len(tail)] = tail
        return jnp.asarray(toks_h)

    def admit_group(self, params, group, frames=None):
        """One bucketed prefill dispatch for ``len(group.slots)`` prompts.

        Right-pads every prompt to ``group.bucket`` (causal masking keeps the
        real tokens clean; pad positions beyond a prompt's end are never
        attended at decode — decode overwrites them), runs ONE batched
        prefill with the stats tap on, samples each row's first token, and
        writes each row's cache into its slot.

        Paged groups share a ``prefix_len``: the batch holds only the prompt
        *tails* (the cached prefix is gathered from the pool and attended at
        offset ``prefix_len``), and the compact prefill k/v is scattered
        into each slot's physical blocks.

        Returns ``(first_tokens (n,), finished (n,), stats)`` — the first two
        as host arrays (one sync for the whole group); ``finished[i]`` marks
        a request already over at admission (budget of 1, EOS on the first
        token, or a prompt that fills the cache exactly).

        Encoder-decoder requests carry per-request ``frames``; staging them
        onto the device happens *here* (not in the engine facade) — all
        array allocation belongs to the runner.
        """
        if frames is None and self.cfg.family == "encdec":
            frames = jnp.stack([
                jnp.asarray(r.frames) if r.frames.ndim == 2
                else jnp.asarray(r.frames)[0] for r in group.requests])
        if self.paged:
            return self._admit_group_paged(params, group, frames)
        batch = {"tokens": self._assemble(group.requests, group.bucket, 0)}
        if frames is not None:
            batch["frames"] = frames
        logits, sstate, stats = self._prefill_jit(params, batch,
                                                  max_len=self.ecfg.max_len)
        reqs = group.requests
        plens_h = np.asarray([len(r.prompt) for r in reqs], np.int32)
        last = jnp.take_along_axis(logits,
                                   jnp.asarray(plens_h - 1)[:, None, None],
                                   axis=1)[:, 0]
        self.state = _write_slots(self.state, sstate, group.slots)
        first_h, fin_h = self._finish_admission(group.slots, reqs, last,
                                                plens_h)
        return first_h, fin_h, stats

    def _finish_admission(self, slots, reqs, last, plens_h):
        """Shared admission epilogue: sample each row's first token, arm the
        slot lanes (pos/cur_tok/budget/done — a request can be over already:
        budget of 1, EOS first token, or a cache-filling prompt), and pull
        the one host sync for the group.

        Only ``first`` crosses the device boundary: prompt lengths and
        budgets are host-known, so the finished mask is host math — the
        old device-side ``fin`` cost an extra h2d of host-derived operands
        plus their d2h round trip for data the host already had."""
        ecfg = self.ecfg
        self.key, sk = jax.random.split(self.key)
        first = sample(last, sk, ecfg.temperature)
        idx = jnp.asarray(slots, jnp.int32)
        budget_h = np.asarray([r.remaining for r in reqs], np.int32) - 1
        self.pos = self.pos.at[idx].set(jnp.asarray(plens_h))  # decode
        self.cur_tok = self.cur_tok.at[idx].set(first[:, None])  # overwrites
        self.remaining = self.remaining.at[idx].set(jnp.asarray(budget_h))
        first_h = jax.device_get(first)  # tracecheck: ok[TC103] one designed
        #                                  sync per admission group
        fin_h = ((plens_h >= ecfg.max_len) | (budget_h <= 0)
                 | (first_h == ecfg.eos_token))
        self.done = self.done.at[idx].set(jnp.asarray(fin_h))
        self.host_syncs += 1
        self._repin()                    # admission writes → canonical layout
        return first_h, fin_h

    def _admit_group_paged(self, params, group, frames=None):
        ecfg, kvcfg = self.ecfg, self.kvcfg
        bs = kvcfg.block_size
        slots, reqs = group.slots, group.requests
        n, bucket, pfx = len(reqs), group.bucket, group.prefix_len
        batch = {"tokens": self._assemble(reqs, bucket, pfx)}
        if frames is not None:
            batch["frames"] = frames
        prefix_kv = None
        if pfx:
            nbp = pfx // bs
            ptab = jnp.asarray([[r.blocks[j] for j in range(nbp)]
                                for r in reqs], jnp.int32)
            prefix_kv = _gather_prefix(self.state["stack"], ptab, kvcfg)
        logits, sstate, stats = self._prefill_jit(
            params, batch, max_len=ecfg.max_len, prefix_kv=prefix_kv,
            pos0=pfx)
        tlens = jnp.asarray([len(r.prompt) - pfx for r in reqs], jnp.int32)
        last = jnp.take_along_axis(logits, (tlens - 1)[:, None, None],
                                   axis=1)[:, 0]
        # scatter the compact tail k/v into each slot's physical blocks;
        # pad blocks past the prompt (and any logical block the request
        # never owns) write to the sink
        nbw = -(-bucket // bs)
        pb0 = pfx // bs
        phys = np.full((n, nbw), SINK, np.int32)
        for i, r in enumerate(reqs):
            plen = len(r.prompt)
            for j in range(nbw):
                lb = pb0 + j
                if lb * bs < plen and lb < len(r.blocks):
                    phys[i, j] = r.blocks[lb]
        self.state["stack"] = _write_paged(self.state["stack"],
                                           sstate["stack"],
                                           jnp.asarray(phys), bs)
        # per-slot block-table rows (unowned entries stay at the sink)
        nblk = ecfg.max_len // bs
        rows = np.full((n, nblk), SINK, np.int32)
        for i, r in enumerate(reqs):
            rows[i, :len(r.blocks)] = r.blocks
        idx = jnp.asarray(slots, jnp.int32)
        self.state["block_table"] = \
            self.state["block_table"].at[idx].set(jnp.asarray(rows))
        plens_h = np.asarray([len(r.prompt) for r in reqs], np.int32)
        first_h, fin_h = self._finish_admission(slots, reqs, last, plens_h)
        return first_h, fin_h, stats

    def release_slots(self, slots):
        """Deactivate slots whose requests finished / were preempted or
        cancelled: done lane on, budget zeroed, and (paged) the block-table
        row pointed at the sink so the lane's clamped writes can never land
        in blocks the allocator has handed to someone else.

        Also the *parking* primitive for mid-chunked-prefill lanes
        (DESIGN.md §13): ``pos`` is pushed to ``max_len`` so a parked
        lane's done-lane garbage writes clamp to row ``max_len - 1`` —
        a row no chunk's prefix gather ever reads (gathers stop strictly
        before the prompt's last token) and every armed lane overwrites
        before reading.  Dense slabs need this; paged lanes are already
        safe via the sink row.

        Runs mid-decode (a request can finish inside the steady-state
        loop), so the slot set crosses via one explicit ``device_put`` and
        the updates are masked ``where``s over device-resident constants —
        transfer-guard clean.  (An ``.at[idx].set`` scatter would NOT be:
        eager advanced-index normalization compares the index array against
        a host scalar, an implicit h2d the guard rejects.)"""
        mask_h = np.zeros((self.ecfg.max_slots,), bool)
        mask_h[list(slots)] = True
        mask = jax.device_put(mask_h) if self._rep is None \
            else jax.device_put(mask_h, self._rep)
        self.done = jnp.logical_or(self.done, mask)
        self.remaining = jnp.where(mask, self._zero, self.remaining)
        self.pos = jnp.where(mask, self._maxlen, self.pos)
        if self.paged:
            self.state["block_table"] = jnp.where(
                mask[:, None], self._sink, self.state["block_table"])

    # -------------------------------------------------------- chunked prefill

    def prefill_chunk(self, params, plan):
        """One chunked-prefill dispatch (DESIGN.md §13): ingest prompt rows
        ``[start, start + length)`` of one request into its parked slot.

        The chunk is padded to the fixed ``prefill_chunk`` width (shape
        stability: one prefill program per distinct prefix length, not per
        tail length) and attends to the already-resident rows as tail-
        prefill context — gathered from the slot's physical blocks (paged)
        or its slab rows (dense), exactly the prefix-cache mechanics of
        DESIGN.md §8 with ``pos0 = start``.  Pad columns are causally
        masked during the chunk and land past the prompt point (sink
        blocks / overwritten-before-read slab rows), so they never
        contaminate later reads.

        Non-final chunks return ``(None, None, stats)`` — the lane stays
        parked.  The final chunk runs the shared admission epilogue:
        samples the first token from the last *real* row's logits, installs
        the (paged) block-table row, arms the lane, and returns
        ``(first (1,), finished (1,), stats)`` host arrays."""
        ecfg, kvcfg = self.ecfg, self.kvcfg
        req, slot = plan.req, plan.slot
        C = ecfg.prefill_chunk
        start, n = plan.start, plan.length
        toks_h = np.zeros((1, C), np.int32)
        toks_h[0, :n] = req.prompt[start:start + n]
        batch = {"tokens": jnp.asarray(toks_h)}
        prefix_kv = None
        if start:
            if self.paged:
                nbp = start // kvcfg.block_size
                ptab = jnp.asarray([req.blocks[:nbp]], jnp.int32)
                prefix_kv = _gather_prefix(self.state["stack"], ptab, kvcfg)
            else:
                prefix_kv = _gather_dense_prefix(
                    self.state["stack"], jnp.asarray([slot], jnp.int32),
                    pfx=start, kvcfg=kvcfg)
        logits, sstate, stats = self._prefill_jit(
            params, batch, max_len=ecfg.max_len, prefix_kv=prefix_kv,
            pos0=start, compact_state=True)
        if self.paged:
            bs = kvcfg.block_size
            nbw = C // bs                    # C % bs == 0 (engine-validated)
            pb0 = start // bs
            end = start + n
            phys = np.full((1, nbw), SINK, np.int32)
            for j in range(nbw):
                lb = pb0 + j
                if lb * bs < end and lb < len(req.blocks):
                    phys[0, j] = req.blocks[lb]
            self.state["stack"] = _write_paged(self.state["stack"],
                                               sstate["stack"],
                                               jnp.asarray(phys), bs)
        else:
            self.state["stack"] = _write_rows(self.state["stack"],
                                              sstate["stack"], slot, start,
                                              ecfg.max_len)
        if not plan.final:
            self._repin()                   # chunk writes → canonical layout
            return None, None, stats
        last = logits[:, n - 1]             # last real row's logits
        if self.paged:
            nblk = ecfg.max_len // kvcfg.block_size
            rows = np.full((1, nblk), SINK, np.int32)
            rows[0, :len(req.blocks)] = req.blocks
            idx = jnp.asarray([slot], jnp.int32)
            self.state["block_table"] = \
                self.state["block_table"].at[idx].set(jnp.asarray(rows))
        plens_h = np.asarray([len(req.prompt)], np.int32)
        first_h, fin_h = self._finish_admission([slot], [req], last, plens_h)
        return first_h, fin_h, stats

    # ----------------------------------------------------------------- decode

    def _small_decode_jit(self):
        """Lazy K=1 decode program for degradation-ladder rung 2 — one
        compile at the first degradation, cached (and counted) afterwards,
        so an oscillating ladder never grows the jit caches."""
        if self._decode_small is None:
            ecfg = self.ecfg
            self._decode_small = jax.jit(partial(
                lm.decode_many, self.cfg, pctx=self.pctx, kvcfg=self.kvcfg,
                kcfg=self.kncfg, K=1, max_len=ecfg.max_len,
                detect_faults=self.detect_faults,
                temperature=ecfg.temperature, eos_token=ecfg.eos_token),
                **self._out_kw)
        return self._decode_small

    def decode_block(self, params, draft_params=None, small_chunk=False):
        """Run one fused decode dispatch over every slot.

        Default: ``decode_chunk`` scanned decode steps (``lm.decode_many``).
        With ``draft_params`` (and ``EngineConfig.speculate_k`` > 0): the
        self-speculative program instead — ``decode_chunk`` draft/verify
        windows of ``speculate_k`` drafted tokens each (DESIGN.md §11), so
        the block widens to ``K·(speculate_k+1)`` candidate columns with the
        per-window acceptance length folded into ``valid``.
        ``small_chunk`` (degradation-ladder rung 2, DESIGN.md §12) swaps in
        the K=1 program; the engine only sets it after it has already
        dropped speculation (rung 1), so the two flags never combine.

        Returns host copies ``(tokens (B, cols), valid (B, cols), done (B,),
        fault (B,) | None)`` — one blocking transfer for the whole block
        either way; ``fault`` is None with guards off and marks lanes whose
        logits went non-finite otherwise (the lane emitted nothing from the
        faulting step on — the scheduler fails just that request).
        """
        spec = draft_params is not None and self._spec_jit is not None \
            and not small_chunk
        if spec:
            args = (draft_params, params, self.state, self.cur_tok, self.pos,
                    self.done, self.remaining, self.key)
            fn = self._spec_jit
        else:
            fn = self._small_decode_jit() if small_chunk else self._decode_jit
            args = (params, self.state, self.cur_tok, self.pos, self.done,
                    self.remaining, self.key)
        if self.detect_faults:
            (toks, valid, fault), carry = fn(*args, self._poison)
        else:
            (toks, valid), carry = fn(*args)
            fault = None
        (self.state, self.cur_tok, self.pos, self.done, self.remaining,
         self.key) = carry
        self.host_syncs += 1
        fetch = ((toks, valid, self.done) if fault is None
                 else (toks, valid, self.done, fault))
        out = jax.device_get(fetch)              # the ONE designed sync/chunk
        if fault is None:
            out = out + (None,)
        if spec:
            W = self.ecfg.speculate_k
            v = np.asarray(out[1]).reshape(out[1].shape[0], -1, W + 1)
            live = v[:, :, 0]                     # a live window always emits
            emitted = v.sum(axis=2)
            self.spec_windows += int(live.sum())
            self.spec_drafted += int(live.sum()) * W
            self.spec_accepted += int(np.maximum(emitted - 1, 0).sum())
        return out


@partial(jax.jit, static_argnames=("kvcfg",))
def _gather_prefix(stack_state, ptab, kvcfg):
    """Materialize the shared-prefix k/v for a tail prefill: per run, gather
    ``ptab``'s (n, nbp) physical blocks from each layer's pool and (for
    quantized layouts) dequantize to f32 — the same values (and dtype) the
    tail's quantize→dequantize attention read uses, so warm and cold
    prefills see one consistent context.  (k, v) arrays (R, n, Hkv, P, ·),
    post-rope, ready to ride the layer scan as xs."""
    from repro.core.kvquant import dequantize_kv

    out = []
    for run in stack_state:
        st = run["u0"]
        if "k" in st:
            kv = (_gather_pool(st["k"], ptab), _gather_pool(st["v"], ptab))
        else:
            kv = tuple(
                dequantize_kv(_gather_pool(st[nm + "_q"], ptab),
                              _gather_pool(st[nm + "_s"], ptab),
                              jnp.float32, bits=kvcfg.bits,
                              group_size=kvcfg.group_size)
                for nm in ("k", "v"))
        out.append(kv)
    return out


@partial(jax.jit, static_argnames=("pfx", "kvcfg"))
def _gather_dense_prefix(stack_state, slot, pfx, kvcfg):
    """Materialize one slot's first ``pfx`` dense-slab rows as tail-prefill
    context — the dense twin of :func:`_gather_prefix` for chunked prefill
    (DESIGN.md §13): chunk N attends the rows chunks < N wrote.  Quantized
    layouts dequantize to f32, matching the QDQ values the chunk's own
    attention read uses, so every chunk sees one consistent context.
    ``slot``: (1,) int32.  Returns per-run (k, v) arrays (R, 1, Hkv, pfx, ·),
    post-rope, ready to ride the layer scan as xs."""
    from repro.core.kvquant import dequantize_kv

    out = []
    for run in stack_state:
        st = run["u0"]
        if "k" in st:
            kv = (st["k"][:, slot, :, :pfx], st["v"][:, slot, :, :pfx])
        else:
            kv = tuple(
                dequantize_kv(st[nm + "_q"][:, slot, :, :pfx],
                              st[nm + "_s"][:, slot, :, :pfx],
                              jnp.float32, bits=kvcfg.bits,
                              group_size=kvcfg.group_size)
                for nm in ("k", "v"))
        out.append(kv)
    return out
