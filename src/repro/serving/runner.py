"""DeviceRunner — the jitted device half of the serving engine.

Owns the batched decode state (slot caches, positions, per-slot done flags
and generation budgets) plus the two compiled programs:

* a bucketed batched prefill — one dispatch per admission group with the
  stats tap on, instead of B=1 sequential prefills;
* ``lm.decode_many`` — a ``lax.scan`` over ``decode_chunk`` decode steps
  with on-device sampling / EOS / budget / capacity masking, so the host
  sees ONE blocking transfer per chunk (a (B, K) token block + flags)
  instead of one per token per slot.  The engine's ``KernelConfig``
  (``kncfg``) is baked into this program as a static arg: with
  ``use_pallas=True`` every packed-weight matmul inside the scan dispatches
  the fused Pallas ``ttq_gemm``.

``host_syncs`` counts blocking device→host transfers — the number
``benchmarks/bench_engine.py`` reports per generated token.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.quant.api import _path_str

from .sampling import sample


def _write_slots(batched, src, slots):
    """Write the rows of a batch-``n`` prefill state into slots ``slots`` of
    the batched decode state (stack leaves carry (R, B, ...); other leaves
    (B, ...)) — codes and scales alike for quantized cache layouts."""
    idx = jnp.asarray(slots, jnp.int32)

    def per(path, bl, sl):
        if _path_str(path).startswith("stack"):
            return bl.at[:, idx].set(sl.astype(bl.dtype))
        return bl.at[idx].set(sl.astype(bl.dtype))

    return jax.tree_util.tree_map_with_path(per, batched, src)


class DeviceRunner:
    def __init__(self, cfg, ecfg, kvcfg, *, kncfg=None, pctx=None, key=None):
        self.cfg, self.ecfg, self.kvcfg, self.pctx = cfg, ecfg, kvcfg, pctx
        self.kncfg = kncfg                      # KernelConfig: packed-weight
        self.key = key if key is not None else jax.random.PRNGKey(0)
        B, ML = ecfg.max_slots, ecfg.max_len
        K = max(1, ecfg.decode_chunk)           # 0 = auto, resolved upstream
        self.state = lm.init_decode_state(cfg, B, ML, kvcfg=kvcfg)
        self.pos = jnp.zeros((B,), jnp.int32)
        self.cur_tok = jnp.zeros((B, 1), jnp.int32)
        self.done = jnp.ones((B,), bool)        # empty slot = done lane
        self.remaining = jnp.zeros((B,), jnp.int32)
        self.host_syncs = 0                     # blocking device→host copies
        self._decode_jit = jax.jit(partial(
            lm.decode_many, cfg, pctx=pctx, kvcfg=kvcfg, kcfg=kncfg,
            K=K, max_len=ML,
            temperature=ecfg.temperature, eos_token=ecfg.eos_token))
        self._prefill_jit = jax.jit(partial(lm.prefill, cfg, pctx=pctx,
                                            collect_stats=True,
                                            full_logits=True, kvcfg=kvcfg),
                                    static_argnames=("max_len",))

    # -------------------------------------------------------------- admission

    def admit_group(self, params, group, frames=None):
        """One bucketed prefill dispatch for ``len(group.slots)`` prompts.

        Right-pads every prompt to ``group.bucket`` (causal masking keeps the
        real tokens clean; pad positions beyond a prompt's end are never
        attended at decode — decode overwrites them), runs ONE batched
        prefill with the stats tap on, samples each row's first token, and
        writes each row's cache into its slot.

        Returns ``(first_tokens (n,), finished (n,), stats)`` — the first two
        as host arrays (one sync for the whole group); ``finished[i]`` marks
        a request already over at admission (budget of 1, EOS on the first
        token, or a prompt that fills the cache exactly).
        """
        import numpy as np

        ecfg = self.ecfg
        slots, reqs = group.slots, group.requests
        n, bucket = len(reqs), group.bucket
        toks_h = np.zeros((n, bucket), np.int32)   # assemble on host: one
        for i, req in enumerate(reqs):             # transfer, not n dispatches
            toks_h[i, :len(req.prompt)] = req.prompt
        batch = {"tokens": jnp.asarray(toks_h)}
        if frames is not None:
            batch["frames"] = frames
        logits, sstate, stats = self._prefill_jit(params, batch,
                                                  max_len=ecfg.max_len)
        plens = jnp.asarray([len(r.prompt) for r in reqs], jnp.int32)
        last = jnp.take_along_axis(logits, (plens - 1)[:, None, None],
                                   axis=1)[:, 0]
        self.key, sk = jax.random.split(self.key)
        first = sample(last, sk, ecfg.temperature)
        idx = jnp.asarray(slots, jnp.int32)
        self.state = _write_slots(self.state, sstate, slots)
        self.pos = self.pos.at[idx].set(plens)  # decode overwrites pads
        self.cur_tok = self.cur_tok.at[idx].set(first[:, None])
        budget = jnp.asarray([r.max_new for r in reqs], jnp.int32) - 1
        fin = ((plens >= ecfg.max_len) | (budget <= 0)
               | (first == ecfg.eos_token))
        self.remaining = self.remaining.at[idx].set(budget)
        self.done = self.done.at[idx].set(fin)
        self.host_syncs += 1
        first_h, fin_h = jax.device_get((first, fin))
        return first_h, fin_h, stats

    # ----------------------------------------------------------------- decode

    def decode_block(self, params):
        """Run ``decode_chunk`` fused decode steps over every slot.

        Returns host copies ``(tokens (B, K), valid (B, K), done (B,))`` —
        one blocking transfer for the whole block."""
        (toks, valid), carry = self._decode_jit(
            params, self.state, self.cur_tok, self.pos, self.done,
            self.remaining, self.key)
        (self.state, self.cur_tok, self.pos, self.done, self.remaining,
         self.key) = carry
        self.host_syncs += 1
        return jax.device_get((toks, valid, self.done))
