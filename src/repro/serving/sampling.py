"""Token sampling."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, key=None, temperature: float = 0.0, top_k: int = 0):
    """logits (B, V) → (B,) int32. temperature 0 → greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(lg, top_k)
        lg = jnp.where(lg < vals[..., -1:], -jnp.inf, lg)
    return jax.random.categorical(key, lg).astype(jnp.int32)
