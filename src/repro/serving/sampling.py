"""Token sampling — public re-export.

The implementation lives in :mod:`repro.models.common` (``sample_logits``)
so the fused on-device decode loop (``lm.decode_many``) can sample inside
its ``lax.scan`` without a models → serving import cycle.
"""
from __future__ import annotations

from repro.models.common import sample_logits as sample

__all__ = ["sample"]
