"""Sharded checkpointing with atomic commit, keep-N, and mesh resharding.

Layout:  <dir>/step_<n>/   arrays.npz  (flattened path → array)
                           manifest.json (paths, shapes, dtypes, step)
         <dir>/step_<n>.COMMITTED      (atomic marker, written last)

Restore is mesh-agnostic: arrays are loaded on host and ``device_put`` with
the *target* sharding — a checkpoint written on mesh A restores onto mesh B
(elastic scaling / failure replacement without full-fleet restart).

This container is single-process; on a real fleet each host writes its own
``arrays.<host>.npz`` of local shards (addressable_shards) and the manifest
carries the global shape — the code paths are the same modulo the per-host
slice bookkeeping, noted inline.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(p.idx) if isinstance(p, jax.tree_util.SequenceKey)
            else str(p) for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _marker(self, step: int) -> str:
        return self._step_dir(step) + ".COMMITTED"

    def save(self, step: int, tree) -> str:
        flat = _flatten(tree)

        def host(v):
            a = np.asarray(v)
            if a.dtype.kind not in "biufc":      # bf16 etc. → exact f32 widen
                a = a.astype(np.float32)
            return a

        arrays = {k: host(v) for k, v in flat.items()}
        # On multi-host: np.asarray over v.addressable_shards + host suffix.
        tmp = tempfile.mkdtemp(dir=self.dir)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in arrays.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                        # atomic on same fs
        with open(self._marker(step), "w") as f:
            f.write("ok")                            # commit marker last
        self._gc()
        return final

    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".COMMITTED"):
                s = int(name.split("_")[1])
                if os.path.exists(self._marker(s)):
                    out.append(s)
        return sorted(out)

    def latest_step(self):
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like):
        """Restore into the structure (and shardings) of ``like``."""
        with np.load(os.path.join(self._step_dir(step), "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten_like(like, flat)

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
            try:
                os.remove(self._marker(s))
            except OSError:
                pass


def _unflatten_like(like, flat: dict):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(p.idx) if isinstance(p, jax.tree_util.SequenceKey)
            else str(p) for p in path)
        arr = flat[key]
        val = jnp.asarray(arr).astype(leaf.dtype)
        sh = getattr(leaf, "sharding", None)
        if sh is not None and hasattr(sh, "mesh"):
            val = jax.device_put(val, sh)            # reshard to target mesh
        out.append(val)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def reshard_restore(manager: CheckpointManager, step: int, like_tree,
                    target_shardings):
    """Elastic scaling: restore a checkpoint onto a *different* mesh.

    ``target_shardings`` mirrors ``like_tree`` with NamedShardings built on
    the new mesh.
    """
    with np.load(os.path.join(manager._step_dir(step), "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    shard_leaves = jax.tree_util.tree_leaves(target_shardings)
    out = []
    for (path, leaf), sh in zip(leaves, shard_leaves):
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(p.idx) if isinstance(p, jax.tree_util.SequenceKey)
            else str(p) for p in path)
        out.append(jax.device_put(jnp.asarray(flat[key]).astype(leaf.dtype), sh))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like_tree), out)
