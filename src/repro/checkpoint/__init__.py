from .manager import CheckpointManager, reshard_restore

__all__ = ["CheckpointManager", "reshard_restore"]
