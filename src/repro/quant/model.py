"""QuantizedModel — the calibrate → requantize → decode_params facade.

Owns everything the TTQ lifecycle needs around a parameter tree:

* a :class:`~repro.quant.session.CalibrationSession` accumulating the live
  workload's activation statistics (decay, fork/merge for multi-stream),
* the data-free low-rank factor tree (computed **once**; requantization
  reuses it — no per-requant SVD),
* the current quantized parameter tree and a requantization counter,
* the :class:`~repro.quant.api.FusedRequantPlan` — requantization runs as
  one jitted device program per weight family (built lazily on the first
  requantize, reused afterwards) instead of an eager per-leaf ``tree_map``,
* the **delta gate**: ``requantize(threshold=…)`` re-quantizes only layers
  whose activation diagonal D drifted (relative L2) beyond the threshold
  since their last snapshot, reusing the previous
  :class:`~repro.core.ttq.QuantizedTensor` elsewhere.

Typical serving loop::

    qm = QuantizedModel(params, policy, halflife=ecfg.stats_halflife)
    ...
    qm.calibrate(prefill_stats, tokens=n_prefill_tokens)
    qm.requantize()                      # async: a handful of device programs
    logits = decode(qm.decode_params, ...)

Requantization never blocks the host: the family programs are
async-dispatched and the returned tree holds device futures — subsequent
decode work is *enqueued* behind them, not waited on.  With
``double_buffer=True`` the swap is additionally gated on device readiness:
``decode_params`` keeps returning the previous tree until every leaf of the
new one reports ``is_ready()``, so queued decode blocks keep hitting the old
weights while the requant runs.  That makes emitted tokens depend on device
timing (how many chunks land before the swap), so it is an explicit opt-in —
the default swaps deterministically at the requantize call.

Multi-stream: ``child = qm.fork()`` shares params and low-rank factors but
gets an independent calibration session; join with
``qm.adopt(child.session)`` (exact — the statistics are additive).
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro.core.awq import AWQConfig
from repro.core.policy import QuantPolicy

from .api import FusedRequantPlan, lowrank_tree, quantize_params
from .guards import GuardConfig, qt_health
from .session import CalibrationSession


_AUTO = object()   # sentinel: compute the low-rank tree from the policy


class QuantizedModel:
    def __init__(self, params: Any, policy: QuantPolicy, *,
                 acfg: Optional[AWQConfig] = None, halflife: float = 0.0,
                 session: Optional[CalibrationSession] = None,
                 lowrank: Any = _AUTO, fused: bool = True,
                 double_buffer: bool = False, pctx=None,
                 draft_policy: Optional[QuantPolicy] = None,
                 health_gate: Optional[GuardConfig] = None):
        self.params = params
        self.policy = policy
        self.acfg = acfg
        self.fused = fused
        self.double_buffer = double_buffer
        self.pctx = pctx                 # mesh → shard-local requant plans
        self.session = session if session is not None else \
            CalibrationSession(halflife=halflife)
        if lowrank is _AUTO:
            self.lowrank_tree = lowrank_tree(params, policy) \
                if policy.any_enabled else None
        else:
            self.lowrank_tree = lowrank
        self.qparams = None
        self.n_requants = 0
        # fused-plan state (lazy: the plan needs a concrete stats structure)
        self._plan: Optional[FusedRequantPlan] = None
        self._plan_key = None
        self._qt_by_path: dict = {}      # path_str → last QuantizedTensor
        self._last_D: dict = {}          # path_str → (lead..., d) f32 snapshot
        self._pending = None             # double buffer: not-yet-ready tree
        # requant health gate (DESIGN.md §12): with a GuardConfig, every
        # candidate tree is validated (finite scales/zero/D⁻¹, bounded D⁻¹
        # drift) BEFORE it can reach a swap or refresh the delta-gate
        # snapshots; rejections keep serving the last-good tree
        self.health_gate = health_gate
        self.requant_rejections = 0
        self.last_health_drift = 0.0
        self._fault_hook = None          # designated injection site: called
                                         # with the candidate tree pre-
                                         # validation (serving/faults.py)
        # self-speculative draft tree (DESIGN.md §11): a second quantized
        # tree from the SAME calibration snapshot.  None → no draft tree;
        # a disabled draft policy (e.g. NO_QUANT) keeps draft_params on the
        # fp weights while the verify tree quantizes normally.
        self.draft_policy = draft_policy
        self._draft_enabled = (draft_policy is not None
                               and draft_policy.any_enabled)
        if self._draft_enabled and not fused:
            raise ValueError("draft_policy (self-speculative decoding) needs "
                             "the fused requant plan; construct "
                             "QuantizedModel(fused=True) (the default)")
        self.draft_lowrank_tree = lowrank_tree(params, draft_policy) \
            if self._draft_enabled and draft_policy.rank > 0 else None
        self.draft_qparams = None
        self._draft_plan: Optional[FusedRequantPlan] = None
        self._draft_qt_by_path: dict = {}
        self._draft_last_D: dict = {}
        self._draft_pending = None
        # delta-gate accounting (read by the engine / serve summary;
        # verify-tree counts — the draft tree gates with its own snapshots)
        self.last_requant_layers = 0
        self.last_skipped_layers = 0
        self.total_requant_layers = 0
        self.total_skipped_layers = 0

    # -------------------------------------------------------------- lifecycle

    def calibrate(self, stats: Any, tokens: float,
                  provenance: tuple = ()) -> "QuantizedModel":
        """Fold one prefill's activation statistics into the session.
        ``provenance`` (request ids) rides into the quarantine log when a
        guarded session rejects the update."""
        self.session.update(stats, tokens, provenance=provenance)
        return self

    def _active(self) -> bool:
        from .registry import get_quantizer
        pols = [self.policy] + \
            ([self.draft_policy] if self._draft_enabled else [])
        active = [q for pol in pols for q in map(get_quantizer,
                                                 pol.methods()) if q.enabled]
        if not active:
            return False
        if not self.session.calibrated and all(q.requires_stats
                                               for q in active):
            return False
        return True

    @property
    def compiled_programs(self) -> int:
        """Jit-cache entries of the fused requant plan(s) (0 before the first
        requant builds them; draft + verify trees sum — the ≤2× budget of
        DESIGN.md §11)."""
        n = self._plan.compiled_programs if self._plan is not None else 0
        if self._draft_plan is not None:
            n += self._draft_plan.compiled_programs
        return n

    def _ensure_plan(self, stats) -> Optional[FusedRequantPlan]:
        """Build the fused plan(s) for the current tree structures.

        Returns the *verify* plan, or None when the verify policy is fully
        disabled (draft-only mode: a quantized draft speculates for the fp
        model — DESIGN.md §11); the draft plan is built either way.
        """
        key = (jax.tree_util.tree_structure(self.params),
               jax.tree_util.tree_structure(stats))
        if self._plan_key != key:
            self._plan = FusedRequantPlan(self.params, stats, self.policy,
                                          acfg=self.acfg,
                                          lowrank_tree=self.lowrank_tree,
                                          pctx=self.pctx) \
                if self.policy.any_enabled else None
            if self._draft_enabled:
                self._draft_plan = FusedRequantPlan(
                    self.params, stats, self.draft_policy, acfg=self.acfg,
                    lowrank_tree=self.draft_lowrank_tree, pctx=self.pctx)
            self._plan_key = key
        return self._plan

    def requantize(self, threshold: Optional[float] = None):
        """(Re)quantize from the session's current statistics.

        ``threshold`` arms the delta gate: only leaves whose activation
        diagonal D drifted by at least ``threshold`` in relative L2 since
        their last quantization are re-quantized (0 → everything, ∞ →
        nothing); leaves below the gate reuse their previous
        ``QuantizedTensor``.  ``None`` (default) requantizes everything
        without computing drift.

        Returns the quantized tree, or None when every reachable method
        (base policy or override) is disabled, or when all enabled methods
        still need statistics the session doesn't have yet.
        """
        if not self._active():
            return None
        stats, count = self.session.as_calib()
        if not self.fused:
            if threshold is not None:
                raise ValueError(
                    "requantize(threshold=...) — the delta gate — needs the "
                    "fused plan; construct QuantizedModel(fused=True) "
                    "(the default) or drop the threshold")
            self.qparams = quantize_params(
                self.params, stats, self.policy, count=count,
                acfg=self.acfg, lowrank_tree=self.lowrank_tree)
            self.n_requants += 1
            return self.qparams
        plan = self._ensure_plan(stats)
        tree = None
        if plan is not None:
            tree, n_requant, n_skip = self._attempt(
                plan, self.lowrank_tree, self._qt_by_path, self._last_D,
                stats, count, threshold)
            if tree is None:
                # sustained corruption (the immediate clean retry failed
                # too): the newest accepted calibration update is the prime
                # suspect — drop it and keep serving the last-good tree.
                # n_requants stays put, so the engine's cadence re-arms.
                self.session.rollback(1)
                return None
            self.last_requant_layers = n_requant
            self.last_skipped_layers = n_skip
            self.total_requant_layers += n_requant
            self.total_skipped_layers += n_skip
            if self.double_buffer and self.qparams is not None:
                self._pending = tree     # swap when device-ready (opt-in:
            else:                        # token timing becomes device-bound)
                self.qparams = tree
        if self._draft_plan is not None:
            # draft tree: same stats snapshot, same delta-gate semantics,
            # its own D snapshots (the gates may fire on different steps)
            dtree, _, _ = self._attempt(
                self._draft_plan, self.draft_lowrank_tree,
                self._draft_qt_by_path, self._draft_last_D,
                stats, count, threshold)
            if dtree is None and plan is None:
                # draft-only mode: the draft IS the primary tree
                self.session.rollback(1)
                return None
            if dtree is not None:
                if self.double_buffer and self.draft_qparams is not None:
                    self._draft_pending = dtree
                else:
                    self.draft_qparams = dtree
                if tree is None:
                    tree = dtree         # draft-only mode: report the draft
            # a rejected draft beside a healthy verify tree keeps its old
            # draft (speculation stays token-correct — the verify tree
            # decides every emitted token; only acceptance rate suffers)
        self.n_requants += 1             # tree so cadence accounting (the
        return tree                      # engine's note_requant) still fires

    def _attempt(self, plan, lowrank, qt_by_path, last_D, stats, count,
                 threshold):
        """One tree's requant with the health gate: a rejected candidate is
        retried once immediately (transient corruption — a flipped device
        buffer, an injected fault — yields a clean tree on the very next
        dispatch from the same stats), then given up on."""
        tries = 2 if self.health_gate is not None else 1
        for _ in range(tries):
            tree, n_requant, n_skip = self._run_plan(
                plan, lowrank, qt_by_path, last_D, stats, count, threshold)
            if tree is not None:
                return tree, n_requant, n_skip
        return None, 0, 0

    def _run_plan(self, plan, lowrank, qt_by_path, last_D, stats, count,
                  threshold):
        """Run one tree's fused plan (gate → family programs → health gate →
        snapshot refresh).  Returns (tree, n_requant, n_skip); a
        health-rejected candidate returns (None, 0, 0) *without* touching
        the delta-gate snapshots — nothing of it survives."""
        only = None
        n_requant, n_skip = plan.n_layers, 0
        if threshold is not None and qt_by_path:
            drifts = plan.drift(stats, count, last_D)
            only, n_requant, n_skip = plan.gate(drifts, threshold,
                                                set(qt_by_path))
        tree = plan.run(self.params, stats, count, lowrank,
                        only=only, reuse=qt_by_path)
        if self._fault_hook is not None:
            tree = self._fault_hook(tree)
        if self.health_gate is not None:
            prev = {p: qt.dinv for p, qt in qt_by_path.items()
                    if qt.dinv is not None}
            ok, drift = qt_health(tree, prev,
                                  self.health_gate.requant_max_drift)
            self.last_health_drift = drift
            if not ok:
                self.requant_rejections += 1
                return None, 0, 0
        # refresh the per-path snapshot for everything that was requantized
        from repro.core.ttq import QuantizedTensor

        def note(path, leaf):
            if isinstance(leaf, QuantizedTensor):
                from .api import _path_str
                ps = _path_str(path)
                if qt_by_path.get(ps) is not leaf:
                    last_D[ps] = 1.0 / leaf.dinv
                qt_by_path[ps] = leaf

        jax.tree_util.tree_map_with_path(
            lambda p, l: note(p, l),
            tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        return tree, n_requant, n_skip

    def _swap_if_ready(self):
        if self._pending is not None:
            leaves = jax.tree.leaves(self._pending)
            if all(l.is_ready() for l in leaves if hasattr(l, "is_ready")):
                self.qparams, self._pending = self._pending, None
        if self._draft_pending is not None:
            leaves = jax.tree.leaves(self._draft_pending)
            if all(l.is_ready() for l in leaves if hasattr(l, "is_ready")):
                self.draft_qparams, self._draft_pending = \
                    self._draft_pending, None

    @property
    def decode_params(self):
        """Latest *device-ready* quantized tree; falls back to the previous
        tree while a requantization is in flight, and to the fp parameters
        before the first requantization."""
        self._swap_if_ready()
        return self.qparams if self.qparams is not None else self.params

    @property
    def draft_params(self):
        """Latest device-ready DRAFT tree (DESIGN.md §11); the fp parameters
        before the first requantization or when the draft policy is disabled
        (a fp draft is a valid — maximally accurate — speculator)."""
        self._swap_if_ready()
        return self.draft_qparams if self.draft_qparams is not None \
            else self.params

    # ------------------------------------------------------------ fork / join

    def fork(self) -> "QuantizedModel":
        """Independent calibration stream sharing params + low-rank factors."""
        return QuantizedModel(self.params, self.policy, acfg=self.acfg,
                              session=self.session.fork(),
                              lowrank=self.lowrank_tree, fused=self.fused,
                              double_buffer=self.double_buffer,
                              pctx=self.pctx, draft_policy=self.draft_policy,
                              health_gate=self.health_gate)

    def adopt(self, session: CalibrationSession) -> "QuantizedModel":
        """Join a forked stream's statistics into this model's session."""
        self.session = self.session.merge(session)
        return self
