"""QuantizedModel — the calibrate → requantize → decode_params facade.

Owns everything the TTQ lifecycle needs around a parameter tree:

* a :class:`~repro.quant.session.CalibrationSession` accumulating the live
  workload's activation statistics (decay, fork/merge for multi-stream),
* the data-free low-rank factor tree (computed **once**; requantization
  reuses it — no per-requant SVD),
* the current quantized parameter tree and a requantization counter.

Typical serving loop::

    qm = QuantizedModel(params, policy, halflife=ecfg.stats_halflife)
    ...
    qm.calibrate(prefill_stats, tokens=n_prefill_tokens)
    qm.requantize()
    logits = decode(qm.decode_params, ...)

Multi-stream: ``child = qm.fork()`` shares params and low-rank factors but
gets an independent calibration session; join with
``qm.adopt(child.session)`` (exact — the statistics are additive).
"""
from __future__ import annotations

from typing import Any, Optional

from repro.core.awq import AWQConfig
from repro.core.policy import QuantPolicy

from .api import lowrank_tree, quantize_params
from .session import CalibrationSession


_AUTO = object()   # sentinel: compute the low-rank tree from the policy


class QuantizedModel:
    def __init__(self, params: Any, policy: QuantPolicy, *,
                 acfg: Optional[AWQConfig] = None, halflife: float = 0.0,
                 session: Optional[CalibrationSession] = None,
                 lowrank: Any = _AUTO):
        self.params = params
        self.policy = policy
        self.acfg = acfg
        self.session = session if session is not None else \
            CalibrationSession(halflife=halflife)
        if lowrank is _AUTO:
            self.lowrank_tree = lowrank_tree(params, policy) \
                if policy.any_enabled else None
        else:
            self.lowrank_tree = lowrank
        self.qparams = None
        self.n_requants = 0

    # -------------------------------------------------------------- lifecycle

    def calibrate(self, stats: Any, tokens: float) -> "QuantizedModel":
        """Fold one prefill's activation statistics into the session."""
        self.session.update(stats, tokens)
        return self

    def requantize(self):
        """(Re)quantize from the session's current statistics.

        Returns the quantized tree, or None when every reachable method
        (base policy or override) is disabled, or when all enabled methods
        still need statistics the session doesn't have yet.
        """
        from .registry import get_quantizer
        active = [q for q in map(get_quantizer, self.policy.methods())
                  if q.enabled]
        if not active:
            return None
        if not self.session.calibrated and all(q.requires_stats
                                               for q in active):
            return None
        stats, count = self.session.as_calib()
        self.qparams = quantize_params(
            self.params, stats, self.policy, count=count,
            acfg=self.acfg, lowrank_tree=self.lowrank_tree)
        self.n_requants += 1
        return self.qparams

    @property
    def decode_params(self):
        """Quantized tree if one exists, else the fp parameters."""
        return self.qparams if self.qparams is not None else self.params

    # ------------------------------------------------------------ fork / join

    def fork(self) -> "QuantizedModel":
        """Independent calibration stream sharing params + low-rank factors."""
        return QuantizedModel(self.params, self.policy, acfg=self.acfg,
                              session=self.session.fork(),
                              lowrank=self.lowrank_tree)

    def adopt(self, session: CalibrationSession) -> "QuantizedModel":
        """Join a forked stream's statistics into this model's session."""
        self.session = self.session.merge(session)
        return self
