"""repro.quant — the unified quantization API.

Three pillars (DESIGN.md):

* **method registry** — :class:`Quantizer` protocol + ``@register_quantizer``;
  methods (``ttq`` / ``awq`` / ``rtn`` / ``gptq`` / ``none``) are pluggable
  objects, not string ``if`` chains.
* **CalibrationSession** — first-class ownership of the additive activation
  statistics: accumulate / decay / snapshot / fork / merge.
* **per-layer policy overrides** — ``QuantPolicy.overrides`` maps fnmatch
  patterns on parameter paths to partial-policy deltas, giving declarative
  mixed precision (attention 4-bit g=32, MLP 3-bit g=64, edge blocks 8-bit…).

Tied together by :class:`QuantizedModel`:
``calibrate(stats) → requantize() → decode_params``.
"""
from repro.core.kvquant import BF16_KV, KVCacheConfig
from repro.core.policy import (FUSED_KERNELS, KernelConfig, NO_QUANT,
                               QuantPolicy, override, ttq_policy)

from .api import FusedRequantPlan, lowrank_tree, quantize_params
from .guards import GuardConfig
from .model import QuantizedModel
from .registry import (Quantizer, get_quantizer, register_quantizer,
                       registered_methods)
from .session import CalibrationSession, QuarantineRecord

__all__ = [
    "BF16_KV", "CalibrationSession", "FUSED_KERNELS", "FusedRequantPlan",
    "GuardConfig", "KVCacheConfig", "KernelConfig", "NO_QUANT",
    "QuantPolicy", "QuantizedModel", "QuarantineRecord",
    "Quantizer", "get_quantizer", "lowrank_tree", "override",
    "quantize_params", "register_quantizer", "registered_methods",
    "ttq_policy",
]
