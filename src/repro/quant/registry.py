"""Quantization-method registry — pluggable method objects, no string `if` chains.

A method is a :class:`Quantizer` instance registered under a name::

    @register_quantizer("my_method")
    class MyQuantizer:
        requires_stats = True
        def diag(self, stat, count, acfg, d): ...
        def quantize_weight(self, W, stat, count, policy, acfg, B=None, A=None): ...

The tree-level driver (:func:`repro.quant.api.quantize_params`) resolves the
method once per parameter path (after per-layer policy overrides) and asks the
quantizer for (a) the activation scaling diagonal D and (b) the quantized
weight.  ``enabled=False`` methods (the ``"none"`` placeholder) switch
quantization off without any ``policy.method == "..."`` checks at call sites.

Built-ins:

* ``ttq``  — the paper's method: D from the *live* activation statistics.
* ``awq``  — identical closed form, offline-calibrated usage (stats from a
  fixed calibration set instead of the live workload).
* ``rtn``  — round-to-nearest, activation-unaware (D = 1).
* ``gptq`` — diagonal-Hessian surrogate on the tree path (only the additive
  diagonal sufficient statistic is available online; with a diagonal Hessian
  the OBS error propagation vanishes and the closed form coincides with the
  activation-aware scaling).  The full-covariance reference lives in
  :func:`repro.core.gptq.gptq_qdq` and is exposed as ``qdq_reference`` for
  layer-level benchmarks.
* ``none`` — disabled placeholder (full precision).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Protocol, runtime_checkable

import jax.numpy as jnp

from repro.core.awq import AWQConfig, diag_from_stats


@runtime_checkable
class Quantizer(Protocol):
    """Protocol every registered quantization method implements."""

    name: str               # filled in by @register_quantizer
    enabled: bool           # False → method is a no-op (params stay fp)
    requires_stats: bool    # True → needs accumulated activation statistics

    def diag(self, stat: Any, count: Any, acfg: AWQConfig, d: int) -> jnp.ndarray:
        """Activation scaling vector D (d,) from the sufficient statistic."""
        ...

    def quantize_weight(self, W, stat, count, policy, acfg,
                        B=None, A=None):
        """One (d', d) weight → :class:`repro.core.ttq.QuantizedTensor`."""
        ...


_REGISTRY: Dict[str, Quantizer] = {}


def register_quantizer(name: str):
    """Class decorator: instantiate and register under ``name``."""

    def deco(cls):
        inst = cls()
        inst.name = name
        _REGISTRY[name] = inst
        return cls

    return deco


def get_quantizer(name: str) -> Quantizer:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown quantization method {name!r}; registered: "
            f"{registered_methods()}") from None


def registered_methods() -> tuple:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# built-in methods
# ---------------------------------------------------------------------------


class _BaseQuantizer:
    enabled = True
    requires_stats = True

    def diag(self, stat, count, acfg: AWQConfig, d: int) -> jnp.ndarray:
        return diag_from_stats(stat, count, acfg)

    def quantize_weight(self, W, stat, count, policy, acfg, B=None, A=None):
        from repro.core.ttq import quantize_weight
        D = self.diag(stat, count, acfg, W.shape[-1])
        return quantize_weight(W, D, policy, B, A)


@register_quantizer("ttq")
class TTQQuantizer(_BaseQuantizer):
    """Test-time quantization: D from the live workload's statistics."""


@register_quantizer("awq")
class AWQQuantizer(_BaseQuantizer):
    """Same closed form as TTQ; stats come from an offline calibration set."""


@register_quantizer("rtn")
class RTNQuantizer(_BaseQuantizer):
    """Round-to-nearest: activation-unaware, D = 1."""

    requires_stats = False

    def diag(self, stat, count, acfg: AWQConfig, d: int) -> jnp.ndarray:
        return jnp.ones((d,), jnp.float32)


@register_quantizer("gptq")
class GPTQQuantizer(_BaseQuantizer):
    """Diagonal-Hessian GPTQ for the (online) tree path.

    Only diag[XXᵀ] is available as an additive online statistic; the OBS
    cross-column compensation needs the full Hessian, so the tree path uses
    the activation-aware diagonal closed form (== AWQ/TTQ scaling, the
    paper's Appendix C equivalence).  ``qdq_reference`` runs the exact
    column-serial algorithm against raw activations for benchmarks.
    """

    @staticmethod
    def qdq_reference(W, X, qcfg):
        from repro.core.gptq import gptq_qdq
        return gptq_qdq(W, X, qcfg)


@register_quantizer("none")
class NoneQuantizer(_BaseQuantizer):
    """Quantization disabled — parameters stay in full precision."""

    enabled = False
    requires_stats = False

    def quantize_weight(self, W, stat, count, policy, acfg, B=None, A=None):
        return W
