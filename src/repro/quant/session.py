"""CalibrationSession — first-class ownership of online activation statistics.

The paper's calibration state is a pytree of additive sufficient statistics
(Σ_t |x_t|^p per linear input feature) plus a token count.  Everything the
serving engine and the benchmarks used to hand-roll (tree-add, tree-scale,
halflife decay, count bookkeeping) lives here, with two extras needed for
multi-stream serving:

* ``snapshot()`` / ``fork()`` — O(1) copies (jax arrays are immutable, so the
  stats tree is shared by reference; subsequent ``update``s rebuild the tree
  functionally and never mutate a snapshot).
* ``merge(other)`` — join two sessions by summing their sufficient statistics
  (exact, because the statistics are additive): fork per stream, join at
  requantization time.  Merging sessions with different halflives is a
  ``ValueError`` — their stats carry incompatible decay weighting, so the
  sum would silently misweight one stream.

Decay: with ``halflife=h`` (measured in updates), every ``update`` first
scales existing stats and count by ``0.5**(1/h)``, so a request admitted h
updates ago carries half the weight of the current one.  ``halflife=0``
disables decay (plain accumulation).

**Poisoning defense (DESIGN.md §12):** constructed with a
:class:`~repro.quant.guards.GuardConfig`, every ``update`` is validated
before it folds — non-finite stats, a bad token count, or a per-token
magnitude beyond ``calib_outlier_factor`` × the running distribution is
*quarantined* (a bounded provenance log, ``n_rejected`` counter) instead of
accumulated.  Accepted folds push the pre-update state onto a bounded
last-good ring, so a poisoned stream that slipped past the gate (or a
downstream requant health rejection) can ``rollback(n)`` to the state
before the last n accepted updates.  Without a guard config the session
behaves exactly as before — validation is strictly opt-in.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional, Tuple

import jax

from .guards import GuardConfig, stats_summary, token_count_ok


def _tree_add(a: Any, b: Any) -> Any:
    if a is None:
        return b
    if b is None:
        return a
    return jax.tree.map(lambda x, y: x + y, a, b)


def _tree_scale(a: Any, s: float) -> Any:
    if a is None:
        return None
    return jax.tree.map(lambda x: x * s, a)


@dataclasses.dataclass(frozen=True)
class QuarantineRecord:
    """One rejected calibration update, with provenance for the audit
    trail: why it was rejected, which update index it would have been,
    which request ids produced it, and its measured per-leaf magnitude."""
    reason: str                  # "non-finite-stats" | "bad-token-count"
                                 # | "outlier-stats"
    tokens: float                # claimed token count of the update
    update_idx: int              # n_updates at rejection time
    provenance: Tuple[int, ...]  # request ids that produced the stats
    mean_abs: float              # measured mean |stat| of the update


class CalibrationSession:
    """Accumulates activation statistics for online (re)quantization."""

    def __init__(self, halflife: float = 0.0,
                 stats: Any = None, count: float = 0.0, n_updates: int = 0,
                 guard: Optional[GuardConfig] = None):
        self.halflife = float(halflife)
        self.stats = stats
        self.count = float(count)
        self.n_updates = int(n_updates)
        self.guard = guard
        self.n_rejected = 0
        self.quarantine: deque = deque(
            maxlen=guard.quarantine_max if guard is not None else 16)
        # last-good ring: (stats, count, n_updates) BEFORE each accepted
        # fold, newest last — rollback(n) pops n entries
        self._ring: deque = deque(
            maxlen=guard.snapshot_ring if guard is not None else 4)

    # ------------------------------------------------------------- lifecycle

    def _validate(self, stats: Any, tokens: float) -> Tuple[str, float]:
        """(reason, mean_abs): empty reason = accept.  One summary program
        for the update and (once armed) one for the running tree — both
        outside the decode hot loop."""
        if not token_count_ok(tokens):
            return "bad-token-count", 0.0
        fin, mean = stats_summary(stats)
        if not fin:
            return "non-finite-stats", mean
        g = self.guard
        if (self.stats is not None and self.n_updates >= g.calib_warmup_updates
                and g.calib_outlier_factor > 0):
            _, run_mean = stats_summary(self.stats)
            run_rate = run_mean / max(self.count, 1.0)
            rate = mean / float(tokens)
            if run_rate > 0 and rate > g.calib_outlier_factor * run_rate:
                return "outlier-stats", mean
        return "", mean

    def update(self, stats: Any, tokens: float,
               provenance: Tuple[int, ...] = ()) -> "CalibrationSession":
        """Fold one prefill's statistics in (with decay if halflife > 0).

        With a guard config the update is validated first; rejections are
        quarantined (with ``provenance`` — typically the admitted request
        ids) and leave the session state untouched."""
        if self.guard is not None:
            reason, mean = self._validate(stats, tokens)
            if reason:
                self.n_rejected += 1
                self.quarantine.append(QuarantineRecord(
                    reason, float(tokens) if token_count_ok(tokens) else
                    float("nan"), self.n_updates, tuple(provenance), mean))
                return self
            self._ring.append((self.stats, self.count, self.n_updates))
        if self.halflife > 0 and self.stats is not None:
            decay = 0.5 ** (1.0 / self.halflife)
            self.stats = _tree_scale(self.stats, decay)
            self.count *= decay
        self.stats = _tree_add(self.stats, stats)
        self.count += float(tokens)
        self.n_updates += 1
        return self

    def rollback(self, n: int = 1) -> int:
        """Restore the state before the last ``n`` accepted updates (bounded
        by the ring depth).  Returns how many updates were actually undone —
        0 when the ring is empty (guard off, or nothing accepted yet)."""
        undone = 0
        for _ in range(n):
            if not self._ring:
                break
            self.stats, self.count, self.n_updates = self._ring.pop()
            undone += 1
        return undone

    def reset(self) -> "CalibrationSession":
        self.stats, self.count, self.n_updates = None, 0.0, 0
        self._ring.clear()
        return self

    # ----------------------------------------------------------- fork / join

    def snapshot(self) -> "CalibrationSession":
        """Immutable-by-construction copy sharing the current stats tree
        (fresh quarantine/ring — the copy starts its own audit trail)."""
        return CalibrationSession(self.halflife, self.stats,
                                  self.count, self.n_updates,
                                  guard=self.guard)

    fork = snapshot

    def merge(self, other: "CalibrationSession") -> "CalibrationSession":
        """Join: sum of sufficient statistics (exact for additive stats).
        The halflives must agree — each stream's stats are weighted by its
        own decay schedule, so summing across schedules would silently
        misweight one of them."""
        if self.halflife != other.halflife:
            raise ValueError(
                f"cannot merge sessions with different halflives "
                f"({self.halflife} vs {other.halflife}): their statistics "
                f"carry incompatible decay weighting — fork from one parent "
                f"or resample one stream")
        return CalibrationSession(
            self.halflife,
            _tree_add(self.stats, other.stats),
            self.count + other.count,
            self.n_updates + other.n_updates,
            guard=self.guard,
        )

    # ------------------------------------------------------------ inspection

    @property
    def calibrated(self) -> bool:
        return self.stats is not None

    def as_calib(self) -> tuple:
        """(stats, count) pair for the tree quantization driver."""
        return self.stats, max(self.count, 1.0)

    def __repr__(self) -> str:
        extra = (f", rejected={self.n_rejected}"
                 if self.guard is not None else "")
        return (f"CalibrationSession(count={self.count:.0f}, "
                f"n_updates={self.n_updates}, halflife={self.halflife}, "
                f"calibrated={self.calibrated}{extra})")
