"""CalibrationSession — first-class ownership of online activation statistics.

The paper's calibration state is a pytree of additive sufficient statistics
(Σ_t |x_t|^p per linear input feature) plus a token count.  Everything the
serving engine and the benchmarks used to hand-roll (tree-add, tree-scale,
halflife decay, count bookkeeping) lives here, with two extras needed for
multi-stream serving:

* ``snapshot()`` / ``fork()`` — O(1) copies (jax arrays are immutable, so the
  stats tree is shared by reference; subsequent ``update``s rebuild the tree
  functionally and never mutate a snapshot).
* ``merge(other)`` — join two sessions by summing their sufficient statistics
  (exact, because the statistics are additive): fork per stream, join at
  requantization time.

Decay: with ``halflife=h`` (measured in updates), every ``update`` first
scales existing stats and count by ``0.5**(1/h)``, so a request admitted h
updates ago carries half the weight of the current one.  ``halflife=0``
disables decay (plain accumulation).
"""
from __future__ import annotations

from typing import Any

import jax


def _tree_add(a: Any, b: Any) -> Any:
    if a is None:
        return b
    if b is None:
        return a
    return jax.tree.map(lambda x, y: x + y, a, b)


def _tree_scale(a: Any, s: float) -> Any:
    if a is None:
        return None
    return jax.tree.map(lambda x: x * s, a)


class CalibrationSession:
    """Accumulates activation statistics for online (re)quantization."""

    def __init__(self, halflife: float = 0.0,
                 stats: Any = None, count: float = 0.0, n_updates: int = 0):
        self.halflife = float(halflife)
        self.stats = stats
        self.count = float(count)
        self.n_updates = int(n_updates)

    # ------------------------------------------------------------- lifecycle

    def update(self, stats: Any, tokens: float) -> "CalibrationSession":
        """Fold one prefill's statistics in (with decay if halflife > 0)."""
        if self.halflife > 0 and self.stats is not None:
            decay = 0.5 ** (1.0 / self.halflife)
            self.stats = _tree_scale(self.stats, decay)
            self.count *= decay
        self.stats = _tree_add(self.stats, stats)
        self.count += float(tokens)
        self.n_updates += 1
        return self

    def reset(self) -> "CalibrationSession":
        self.stats, self.count, self.n_updates = None, 0.0, 0
        return self

    # ----------------------------------------------------------- fork / join

    def snapshot(self) -> "CalibrationSession":
        """Immutable-by-construction copy sharing the current stats tree."""
        return CalibrationSession(self.halflife, self.stats,
                                  self.count, self.n_updates)

    fork = snapshot

    def merge(self, other: "CalibrationSession") -> "CalibrationSession":
        """Join: sum of sufficient statistics (exact for additive stats)."""
        return CalibrationSession(
            self.halflife,
            _tree_add(self.stats, other.stats),
            self.count + other.count,
            self.n_updates + other.n_updates,
        )

    # ------------------------------------------------------------ inspection

    @property
    def calibrated(self) -> bool:
        return self.stats is not None

    def as_calib(self) -> tuple:
        """(stats, count) pair for the tree quantization driver."""
        return self.stats, max(self.count, 1.0)

    def __repr__(self) -> str:
        return (f"CalibrationSession(count={self.count:.0f}, "
                f"n_updates={self.n_updates}, halflife={self.halflife}, "
                f"calibrated={self.calibrated})")
