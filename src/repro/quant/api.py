"""Whole-model quantization driver: join params ↔ activation stats by path.

This is the tree-level orchestration behind every quantization entry point
(engine requantization, benchmark sweeps, dry-run shape inference).  Per
parameter path it:

1. resolves the effective :class:`~repro.core.policy.QuantPolicy` through the
   policy's fnmatch ``overrides`` (mixed precision),
2. resolves the effective policy's ``method`` through the
   :mod:`repro.quant.registry` (no string dispatch),
3. locates the matching activation-statistic leaf (methods with
   ``requires_stats=False`` synthesize a zero statistic), and
4. asks the quantizer for the :class:`~repro.core.ttq.QuantizedTensor`,
   vmapping over leading run / expert dims.

Two execution strategies share the same per-path resolution:

* :func:`quantize_params` — the eager per-leaf driver (one small dispatch
  chain per leaf; the reference semantics and the fallback);
* :class:`FusedRequantPlan` — the serving hot path: leaves are grouped into
  *families* sharing (d', d, quant settings), each family is ONE jitted
  device program that stacks the member weights (leading run / expert dims
  flattened), computes the AWQ diagonals, subtracts the precomputed
  low-rank residuals, and quantizes the whole stack in a single Pallas
  ``ttq_quantize`` dispatch (or one vmapped jnp quantize when the packed
  kernel does not apply).  A whole-model requantization is a handful of
  async-dispatched programs instead of hundreds of per-leaf ops.

Self-speculative decoding (DESIGN.md §11) instantiates TWO plans over the
same parameter tree — the verify policy and a uniform low-bit
``policy.draft_variant()`` — and runs both against one calibration snapshot:
the families differ only in their (bits, group, rank) key, so requant stays
~1 program/family/tree and the draft+verify pair emits at most 2× the
single-tree program count (:class:`~repro.quant.model.QuantizedModel` owns
the pairing and the per-tree delta-gate snapshots).

``repro.core`` keeps thin delegating shims so historical imports
(``repro.core.quantize_params``) continue to work.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.awq import AWQConfig
from repro.core.lowrank import svd_factors
from repro.core.policy import QuantPolicy

# projections sharing their input with a tapped sibling (one tap per input).
STAT_ALIAS = {
    "wk": "wq", "wv": "wq", "wkv_a": "wq", "wu": "wg",
    "w_in": "w_branch", "w_z": "w_x", "w_B": "w_x", "w_C": "w_x", "w_dt": "w_x",
}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(getattr(p, "key", p)))
    return ".".join(parts)


def _stats_key(rel_path: tuple) -> str:
    """('u0','mix','wq') → 'u0.mix.wq' with alias resolution on the leaf name."""
    *head, leaf = rel_path
    leaf = STAT_ALIAS.get(leaf, leaf)
    return ".".join([*head, leaf])


def _lookup_stats(stats_run: dict, rel_path: tuple):
    key = _stats_key(rel_path)
    if key in stats_run:
        return stats_run[key]
    # expert weights: stats stored per 'experts.wg'/'experts.wd'
    if rel_path[-1] in ("wg", "wu", "wd") and "experts" in rel_path:
        leaf = "wg" if rel_path[-1] in ("wg", "wu") else "wd"
        key2 = ".".join([*rel_path[:-1], leaf])
        if key2 in stats_run:
            return stats_run[key2]
    return None


def _tree_get(tree, path):
    node = tree
    try:
        for p in path:
            key = p.key if isinstance(p, jax.tree_util.DictKey) else (
                p.idx if isinstance(p, jax.tree_util.SequenceKey) else p)
            node = node[key]
        return node
    except (KeyError, IndexError, TypeError):
        return None


def quantize_params(params, stats, policy: QuantPolicy, *,
                    count: float = 1.0, acfg: Optional[AWQConfig] = None,
                    lowrank_tree=None):
    """Quantize the whole model: replace quantizable 2-D/3-D weights by
    :class:`~repro.core.ttq.QuantizedTensor`, joining activation stats by
    param path.

    ``stats`` is the structure produced by ``models.lm.forward(collect_stats=
    True)``: {'stack': [run-dicts of Σx² leaves, leading run dim], ...}.
    Weights whose stats are missing (untapped), that match ``policy.skip``,
    or whose override-resolved method is disabled stay in full precision.
    """
    countf = jnp.asarray(count, jnp.float32)
    # a caller-supplied acfg replaces the policy's *base* statistics config;
    # per-path overrides (p/alpha/lam/form) still apply on top of it
    base = policy if acfg is None else policy.with_(acfg=acfg)

    def per_leaf(path, leaf):
        ps = _path_str(path)
        if not isinstance(leaf, jnp.ndarray) or leaf.ndim < 2 or leaf.ndim > 4:
            return leaf
        eff = base.resolve(ps)
        if not eff.quantizes(ps.split(".")[-1]) or not eff.quantizes(ps):
            return leaf
        qz = eff.quantizer
        eff_acfg = eff.acfg
        parts = ps.split(".")
        ba = _tree_get(lowrank_tree, path) if lowrank_tree is not None else None

        def quant_one(W, stat, BA=None):
            B = A = None
            if BA is not None:
                B, A = BA["B"], BA["A"]
            elif eff.rank > 0 and min(W.shape) > eff.rank:
                B, A = svd_factors(W, eff.rank)
            return qz.quantize_weight(W, stat, countf, eff, eff_acfg, B, A)

        # locate the stats leaf for this weight (stats-free methods need none)
        stat = None
        if qz.requires_stats:
            if parts[0] not in ("stack", "enc_stack"):
                if isinstance(stats, dict) and ps in stats and leaf.ndim == 2:
                    return quant_one(leaf, stats[ps], None)
                return leaf
            run = (stats or {}).get(parts[0])
            if run is None:
                return leaf
            stat = _lookup_stats(run[int(parts[1])], tuple(parts[2:]))
            if stat is None:
                return leaf
        elif (parts[0] in ("stack", "enc_stack") and leaf.ndim >= 3) \
                or (parts[0] not in ("stack", "enc_stack") and leaf.ndim == 2):
            # stacked weights are ≥3-D (run dim); stacked 1-D params (norm
            # scales, decay vectors) must not be mistaken for 2-D weights
            stat = jnp.zeros(leaf.shape[:-2] + leaf.shape[-1:], jnp.float32)
        else:
            return leaf
        if ba is None:
            fn = lambda W, s: quant_one(W, s, None)
            for _ in range(leaf.ndim - 2):           # vmap over run / expert dims
                fn = jax.vmap(fn)
            return fn(leaf, stat)
        fn = quant_one
        for _ in range(leaf.ndim - 2):
            fn = jax.vmap(fn)
        return fn(leaf, stat, ba)

    return jax.tree_util.tree_map_with_path(per_leaf, params)


# ---------------------------------------------------------------------------
# fused whole-tree requantization (the serving hot path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Member:
    """One quantizable leaf inside a family (host-side bookkeeping only)."""

    path: tuple                    # jax key path into the params tree
    path_str: str
    lead: tuple                    # leading run / expert dims, () for 2-D
    dp: int
    d: int
    eff: QuantPolicy               # override-resolved policy for this path
    stat_get: Optional[Callable]   # stats tree → lead+(d,) array; None → zeros
    has_ba: bool

    @property
    def n(self) -> int:
        out = 1
        for s in self.lead:
            out *= s
        return out


class FusedRequantPlan:
    """Whole-model requantization as one jitted device program per family.

    Built once per (params structure × stats structure × policy).  Families
    group leaves by ``(d', d, quant settings, low-rank presence)``; each
    family's program concatenates the member weights into one (N, d', d)
    stack, computes the per-row AWQ diagonal D from the stacked statistics,
    subtracts the precomputed low-rank residual, and quantizes in ONE
    dispatch — the Pallas ``ttq_quantize`` kernel (batched over N via vmap:
    a single pallas_call with a leading batch grid axis) when the policy's
    packed path + :class:`~repro.core.policy.KernelConfig` apply, else one
    vmapped jnp ``awq_quantize``.  Either way the whole family is a single
    XLA program, async-dispatched, whose results double-buffer under
    :class:`~repro.quant.model.QuantizedModel`.

    Methods with a custom ``quantize_weight`` (anything that is not the
    registry's ``_BaseQuantizer`` closed form) fall back to the eager
    per-leaf path for those leaves — correctness first.

    ``run(params, stats, count, lowrank_tree, only=...)`` returns the full
    quantized parameter tree; ``only`` (a set of family keys) restricts the
    dispatch to a subset — the delta-gate path — with the remaining leaves
    filled from ``reuse`` (previous :class:`QuantizedTensor`s by path).
    """

    def __init__(self, params, stats, policy: QuantPolicy, *,
                 acfg: Optional[AWQConfig] = None, lowrank_tree=None,
                 pctx=None):
        from .registry import _BaseQuantizer
        base = policy if acfg is None else policy.with_(acfg=acfg)
        self.policy = policy
        # shard-local requant: with a mesh, every family program pins its
        # QuantizedTensor outputs to the serving layout (parallel/rules.py)
        # so each weight shard quantizes in place — the only cross-device
        # traffic is the per-column diagonal stats (already replicated)
        self.pctx = pctx if (pctx is not None and pctx.mesh is not None) \
            else None
        self.families: Dict[tuple, List[_Member]] = {}
        self.eager: List[_Member] = []
        self._family_fns: Dict[tuple, Callable] = {}
        self._drift_fn = None

        def visit(path, leaf):
            ps = _path_str(path)
            if not isinstance(leaf, jnp.ndarray) or leaf.ndim < 2 or leaf.ndim > 4:
                return
            eff = base.resolve(ps)
            if not eff.quantizes(ps.split(".")[-1]) or not eff.quantizes(ps):
                return
            qz = eff.quantizer
            parts = ps.split(".")
            dp, d = leaf.shape[-2:]
            lead = tuple(leaf.shape[:-2])
            stat_get: Optional[Callable] = None
            if qz.requires_stats:
                if parts[0] not in ("stack", "enc_stack"):
                    if not (isinstance(stats, dict) and ps in stats
                            and leaf.ndim == 2):
                        return
                    stat_get = (lambda st, _k=ps: st[_k])
                else:
                    run = (stats or {}).get(parts[0])
                    if run is None:
                        return
                    idx = int(parts[1])
                    rel = tuple(parts[2:])
                    if _lookup_stats(run[idx], rel) is None:
                        return
                    # resolve the concrete key once (alias + expert fallback)
                    key = _stats_key(rel)
                    if key not in run[idx]:
                        leafname = "wg" if rel[-1] in ("wg", "wu") else "wd"
                        key = ".".join([*rel[:-1], leafname])
                    stat_get = (lambda st, _r=parts[0], _i=idx, _k=key:
                                st[_r][_i][_k])
            elif not ((parts[0] in ("stack", "enc_stack") and leaf.ndim >= 3)
                      or (parts[0] not in ("stack", "enc_stack")
                          and leaf.ndim == 2)):
                return                      # stacked 1-D params are not weights
            ba = _tree_get(lowrank_tree, path) if lowrank_tree is not None \
                else None
            has_ba = ba is not None
            mem = _Member(path=tuple(path), path_str=ps, lead=lead, dp=dp,
                          d=d, eff=eff, stat_get=stat_get, has_ba=has_ba)
            # eager per-leaf fallback for (a) custom closed forms and (b)
            # leaves the precomputed low-rank tree does not cover but whose
            # policy rank demands an inline SVD (matches quantize_params)
            inline_svd = (not has_ba and eff.rank > 0
                          and min(dp, d) > eff.rank)
            if (type(qz).quantize_weight is not _BaseQuantizer.quantize_weight
                    or inline_svd):
                self.eager.append(mem)
                return
            qcfg = eff.qcfg
            if qcfg.layout != "row":
                qcfg = dataclasses.replace(qcfg, layout="row")
            # eff.rank is part of the key: members with low-rank factors
            # concatenate their (d', r)/(r, d) B/A stacks, so mixed ranks
            # (per-layer rank overrides) must land in separate families
            key = (dp, d, qcfg, eff.acfg, eff.method, eff.packed, has_ba,
                   eff.rank)
            self.families.setdefault(key, []).append(mem)

        jax.tree_util.tree_map_with_path(lambda p, l: visit(p, l) or None,
                                         params)
        for key in self.families:
            self._family_fns[key] = jax.jit(partial(self._run_family, key))

    @property
    def compiled_programs(self) -> int:
        """Programs resident in the per-family jit caches.  Steady state is
        one per family: a growing count means some family argument is
        changing shape/dtype between requants (a recompile regression —
        DESIGN.md §"Static analysis & runtime invariants")."""
        return sum(fn._cache_size() for fn in self._family_fns.values())

    # ------------------------------------------------------------- execution

    @property
    def n_layers(self) -> int:
        """Total quantized-leaf count (stacked leaves count once per path)."""
        return sum(len(ms) for ms in self.families.values()) + len(self.eager)

    def _gather(self, members, params, stats, count, lowrank_tree):
        countf = jnp.asarray(count, jnp.float32)
        Ws, Ss, Bs, As = [], [], [], []
        for m in members:
            Ws.append(_tree_get(params, m.path))
            if m.stat_get is not None:
                Ss.append(m.stat_get(stats))
            else:
                Ss.append(jnp.zeros(m.lead + (m.d,), jnp.float32))
            if m.has_ba:
                ba = _tree_get(lowrank_tree, m.path)
                Bs.append(ba["B"])
                As.append(ba["A"])
        return Ws, Ss, countf, Bs, As

    def _run_family(self, key, Ws, Ss, countf, Bs, As):
        """ONE device program: stack → D → (W−BA)∘D → quantize → split."""
        from repro.core.qdq import pack_bits
        from repro.core.ttq import QuantizedTensor
        from .registry import get_quantizer
        dp, d, qcfg, eff_acfg, method, packed_on, has_ba, _rank = key
        members = self.families[key]
        qz = get_quantizer(method)
        W = jnp.concatenate([w.reshape(-1, dp, d).astype(jnp.float32)
                             for w in Ws], axis=0)              # (N, d', d)
        S = jnp.concatenate([s.reshape(-1, d) for s in Ss], axis=0)
        D = jax.vmap(lambda s: qz.diag(s, countf, eff_acfg, d))(S)   # (N, d)
        if has_ba:
            B = jnp.concatenate([b.reshape(-1, dp, b.shape[-1])
                                 for b in Bs], axis=0)
            A = jnp.concatenate([a.reshape(-1, a.shape[-2], d)
                                 for a in As], axis=0)
            W = W - jnp.einsum("nor,nrd->nod", B.astype(jnp.float32),
                               A.astype(jnp.float32))
        per = 32 // qcfg.bits if 32 % qcfg.bits == 0 else 0
        packable = packed_on and per > 0 and d % per == 0
        kernel_ok = (packable and self.policy.kernel.use_pallas
                     and qcfg.bits in (2, 4, 8) and not qcfg.symmetric
                     and qcfg.nu == 1.0)
        if kernel_ok:
            from repro.kernels import ops as kops
            kw = self.policy.kernel.quant_kw
            pk, Sc, Z = jax.vmap(lambda w, dd: kops.ttq_quantize(
                w, dd, bits=qcfg.bits, group_size=qcfg.group_size, **kw))(W, D)
            wint = None
        else:
            from repro.core.awq import awq_quantize
            wint, Sc, Z = jax.vmap(
                lambda w, dd: awq_quantize(w, dd, qcfg))(W, D)
            pk = pack_bits(wint.astype(jnp.int32), qcfg.bits) if packable \
                else None
            if packable:
                wint = None
        dinv = (1.0 / D).astype(jnp.float32)
        out, off = [], 0
        for i, m in enumerate(members):
            n = m.n
            sl = slice(off, off + n)
            off += n

            def shaped(x, m=m):
                return None if x is None else x.reshape(m.lead + x.shape[1:])
            qt = QuantizedTensor(
                wint=shaped(None if wint is None else wint[sl]),
                packed=shaped(None if pk is None else pk[sl]),
                scale=shaped(Sc[sl]), zero=shaped(Z[sl]),
                dinv=shaped(dinv[sl]),
                B=Bs[i] if has_ba else None, A=As[i] if has_ba else None,
                bits=qcfg.bits, group_size=qcfg.group_size,
                out_features=dp, in_features=d)
            if self.pctx is not None:
                from repro.parallel.rules import constrain_qt
                qt = constrain_qt(m.path_str, qt, self.pctx)
            out.append(qt)
        return out

    def _eager_leaf(self, m: _Member, params, stats, count, lowrank_tree):
        """Per-leaf fallback for methods with a custom closed form."""
        countf = jnp.asarray(count, jnp.float32)
        leaf = _tree_get(params, m.path)
        stat = m.stat_get(stats) if m.stat_get is not None \
            else jnp.zeros(m.lead + (m.d,), jnp.float32)
        ba = _tree_get(lowrank_tree, m.path) if m.has_ba else None
        qz = m.eff.quantizer

        def quant_one(W, s, BA=None):
            B = A = None
            if BA is not None:
                B, A = BA["B"], BA["A"]
            elif m.eff.rank > 0 and min(W.shape) > m.eff.rank:
                B, A = svd_factors(W, m.eff.rank)
            return qz.quantize_weight(W, s, countf, m.eff, m.eff.acfg, B, A)

        if ba is None:
            fn = lambda W, s: quant_one(W, s, None)
            for _ in range(len(m.lead)):
                fn = jax.vmap(fn)
            return fn(leaf, stat)
        fn = quant_one
        for _ in range(len(m.lead)):
            fn = jax.vmap(fn)
        return fn(leaf, stat, ba)

    def run(self, params, stats, count, lowrank_tree=None, *, only=None,
            reuse: Optional[Dict[str, Any]] = None):
        """Quantize the tree; families not in ``only`` (when given) are
        filled from ``reuse`` ({path_str: QuantizedTensor}) or left fp."""
        results: Dict[str, Any] = dict(reuse or {})
        for key, members in self.families.items():
            if only is not None and key not in only:
                continue
            args = self._gather(members, params, stats, count, lowrank_tree)
            qts = self._family_fns[key](*args)
            for m, qt in zip(members, qts):
                results[m.path_str] = qt
        for m in self.eager:
            if only is not None and ("eager", m.path_str) not in only:
                continue
            results[m.path_str] = self._eager_leaf(m, params, stats, count,
                                                   lowrank_tree)
        return jax.tree_util.tree_map_with_path(
            lambda p, l: results.get(_path_str(p), l), params)

    # ------------------------------------------------------------ delta gate

    def drift(self, stats, count, last_D: Dict[str, Any]) -> Dict[str, float]:
        """Relative-L2 drift of the activation diagonal D per leaf since the
        snapshot in ``last_D`` ({path_str: (N, d) f32}).  Leaves without a
        snapshot are omitted (the caller must requantize them).  One small
        jitted program + one host transfer of scalars per call."""
        members = [m for ms in self.families.values() for m in ms] + self.eager
        tracked = [m for m in members if m.path_str in last_D]
        if not tracked:
            return {}
        if self._drift_fn is None:
            def fn(stats, countf, prevs):
                outs = []
                for m, prev in zip(tracked, prevs):
                    s = (m.stat_get(stats) if m.stat_get is not None
                         else jnp.zeros(m.lead + (m.d,))).reshape(-1, m.d)
                    qz = m.eff.quantizer
                    Dn = jax.vmap(lambda ss: qz.diag(ss, countf, m.eff.acfg,
                                                     m.d))(s)
                    Dp = prev.reshape(-1, m.d)
                    num = jnp.linalg.norm(Dn - Dp, axis=-1)
                    den = jnp.linalg.norm(Dp, axis=-1) + 1e-12
                    outs.append(jnp.max(num / den))
                return jnp.stack(outs)
            self._drift_fn = jax.jit(fn)
            self._drift_members = [m.path_str for m in tracked]
        if [m.path_str for m in tracked] != self._drift_members:
            self._drift_fn = None           # snapshot set changed → rebuild
            return self.drift(stats, count, last_D)
        vals = self._drift_fn(stats, jnp.asarray(count, jnp.float32),
                              [last_D[m.path_str] for m in tracked])
        import numpy as np
        return {m.path_str: float(v) for m, v in zip(tracked,
                                                     np.asarray(vals))}

    def gate(self, drifts: Dict[str, float], threshold: float,
             have: set) -> tuple:
        """Family keys to requantize: any member whose drift ≥ threshold, or
        without a previous QuantizedTensor (``have`` = reusable paths)."""
        only = set()
        n_requant = n_skip = 0
        for key, members in self.families.items():
            hit = [m for m in members
                   if m.path_str not in have
                   or drifts.get(m.path_str, float("inf")) >= threshold]
            if hit:
                only.add(key)
                n_requant += len(members)
            else:
                n_skip += len(members)
        for m in self.eager:
            if (m.path_str not in have
                    or drifts.get(m.path_str, float("inf")) >= threshold):
                only.add(("eager", m.path_str))
                n_requant += 1
            else:
                n_skip += 1
        return only, n_requant, n_skip


def lowrank_tree(params, policy: QuantPolicy):
    """Offline, data-free SVD factors for every quantizable 2/3-D weight.

    Returns a pytree of {'B','A'} dicts (None where ineligible) matching the
    param container structure, vmapped over leading run / expert dims, or
    None when no path resolves to rank > 0 (base policy *or* overrides).
    Computed once per model; :func:`quantize_params` consumes it via
    ``lowrank_tree=`` so requantization never re-runs the SVD.
    """
    found = False

    def per_leaf(path, leaf):
        nonlocal found
        ps = _path_str(path)
        eff = policy.resolve(ps)
        last = ps.split(".")[-1]
        if (getattr(leaf, "ndim", 0) in (2, 3) and eff.rank > 0
                and eff.quantizes(last) and eff.quantizes(ps)
                and min(leaf.shape[-2:]) > eff.rank):
            found = True
            fn = lambda W: dict(zip(("B", "A"), svd_factors(W, eff.rank)))
            for _ in range(leaf.ndim - 2):
                fn = jax.vmap(fn)
            return fn(leaf)
        return None

    tree = jax.tree_util.tree_map_with_path(per_leaf, params)
    return tree if found else None
