"""Whole-model quantization driver: join params ↔ activation stats by path.

This is the tree-level orchestration behind every quantization entry point
(engine requantization, benchmark sweeps, dry-run shape inference).  Per
parameter path it:

1. resolves the effective :class:`~repro.core.policy.QuantPolicy` through the
   policy's fnmatch ``overrides`` (mixed precision),
2. resolves the effective policy's ``method`` through the
   :mod:`repro.quant.registry` (no string dispatch),
3. locates the matching activation-statistic leaf (methods with
   ``requires_stats=False`` synthesize a zero statistic), and
4. asks the quantizer for the :class:`~repro.core.ttq.QuantizedTensor`,
   vmapping over leading run / expert dims.

``repro.core`` keeps thin delegating shims so historical imports
(``repro.core.quantize_params``) continue to work.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.awq import AWQConfig
from repro.core.lowrank import svd_factors
from repro.core.policy import QuantPolicy

# projections sharing their input with a tapped sibling (one tap per input).
STAT_ALIAS = {
    "wk": "wq", "wv": "wq", "wkv_a": "wq", "wu": "wg",
    "w_in": "w_branch", "w_z": "w_x", "w_B": "w_x", "w_C": "w_x", "w_dt": "w_x",
}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(getattr(p, "key", p)))
    return ".".join(parts)


def _stats_key(rel_path: tuple) -> str:
    """('u0','mix','wq') → 'u0.mix.wq' with alias resolution on the leaf name."""
    *head, leaf = rel_path
    leaf = STAT_ALIAS.get(leaf, leaf)
    return ".".join([*head, leaf])


def _lookup_stats(stats_run: dict, rel_path: tuple):
    key = _stats_key(rel_path)
    if key in stats_run:
        return stats_run[key]
    # expert weights: stats stored per 'experts.wg'/'experts.wd'
    if rel_path[-1] in ("wg", "wu", "wd") and "experts" in rel_path:
        leaf = "wg" if rel_path[-1] in ("wg", "wu") else "wd"
        key2 = ".".join([*rel_path[:-1], leaf])
        if key2 in stats_run:
            return stats_run[key2]
    return None


def _tree_get(tree, path):
    node = tree
    try:
        for p in path:
            key = p.key if isinstance(p, jax.tree_util.DictKey) else (
                p.idx if isinstance(p, jax.tree_util.SequenceKey) else p)
            node = node[key]
        return node
    except (KeyError, IndexError, TypeError):
        return None


def quantize_params(params, stats, policy: QuantPolicy, *,
                    count: float = 1.0, acfg: Optional[AWQConfig] = None,
                    lowrank_tree=None):
    """Quantize the whole model: replace quantizable 2-D/3-D weights by
    :class:`~repro.core.ttq.QuantizedTensor`, joining activation stats by
    param path.

    ``stats`` is the structure produced by ``models.lm.forward(collect_stats=
    True)``: {'stack': [run-dicts of Σx² leaves, leading run dim], ...}.
    Weights whose stats are missing (untapped), that match ``policy.skip``,
    or whose override-resolved method is disabled stay in full precision.
    """
    countf = jnp.asarray(count, jnp.float32)
    # a caller-supplied acfg replaces the policy's *base* statistics config;
    # per-path overrides (p/alpha/lam/form) still apply on top of it
    base = policy if acfg is None else policy.with_(acfg=acfg)

    def per_leaf(path, leaf):
        ps = _path_str(path)
        if not isinstance(leaf, jnp.ndarray) or leaf.ndim < 2 or leaf.ndim > 4:
            return leaf
        eff = base.resolve(ps)
        if not eff.quantizes(ps.split(".")[-1]) or not eff.quantizes(ps):
            return leaf
        qz = eff.quantizer
        eff_acfg = eff.acfg
        parts = ps.split(".")
        ba = _tree_get(lowrank_tree, path) if lowrank_tree is not None else None

        def quant_one(W, stat, BA=None):
            B = A = None
            if BA is not None:
                B, A = BA["B"], BA["A"]
            elif eff.rank > 0 and min(W.shape) > eff.rank:
                B, A = svd_factors(W, eff.rank)
            return qz.quantize_weight(W, stat, countf, eff, eff_acfg, B, A)

        # locate the stats leaf for this weight (stats-free methods need none)
        stat = None
        if qz.requires_stats:
            if parts[0] not in ("stack", "enc_stack"):
                if isinstance(stats, dict) and ps in stats and leaf.ndim == 2:
                    return quant_one(leaf, stats[ps], None)
                return leaf
            run = (stats or {}).get(parts[0])
            if run is None:
                return leaf
            stat = _lookup_stats(run[int(parts[1])], tuple(parts[2:]))
            if stat is None:
                return leaf
        elif (parts[0] in ("stack", "enc_stack") and leaf.ndim >= 3) \
                or (parts[0] not in ("stack", "enc_stack") and leaf.ndim == 2):
            # stacked weights are ≥3-D (run dim); stacked 1-D params (norm
            # scales, decay vectors) must not be mistaken for 2-D weights
            stat = jnp.zeros(leaf.shape[:-2] + leaf.shape[-1:], jnp.float32)
        else:
            return leaf
        if ba is None:
            fn = lambda W, s: quant_one(W, s, None)
            for _ in range(leaf.ndim - 2):           # vmap over run / expert dims
                fn = jax.vmap(fn)
            return fn(leaf, stat)
        fn = quant_one
        for _ in range(leaf.ndim - 2):
            fn = jax.vmap(fn)
        return fn(leaf, stat, ba)

    return jax.tree_util.tree_map_with_path(per_leaf, params)


def lowrank_tree(params, policy: QuantPolicy):
    """Offline, data-free SVD factors for every quantizable 2/3-D weight.

    Returns a pytree of {'B','A'} dicts (None where ineligible) matching the
    param container structure, vmapped over leading run / expert dims, or
    None when no path resolves to rank > 0 (base policy *or* overrides).
    Computed once per model; :func:`quantize_params` consumes it via
    ``lowrank_tree=`` so requantization never re-runs the SVD.
    """
    found = False

    def per_leaf(path, leaf):
        nonlocal found
        ps = _path_str(path)
        eff = policy.resolve(ps)
        last = ps.split(".")[-1]
        if (getattr(leaf, "ndim", 0) in (2, 3) and eff.rank > 0
                and eff.quantizes(last) and eff.quantizes(ps)
                and min(leaf.shape[-2:]) > eff.rank):
            found = True
            fn = lambda W: dict(zip(("B", "A"), svd_factors(W, eff.rank)))
            for _ in range(leaf.ndim - 2):
                fn = jax.vmap(fn)
            return fn(leaf)
        return None

    tree = jax.tree_util.tree_map_with_path(per_leaf, params)
    return tree if found else None
