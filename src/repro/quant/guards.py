"""Robustness guards for the TTQ lifecycle (DESIGN.md §12).

TTQ's online calibration makes the shared statistics stream the engine's
most dangerous mutable state: one degenerate prompt (NaN/Inf activations,
an extreme outlier) gets tree-added into the session and the next fused
requant bakes the poison into the weights served to *every* subsequent
request.  This module owns the two validation points that keep that from
happening, plus the knobs for the serving-side isolation machinery:

* :func:`stats_summary` — one tiny jitted reduction per stats-tree
  structure returning ``(all_finite, mean_abs)``; the
  :class:`~repro.quant.session.CalibrationSession` guard calls it on every
  incoming update (and once on the running tree for the outlier gate);
* :func:`qt_health` — validates a candidate quantized tree *before* it can
  reach a weight swap: every scale/zero/D⁻¹ leaf finite, and (optionally)
  the relative drift of D⁻¹ against the last-good tree bounded;
* :class:`GuardConfig` — the frozen knob bundle ``EngineConfig.guard_cfg``
  carries through the scheduler (retry/backoff, admission-attempt cap),
  the engine (degradation-ladder hysteresis) and the quant model.

Both validators cost one blocking host transfer of two scalars — they run
per admission / per requant, never inside the decode hot loop, so the
transfer-guard and host-syncs/token invariants are untouched.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Knobs for the robustness layer (DESIGN.md §12).  Frozen so it can
    ride the (frozen) ``EngineConfig`` and be shared across components."""
    calib_outlier_factor: float = 100.0   # reject updates whose per-token
                                          # mean |stat| exceeds factor × the
                                          # running per-token mean
    calib_warmup_updates: int = 1         # accepted updates before the
                                          # outlier gate arms (the first
                                          # update defines the distribution)
    snapshot_ring: int = 4                # last-good pre-update snapshots
                                          # kept for rollback
    quarantine_max: int = 16              # rejected-update records retained
    requant_max_drift: float = -1.0       # max relative L2 drift of D⁻¹ per
                                          # swap (<0 = finiteness check only)
    max_retries: int = 1                  # per-request decode-fault retries
                                          # before the request errors out
    max_admission_attempts: int = 8       # MemoryError→preempt retries per
                                          # request per planning round (the
                                          # scheduler lifts this to at least
                                          # max_slots+1 so legitimate
                                          # preemption chains never trip it)
    degrade_pressure: float = 0.95        # pool pressure that climbs the
                                          # degradation ladder one rung
    recover_pressure: float = 0.5         # pressure that climbs back down


@jax.jit
def _summarize(tree):
    """(all_finite, mean |leaf|) over every array leaf of ``tree``."""
    leaves = jax.tree.leaves(tree)
    finite = jnp.asarray(True)
    total = jnp.asarray(0.0, jnp.float32)
    n = 0
    for leaf in leaves:
        finite = finite & jnp.isfinite(leaf).all()
        total = total + jnp.abs(leaf).astype(jnp.float32).sum()
        n += leaf.size
    return finite, total / max(n, 1)


def stats_summary(tree: Any) -> Tuple[bool, float]:
    """Host-side ``(all_finite, mean_abs)`` of a stats tree.

    One jitted program per tree *structure* (the engine sees exactly one:
    its model's stats layout), one blocking transfer of two scalars."""
    fin, mean = jax.device_get(_summarize(tree))
    return bool(fin), float(mean)


@jax.jit
def _qt_summarize(arrs, pairs):
    """Finiteness over ``arrs`` + max relative L2 drift over ``pairs``."""
    finite = jnp.asarray(True)
    for a in arrs:
        finite = finite & jnp.isfinite(a).all()
    drift = jnp.asarray(0.0, jnp.float32)
    for new, prev in pairs:
        num = jnp.linalg.norm((new - prev).astype(jnp.float32).ravel())
        den = jnp.maximum(jnp.linalg.norm(prev.astype(jnp.float32).ravel()),
                          1e-12)
        drift = jnp.maximum(drift, num / den)
    return finite, drift


def qt_health(tree: Any, prev_dinv: Dict[str, Any],
              max_drift: float) -> Tuple[bool, float]:
    """Validate a candidate quantized tree before it can reach a weight
    swap: every ``QuantizedTensor`` scale / zero / D⁻¹ leaf finite, and —
    when ``max_drift >= 0`` — the per-leaf relative L2 drift of D⁻¹ against
    the last-good tree (``prev_dinv``: path → previous dinv) bounded.

    Returns ``(healthy, max_drift_observed)``.  Leaves the delta gate
    untouched: a rejected tree's snapshots are never refreshed, so the
    next attempt re-quantizes from the same last-good state."""
    from repro.core.ttq import QuantizedTensor

    from .api import _path_str

    arrs, pairs = [], []

    def visit(path, leaf):
        if not isinstance(leaf, QuantizedTensor):
            return leaf
        for a in (leaf.scale, leaf.zero, leaf.dinv):
            if a is not None:
                arrs.append(a)
        prev = prev_dinv.get(_path_str(path))
        if prev is not None and leaf.dinv is not None \
                and prev.shape == leaf.dinv.shape:
            pairs.append((leaf.dinv, prev))
        return leaf

    jax.tree_util.tree_map_with_path(
        visit, tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    if not arrs:
        return True, 0.0
    fin, drift = jax.device_get(_qt_summarize(arrs, pairs))
    ok = bool(fin) and (max_drift < 0 or float(drift) <= float(max_drift))
    return ok, float(drift)


def token_count_ok(tokens: float) -> bool:
    """Token-count sanity for a calibration update: finite and positive."""
    try:
        t = float(tokens)
    except (TypeError, ValueError):
        return False
    return math.isfinite(t) and t > 0


def compiled_programs() -> int:
    """Jit-cache entries of the guard reductions (module-level caches —
    counted into ``TTQEngine.compiled_programs`` so the zero-steady-state
    recompile gates see them)."""
    return _summarize._cache_size() + _qt_summarize._cache_size()
