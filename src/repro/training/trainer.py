"""Training substrate — microbatched train step, ZeRO-1 sharding, FT loop.

``make_train_step`` builds the jitted step:

    (params, opt_state, batch) → (params, opt_state, metrics)

* gradient accumulation over ``n_microbatches`` with ``lax.scan`` — bounds
  activation memory AND lets XLA overlap microbatch-i's reduce-scatter with
  microbatch-(i+1)'s compute (latency-hiding scheduler),
* per-unit remat inside the layer scan (models/stack.py),
* ZeRO-1: (master, m, v) sharded over the data axes via
  ``opt_sharding`` — GSPMD inserts the gather on use,
* optional int8 gradient compression w/ error feedback (shard_map DP variant).

The :class:`Trainer` adds the production loop: checkpoint/restart, straggler
deadline-skip, failure injection (for FT tests), elastic re-mesh on resume.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_state_init, compressed_psum, cosine_schedule)
from repro.parallel import ParallelCtx, compat, param_sharding, shard_map

P = jax.sharding.PartitionSpec


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_microbatches: int = 1
    remat: bool = True
    zero1: bool = True
    grad_compress: bool = False      # int8 + error feedback (shard_map DP)
    opt: AdamWConfig = AdamWConfig()
    warmup: int = 100
    total_steps: int = 1000
    step_deadline_s: float = 0.0     # >0 → straggler deadline (Trainer loop)
    checkpoint_every: int = 100
    checkpoint_dir: str = ""
    keep: int = 3


def _microbatch(batch, n: int):
    """Split leading batch dim into (n, B/n, ...)."""
    return jax.tree.map(lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]),
                        batch)


def opt_sharding(opt_state, pshard, pctx: ParallelCtx, zero1: bool):
    """Sharding for opt state: like params, plus dp over dim0 when free (ZeRO-1)."""
    mesh = pctx.mesh
    dp_axes = pctx.data_axes
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]

    def per(ps, leaf):
        spec = list(ps.spec) + [None] * (leaf.ndim - len(ps.spec))
        if zero1:
            for i in range(leaf.ndim):
                if spec[i] is None and leaf.shape[i] % dp_size == 0 and leaf.shape[i] >= dp_size:
                    spec[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                    break
        return jax.sharding.NamedSharding(mesh, P(*spec))

    scalar = jax.sharding.NamedSharding(mesh, P())
    return {
        "step": scalar,
        "master": jax.tree.map(per, pshard, opt_state["master"]),
        "m": jax.tree.map(per, pshard, opt_state["m"]),
        "v": jax.tree.map(per, pshard, opt_state["v"]),
    }


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    pctx: Optional[ParallelCtx] = None,
                    loss_fn: Optional[Callable] = None,
                    param_dtypes=None):
    """Build the train step: (opt_state, batch) → (opt_state, metrics).

    Compute params are *derived* from the f32 masters at step start (mixed
    precision without buffer aliasing — opt_state is safely donatable; with
    ZeRO-1 the cast IS the all-gather of the sharded master).
    """
    lfn = loss_fn or (lambda p, b: lm.loss_fn(cfg, p, b, pctx=pctx,
                                              remat=tcfg.remat)[0])
    nmb = tcfg.n_microbatches

    def step_fn(opt_state, batch):
        dts = param_dtypes or jax.tree.map(lambda _: jnp.bfloat16,
                                           opt_state["master"])
        params = jax.tree.map(lambda m, dt: m.astype(dt),
                              opt_state["master"], dts)
        if pctx is not None and pctx.mesh is not None:
            shard = param_sharding(params, pctx)
            params = jax.tree.map(jax.lax.with_sharding_constraint, params, shard)
        if nmb > 1:
            mbs = _microbatch(batch, nmb)

            def acc(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(lfn)(params, mb)
                g_acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32),
                                     g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            loss = loss / nmb
        else:
            loss, grads = jax.value_and_grad(lfn)(params, batch)
        lr = cosine_schedule(opt_state["step"], tcfg.warmup, tcfg.total_steps,
                             tcfg.opt.lr)
        _, opt_state, om = adamw_update(grads, opt_state, tcfg.opt,
                                        params=params, lr_t=lr)
        return opt_state, {"loss": loss, **om}

    return step_fn


def make_compressed_dp_step(cfg: ModelConfig, tcfg: TrainConfig,
                            pctx: ParallelCtx):
    """DP-only variant with int8 gradient all-reduce + error feedback.

    Built with shard_map over the data axes (model axis unused — the
    demonstration of the distributed-optimization trick at small scale; the
    big pjit step keeps gradient reduction inside GSPMD).
    """
    dp = pctx.dp
    mesh = pctx.mesh

    def local_loss(params, batch):
        return lm.loss_fn(cfg, params, batch, remat=tcfg.remat)[0]

    def step_fn(params, opt_state, err, batch):
        def shard_fn(params, opt_state, err, batch):
            loss, grads = jax.value_and_grad(local_loss)(params, batch)
            grads, err_new = compressed_psum(grads, pctx.data_axes, err)
            n = 1
            for a in pctx.data_axes:
                n *= compat.axis_size(a)
            grads = jax.tree.map(lambda g: g / n, grads)
            lr = cosine_schedule(opt_state["step"], tcfg.warmup,
                                 tcfg.total_steps, tcfg.opt.lr)
            params, opt_state, om = adamw_update(grads, opt_state, tcfg.opt,
                                                 params=params, lr_t=lr)
            loss = jax.lax.pmean(loss, pctx.data_axes)
            return params, opt_state, err_new, {"loss": loss, **om}

        pspec = jax.tree.map(lambda _: P(), params)
        ospec = jax.tree.map(lambda _: P(), opt_state)
        espec = jax.tree.map(lambda _: P(), err)
        bspec = jax.tree.map(lambda _: P(dp), batch)
        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(pspec, ospec, espec, bspec),
            out_specs=(pspec, ospec, espec,
                       {"loss": P(), "grad_norm": P(), "lr": P()}),
            check_vma=False,
        )(params, opt_state, err, batch)

    return step_fn


class Trainer:
    """Production loop: jit, donate, checkpoint/restart, straggler deadline,
    failure injection for FT tests, elastic re-mesh on resume."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, data_iter,
                 pctx: Optional[ParallelCtx] = None, key=None):
        from repro.checkpoint import CheckpointManager
        self.cfg, self.tcfg, self.pctx = cfg, tcfg, pctx
        self.data = data_iter
        key = key if key is not None else jax.random.PRNGKey(0)
        params0 = lm.init_params(cfg, key)
        self._dtypes = jax.tree.map(lambda p: p.dtype, params0)
        self.opt_state = adamw_init(params0)
        del params0
        if pctx is not None and pctx.mesh is not None:
            tmpl = self.params  # host-side template for sharding rules
            pshard = param_sharding(tmpl, pctx)
            oshard = opt_sharding(self.opt_state, pshard, pctx, tcfg.zero1)
            self.opt_state = jax.tree.map(jax.device_put, self.opt_state, oshard)
        self.step_fn = jax.jit(
            make_train_step(cfg, tcfg, pctx, param_dtypes=self._dtypes),
            donate_argnums=(0,))
        self.ckpt = (CheckpointManager(tcfg.checkpoint_dir, keep=tcfg.keep)
                     if tcfg.checkpoint_dir else None)
        self.step = 0
        self.metrics_log: list = []
        self.failure_hook: Optional[Callable[[int], None]] = None  # FT tests
        self.skipped_steps: list = []

    @property
    def params(self):
        """Compute params (bf16) derived from the f32 masters."""
        return jax.tree.map(lambda m, dt: m.astype(dt),
                            self.opt_state["master"], self._dtypes)

    def restore_if_available(self):
        if self.ckpt is None:
            return False
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        state = self.ckpt.restore(latest, {"opt": self.opt_state})
        self.opt_state = state["opt"]
        self.step = latest
        return True

    def run(self, n_steps: int):
        deadline = self.tcfg.step_deadline_s
        end = self.step + n_steps
        while self.step < end:
            batch = next(self.data)
            if self.failure_hook is not None:
                self.failure_hook(self.step)   # may raise — simulated crash
            t0 = time.monotonic()
            self.opt_state, m = self.step_fn(self.opt_state, batch)
            m = jax.tree.map(float, m)
            dt = time.monotonic() - t0
            if deadline > 0 and dt > deadline:
                # straggler: log + continue (a real fleet reissues the step on
                # a backup slice; state here is already consistent post-step)
                self.skipped_steps.append((self.step, dt))
            self.metrics_log.append({"step": self.step, "time_s": dt, **m})
            self.step += 1
            if self.ckpt and self.step % self.tcfg.checkpoint_every == 0:
                self.ckpt.save(self.step, {"opt": self.opt_state})
        return self.metrics_log
