"""Fault-tolerance runtime pieces — heartbeat/straggler monitor, failure
injection (tests), elastic re-mesh controller.

On a real fleet these hook into the cluster scheduler; here they are
process-local but exercise the same state machine the Trainer relies on:
    monitor → detect (deadline / injected fault) → recover
    (restart-from-checkpoint | skip-step | re-mesh-and-reshard).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.checkpoint import CheckpointManager, reshard_restore
from repro.parallel import ParallelCtx, param_sharding


class StepMonitor:
    """Per-step deadline watchdog. Stores (step, duration) of violations.

    A real deployment maps `on_straggle` to reissuing the step on a backup
    slice (the optimizer state is consistent because the step either fully
    completed or is re-run from the same params — steps are idempotent given
    the deterministic data pipeline).
    """

    def __init__(self, deadline_s: float,
                 on_straggle: Optional[Callable[[int, float], None]] = None):
        self.deadline = deadline_s
        self.violations: list = []
        self.on_straggle = on_straggle
        self._t0 = 0.0

    def start(self):
        self._t0 = time.monotonic()

    def finish(self, step: int) -> bool:
        dt = time.monotonic() - self._t0
        if self.deadline > 0 and dt > self.deadline:
            self.violations.append((step, dt))
            if self.on_straggle:
                self.on_straggle(step, dt)
            return True
        return False


class FailureInjector:
    """Deterministic fault injection for FT tests: raises at chosen steps."""

    class Crash(RuntimeError):
        pass

    def __init__(self, fail_at: set):
        self.fail_at = set(fail_at)
        self.fired: set = set()

    def __call__(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise FailureInjector.Crash(f"injected failure at step {step}")


class ElasticController:
    """Elastic scaling: resume a checkpoint onto a different mesh.

    ``rescale(ckpt_dir, step, params_like, opt_like, new_pctx)`` loads the
    latest consistent checkpoint and reshards every leaf onto the new mesh —
    the recovery path when a pod is lost (shrink) or re-added (grow).
    """

    @staticmethod
    def rescale(ckpt: CheckpointManager, step: int, params_like, opt_like,
                new_pctx: ParallelCtx, opt_sharding_fn=None):
        pshard = param_sharding(params_like, new_pctx)
        like = {"params": params_like}
        shard = {"params": pshard}
        if opt_like is not None:
            if opt_sharding_fn is None:
                mesh = new_pctx.mesh
                oshard = jax.tree.map(
                    lambda l: jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec(*([None] * l.ndim))),
                    opt_like)
            else:
                oshard = opt_sharding_fn(opt_like, pshard, new_pctx)
            like["opt"] = opt_like
            shard["opt"] = oshard
        out = reshard_restore(ckpt, step, like, shard)
        return out["params"], out.get("opt")
