from .ft import ElasticController, FailureInjector, StepMonitor

__all__ = ["ElasticController", "FailureInjector", "StepMonitor"]
