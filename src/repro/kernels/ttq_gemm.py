"""Pallas-TPU fused dequant matmul — the Marlin analogue for TPU v5e.

y (T, d') = x (T, d) [∘ D⁻¹] @ deq(W_packed)ᵀ

Weights live in HBM packed ``32//bits`` values per int32, (d', d·bits/32) —
the 4-bit path moves 4× fewer weight bytes than bf16, which is the entire
speedup mechanism for memory-bound decode (paper Appendix H, Tables 4-8).
Per k-tile the kernel:

  HBM→VMEM  w_packed (bn, bk·bits/32) int32, scale/zero (bn, bk/g)
  VPU       unpack nibbles (shift+mask), dequantize to f32 with the groupwise
            scale broadcast, optional x-tile prescale by D⁻¹ (prologue fusion
            the paper could not do on CUDA)
  MXU       (bm, bk) @ (bk, bn) accumulate f32 into the output tile

Grid (T/bm, d'/bn, d/bk) with the k axis marked "arbitrary" (sequential
accumulation); bm/bn default 128 (MXU-aligned), bk 256.  Block constraints:
bk % group_size == 0 and bk % (32//bits) == 0.

Validated in interpret mode on CPU (this container); on real hardware the
(bn, bk/g) scale tiles with g=32 imply an 8-lane broadcast-reshape that Mosaic
supports via jnp.repeat; g ∈ {128, 256} is layout-optimal (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(x_ref, w_ref, s_ref, z_ref, dinv_ref, o_ref, *, bits: int,
                 group_size: int, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    per = 32 // bits
    mask = (1 << bits) - 1
    packed = w_ref[...]                                   # (bn, bk//per) int32
    bn, bkp = packed.shape
    bk = bkp * per
    shifts = (jnp.arange(per, dtype=jnp.int32) * bits)[None, None, :]
    wint = (packed[:, :, None] >> shifts) & mask          # (bn, bk//per, per)
    wint = wint.reshape(bn, bk).astype(jnp.float32)
    g = group_size
    s = jnp.repeat(s_ref[...].astype(jnp.float32), g, axis=1)   # (bn, bk)
    z = jnp.repeat(z_ref[...].astype(jnp.float32), g, axis=1)
    w = wint * s + z                                      # dequantized (bn, bk)

    x = x_ref[...].astype(jnp.float32)                    # (bm, bk)
    if dinv_ref is not None:
        x = x * dinv_ref[...].astype(jnp.float32)         # (1, bk) broadcast
    o_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _pad_to(x, m, axis):
    r = (-x.shape[axis]) % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, r)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group_size", "bm", "bn", "bk", "interpret"),
)
def ttq_gemm(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
             zero: jnp.ndarray, dinv: jnp.ndarray | None = None, *,
             bits: int = 4, group_size: int = 32,
             bm: int = 128, bn: int = 128, bk: int = 256,
             interpret: bool | None = None) -> jnp.ndarray:
    """x: (..., d) → (..., d'). packed: (d', d·bits/32) int32; S,Z: (d', d/g)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    per = 32 // bits
    lead = x.shape[:-1]
    d = x.shape[-1]
    dp = packed.shape[0]
    x2 = x.reshape(-1, d)
    T = x2.shape[0]

    # MXU path needs 8-row alignment; interpret mode takes T exactly so the
    # emulated dot presents the same (M, K)×(K, N) shape as the jnp fallback
    # (padding rows changes the backend's gemm micro-kernel choice, which
    # perturbs f32 accumulation order → bf16 rounding-boundary flips)
    bm = min(bm, T if interpret else max(8, ((T + 7) // 8) * 8))
    bk = min(bk, d)
    assert d % bk == 0 or bk >= d, "d must tile by bk"
    if bk % group_size or bk % per:
        raise ValueError(f"bk={bk} must be divisible by group_size={group_size} and {per}")
    bn = min(bn, dp)

    x2 = _pad_to(x2, bm, 0)
    packed_p = _pad_to(packed, bn, 0)
    scale_p = _pad_to(scale, bn, 0)
    zero_p = _pad_to(zero, bn, 0)
    Tp, dpp = x2.shape[0], packed_p.shape[0]
    n_k = d // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bn, bk // per), lambda i, j, k: (j, k)),
        pl.BlockSpec((bn, bk // group_size), lambda i, j, k: (j, k)),
        pl.BlockSpec((bn, bk // group_size), lambda i, j, k: (j, k)),
    ]
    args = [x2, packed_p, scale_p, zero_p]
    if dinv is not None:
        in_specs.append(pl.BlockSpec((1, bk), lambda i, j, k: (0, k)))
        args.append(dinv.reshape(1, d))
        kern = functools.partial(_gemm_kernel, bits=bits, group_size=group_size, n_k=n_k)
    else:
        kern = functools.partial(
            lambda xr, wr, sr, zr, orf, **kw: _gemm_kernel(xr, wr, sr, zr, None, orf, **kw),
            bits=bits, group_size=group_size, n_k=n_k)

    out = pl.pallas_call(
        kern,
        grid=(Tp // bm, dpp // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, dpp), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(*args)
    return out[:T, :dp].reshape(*lead, dp).astype(x.dtype)
