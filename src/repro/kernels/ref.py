"""Pure-jnp oracles for the Pallas kernels (the allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qdq import unpack_bits


def ttq_gemm_ref(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                 zero: jnp.ndarray, *, bits: int, group_size: int,
                 dinv: jnp.ndarray | None = None) -> jnp.ndarray:
    """y (T, d') = x (T, d) [∘dinv] @ deq(packed (d', d·bits/32), S, Z)ᵀ, f32 accum."""
    dp, _ = packed.shape[0], packed.shape[1]
    d = x.shape[-1]
    wint = unpack_bits(packed, d, bits).astype(jnp.float32)          # (d', d)
    g = group_size
    s = jnp.repeat(scale.astype(jnp.float32), g, axis=1)             # (d', d)
    z = jnp.repeat(zero.astype(jnp.float32), g, axis=1)
    W = wint * s + z
    xf = x.astype(jnp.float32)
    if dinv is not None:
        xf = xf * dinv[None, :].astype(jnp.float32)
    return xf @ W.T


NEG_INF = -1e30


def kv_attn_ref(q: jnp.ndarray, kq: jnp.ndarray, ks: jnp.ndarray,
                vq: jnp.ndarray, vs: jnp.ndarray, cur_pos: jnp.ndarray, *,
                bits: int = 8, group_size: int = 0,
                scale: float | None = None, soft_cap: float = 0.0,
                window: int = 0) -> jnp.ndarray:
    """Decode attention over a quantized cache: dequantize, then the same
    grouped-query math as ``models.common.decode_attention`` (f32 softmax).

    q: (B,H,1,Dh); kq/vq codes (B,Hkv,S,Dc); ks/vs scales (B,Hkv,S,Dh//g);
    cur_pos: (B,) int32.  The allclose target for ``ttq_attn``.
    """
    from repro.core.kvquant import dequantize_kv
    B, H, _, Dh = q.shape
    Hkv, S = kq.shape[1], kq.shape[2]
    G = H // Hkv
    sc = scale if scale is not None else Dh ** -0.5
    k = dequantize_kv(kq, ks, jnp.float32, bits=bits, group_size=group_size)
    v = dequantize_kv(vq, vs, jnp.float32, bits=bits, group_size=group_size)
    qg = (q[:, :, 0].astype(jnp.float32) * sc).reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k)
    if soft_cap > 0:
        s = soft_cap * jnp.tanh(s / soft_cap)
    ki = jnp.arange(S)
    mask = ki[None, :] <= cur_pos[:, None]
    if window > 0:
        mask &= ki[None, :] > cur_pos[:, None] - window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v)
    return o.reshape(B, H, 1, Dh).astype(q.dtype)


def kv_suffix_attn_ref(q: jnp.ndarray, kq: jnp.ndarray, ks: jnp.ndarray,
                       vq: jnp.ndarray, vs: jnp.ndarray, pos: jnp.ndarray, *,
                       bits: int = 8, group_size: int = 0,
                       scale: float | None = None,
                       soft_cap: float = 0.0) -> jnp.ndarray:
    """Speculative-window attention over a quantized cache (DESIGN.md §11).

    q: (B,H,S,Dh) — S in-window queries per slot at absolute positions
    ``pos[b]..pos[b]+S-1``; the window's k/v rows were already written to the
    cache (write-then-read), so query s attends rows ≤ pos[b]+s.  Same
    dequantize-then-grouped-query math as :func:`kv_attn_ref` with a query
    axis, so verify logits match sequential decode bit-for-bit.
    """
    from repro.core.kvquant import dequantize_kv
    B, H, S, Dh = q.shape
    Hkv, Smax = kq.shape[1], kq.shape[2]
    G = H // Hkv
    sc = scale if scale is not None else Dh ** -0.5
    k = dequantize_kv(kq, ks, jnp.float32, bits=bits, group_size=group_size)
    v = dequantize_kv(vq, vs, jnp.float32, bits=bits, group_size=group_size)
    qg = (q.astype(jnp.float32) * sc).reshape(B, Hkv, G, S, Dh)
    s = jnp.einsum("bhgsd,bhkd->bhgsk", qg, k)
    if soft_cap > 0:
        s = soft_cap * jnp.tanh(s / soft_cap)
    ki = jnp.arange(Smax)
    qi = pos[:, None] + jnp.arange(S)                          # (B, S)
    mask = ki[None, None, :] <= qi[:, :, None]                 # (B, S, Smax)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgsk,bhkd->bhgsd", p, v)
    return o.reshape(B, H, S, Dh).astype(q.dtype)


def kv_paged_suffix_attn_ref(q: jnp.ndarray, kq: jnp.ndarray, ks: jnp.ndarray,
                             vq: jnp.ndarray, vs: jnp.ndarray,
                             block_table: jnp.ndarray, pos: jnp.ndarray, *,
                             bits: int = 8, group_size: int = 0,
                             scale: float | None = None,
                             soft_cap: float = 0.0) -> jnp.ndarray:
    """Paged speculative-window attention: gather each slot's block-table view
    into the contiguous layout, then the exact :func:`kv_suffix_attn_ref`
    math (mirrors :func:`kv_paged_attn_ref`)."""
    kqg, ksg = gather_paged_kv(kq, block_table), gather_paged_kv(ks, block_table)
    vqg, vsg = gather_paged_kv(vq, block_table), gather_paged_kv(vs, block_table)
    return kv_suffix_attn_ref(q, kqg, ksg, vqg, vsg, pos, bits=bits,
                              group_size=group_size, scale=scale,
                              soft_cap=soft_cap)


def gather_paged_kv(pool: jnp.ndarray, block_table: jnp.ndarray) -> jnp.ndarray:
    """Materialize a per-slot contiguous view of a paged pool.

    pool (NB, Hkv, bs, D·) indexed by block_table (B, nblk) →
    (B, Hkv, nblk·bs, D·).  Slots' unallocated entries point at the sink
    block 0; its rows are garbage but land beyond ``cur_pos`` and are masked
    by the attention read.
    """
    g = jnp.take(pool, block_table, axis=0)              # (B, nblk, Hkv, bs, D)
    B, nblk, Hkv, bs, D = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(B, Hkv, nblk * bs, D)


def kv_paged_attn_ref(q: jnp.ndarray, kq: jnp.ndarray, ks: jnp.ndarray,
                      vq: jnp.ndarray, vs: jnp.ndarray,
                      block_table: jnp.ndarray, cur_pos: jnp.ndarray, *,
                      bits: int = 8, group_size: int = 0,
                      scale: float | None = None,
                      soft_cap: float = 0.0) -> jnp.ndarray:
    """Paged decode attention oracle: gather the block table's view of each
    (NB, Hkv, bs, ·) pool into the contiguous (B, Hkv, S, ·) layout, then the
    exact :func:`kv_attn_ref` math — the allclose target for the paged Pallas
    kernel and the ``use_pallas=False`` fallback."""
    kqg, ksg = gather_paged_kv(kq, block_table), gather_paged_kv(ks, block_table)
    vqg, vsg = gather_paged_kv(vq, block_table), gather_paged_kv(vs, block_table)
    return kv_attn_ref(q, kqg, ksg, vqg, vsg, cur_pos, bits=bits,
                       group_size=group_size, scale=scale, soft_cap=soft_cap)


def ttq_quantize_ref(W: jnp.ndarray, D: jnp.ndarray, *, bits: int,
                     group_size: int):
    """Online scaled groupwise quantize+pack.

    W (d', d), D (d,) → packed (d', d·bits/32) int32, S (d', d/g) f32, Z (d', d/g) f32.
    """
    qmax = (1 << bits) - 1
    g = group_size
    dp, d = W.shape
    Ws = W.astype(jnp.float32) * D[None, :].astype(jnp.float32)
    Wg = Ws.reshape(dp, d // g, g)
    wmax = Wg.max(axis=-1)
    wmin = Wg.min(axis=-1)
    S = jnp.maximum((wmax - wmin) / qmax, 1e-12)
    Z = wmin
    wint = jnp.clip(jnp.round((Wg - Z[..., None]) / S[..., None]), 0, qmax)
    wint = wint.reshape(dp, d).astype(jnp.int32)
    per = 32 // bits
    shifts = jnp.arange(per, dtype=jnp.int32) * bits
    packed = (wint.reshape(dp, d // per, per) << shifts).sum(axis=-1)
    return packed, S, Z
