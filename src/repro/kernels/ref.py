"""Pure-jnp oracles for the Pallas kernels (the allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.qdq import unpack_bits


def ttq_gemm_ref(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                 zero: jnp.ndarray, *, bits: int, group_size: int,
                 dinv: jnp.ndarray | None = None) -> jnp.ndarray:
    """y (T, d') = x (T, d) [∘dinv] @ deq(packed (d', d·bits/32), S, Z)ᵀ, f32 accum."""
    dp, _ = packed.shape[0], packed.shape[1]
    d = x.shape[-1]
    wint = unpack_bits(packed, d, bits).astype(jnp.float32)          # (d', d)
    g = group_size
    s = jnp.repeat(scale.astype(jnp.float32), g, axis=1)             # (d', d)
    z = jnp.repeat(zero.astype(jnp.float32), g, axis=1)
    W = wint * s + z
    xf = x.astype(jnp.float32)
    if dinv is not None:
        xf = xf * dinv[None, :].astype(jnp.float32)
    return xf @ W.T


def ttq_quantize_ref(W: jnp.ndarray, D: jnp.ndarray, *, bits: int,
                     group_size: int):
    """Online scaled groupwise quantize+pack.

    W (d', d), D (d,) → packed (d', d·bits/32) int32, S (d', d/g) f32, Z (d', d/g) f32.
    """
    qmax = (1 << bits) - 1
    g = group_size
    dp, d = W.shape
    Ws = W.astype(jnp.float32) * D[None, :].astype(jnp.float32)
    Wg = Ws.reshape(dp, d // g, g)
    wmax = Wg.max(axis=-1)
    wmin = Wg.min(axis=-1)
    S = jnp.maximum((wmax - wmin) / qmax, 1e-12)
    Z = wmin
    wint = jnp.clip(jnp.round((Wg - Z[..., None]) / S[..., None]), 0, qmax)
    wint = wint.reshape(dp, d).astype(jnp.int32)
    per = 32 // bits
    shifts = jnp.arange(per, dtype=jnp.int32) * bits
    packed = (wint.reshape(dp, d // per, per) << shifts).sum(axis=-1)
    return packed, S, Z
