"""Pallas-TPU online quantization — one streaming pass HBM→VMEM→HBM.

Given the bf16/f32 master weight W (d', d) and the per-prompt activation
diagonal D (d,), produce in a single pass:

    packed (d', d·bits/32) int32   — nibble-packed G[(W∘D)]
    scale  (d', d/g) f32, zero (d', d/g) f32

This is TTQ's per-prompt "find_params" (paper Appendix H) as a memory-bound
streaming kernel: each (bm, bk) tile is read once, scaled by D, reduced to
groupwise min/max on the VPU, quantized, packed, and written back at
``bits/16`` of the input traffic.  No inter-tile dependencies → fully parallel
grid (d'/bm, d/bk); bk % group_size == 0 keeps groups tile-local.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(w_ref, d_ref, packed_ref, s_ref, z_ref, *, bits: int,
                  group_size: int):
    qmax = float((1 << bits) - 1)
    per = 32 // bits
    g = group_size
    w = w_ref[...].astype(jnp.float32) * d_ref[...].astype(jnp.float32)  # (bm,bk)
    bm, bk = w.shape
    wg = w.reshape(bm, bk // g, g)
    wmax = wg.max(axis=-1)
    wmin = wg.min(axis=-1)
    s = jnp.maximum((wmax - wmin) / qmax, 1e-12)                  # (bm, bk//g)
    z = wmin
    wint = jnp.clip(jnp.round((wg - z[..., None]) / s[..., None]), 0.0, qmax)
    wint = wint.reshape(bm, bk).astype(jnp.int32)
    shifts = (jnp.arange(per, dtype=jnp.int32) * bits)[None, None, :]
    packed = (wint.reshape(bm, bk // per, per) << shifts).sum(axis=-1)
    packed_ref[...] = packed
    s_ref[...] = s
    z_ref[...] = z


@functools.partial(
    jax.jit, static_argnames=("bits", "group_size", "bm", "bk", "interpret"))
def ttq_quantize(W: jnp.ndarray, D: jnp.ndarray, *, bits: int = 4,
                 group_size: int = 32, bm: int = 256, bk: int = 512,
                 interpret: bool | None = None):
    """W (d', d) ∘ D (d,) → (packed int32 (d', d·bits/32), S, Z (d', d/g))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    per = 32 // bits
    dp, d = W.shape
    bm = min(bm, dp)
    bk = min(bk, d)
    if d % bk or dp % bm:
        # fall back to whole-row/col blocks for ragged shapes
        bm = dp if dp % bm else bm
        bk = d if d % bk else bk
    if bk % group_size or bk % per:
        raise ValueError(f"bk={bk} must be divisible by g={group_size} and {per}")

    grid = (dp // bm, d // bk)
    kern = functools.partial(_quant_kernel, bits=bits, group_size=group_size)
    packed, S, Z = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, bk), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bk // per), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // group_size), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // group_size), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp, d // per), jnp.int32),
            jax.ShapeDtypeStruct((dp, d // group_size), jnp.float32),
            jax.ShapeDtypeStruct((dp, d // group_size), jnp.float32),
        ],
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
        interpret=interpret,
    )(W, D.reshape(1, d))
    return packed, S, Z
