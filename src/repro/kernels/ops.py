"""jit'd public wrappers for the Pallas kernels, with pure-jnp fallbacks.

The rest of the framework calls these; ``use_pallas=False`` (or unsupported
bit-widths) routes to the XLA fallback so every code path runs everywhere.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import ref as _ref
from .ttq_attn import ttq_decode_attention as _ttq_attn_pallas
from .ttq_attn import ttq_paged_decode_attention as _ttq_paged_attn_pallas
from .ttq_gemm import ttq_gemm as _ttq_gemm_pallas
from .ttq_quantize import ttq_quantize as _ttq_quantize_pallas

_PACKABLE = (2, 4, 8)
_KV_BITS = (4, 8)


def ttq_gemm(x, packed, scale, zero, dinv=None, *, bits=4, group_size=32,
             use_pallas=True, **block_kw):
    if use_pallas and bits in _PACKABLE:
        return _ttq_gemm_pallas(x, packed, scale, zero, dinv, bits=bits,
                                group_size=group_size, **block_kw)
    lead = x.shape[:-1]
    y = _ref.ttq_gemm_ref(x.reshape(-1, x.shape[-1]), packed, scale, zero,
                          bits=bits, group_size=group_size, dinv=dinv)
    return y.reshape(*lead, -1).astype(x.dtype)


def kv_decode_attention(q, kq, ks, vq, vs, cur_pos, *, bits=8, group_size=0,
                        scale=None, soft_cap=0.0, window=0, use_pallas=True,
                        **block_kw):
    """Decode attention over an int8/int4 KV cache (fused dequant read).

    The Pallas path streams the quantized cache HBM→VMEM and dequantizes
    in-register; unsupported bit-widths or a windowed mask route to the
    pure-jnp oracle so every code path runs everywhere.
    """
    if use_pallas and bits in _KV_BITS and window == 0:
        return _ttq_attn_pallas(q, kq, ks, vq, vs, cur_pos, bits=bits,
                                group_size=group_size, scale=scale,
                                soft_cap=soft_cap, **block_kw)
    return _ref.kv_attn_ref(q, kq, ks, vq, vs, cur_pos, bits=bits,
                            group_size=group_size, scale=scale,
                            soft_cap=soft_cap, window=window)


def kv_paged_decode_attention(q, kq, ks, vq, vs, block_table, cur_pos, *,
                              bits=8, group_size=0, scale=None, soft_cap=0.0,
                              use_pallas=True):
    """Decode attention over a block-paged int8/int4 KV pool.

    ``kq/ks/vq/vs`` are the (NB, Hkv, block_size, ·) pools; ``block_table``
    (B, nblk) maps each slot's logical blocks to physical pool blocks.  The
    Pallas path streams one physical block per S-tile through a
    scalar-prefetched table lookup; the fallback gathers the table's view
    and runs the contiguous jnp oracle (identical math).
    """
    if use_pallas and bits in _KV_BITS:
        return _ttq_paged_attn_pallas(q, kq, ks, vq, vs, block_table, cur_pos,
                                      bits=bits, group_size=group_size,
                                      scale=scale, soft_cap=soft_cap)
    return _ref.kv_paged_attn_ref(q, kq, ks, vq, vs, block_table, cur_pos,
                                  bits=bits, group_size=group_size,
                                  scale=scale, soft_cap=soft_cap)


def ttq_quantize(W, D, *, bits=4, group_size=32, use_pallas=True, **block_kw):
    if use_pallas and bits in _PACKABLE:
        return _ttq_quantize_pallas(W, D, bits=bits, group_size=group_size,
                                    **block_kw)
    return _ref.ttq_quantize_ref(W, D, bits=bits, group_size=group_size)
