"""jit'd public wrappers for the Pallas kernels, with pure-jnp fallbacks.

The rest of the framework calls these; ``use_pallas=False`` (or unsupported
bit-widths) routes to the XLA fallback so every code path runs everywhere.

The ``*_tp`` variants wrap a dispatch in ``shard_map`` when a mesh is active
so each device runs the kernel on its local weight/KV-head shard
(DESIGN.md §"Mesh-sharded serving"); when the static shapes don't divide the
model axis they fall back to the unwrapped call, which GSPMD partitions.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref as _ref
from .ttq_attn import ttq_decode_attention as _ttq_attn_pallas
from .ttq_attn import ttq_paged_decode_attention as _ttq_paged_attn_pallas
from .ttq_gemm import ttq_gemm as _ttq_gemm_pallas
from .ttq_quantize import ttq_quantize as _ttq_quantize_pallas

_PACKABLE = (2, 4, 8)
_KV_BITS = (4, 8)


def ttq_gemm(x, packed, scale, zero, dinv=None, *, bits=4, group_size=32,
             use_pallas=True, **block_kw):
    if use_pallas and bits in _PACKABLE:
        return _ttq_gemm_pallas(x, packed, scale, zero, dinv, bits=bits,
                                group_size=group_size, **block_kw)
    lead = x.shape[:-1]
    y = _ref.ttq_gemm_ref(x.reshape(-1, x.shape[-1]), packed, scale, zero,
                          bits=bits, group_size=group_size, dinv=dinv)
    return y.reshape(*lead, -1).astype(x.dtype)


def kv_decode_attention(q, kq, ks, vq, vs, cur_pos, *, bits=8, group_size=0,
                        scale=None, soft_cap=0.0, window=0, use_pallas=True,
                        **block_kw):
    """Decode attention over an int8/int4 KV cache (fused dequant read).

    The Pallas path streams the quantized cache HBM→VMEM and dequantizes
    in-register; unsupported bit-widths or a windowed mask route to the
    pure-jnp oracle so every code path runs everywhere.
    """
    if use_pallas and bits in _KV_BITS and window == 0:
        return _ttq_attn_pallas(q, kq, ks, vq, vs, cur_pos, bits=bits,
                                group_size=group_size, scale=scale,
                                soft_cap=soft_cap, **block_kw)
    return _ref.kv_attn_ref(q, kq, ks, vq, vs, cur_pos, bits=bits,
                            group_size=group_size, scale=scale,
                            soft_cap=soft_cap, window=window)


def kv_paged_decode_attention(q, kq, ks, vq, vs, block_table, cur_pos, *,
                              bits=8, group_size=0, scale=None, soft_cap=0.0,
                              use_pallas=True):
    """Decode attention over a block-paged int8/int4 KV pool.

    ``kq/ks/vq/vs`` are the (NB, Hkv, block_size, ·) pools; ``block_table``
    (B, nblk) maps each slot's logical blocks to physical pool blocks.  The
    Pallas path streams one physical block per S-tile through a
    scalar-prefetched table lookup; the fallback gathers the table's view
    and runs the contiguous jnp oracle (identical math).
    """
    if use_pallas and bits in _KV_BITS:
        return _ttq_paged_attn_pallas(q, kq, ks, vq, vs, block_table, cur_pos,
                                      bits=bits, group_size=group_size,
                                      scale=scale, soft_cap=soft_cap)
    return _ref.kv_paged_attn_ref(q, kq, ks, vq, vs, block_table, cur_pos,
                                  bits=bits, group_size=group_size,
                                  scale=scale, soft_cap=soft_cap)


def kv_suffix_attention(q, kq, ks, vq, vs, pos, *, bits=8, group_size=0,
                        scale=None, soft_cap=0.0, use_pallas=True,
                        **block_kw):
    """Speculative-verify attention over an int8/int4 KV cache.

    ``q`` carries the S in-window queries per slot; the window's k/v rows
    were already scattered into the cache (write-then-read, DESIGN.md §11).
    Dispatch hint only for now: a Pallas suffix kernel would need a q-tile
    axis on the decode kernel's S-loop, so every bit-width routes to the
    pure-jnp oracle (``use_pallas`` accepted for signature parity).
    """
    del use_pallas, block_kw
    return _ref.kv_suffix_attn_ref(q, kq, ks, vq, vs, pos, bits=bits,
                                   group_size=group_size, scale=scale,
                                   soft_cap=soft_cap)


def kv_paged_suffix_attention(q, kq, ks, vq, vs, block_table, pos, *, bits=8,
                              group_size=0, scale=None, soft_cap=0.0,
                              use_pallas=True):
    """Speculative-verify attention over a block-paged int8/int4 KV pool.

    Gathers the block table's view and runs the contiguous suffix oracle —
    identical math to the paged decode read (no Pallas suffix kernel yet).
    """
    del use_pallas
    return _ref.kv_paged_suffix_attn_ref(q, kq, ks, vq, vs, block_table, pos,
                                         bits=bits, group_size=group_size,
                                         scale=scale, soft_cap=soft_cap)


def ttq_quantize(W, D, *, bits=4, group_size=32, use_pallas=True, **block_kw):
    if use_pallas and bits in _PACKABLE:
        return _ttq_quantize_pallas(W, D, bits=bits, group_size=group_size,
                                    **block_kw)
    return _ref.ttq_quantize_ref(W, D, bits=bits, group_size=group_size)


# ---------------------------------------------------------------- TP wrappers

def _mesh_sizes(pctx):
    """(model size, data size) — 0 when no usable mesh/model axis."""
    if pctx is None or pctx.mesh is None:
        return 0, 1
    sizes = dict(pctx.mesh.shape)
    n = sizes.get(pctx.model_axis, 0)
    ndp = 1
    for a in pctx.data_axes:
        ndp *= sizes.get(a, 1)
    return n, ndp


def _tp_gemm_ok(pctx, tp, x, packed, scale, bits, group_size):
    """Static-shape eligibility for a shard_map'd TP gemm: every sharded dim
    must divide exactly, and a column (input-feature) split must keep each
    local slice group- and pack-aligned so scale/zero/packed slices line up."""
    if tp not in ("row", "col") or x.ndim < 2:
        return False
    n, ndp = _mesh_sizes(pctx)
    if n <= 1 or x.shape[0] % ndp:
        return False
    if tp == "row":
        return packed.shape[0] % n == 0 and scale.shape[0] % n == 0
    d = x.shape[-1]
    per = 32 // bits
    g = group_size or d
    return (d % n == 0 and (d // n) % g == 0 and (d // n) % per == 0
            and packed.shape[1] % n == 0 and scale.shape[1] % n == 0)


def ttq_gemm_tp(x, packed, scale, zero, dinv=None, *,  # tracecheck: ok[TC303]
                bits=4, group_size=32, use_pallas=True, pctx=None, tp=None,
                **block_kw):  # use_pallas forwards to ttq_gemm's own oracle
    """``ttq_gemm`` with Megatron-style tensor parallelism.

    ``tp='row'``: output features sharded on the model axis — each device
    multiplies against its (d'/n, d) shard, no collective, output stays
    sharded.  ``tp='col'``: input features sharded — each device consumes its
    x shard against a (d', d/n) weight slice and a psum over the model axis
    rebuilds the full output.  Ineligible shapes use the unwrapped dispatch
    (GSPMD partitions or replicates it).
    """
    gemm = partial(ttq_gemm, bits=bits, group_size=group_size,
                   use_pallas=use_pallas, **block_kw)
    if not _tp_gemm_ok(pctx, tp, x, packed, scale, bits, group_size):
        return gemm(x, packed, scale, zero, dinv)
    from repro.parallel.compat import shard_map
    P = jax.sharding.PartitionSpec
    m, dp = pctx.model_axis, pctx.dp
    lead = [None] * (x.ndim - 2)
    if dinv is None:
        dinv = jnp.ones((x.shape[-1],), jnp.float32)
    if tp == "row":
        in_specs = (P(dp, *lead, None), P(m, None), P(m, None), P(m, None),
                    P(None))
        out_specs = P(dp, *lead, m)

        def fn(xx, pk, sc, zr, dv):
            return gemm(xx, pk, sc, zr, dv)
    else:
        in_specs = (P(dp, *lead, m), P(None, m), P(None, m), P(None, m), P(m))
        out_specs = P(dp, *lead, None)

        def fn(xx, pk, sc, zr, dv):
            return jax.lax.psum(gemm(xx, pk, sc, zr, dv), m)
    return shard_map(fn, mesh=pctx.mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)(
        x, packed, scale, zero, dinv)


def _tp_attn_ok(pctx, q, kq, batched_cache):
    n, ndp = _mesh_sizes(pctx)
    if n <= 1 or q.shape[0] % ndp:
        return False
    hkv = kq.shape[1]
    return q.shape[1] % n == 0 and hkv % n == 0


def kv_decode_attention_tp(q, kq, ks, vq, vs, cur_pos, *, pctx=None, **kw):
    """Head-parallel ``kv_decode_attention``: q heads and KV heads shard the
    model axis together (the GQA q→kv mapping is block-contiguous, so each
    device's q-head shard attends exactly its local KV-head shard)."""
    call = partial(kv_decode_attention, **kw)
    if not _tp_attn_ok(pctx, q, kq, True):
        return call(q, kq, ks, vq, vs, cur_pos)
    from repro.parallel.compat import shard_map
    P = jax.sharding.PartitionSpec
    m, dp = pctx.model_axis, pctx.dp
    hs = P(dp, m, None, None)
    return shard_map(lambda *a: call(*a), mesh=pctx.mesh,
                     in_specs=(hs, hs, hs, hs, hs, P(dp)), out_specs=hs,
                     check_vma=False)(q, kq, ks, vq, vs, cur_pos)


def kv_suffix_attention_tp(q, kq, ks, vq, vs, pos, *, pctx=None, **kw):
    """Head-parallel :func:`kv_suffix_attention` — same sharding contract as
    :func:`kv_decode_attention_tp` (q/KV heads co-shard the model axis; the
    per-slot window-start positions replicate per data shard)."""
    call = partial(kv_suffix_attention, **kw)
    if not _tp_attn_ok(pctx, q, kq, True):
        return call(q, kq, ks, vq, vs, pos)
    from repro.parallel.compat import shard_map
    P = jax.sharding.PartitionSpec
    m, dp = pctx.model_axis, pctx.dp
    hs = P(dp, m, None, None)
    return shard_map(lambda *a: call(*a), mesh=pctx.mesh,
                     in_specs=(hs, hs, hs, hs, hs, P(dp)), out_specs=hs,
                     check_vma=False)(q, kq, ks, vq, vs, pos)


def kv_paged_suffix_attention_tp(q, kq, ks, vq, vs, block_table, pos, *,
                                 pctx=None, **kw):
    """Head-parallel paged suffix attention: pools shard over KV heads, the
    block table and window-start positions replicate per data shard (mirrors
    :func:`kv_paged_decode_attention_tp`)."""
    call = partial(kv_paged_suffix_attention, **kw)
    if not _tp_attn_ok(pctx, q, kq, False):
        return call(q, kq, ks, vq, vs, block_table, pos)
    from repro.parallel.compat import shard_map
    P = jax.sharding.PartitionSpec
    m, dp = pctx.model_axis, pctx.dp
    qs = P(dp, m, None, None)
    pool = P(None, m, None, None)
    return shard_map(lambda *a: call(*a), mesh=pctx.mesh,
                     in_specs=(qs, pool, pool, pool, pool, P(dp, None), P(dp)),
                     out_specs=qs, check_vma=False)(
        q, kq, ks, vq, vs, block_table, pos)


def kv_paged_decode_attention_tp(q, kq, ks, vq, vs, block_table, cur_pos, *,
                                 pctx=None, **kw):
    """Head-parallel paged decode attention: the (NB, Hkv, bs, ·) pools shard
    over KV heads (never the physical-block dim — block ids are global), the
    per-slot block table and positions stay replicated per data shard."""
    call = partial(kv_paged_decode_attention, **kw)
    if not _tp_attn_ok(pctx, q, kq, False):
        return call(q, kq, ks, vq, vs, block_table, cur_pos)
    from repro.parallel.compat import shard_map
    P = jax.sharding.PartitionSpec
    m, dp = pctx.model_axis, pctx.dp
    qs = P(dp, m, None, None)
    pool = P(None, m, None, None)
    return shard_map(lambda *a: call(*a), mesh=pctx.mesh,
                     in_specs=(qs, pool, pool, pool, pool, P(dp, None), P(dp)),
                     out_specs=qs, check_vma=False)(
        q, kq, ks, vq, vs, block_table, cur_pos)
