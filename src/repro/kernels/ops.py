"""jit'd public wrappers for the Pallas kernels, with pure-jnp fallbacks.

The rest of the framework calls these; ``use_pallas=False`` (or unsupported
bit-widths) routes to the XLA fallback so every code path runs everywhere.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import ref as _ref
from .ttq_gemm import ttq_gemm as _ttq_gemm_pallas
from .ttq_quantize import ttq_quantize as _ttq_quantize_pallas

_PACKABLE = (2, 4, 8)


def ttq_gemm(x, packed, scale, zero, dinv=None, *, bits=4, group_size=32,
             use_pallas=True, **block_kw):
    if use_pallas and bits in _PACKABLE:
        return _ttq_gemm_pallas(x, packed, scale, zero, dinv, bits=bits,
                                group_size=group_size, **block_kw)
    lead = x.shape[:-1]
    y = _ref.ttq_gemm_ref(x.reshape(-1, x.shape[-1]), packed, scale, zero,
                          bits=bits, group_size=group_size, dinv=dinv)
    return y.reshape(*lead, -1).astype(x.dtype)


def ttq_quantize(W, D, *, bits=4, group_size=32, use_pallas=True, **block_kw):
    if use_pallas and bits in _PACKABLE:
        return _ttq_quantize_pallas(W, D, bits=bits, group_size=group_size,
                                    **block_kw)
    return _ref.ttq_quantize_ref(W, D, bits=bits, group_size=group_size)
