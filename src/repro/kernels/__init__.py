"""Pallas TPU kernels for the paper's compute hot-spots.

* ``ttq_gemm``     — fused int-packed dequant matmul (the Marlin analogue):
                     HBM int4/int8 weights → VMEM unpack+dequant → MXU.
* ``ttq_quantize`` — the per-prompt online quantization as one streaming pass.

``ops`` wraps both with jnp fallbacks; ``ref`` holds the pure-jnp oracles the
tests assert against (interpret=True on CPU, compiled on TPU).
"""
from .ops import ttq_gemm, ttq_quantize

__all__ = ["ttq_gemm", "ttq_quantize"]
