"""Pallas TPU kernels for the paper's compute hot-spots.

* ``ttq_gemm``            — fused int-packed dequant matmul (the Marlin
                            analogue): HBM int4/int8 weights → VMEM
                            unpack+dequant → MXU.
* ``ttq_quantize``        — the per-prompt online quantization as one
                            streaming pass.
* ``kv_decode_attention`` — fused dequant decode-attention over an int8/int4
                            KV cache (flash-decoding over the S axis).
* ``kv_paged_decode_attention`` — the block-paged variant: flash-decoding
                            over a per-slot block table into a shared
                            (num_blocks, Hkv, block_size, ·) quantized pool
                            (scalar-prefetched table lookups per S-tile).

``ops`` wraps all with jnp fallbacks; ``ref`` holds the pure-jnp oracles the
tests assert against (interpret=True on CPU, compiled on TPU).
"""
from .ops import (kv_decode_attention, kv_paged_decode_attention, ttq_gemm,
                  ttq_quantize)

__all__ = ["kv_decode_attention", "kv_paged_decode_attention", "ttq_gemm",
           "ttq_quantize"]
