"""Pallas-TPU fused dequant decode-attention — the KV-cache analogue of
``ttq_gemm``.

o (B,H,1,Dh) = softmax(q·deq(K_codes)ᵀ/√Dh) · deq(V_codes)

The cache lives in HBM as int8 codes (1 B/elem) or int4 packed 8-per-int32
(0.5 B/elem) plus f32 per-(head, token, group) scales — decode attention is
memory-bound, so moving ~half (int8) or ~quarter (int4) of the bf16 bytes is
the entire speedup mechanism (EXPERIMENTS.md §Roofline).  Per S-tile the
kernel:

  HBM→VMEM  k/v codes (bs, Dh·bits/32 or bs, Dh) + scales (bs, Dh/g)
  VPU       unpack nibbles (shift+mask, int4 only), dequantize to f32 with
            the groupwise scale broadcast — the cache is NEVER materialized
            at bf16 in HBM
  MXU       (G, Dh) @ (Dh, bs) scores; online-softmax accumulate into a
            (G, Dh) f32 output tile (flash-decoding over the S axis)

Grid (B, Hkv, S/bs) with the S axis "arbitrary" (sequential — the running
max/denominator/accumulator live in VMEM scratch, initialized at s==0 and
written out at the last tile).  ``cur_pos`` rides in SMEM; slots beyond it
are masked with an explicit where (NOT exp(-inf - -inf), which would poison
fully-masked tiles).

Validated in interpret mode on CPU (this container) against
``ref.kv_attn_ref``; ``ops.kv_decode_attention`` is the public wrapper with
the ``use_pallas=False`` escape hatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dequant_tile(codes, scales, *, bits: int, group_size: int, Dh: int):
    """codes (bs, Dc) int8/int32, scales (bs, Dh//g) f32 → (bs, Dh) f32."""
    bs = codes.shape[0]
    if bits == 8:
        w = codes.astype(jnp.float32)
    else:
        shifts = (jnp.arange(8, dtype=jnp.int32) * 4)[None, None, :]
        w = (codes[:, :, None] >> shifts) & 0xF                # (bs, Dh//8, 8)
        w = w.reshape(bs, Dh).astype(jnp.float32) - 8.0
    g = group_size or Dh
    s = scales.astype(jnp.float32)
    if g != Dh:
        s = jnp.repeat(s, g, axis=-1)                          # (bs, Dh)
    return w * s


def _attn_kernel(pos_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref, o_ref,
                 m_ref, l_ref, acc_ref, *, bits: int, group_size: int,
                 soft_cap: float, bs: int, Dh: int, n_s: int):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cur = pos_ref[0, 0]
    q = q_ref[0, 0]                                            # (G, Dh) f32
    k = _dequant_tile(kq_ref[0, 0], ks_ref[0, 0], bits=bits,
                      group_size=group_size, Dh=Dh)            # (bs, Dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)
    if soft_cap > 0:
        s = soft_cap * jnp.tanh(s / soft_cap)
    ki = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = ki <= cur
    s = jnp.where(mask, s, NEG_INF)
    m_prev, l_prev = m_ref[...], l_ref[...]                    # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # explicit mask-zeroing: a fully-masked tile must contribute 0, not
    # exp(NEG_INF - NEG_INF) = 1 per slot
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)               # (G, bs)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    v = _dequant_tile(vq_ref[0, 0], vs_ref[0, 0], bits=bits,
                      group_size=group_size, Dh=Dh)            # (bs, Dh)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def _paged_attn_kernel(bt_ref, pos_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref,
                       o_ref, m_ref, l_ref, acc_ref, *, bits: int,
                       group_size: int, soft_cap: float, bs: int, Dh: int,
                       n_s: int):
    """Flash-decoding over the *block table* instead of a contiguous S axis.

    Identical online-softmax math to :func:`_attn_kernel`; the only paged
    difference is upstream — the k/v BlockSpecs index the (NB, Hkv, bs, ·)
    pool through the scalar-prefetched block table, so tile ``s`` of slot
    ``b`` streams physical block ``bt[b, s]`` HBM→VMEM.  Tiles past
    ``cur_pos`` (sink/stale blocks) are masked here exactly like padding."""
    b = pl.program_id(0)
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cur = pos_ref[b]
    q = q_ref[0, 0]                                            # (G, Dh) f32
    k = _dequant_tile(kq_ref[0, 0], ks_ref[0, 0], bits=bits,
                      group_size=group_size, Dh=Dh)            # (bs, Dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)
    if soft_cap > 0:
        s = soft_cap * jnp.tanh(s / soft_cap)
    ki = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = ki <= cur
    s = jnp.where(mask, s, NEG_INF)
    m_prev, l_prev = m_ref[...], l_ref[...]                    # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)               # (G, bs)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    v = _dequant_tile(vq_ref[0, 0], vs_ref[0, 0], bits=bits,
                      group_size=group_size, Dh=Dh)            # (bs, Dh)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "scale",
                                             "soft_cap", "interpret"))
def ttq_paged_decode_attention(q: jnp.ndarray, kq: jnp.ndarray,
                               ks: jnp.ndarray, vq: jnp.ndarray,
                               vs: jnp.ndarray, block_table: jnp.ndarray,
                               cur_pos: jnp.ndarray, *, bits: int = 8,
                               group_size: int = 0, scale: float | None = None,
                               soft_cap: float = 0.0,
                               interpret: bool | None = None) -> jnp.ndarray:
    """q: (B,H,1,Dh); kq/vq: (NB,Hkv,bs,Dc) pool codes; ks/vs:
    (NB,Hkv,bs,Dh//g) f32 pool scales; block_table: (B,nblk) int32 physical
    block ids; cur_pos: (B,) int32 → o (B,H,1,Dh).

    The S-tile is one pool block (``bs = block_size``): grid (B, Hkv, nblk)
    with the block axis sequential, the block table riding as a
    scalar-prefetch argument so each tile's BlockSpec resolves its physical
    pool block before the body runs (the paged flash-decoding idiom)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, _, Dh = q.shape
    Hkv, bs = kq.shape[1], kq.shape[2]
    G = H // Hkv
    Gn = ks.shape[3]
    Dc = kq.shape[3]
    nblk = block_table.shape[1]
    sc = scale if scale is not None else Dh ** -0.5
    qg = (q[:, :, 0].astype(jnp.float32) * sc).reshape(B, Hkv, G, Dh)
    bt = jnp.asarray(block_table, jnp.int32)
    pos = jnp.asarray(cur_pos, jnp.int32)

    kern = functools.partial(_paged_attn_kernel, bits=bits,
                             group_size=group_size, soft_cap=soft_cap,
                             bs=bs, Dh=Dh, n_s=nblk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # block table + cur_pos
        grid=(B, Hkv, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, s, bt_r, p_r: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, Dc),
                         lambda b, h, s, bt_r, p_r: (bt_r[b, s], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, Gn),
                         lambda b, h, s, bt_r, p_r: (bt_r[b, s], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, Dc),
                         lambda b, h, s, bt_r, p_r: (bt_r[b, s], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, Gn),
                         lambda b, h, s, bt_r, p_r: (bt_r[b, s], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh),
                               lambda b, h, s, bt_r, p_r: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),       # running max
            pltpu.VMEM((G, 1), jnp.float32),       # running denom
            pltpu.VMEM((G, Dh), jnp.float32),      # output accumulator
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(bt, pos, qg, kq, ks, vq, vs)
    return out.reshape(B, H, 1, Dh).astype(q.dtype)


def _pad_seq(x, m):
    r = (-x.shape[2]) % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[2] = (0, r)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "scale",
                                             "soft_cap", "bs", "interpret"))
def ttq_decode_attention(q: jnp.ndarray, kq: jnp.ndarray, ks: jnp.ndarray,
                         vq: jnp.ndarray, vs: jnp.ndarray, cur_pos: jnp.ndarray,
                         *, bits: int = 8, group_size: int = 0,
                         scale: float | None = None, soft_cap: float = 0.0,
                         bs: int = 256, interpret: bool | None = None
                         ) -> jnp.ndarray:
    """q: (B,H,1,Dh); kq/vq: (B,Hkv,S,Dc) codes; ks/vs: (B,Hkv,S,Dh//g) f32;
    cur_pos: (B,) int32 → o (B,H,1,Dh).  Positions > cur_pos are masked."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, _, Dh = q.shape
    Hkv, S = kq.shape[1], kq.shape[2]
    G = H // Hkv
    Gn = ks.shape[3]
    Dc = kq.shape[3]
    sc = scale if scale is not None else Dh ** -0.5
    qg = (q[:, :, 0].astype(jnp.float32) * sc).reshape(B, Hkv, G, Dh)

    bs = min(bs, S)
    kq, ks = _pad_seq(kq, bs), _pad_seq(ks, bs)
    vq, vs = _pad_seq(vq, bs), _pad_seq(vs, bs)
    Sp = kq.shape[2]
    n_s = Sp // bs
    pos2 = jnp.asarray(cur_pos, jnp.int32).reshape(B, 1)

    kern = functools.partial(_attn_kernel, bits=bits, group_size=group_size,
                             soft_cap=soft_cap, bs=bs, Dh=Dh, n_s=n_s)
    out = pl.pallas_call(
        kern,
        grid=(B, Hkv, n_s),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, Dc), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, Gn), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, Dc), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, Gn), lambda b, h, s: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),       # running max
            pltpu.VMEM((G, 1), jnp.float32),       # running denom
            pltpu.VMEM((G, Dh), jnp.float32),      # output accumulator
        ],
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(pos2, qg, kq, ks, vq, vs)
    return out.reshape(B, H, 1, Dh).astype(q.dtype)
