"""TTQ core: groupwise QDQ, activation-aware statistics, online quantization.

Public API re-exports — the rest of the framework imports from here.
"""
from .awq import AWQConfig, accumulate_stats, activation_diag, awq_qdq, awq_quantize, diag_from_stats
from .gptq import gptq_qdq
from .kvquant import BF16_KV, KVCacheConfig, dequantize_kv, quantize_kv
from .lowrank import alternating_refine, svd_factors, ttq_lowrank_qdq, ttq_lowrank_quantize
from .policy import FUSED_KERNELS, KernelConfig, NO_QUANT, QuantPolicy, ttq_policy
from .qdq import QuantConfig, dequantize, pack_bits, pack_int4, qdq, quantize, rtn, unpack_bits, unpack_int4
from .ttq import (QuantizedTensor, calibrate, dequant, quantize_params,
                  quantize_weight, ttq_linear, ttq_matmul)

__all__ = [
    "AWQConfig", "BF16_KV", "FUSED_KERNELS", "KVCacheConfig", "KernelConfig",
    "QuantConfig", "QuantPolicy",
    "QuantizedTensor", "NO_QUANT",
    "accumulate_stats", "activation_diag", "alternating_refine", "awq_qdq",
    "awq_quantize", "calibrate", "dequant", "dequantize", "dequantize_kv",
    "diag_from_stats",
    "gptq_qdq", "pack_bits", "pack_int4", "qdq", "quantize", "quantize_kv",
    "quantize_weight",
    "rtn", "svd_factors", "ttq_linear", "ttq_lowrank_qdq", "ttq_lowrank_quantize",
    "ttq_matmul", "ttq_policy", "unpack_bits", "unpack_int4",
]
