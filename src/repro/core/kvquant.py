"""int8/int4 KV-cache quantization for TTQ serving — the decode-traffic term.

The paper quantizes *weights* at test time; at 32k+ contexts the KV cache —
not the weights — dominates decode traffic (EXPERIMENTS.md §Roofline: gemma
decode cache ≈ 7.5 GB/device vs ≈ 0.3 GB of int4 weights).  The same
test-time machinery extends naturally: per-(head, token) symmetric int8/int4
with f32 scales, written at prefill and per-decode-step append, dequantized
on the fly inside the attention reads (fused in ``kernels/ttq_attn.py``).

    cache bytes: 2 B/elem (bf16) → 1 B/elem + scale/Dh ≈ 0.5× traffic (int8)
                                 → 0.5 B/elem + scale/Dh ≈ 0.27× (int4-packed)
    quality:     per-head-token scales keep softmax logits within ~1e-2 (int8)

:class:`KVCacheConfig` is the policy knob (``QuantPolicy.kvcache``) that the
serving engine threads into the model's decode-state layout; ``bf16`` keeps
the seed behaviour bit-for-bit.  ``decode_attention_q8`` remains as the
historical int8 per-token opt-in (EXPERIMENTS.md §Roofline "what would move
the decode term further" is this wiring).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_KV_BITS = {"bf16": 16, "int8": 8, "int4": 4}


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    """Static KV-cache layout config (hashable → usable as a jit static arg).

    dtype       'bf16' (seed layout) | 'int8' | 'int4' (packed 8/int32)
    group_size  scale granularity along the head dim; 0 → one scale per
                (head, token) row (the default, matching ``quantize_kv``)
    use_pallas  fused Pallas dequant-attention for the decode read; False →
                pure-jnp fallback (same escape hatch as ``ttq_gemm``)
    paged       block-paged pool layout (DESIGN.md §8): one
                (num_blocks, Hkv, block_size, ·) pool per attention layer
                plus per-slot block tables, instead of the dense
                (max_slots, Hkv, max_len, ·) slab.  Physical block 0 is the
                write sink for done/empty lanes and is never allocated.
    block_size  tokens per pool block (paged only); must divide max_len
    """

    dtype: str = "bf16"
    group_size: int = 0
    use_pallas: bool = True
    paged: bool = False
    block_size: int = 16

    def __post_init__(self):
        if self.dtype not in _KV_BITS:
            raise ValueError(f"kv dtype {self.dtype!r} not in {sorted(_KV_BITS)}")
        if self.paged and self.block_size <= 0:
            raise ValueError("paged cache needs block_size > 0")

    @property
    def bits(self) -> int:
        return _KV_BITS[self.dtype]

    @property
    def quantized(self) -> bool:
        return self.dtype != "bf16"

    def groups(self, head_dim: int) -> int:
        """Number of scale groups per (head, token) row."""
        g = self.group_size or head_dim
        if head_dim % g:
            raise ValueError(f"head_dim={head_dim} not divisible by group_size={g}")
        return head_dim // g

    def code_shape(self, head_dim: int) -> int:
        """Trailing dim of the code tensor (int4 packs 8 nibbles per int32)."""
        if self.dtype == "int4":
            if head_dim % 8:
                raise ValueError(f"head_dim={head_dim} must divide by 8 for int4")
            return head_dim // 8
        return head_dim

    @property
    def code_dtype(self):
        return {"bf16": jnp.bfloat16, "int8": jnp.int8,
                "int4": jnp.int32}[self.dtype]

    def bytes_per_token_head(self, head_dim: int) -> float:
        """Cache bytes per (head, token) row — the decode-traffic unit."""
        if not self.quantized:
            return 2.0 * head_dim
        code = head_dim if self.dtype == "int8" else head_dim / 2
        return code + 4.0 * self.groups(head_dim)


BF16_KV = KVCacheConfig()


def quantize_kv(kv: jnp.ndarray, *, bits: int = 8, group_size: int = 0):
    """(..., S, Dh) → (codes, f32 scales (..., S, Dh//g or 1)).

    Symmetric per-(head, token, group) quantization.  int8 codes are stored
    as int8; int4 codes are biased to [1, 15] and packed 8-per-int32 along
    the head dim (``core.qdq.pack_bits`` layout, unpacked in the kernel).
    """
    Dh = kv.shape[-1]
    g = group_size or Dh
    f = kv.astype(jnp.float32).reshape(*kv.shape[:-1], Dh // g, g)
    qmax = 127.0 if bits == 8 else 7.0
    s = jnp.maximum(jnp.abs(f).max(axis=-1), 1e-8) / qmax      # (..., S, Dh/g)
    q = jnp.clip(jnp.round(f / s[..., None]), -qmax, qmax)
    if bits == 8:
        return q.reshape(*kv.shape).astype(jnp.int8), s
    from .qdq import pack_bits
    codes = (q.reshape(*kv.shape) + 8.0).astype(jnp.int32)     # [1, 15]
    return pack_bits(codes, 4), s


def dequantize_kv(q: jnp.ndarray, s: jnp.ndarray, dtype=jnp.bfloat16, *,
                  bits: int = 8, group_size: int = 0):
    """Inverse of :func:`quantize_kv` (jnp fallback / oracle path)."""
    if bits == 8:
        codes = q.astype(jnp.float32)
    else:
        from .qdq import unpack_bits
        codes = unpack_bits(q, q.shape[-1] * 8, 4).astype(jnp.float32) - 8.0
    Dh = codes.shape[-1]
    g = group_size or Dh
    grouped = codes.reshape(*codes.shape[:-1], Dh // g, g)
    return (grouped * s[..., None]).reshape(*codes.shape).astype(dtype)


def decode_attention_q8(q, kq, ks, vq, vs, cur_pos, *, scale=None,
                        soft_cap: float = 0.0):
    """Single-token attention over an int8-quantized cache (per-token scales).

    q: (B,H,1,Dh); kq/vq: (B,Hkv,S,Dh) int8; ks/vs: (B,Hkv,S,1) f32.
    The k-dot runs on int8 codes (MXU int8 path on TPU) and folds the scale
    into the score; the v-dot dequantizes per block.  Historical opt-in —
    the production path is ``kernels.ops.kv_decode_attention`` driven by
    :class:`KVCacheConfig`, which also supports int4 and grouped scales.
    """
    from repro.models.common import NEG_INF
    B, H, _, Dh = q.shape
    Hkv, S = kq.shape[1], kq.shape[2]
    G = H // Hkv
    sc = scale if scale is not None else Dh ** -0.5
    qg = (q[:, :, 0].astype(jnp.float32) * sc).reshape(B, Hkv, G, Dh)
    # scores: (q·k_int8)·k_scale — int codes contracted, scale applied after
    s_int = jnp.einsum("bhgd,bhkd->bhgk", qg, kq.astype(jnp.float32))
    s_ = s_int * ks[:, :, None, :, 0]
    if soft_cap > 0:
        s_ = soft_cap * jnp.tanh(s_ / soft_cap)
    ki = jnp.arange(S)
    mask = ki[None, :] <= cur_pos[:, None]
    s_ = jnp.where(mask[:, None, None, :], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    pv = p * vs[:, :, None, :, 0]                     # fold v-scale into probs
    o = jnp.einsum("bhgk,bhkd->bhgd", pv, vq.astype(jnp.float32))
    return o.reshape(B, H, 1, Dh).astype(q.dtype)
