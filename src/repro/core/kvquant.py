"""Beyond-paper extension: int8 KV-cache quantization for TTQ serving.

The paper quantizes *weights* at test time; at 32k+ contexts the KV cache —
not the weights — dominates decode traffic (§Roofline: gemma decode cache
≈ 7.5 GB/device vs ≈ 0.3 GB of int4 weights).  The same test-time machinery
extends naturally: per-(head, token) symmetric int8 with an f32 scale, written
at prefill/decode time, dequantized on the fly inside the attention reads.

    cache bytes: 2 B/elem (bf16) → 1 B/elem + scale/Dh ≈ 0.5× traffic
    quality:     per-head-token scales keep softmax logits within ~1e-2

Opt-in (`decode_attention_q8` / `quantize_kv`); the default engine path stays
bf16 — wiring it into the production cache layout is the documented next step
(EXPERIMENTS.md §Roofline "what would move the decode term further").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_kv(kv: jnp.ndarray):
    """(B, Hkv, S, Dh) → (int8 codes, f32 scales (B, Hkv, S, 1))."""
    f = kv.astype(jnp.float32)
    s = jnp.maximum(jnp.abs(f).max(axis=-1, keepdims=True), 1e-8) / 127.0
    q = jnp.clip(jnp.round(f / s), -127, 127).astype(jnp.int8)
    return q, s


def dequantize_kv(q: jnp.ndarray, s: jnp.ndarray, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * s).astype(dtype)


def decode_attention_q8(q, kq, ks, vq, vs, cur_pos, *, scale=None,
                        soft_cap: float = 0.0):
    """Single-token attention over an int8-quantized cache.

    q: (B,H,1,Dh); kq/vq: (B,Hkv,S,Dh) int8; ks/vs: (B,Hkv,S,1) f32.
    The k-dot runs on int8 codes (MXU int8 path on TPU) and folds the scale
    into the score; the v-dot dequantizes per block.
    """
    from repro.models.common import NEG_INF
    B, H, _, Dh = q.shape
    Hkv, S = kq.shape[1], kq.shape[2]
    G = H // Hkv
    sc = scale if scale is not None else Dh ** -0.5
    qg = (q[:, :, 0].astype(jnp.float32) * sc).reshape(B, Hkv, G, Dh)
    # scores: (q·k_int8)·k_scale — int codes contracted, scale applied after
    s_int = jnp.einsum("bhgd,bhkd->bhgk", qg, kq.astype(jnp.float32))
    s_ = s_int * ks[:, :, None, :, 0]
    if soft_cap > 0:
        s_ = soft_cap * jnp.tanh(s_ / soft_cap)
    ki = jnp.arange(S)
    mask = ki[None, :] <= cur_pos[:, None]
    s_ = jnp.where(mask[:, None, None, :], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    pv = p * vs[:, :, None, :, 0]                     # fold v-scale into probs
    o = jnp.einsum("bhgk,bhkd->bhgd", pv, vq.astype(jnp.float32))
    return o.reshape(B, H, 1, Dh).astype(q.dtype)
