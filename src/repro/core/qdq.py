"""Groupwise quantization-dequantization (QDQ) — paper §2 / Appendix B & D.

Pure-jnp, jit-friendly. Two group layouts are supported:

* ``flat``  — the paper's reshape(-1, g): groups of g consecutive elements in
  row-major order (requires W.size % g == 0).  Used by the reference/science
  path because it matches the paper's pseudo-code bit-for-bit.
* ``row``   — groups along the contraction dim d (requires d % g == 0), with
  scale/zero stored as (d', d//g).  This is the kernel layout: packed int4
  weights + per-(row, group) scales feed the Pallas ``ttq_gemm``.

Formats (Appendix D):
* asymmetric: S=(Wmax-Wmin)/(2^q-1), Z=Wmin            (default; best quality)
* symmetric : S=2|W|max/(2^q-1),    Z=-|W|max          (fewer params)
* expansion factor ν (eq. 27-28): shrink the clip range, ν≈0.95 often helps.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static quantization hyper-parameters (hashable → usable as jit static arg)."""

    bits: int = 4
    group_size: int = 32
    symmetric: bool = False
    nu: float = 1.0          # expansion factor (Appendix D); 1.0 = standard
    layout: str = "flat"     # 'flat' (paper) | 'row' (kernel)

    @property
    def qmax(self) -> int:
        return (1 << self.bits) - 1


def _group(W: jnp.ndarray, g: int, layout: str):
    """Reshape to (n_groups, g). Returns (grouped, restore_fn)."""
    if layout == "flat":
        if W.size % g:
            raise ValueError(f"W.size={W.size} not divisible by group_size={g}")
        shape = W.shape
        return W.reshape(-1, g), lambda x: x.reshape(shape)
    elif layout == "row":
        dp, d = W.shape
        if d % g:
            raise ValueError(f"d={d} not divisible by group_size={g}")
        return W.reshape(dp * (d // g), g), lambda x: x.reshape(dp, d)
    raise ValueError(f"unknown layout {layout!r}")


def _scale_zero(Wg: jnp.ndarray, cfg: QuantConfig):
    """Per-group scale/zero-point. Wg: (n_groups, g) → S,Z: (n_groups, 1)."""
    if cfg.symmetric:
        amax = jnp.abs(Wg).max(axis=1, keepdims=True)
        S = 2.0 * amax / cfg.qmax
        Z = -amax
    else:
        wmax = Wg.max(axis=1, keepdims=True)
        wmin = Wg.min(axis=1, keepdims=True)
        if cfg.nu != 1.0:
            c, h = (wmax + wmin) / 2.0, (wmax - wmin) / 2.0
            wmax, wmin = c + cfg.nu * h, c - cfg.nu * h
        S = (wmax - wmin) / cfg.qmax
        Z = wmin
    S = jnp.where(S <= 0, _EPS, S)  # constant groups → avoid div-by-zero
    return S, Z


@partial(jax.jit, static_argnames=("cfg",))
def quantize(W: jnp.ndarray, cfg: QuantConfig):
    """G[W] → (W_int ∈ int8 (flat group layout reshaped back), S, Z).

    S, Z have shape (n_groups,) where n_groups = W.size // g ('flat') or are
    reshaped to (d', d//g) ('row').
    """
    W32 = W.astype(jnp.float32)
    Wg, _restore = _group(W32, cfg.group_size, cfg.layout)
    S, Z = _scale_zero(Wg, cfg)
    itype = jnp.uint8 if cfg.bits <= 8 else jnp.int32
    Wint = jnp.clip(jnp.round((Wg - Z) / S), 0, cfg.qmax).astype(itype)
    if cfg.layout == "row":
        dp, d = W.shape
        g = cfg.group_size
        return (
            Wint.reshape(dp, d),
            S.reshape(dp, d // g),
            Z.reshape(dp, d // g),
        )
    return Wint.reshape(W.shape), S[:, 0], Z[:, 0]


@partial(jax.jit, static_argnames=("cfg",))
def dequantize(Wint: jnp.ndarray, S: jnp.ndarray, Z: jnp.ndarray, cfg: QuantConfig):
    """G⁻[W_int] = W_int ∘ S + Z, undoing the group layout of :func:`quantize`.

    The 'row' path reshapes ONLY the minor dim ((d',d)→(d',d/g,g)) — merging
    the sharded d' into a flat leading dim would force GSPMD to all-gather the
    whole weight just to reshape (§Perf iteration: 10.5 GB/step on gemma
    decode before this fix)."""
    g = cfg.group_size
    if cfg.layout == "row":
        dp, d = Wint.shape
        Wg = Wint.reshape(dp, d // g, g).astype(jnp.float32)
        return (Wg * S[..., None] + Z[..., None]).reshape(dp, d)
    shape = Wint.shape
    Wg = Wint.reshape(-1, g).astype(jnp.float32)
    return (Wg * S[:, None] + Z[:, None]).reshape(shape)


@partial(jax.jit, static_argnames=("cfg",))
def qdq(W: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """Q[W] = G⁻[G[W]] — the groupwise RTN fake-quant used throughout the paper."""
    W32 = W.astype(jnp.float32)
    Wg, restore = _group(W32, cfg.group_size, cfg.layout)
    S, Z = _scale_zero(Wg, cfg)
    Wint = jnp.clip(jnp.round((Wg - Z) / S), 0, cfg.qmax)
    return restore(Wint * S + Z).astype(W.dtype)


def rtn(W: jnp.ndarray, bits: int, group_size: int, **kw) -> jnp.ndarray:
    """Paper's ``rtn(W, q, g)`` pseudo-code, verbatim semantics."""
    return qdq(W, QuantConfig(bits=bits, group_size=group_size, **kw))


# ---------------------------------------------------------------------------
# int4 nibble packing (host/jnp reference; the Pallas kernel has its own).
# Packs 8 int4 values along the last axis into one int32 (little-nibble order).
# ---------------------------------------------------------------------------

def pack_int4(Wint: jnp.ndarray) -> jnp.ndarray:
    """(..., d) int in [0,15] → (..., d//8) int32."""
    if Wint.shape[-1] % 8:
        raise ValueError("last dim must be divisible by 8 to pack int4")
    w = Wint.astype(jnp.int32).reshape(*Wint.shape[:-1], -1, 8)
    shifts = jnp.arange(8, dtype=jnp.int32) * 4
    return (w << shifts).sum(axis=-1)  # nibbles don't overlap → sum == bitwise-or


def unpack_int4(packed: jnp.ndarray, d: int) -> jnp.ndarray:
    """(..., d//8) int32 → (..., d) int32 in [0,15]."""
    shifts = jnp.arange(8, dtype=jnp.int32) * 4
    w = (packed[..., None] >> shifts) & 0xF
    return w.reshape(*packed.shape[:-1], d)


def pack_bits(Wint: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Generic packer: k = 32//bits values per int32 along the last axis."""
    per = 32 // bits
    if Wint.shape[-1] % per:
        raise ValueError(f"last dim must be divisible by {per}")
    w = Wint.astype(jnp.int32).reshape(*Wint.shape[:-1], -1, per)
    shifts = jnp.arange(per, dtype=jnp.int32) * bits
    return (w << shifts).sum(axis=-1)


def unpack_bits(packed: jnp.ndarray, d: int, bits: int) -> jnp.ndarray:
    per = 32 // bits
    mask = (1 << bits) - 1
    shifts = jnp.arange(per, dtype=jnp.int32) * bits
    w = (packed[..., None] >> shifts) & mask
    return w.reshape(*packed.shape[:-1], d)
