"""TTQ — the paper's contribution as a composable JAX module.

Lifecycle (paper Fig. 1b):

    prefill (full precision, stats tap on)          decode (quantized)
    ────────────────────────────────────►  quantize ────────────────►
    stats[layer] += Σ_t |x_t|^p                 │    int4 matmul w/
                                                ▼    prescaled x
                             D = (stats^{1/p}+λ)^α
                             W_int,S,Z = G[(W−BA)∘D]

Three entry points:

* :func:`calibrate`      — stats pytree → per-layer D vectors.
* :func:`quantize_tree`  — fp param pytree (+ D tree, + optional low-rank tree)
                           → :class:`QuantizedTensor` pytree (packed or fake).
* :func:`ttq_linear`     — the functional linear used inside model forwards;
                           dispatches on the param type (fp / QuantizedTensor).

``QuantizedTensor`` is a pytree-registered dataclass so quantized parameter
trees flow through jit / pjit / shard_map like any other params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .awq import AWQConfig, awq_quantize, diag_from_stats
from .lowrank import svd_factors
from .policy import QuantPolicy
from .qdq import QuantConfig, dequantize, pack_bits, unpack_bits


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Groupwise-quantized weight (row layout): y = deq(Wint)·(x/D) [+ B(Ax)].

    ``packed`` holds int32 nibble-packed data (d', d·bits/32) when the policy's
    packed path is on, else ``wint`` holds **uint8** codes in [0, 2^bits−1].
    Exactly one of the two is set.  Codes are unsigned on purpose: 8-bit
    codes span 0..255, which a signed int8 store would wrap — unpacked-on-
    the-fly codes stay int32 for the same reason (bits=8 round-trip
    regression in tests/test_fused_path.py).
    """

    wint: Optional[jnp.ndarray]      # (d', d) uint8 | None
    packed: Optional[jnp.ndarray]    # (d', d*bits//32) int32 | None
    scale: jnp.ndarray               # (d', d//g) f32
    zero: jnp.ndarray                # (d', d//g) f32
    dinv: jnp.ndarray                # (d,) f32 — activation prescale 1/D
    B: Optional[jnp.ndarray]         # (d', r) | None
    A: Optional[jnp.ndarray]         # (r, d) | None
    bits: int = 4
    group_size: int = 32
    out_features: int = 0
    in_features: int = 0

    def tree_flatten(self):
        children = (self.wint, self.packed, self.scale, self.zero, self.dinv,
                    self.B, self.A)
        aux = (self.bits, self.group_size, self.out_features, self.in_features)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def qcfg(self) -> QuantConfig:
        return QuantConfig(bits=self.bits, group_size=self.group_size, layout="row")


def calibrate(stats: Any, counts: Any, acfg: AWQConfig) -> Any:
    """Map accumulated Σ|x|^p stats pytree → D pytree (matching structure)."""
    return jax.tree.map(lambda s, n: diag_from_stats(s, n, acfg), stats, counts)


def quantize_weight(W: jnp.ndarray, D: jnp.ndarray, policy: QuantPolicy,
                    B: Optional[jnp.ndarray] = None,
                    A: Optional[jnp.ndarray] = None) -> QuantizedTensor:
    """Quantize one (d', d) weight online given its activation diagonal D."""
    qcfg = policy.qcfg
    if qcfg.layout != "row":
        qcfg = dataclasses.replace(qcfg, layout="row")
    Wf = W.astype(jnp.float32)
    if B is not None and A is not None and policy.rank > 0:
        Wf = Wf - B.astype(jnp.float32) @ A.astype(jnp.float32)
    else:
        B = A = None
    wint, S, Z = awq_quantize(Wf, D, qcfg)
    dinv = (1.0 / D).astype(jnp.float32)
    packed = wint_out = None
    if policy.packed and (32 % qcfg.bits == 0) and (W.shape[1] % (32 // qcfg.bits) == 0):
        packed = pack_bits(wint.astype(jnp.int32), qcfg.bits)
    else:
        wint_out = wint
    return QuantizedTensor(
        wint=wint_out, packed=packed, scale=S, zero=Z, dinv=dinv, B=B, A=A,
        bits=qcfg.bits, group_size=qcfg.group_size,
        out_features=W.shape[0], in_features=W.shape[1],
    )


def init_lowrank_tree(params: Any, policy: QuantPolicy, is_weight) -> Any:
    """Offline, data-free: top-r SVD factors per quantizable 2-D weight.

    ``is_weight(path, leaf) → bool`` decides eligibility. Returns a pytree of
    {'B','A'} dicts (None where ineligible) with the same treedef as params.
    """
    if policy.rank <= 0:
        return jax.tree.map(lambda _: None, params)

    def per_leaf(path, leaf):
        if is_weight(path, leaf) and leaf.ndim == 2:
            B, A = svd_factors(leaf, policy.rank)
            return {"B": B, "A": A}
        return None

    return jax.tree_util.tree_map_with_path(per_leaf, params)


def dequant(qt: QuantizedTensor) -> jnp.ndarray:
    """Effective fp weight  Ŵ = deq(Wint)∘D⁻¹ [+ BA]  — reference/debug path."""
    wint = qt.wint
    if wint is None:
        # keep unpacked codes in int32: 8-bit codes span 0..255, which
        # overflows a signed int8 cast (the historical hazard) — int32 is
        # what unpack_bits yields and dequantize only needs a float cast
        wint = unpack_bits(qt.packed, qt.in_features, qt.bits)
    Wd = dequantize(wint, qt.scale, qt.zero, qt.qcfg)
    W = Wd * qt.dinv[None, :]
    if qt.B is not None:
        W = W + qt.B.astype(jnp.float32) @ qt.A.astype(jnp.float32)
    return W


def ttq_matmul(x: jnp.ndarray, qt: QuantizedTensor, *,
               use_kernel: bool = False, kcfg=None,
               precision=None, pctx=None, tp=None) -> jnp.ndarray:
    """y = x @ Ŵᵀ for x: (..., d).  Kernel path uses the Pallas ttq_gemm.

    ``kcfg`` (:class:`~repro.core.policy.KernelConfig`) is the policy-driven
    dispatch switch threaded by the model stack: ``use_pallas=True`` (or the
    legacy ``use_kernel`` flag) sends packed weights through ``ttq_gemm``
    with the D⁻¹ prescale fused into the kernel prologue.  The jnp fallback
    prescales x∘D⁻¹ on the (small) activation; the low-rank branch runs in
    fp on the *unscaled* x either way (BA was subtracted before scaling).

    ``pctx``/``tp``: with an active mesh and a TP role hint ('row'|'col')
    from the call site's sharding rule, the kernel dispatch is shard_map'd so
    each device runs ttq_gemm on its local weight shard; the low-rank BA
    correction is tiny and stays outside the wrap (plain GSPMD).
    """
    if kcfg is not None and kcfg.use_pallas:
        use_kernel = True
    if use_kernel and qt.packed is not None:
        from repro.kernels import ops as kops  # local import: kernels are optional
        kw = kcfg.gemm_kw if kcfg is not None else {}
        y = kops.ttq_gemm_tp(x, qt.packed, qt.scale, qt.zero, qt.dinv,
                             bits=qt.bits, group_size=qt.group_size,
                             pctx=pctx, tp=tp, **kw)
    else:
        # f32 prescale + accumulation over the same flattened (T, d)×(d, d')
        # gemm shape the kernel presents, so both paths hit the same backend
        # micro-kernel and the same f32 reduction order (the greedy-equality
        # contract: flipping the kernel on must not move a single token);
        # the cast back to x.dtype mirrors ttq_gemm's epilogue
        lead = x.shape[:-1]
        xs = x.reshape(-1, x.shape[-1]).astype(jnp.float32) * qt.dinv
        wint = qt.wint
        if wint is None:
            wint = unpack_bits(qt.packed, qt.in_features, qt.bits)
        Wd = dequantize(wint, qt.scale, qt.zero, qt.qcfg)
        y = jax.lax.dot_general(xs, Wd, (((1,), (1,)), ((), ())),
                                precision=precision,
                                preferred_element_type=jnp.float32)
        y = y.reshape(*lead, -1).astype(x.dtype)
    if qt.B is not None:
        y = y + jnp.einsum("...r,or->...o", jnp.einsum("...d,rd->...r", x, qt.A.astype(x.dtype)),
                           qt.B.astype(x.dtype))
    return y


def ttq_linear(x: jnp.ndarray, w, **kw) -> jnp.ndarray:
    """Dispatch: fp weight (d', d) → plain matmul; QuantizedTensor → ttq path."""
    if isinstance(w, QuantizedTensor):
        return ttq_matmul(x, w, **kw)
    return jnp.einsum("...d,od->...o", x, w)


# ---------------------------------------------------------------------------
# whole-model quantization now lives in repro.quant.api — thin shims below
# keep historical imports (repro.core.quantize_params, ...) working.
# ---------------------------------------------------------------------------

from repro.quant.api import (STAT_ALIAS, _lookup_stats, _path_str,  # noqa: E402
                             _stats_key, _tree_get, quantize_params)
