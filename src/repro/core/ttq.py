"""TTQ — the paper's contribution as a composable JAX module.

Lifecycle (paper Fig. 1b):

    prefill (full precision, stats tap on)          decode (quantized)
    ────────────────────────────────────►  quantize ────────────────►
    stats[layer] += Σ_t |x_t|^p                 │    int4 matmul w/
                                                ▼    prescaled x
                             D = (stats^{1/p}+λ)^α
                             W_int,S,Z = G[(W−BA)∘D]

Three entry points:

* :func:`calibrate`      — stats pytree → per-layer D vectors.
* :func:`quantize_tree`  — fp param pytree (+ D tree, + optional low-rank tree)
                           → :class:`QuantizedTensor` pytree (packed or fake).
* :func:`ttq_linear`     — the functional linear used inside model forwards;
                           dispatches on the param type (fp / QuantizedTensor).

``QuantizedTensor`` is a pytree-registered dataclass so quantized parameter
trees flow through jit / pjit / shard_map like any other params.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .awq import AWQConfig, awq_quantize, diag_from_stats
from .lowrank import svd_factors
from .policy import QuantPolicy
from .qdq import QuantConfig, dequantize, pack_bits, unpack_bits


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Groupwise-quantized weight (row layout): y = deq(Wint)·(x/D) [+ B(Ax)].

    ``packed`` holds int32 nibble-packed data (d', d·bits/32) when the policy's
    packed path is on, else ``wint`` holds int8.  Exactly one of the two is set.
    """

    wint: Optional[jnp.ndarray]      # (d', d) int8 | None
    packed: Optional[jnp.ndarray]    # (d', d*bits//32) int32 | None
    scale: jnp.ndarray               # (d', d//g) f32
    zero: jnp.ndarray                # (d', d//g) f32
    dinv: jnp.ndarray                # (d,) f32 — activation prescale 1/D
    B: Optional[jnp.ndarray]         # (d', r) | None
    A: Optional[jnp.ndarray]         # (r, d) | None
    bits: int = 4
    group_size: int = 32
    out_features: int = 0
    in_features: int = 0

    def tree_flatten(self):
        children = (self.wint, self.packed, self.scale, self.zero, self.dinv,
                    self.B, self.A)
        aux = (self.bits, self.group_size, self.out_features, self.in_features)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def qcfg(self) -> QuantConfig:
        return QuantConfig(bits=self.bits, group_size=self.group_size, layout="row")


def calibrate(stats: Any, counts: Any, acfg: AWQConfig) -> Any:
    """Map accumulated Σ|x|^p stats pytree → D pytree (matching structure)."""
    return jax.tree.map(lambda s, n: diag_from_stats(s, n, acfg), stats, counts)


def quantize_weight(W: jnp.ndarray, D: jnp.ndarray, policy: QuantPolicy,
                    B: Optional[jnp.ndarray] = None,
                    A: Optional[jnp.ndarray] = None) -> QuantizedTensor:
    """Quantize one (d', d) weight online given its activation diagonal D."""
    qcfg = policy.qcfg
    if qcfg.layout != "row":
        qcfg = dataclasses.replace(qcfg, layout="row")
    Wf = W.astype(jnp.float32)
    if B is not None and A is not None and policy.rank > 0:
        Wf = Wf - B.astype(jnp.float32) @ A.astype(jnp.float32)
    else:
        B = A = None
    wint, S, Z = awq_quantize(Wf, D, qcfg)
    dinv = (1.0 / D).astype(jnp.float32)
    packed = wint_out = None
    if policy.packed and (32 % qcfg.bits == 0) and (W.shape[1] % (32 // qcfg.bits) == 0):
        packed = pack_bits(wint.astype(jnp.int32), qcfg.bits)
    else:
        wint_out = wint
    return QuantizedTensor(
        wint=wint_out, packed=packed, scale=S, zero=Z, dinv=dinv, B=B, A=A,
        bits=qcfg.bits, group_size=qcfg.group_size,
        out_features=W.shape[0], in_features=W.shape[1],
    )


def init_lowrank_tree(params: Any, policy: QuantPolicy, is_weight) -> Any:
    """Offline, data-free: top-r SVD factors per quantizable 2-D weight.

    ``is_weight(path, leaf) → bool`` decides eligibility. Returns a pytree of
    {'B','A'} dicts (None where ineligible) with the same treedef as params.
    """
    if policy.rank <= 0:
        return jax.tree.map(lambda _: None, params)

    def per_leaf(path, leaf):
        if is_weight(path, leaf) and leaf.ndim == 2:
            B, A = svd_factors(leaf, policy.rank)
            return {"B": B, "A": A}
        return None

    return jax.tree_util.tree_map_with_path(per_leaf, params)


def dequant(qt: QuantizedTensor) -> jnp.ndarray:
    """Effective fp weight  Ŵ = deq(Wint)∘D⁻¹ [+ BA]  — reference/debug path."""
    wint = qt.wint
    if wint is None:
        wint = unpack_bits(qt.packed, qt.in_features, qt.bits).astype(jnp.uint8)
    Wd = dequantize(wint, qt.scale, qt.zero, qt.qcfg)
    W = Wd * qt.dinv[None, :]
    if qt.B is not None:
        W = W + qt.B.astype(jnp.float32) @ qt.A.astype(jnp.float32)
    return W


def ttq_matmul(x: jnp.ndarray, qt: QuantizedTensor, *,
               use_kernel: bool = False, precision=None) -> jnp.ndarray:
    """y = x @ Ŵᵀ for x: (..., d).  Kernel path uses the Pallas ttq_gemm.

    The prescale x∘D⁻¹ happens on the (small) activation; the low-rank branch
    runs in fp on the *unscaled* x (BA was subtracted before scaling).
    """
    xs = x * qt.dinv.astype(x.dtype)
    if use_kernel and qt.packed is not None:
        from repro.kernels import ops as kops  # local import: kernels are optional
        y = kops.ttq_gemm(xs, qt.packed, qt.scale, qt.zero,
                          bits=qt.bits, group_size=qt.group_size)
    else:
        wint = qt.wint
        if wint is None:
            wint = unpack_bits(qt.packed, qt.in_features, qt.bits)
        Wd = dequantize(wint, qt.scale, qt.zero, qt.qcfg).astype(x.dtype)
        y = jnp.einsum("...d,od->...o", xs, Wd, precision=precision)
    if qt.B is not None:
        y = y + jnp.einsum("...r,or->...o", jnp.einsum("...d,rd->...r", x, qt.A.astype(x.dtype)),
                           qt.B.astype(x.dtype))
    return y


def ttq_linear(x: jnp.ndarray, w, **kw) -> jnp.ndarray:
    """Dispatch: fp weight (d', d) → plain matmul; QuantizedTensor → ttq path."""
    if isinstance(w, QuantizedTensor):
        return ttq_matmul(x, w, **kw)
    return jnp.einsum("...d,od->...o", x, w)


# ---------------------------------------------------------------------------
# whole-model quantization: join params ↔ activation stats by path
# ---------------------------------------------------------------------------

# projections sharing their input with a tapped sibling (one tap per input).
STAT_ALIAS = {
    "wk": "wq", "wv": "wq", "wkv_a": "wq", "wu": "wg",
    "w_in": "w_branch", "w_z": "w_x", "w_B": "w_x", "w_C": "w_x", "w_dt": "w_x",
}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(getattr(p, "key", p)))
    return ".".join(parts)


def _stats_key(rel_path: tuple) -> str:
    """('u0','mix','wq') → 'u0.mix.wq' with alias resolution on the leaf name."""
    *head, leaf = rel_path
    leaf = STAT_ALIAS.get(leaf, leaf)
    return ".".join([*head, leaf])


def _lookup_stats(stats_run: dict, rel_path: tuple):
    key = _stats_key(rel_path)
    if key in stats_run:
        return stats_run[key]
    # expert weights: stats stored per 'experts.wg'/'experts.wd'
    if rel_path[-1] in ("wg", "wu", "wd") and "experts" in rel_path:
        leaf = "wg" if rel_path[-1] in ("wg", "wu") else "wd"
        key2 = ".".join([*rel_path[:-1], leaf])
        if key2 in stats_run:
            return stats_run[key2]
    return None


def quantize_params(params, stats, policy: QuantPolicy, *,
                    count: float = 1.0, acfg: Optional[AWQConfig] = None,
                    lowrank_tree=None):
    """TTQ the whole model: replace quantizable 2-D/3-D weights by
    :class:`QuantizedTensor`, joining activation stats by param path.

    ``stats`` is the structure produced by ``models.lm.forward(collect_stats=
    True)``: {'stack': [run-dicts of Σx² leaves, leading run dim], ...}.
    Weights whose stats are missing (untapped) or that match ``policy.skip``
    stay in full precision.
    """
    acfg = acfg or policy.acfg
    countf = jnp.asarray(count, jnp.float32)
    is_rtn = policy.method == "rtn"

    def quant_one(W, stat, BA):
        if is_rtn:
            D = jnp.ones((W.shape[-1],), jnp.float32)
        else:
            D = diag_from_stats(stat, countf, acfg)
        B = A = None
        if BA is not None:
            B, A = BA["B"], BA["A"]
        elif policy.rank > 0 and min(W.shape) > policy.rank:
            B, A = svd_factors(W, policy.rank)
        return quantize_weight(W, D, policy, B, A)

    def per_leaf(path, leaf):
        ps = _path_str(path)
        if not isinstance(leaf, jnp.ndarray) or leaf.ndim < 2 or leaf.ndim > 4:
            return leaf
        if not policy.quantizes(ps.split(".")[-1]) or not policy.quantizes(ps):
            return leaf
        parts = ps.split(".")
        ba = _tree_get(lowrank_tree, path) if lowrank_tree is not None else None
        # locate the stats leaf for this weight (RTN needs none)
        stat = None
        if not is_rtn:
            if parts[0] not in ("stack", "enc_stack"):
                if isinstance(stats, dict) and ps in stats and leaf.ndim == 2:
                    return quant_one(leaf, stats[ps], None)
                return leaf
            run = (stats or {}).get(parts[0])
            if run is None:
                return leaf
            stat = _lookup_stats(run[int(parts[1])], tuple(parts[2:]))
            if stat is None:
                return leaf
        elif (parts[0] in ("stack", "enc_stack") and leaf.ndim >= 3) \
                or (parts[0] not in ("stack", "enc_stack") and leaf.ndim == 2):
            # stacked weights are ≥3-D (run dim); stacked 1-D params (norm
            # scales, decay vectors) must not be mistaken for 2-D weights
            stat = jnp.zeros(leaf.shape[:-2] + leaf.shape[-1:], jnp.float32)
        else:
            return leaf
        if ba is None:
            fn = lambda W, s: quant_one(W, s, None)
            for _ in range(leaf.ndim - 2):           # vmap over run / expert dims
                fn = jax.vmap(fn)
            return fn(leaf, stat)
        fn = quant_one
        for _ in range(leaf.ndim - 2):
            fn = jax.vmap(fn)
        return fn(leaf, stat, ba)

    return jax.tree_util.tree_map_with_path(per_leaf, params)


def _tree_get(tree, path):
    node = tree
    try:
        for p in path:
            key = p.key if isinstance(p, jax.tree_util.DictKey) else (
                p.idx if isinstance(p, jax.tree_util.SequenceKey) else p)
            node = node[key]
        return node
    except (KeyError, IndexError, TypeError):
        return None
