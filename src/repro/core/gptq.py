"""GPTQ baseline (Frantar et al., 2022) — optimal-brain-surgeon greedy quantization.

Implemented for the method-comparison benchmarks (paper Table 3 discusses GPTQ's
O[d³] cost as motivation for AWQ/TTQ).  Column-serial with error propagation via
the inverse-Hessian Cholesky; grouped scales are (re)computed per group entry,
matching the reference implementation's ``groupsize`` behaviour.

Complexity O[d³] — use on benchmark-scale layers only (d ≲ 2048 on this CPU box).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .qdq import QuantConfig


def _hessian(X: jnp.ndarray, damp_frac: float = 0.01) -> jnp.ndarray:
    """H = 2 X Xᵀ + λI with λ = damp·mean(diag). X: (T, d) token-major."""
    Xf = X.astype(jnp.float32).reshape(-1, X.shape[-1])
    H = 2.0 * (Xf.T @ Xf)
    damp = damp_frac * jnp.mean(jnp.diag(H)) + 1e-6
    return H + damp * jnp.eye(H.shape[0], dtype=jnp.float32)


@partial(jax.jit, static_argnames=("qcfg",))
def gptq_qdq(W: jnp.ndarray, X: jnp.ndarray, qcfg: QuantConfig) -> jnp.ndarray:
    """Quantize W (d', d) against activations X (T, d). Returns fake-quant Ŵ."""
    d = W.shape[1]
    g, qmax = qcfg.group_size, float(qcfg.qmax)
    H = _hessian(X)
    # Hinv via Cholesky of H⁻¹ (upper), as in the reference implementation.
    Hinv = jnp.linalg.inv(H)
    Hinv = jnp.linalg.cholesky(Hinv, upper=True)  # upper-triangular U, H⁻¹=UᵀU? (see note)
    Wf = W.astype(jnp.float32)

    def body(j, carry):
        Wc, Qc, S, Z = carry
        col = Wc[:, j]
        djj = Hinv[j, j]
        # (re)compute group scale at group boundaries from the *current* weights.
        gstart = (j // g) * g
        in_new_group = (j % g) == 0
        blk = jax.lax.dynamic_slice(Wc, (0, gstart), (Wc.shape[0], g))
        wmax, wmin = blk.max(axis=1), blk.min(axis=1)
        S_new = jnp.maximum((wmax - wmin) / qmax, 1e-12)
        Z_new = wmin
        S = jnp.where(in_new_group, S_new, S)
        Z = jnp.where(in_new_group, Z_new, Z)
        qcol = jnp.clip(jnp.round((col - Z) / S), 0.0, qmax) * S + Z
        err = (col - qcol) / djj
        # propagate to not-yet-quantized columns (row j of Hinv, cols > j).
        row = Hinv[j, :]
        mask = (jnp.arange(d) > j).astype(jnp.float32)
        Wc = Wc - err[:, None] * (row * mask)[None, :]
        Qc = Qc.at[:, j].set(qcol)
        return (Wc, Qc, S, Z)

    S0 = jnp.ones((W.shape[0],), jnp.float32)
    Z0 = jnp.zeros((W.shape[0],), jnp.float32)
    _, Q, _, _ = jax.lax.fori_loop(0, d, body, (Wf, jnp.zeros_like(Wf), S0, Z0))
    return Q.astype(W.dtype)
