"""Low-rank decomposition for TTQ — paper §2 "TTQ with Low-Rank Decomposition" / App. E.

Ŵ = W_q + B·A  with static, data-free factors B=U_r Λ_r^{1/2}, A=Λ_r^{1/2} V_r from
the top-r SVD of W.  Only the *residual* W − BA is quantized — and with TTQ the
residual quantization happens online per prompt:  W_q = Q[(W−BA)∘D]∘D⁻¹.

The factors are computed once offline (no calibration data needed).  The paper's
alternating refinement (eq. 34-35) is provided for the ablation benchmark but the
paper reports "almost no gain" and we confirm (benchmarks/bench_methods.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .awq import awq_qdq, awq_quantize
from .qdq import QuantConfig, qdq


@partial(jax.jit, static_argnames=("r",))
def svd_factors(W: jnp.ndarray, r: int):
    """Top-r principal components of W (d', d) → B (d', r), A (r, d). Eq. 31-33."""
    U, s, Vt = jnp.linalg.svd(W.astype(jnp.float32), full_matrices=False)
    sr = jnp.sqrt(s[:r])
    B = U[:, :r] * sr[None, :]
    A = sr[:, None] * Vt[:r, :]
    return B.astype(W.dtype), A.astype(W.dtype)


@partial(jax.jit, static_argnames=("qcfg",))
def ttq_lowrank_qdq(W, B, A, D, qcfg: QuantConfig):
    """Fake-quant TTQ+LR:  Ŵ = Q[(W−BA)∘D]∘D⁻¹ + BA  (full effective weight)."""
    R = W.astype(jnp.float32) - B.astype(jnp.float32) @ A.astype(jnp.float32)
    Wq = awq_qdq(R, D, qcfg)
    return (Wq + B.astype(jnp.float32) @ A.astype(jnp.float32)).astype(W.dtype)


@partial(jax.jit, static_argnames=("qcfg",))
def ttq_lowrank_quantize(W, B, A, D, qcfg: QuantConfig):
    """Real-quant path: (W_int, S, Z) of the scaled residual; B, A kept fp.

    Serving computes  y = deq(W_int) @ (x/D) + B @ (A @ x).
    """
    R = W.astype(jnp.float32) - B.astype(jnp.float32) @ A.astype(jnp.float32)
    return awq_quantize(R, D, qcfg)


def alternating_refine(W, D, qcfg: QuantConfig, r: int, iters: int = 3):
    """Quantization-aware alternating factorization (eq. 34-35). Ablation only."""
    Wf = W.astype(jnp.float32)
    B, A = svd_factors(Wf, r)
    for _ in range(iters):
        Wq = awq_qdq(Wf - B @ A, D, qcfg)
        B, A = svd_factors(Wf - Wq, r)
    return B, A


def quantize_factors(B, A, qcfg: QuantConfig, which: str = "A"):
    """Appendix-E extension: quantize the low-rank factors themselves.

    'A' or 'B' (one quantized, the other fp — the paper notes these are
    preferable since BA stays un-quantized in neither case, but one-sided
    keeps the product full-rank-accurate); 'both' for the aggressive variant.
    Groups need the factor dims divisible by g — callers should pick
    g ≤ rank for the rank-sized dim or use 'flat' layout (done here).
    """
    from .qdq import qdq
    import dataclasses as _dc
    fcfg = _dc.replace(qcfg, layout="flat")
    qB, qA = B, A
    if which in ("A", "both"):
        qA = qdq(A, fcfg)
    if which in ("B", "both"):
        qB = qdq(B, fcfg)
    return qB, qA
