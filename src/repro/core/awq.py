"""Activation-aware diagonal statistics + AWQ closed form — paper §2 / Appendix C.

The activation-aware loss  L = ‖(W-Ŵ)C^{1/2}‖²  with the diagonal approximation
C ≈ D = diag[XX^T + λI]^α has the closed-form solution  Ŵ = Q[W·D^{1/2}]·D^{-1/2}.
Following the paper's pseudo-code, the scaling vector already absorbs the 1/2
power:  D_i = (‖X_i‖_p + λ)^α  and the QDQ is applied to W ∘ D (per input column).

Two statistic forms:
* ``raw``   — the paper's pseudo-code verbatim: D = (‖X_i‖_p + λ)^α.
* ``blend`` — scale-stabilized Ledoit–Wolf-style shrinkage (paper eq. 13):
  D = ((1-λ)·m_i + λ·mean(m))^{α/2} with m_i = ‖X_i‖²/T.  λ∈[0,1] blends the
  activation-aware loss with the activation-unaware loss (paper eq. 14) and is
  invariant to the activation scale and token count, which matters when stats
  are accumulated across microbatches of different sizes.

Sufficient statistics are additive (Σ_t |x_{t,i}|^p), so online accumulation
over prefill chunks / microbatches is exact.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .qdq import QuantConfig, qdq, quantize

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class AWQConfig:
    """Activation-statistic hyper-parameters (paper Appendix F: α≈0.5, λ≈0.4, p=2)."""

    p: float = 2.0
    alpha: float = 0.5
    lam: float = 0.4
    form: str = "blend"  # 'raw' (paper pseudo-code) | 'blend' (eq. 13 shrinkage)


def accumulate_stats(X: jnp.ndarray, p: float = 2.0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sufficient statistic over tokens. X: (..., T, d) → (Σ|x|^p per feature (d,), count).

    Leading axes (batch, chunks) are folded into the token axis.
    """
    Xf = X.astype(jnp.float32).reshape(-1, X.shape[-1])
    if p == 2.0:
        s = jnp.sum(Xf * Xf, axis=0)
    elif p == 1.0:
        s = jnp.sum(jnp.abs(Xf), axis=0)
    else:
        s = jnp.sum(jnp.abs(Xf) ** p, axis=0)
    return s, jnp.asarray(Xf.shape[0], jnp.float32)


def diag_from_stats(stat: jnp.ndarray, count: jnp.ndarray, cfg: AWQConfig) -> jnp.ndarray:
    """Turn accumulated Σ|x|^p (d,) into the AWQ scaling vector D (d,)."""
    stat = stat.astype(jnp.float32)
    if cfg.form == "raw":
        norm = stat ** (1.0 / cfg.p)                  # ‖X_i‖_p
        D = (norm + cfg.lam) ** cfg.alpha
    elif cfg.form == "blend":
        # blend form is defined on the p=2 sufficient statistic (Σx²).
        m = stat / jnp.maximum(count, 1.0)            # mean x² per feature = diag(C)
        eta = jnp.mean(m)
        Dsq = (1.0 - cfg.lam) * m + cfg.lam * eta     # shrunk diagonal of C (eq. 13)
        D = jnp.maximum(Dsq, _EPS) ** (cfg.alpha / 2.0)
    else:
        raise ValueError(f"unknown AWQ form {cfg.form!r}")
    return jnp.maximum(D, _EPS)


def activation_diag(X: jnp.ndarray, cfg: AWQConfig = AWQConfig()) -> jnp.ndarray:
    """One-shot D from raw activations X: (..., T, d) → (d,)."""
    s, n = accumulate_stats(X, cfg.p)
    return diag_from_stats(s, n, cfg)


@partial(jax.jit, static_argnames=("qcfg",))
def awq_qdq(W: jnp.ndarray, D: jnp.ndarray, qcfg: QuantConfig) -> jnp.ndarray:
    """Fake-quant closed form  Ŵ = Q[W∘D]∘D⁻¹  (paper eq. 20). W: (d', d), D: (d,)."""
    Dn = D[None, :].astype(jnp.float32)
    return (qdq(W.astype(jnp.float32) * Dn, qcfg) / Dn).astype(W.dtype)


@partial(jax.jit, static_argnames=("qcfg",))
def awq_quantize(W: jnp.ndarray, D: jnp.ndarray, qcfg: QuantConfig):
    """Real-quant path: quantize W∘D, keep D separate.

    Returns (W_int, S, Z).  The matmul is  y = deq(W_int,S,Z) @ (x / D):
    the 1/D prescale moves to the activation side (or is folded into the
    preceding normalization scale — see serving/engine.py).
    """
    Ws = W.astype(jnp.float32) * D[None, :].astype(jnp.float32)
    return quantize(Ws, qcfg)


def awq_loss(W: jnp.ndarray, What: jnp.ndarray, C_diag: jnp.ndarray) -> jnp.ndarray:
    """Diagnostic: activation-aware loss ‖(W-Ŵ)diag(c)^{1/2}‖² with c=E[x_i²]."""
    E = (W - What).astype(jnp.float32)
    return jnp.sum(E * E * C_diag[None, :].astype(jnp.float32))
