"""Per-layer quantization policy — which matmuls get TTQ'd and how.

A ``QuantPolicy`` is attached to a model config; the serving engine and the
benchmarks consult it to decide, per named projection, the bits / groupsize /
rank / activation-statistic settings, and whether the packed-int Pallas kernel
or the fake-quant (QDQ) path is used.
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import Optional

from .awq import AWQConfig
from .qdq import QuantConfig


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    method: str = "ttq"            # 'none' | 'rtn' | 'awq' | 'gptq' | 'ttq'
    qcfg: QuantConfig = QuantConfig(bits=4, group_size=32, layout="row")
    acfg: AWQConfig = AWQConfig()
    rank: int = 0                  # low-rank residual rank r (0 = off)
    skip: tuple = ("embed*", "lm_head", "*norm*", "router*",  # fnmatch patterns
                   "w_gate*", "conv*", "pos_embed",           # tiny/elementwise
                   "gamma", "beta")                           # norm params
    packed: bool = False           # real int path (Pallas kernel) vs fake-quant
    per_expert_stats: bool = True  # MoE: accumulate D per expert

    def quantizes(self, name: str) -> bool:
        if self.method == "none":
            return False
        return not any(fnmatch.fnmatch(name, pat) for pat in self.skip)

    def with_(self, **kw) -> "QuantPolicy":
        return dataclasses.replace(self, **kw)


NO_QUANT = QuantPolicy(method="none")


def ttq_policy(bits: int = 4, group_size: int = 32, rank: int = 16,
               packed: bool = False, **kw) -> QuantPolicy:
    return QuantPolicy(
        method="ttq",
        qcfg=QuantConfig(bits=bits, group_size=group_size, layout="row"),
        rank=rank, packed=packed, **kw,
    )
