"""Per-layer quantization policy — which matmuls get TTQ'd and how.

A ``QuantPolicy`` is attached to a model config; the serving engine and the
benchmarks consult it to decide, per named projection, the bits / groupsize /
rank / activation-statistic settings, and whether the packed-int Pallas kernel
or the fake-quant (QDQ) path is used.

Mixed precision is expressed declaratively via ``overrides``: an ordered
tuple of ``(fnmatch pattern, partial-policy delta)`` pairs resolved against
the full parameter path (e.g. ``stack.0.u0.mix.wq``).  Every matching entry
is applied in order (later entries win on conflicting fields), so a policy
like::

    ttq_policy(bits=3, group_size=64).with_overrides(
        override("*.mix.*", bits=4, group_size=32),   # attention: finer
        override("stack.*.u0.*", bits=8),             # first block: 8-bit
    )

gives attention projections 4-bit g=32, the first block 8-bit, and everything
else the 3-bit g=64 base.  Deltas may set top-level fields (``method``,
``rank``, ``packed``), QDQ fields (``bits``, ``group_size``, ``symmetric``,
``nu``, ``layout``) and statistic fields (``p``, ``alpha``, ``lam``,
``form``).  Resolution happens once per parameter path in
:func:`repro.quant.api.quantize_params` (see DESIGN.md).

The method name is resolved through :mod:`repro.quant.registry` — adding a
method is a registry entry, not another ``if`` chain.
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import Optional

from .awq import AWQConfig
from .kvquant import KVCacheConfig
from .qdq import QuantConfig

_QCFG_FIELDS = {f.name for f in dataclasses.fields(QuantConfig)}
_ACFG_FIELDS = {f.name for f in dataclasses.fields(AWQConfig)}


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Static weight-kernel dispatch config (hashable → usable as a jit
    static arg, threaded like :class:`~repro.core.kvquant.KVCacheConfig`).

    ``use_pallas=True`` routes every decode matmul over a *packed*
    :class:`~repro.core.ttq.QuantizedTensor` through the fused Pallas
    ``ttq_gemm`` (in-kernel unpack + dequant + D⁻¹ prologue) instead of the
    jnp dequantize-then-einsum fallback.  Weights without a packed payload
    (``policy.packed=False``, unpackable bit-widths) always take the
    fallback, so the flag is a pure opt-in.

    Block sizes map onto the kernel grids: ``bm/bn/bk`` tile the GEMM
    (T/d'/d axes), ``qbm/qbk`` tile the online-quantize kernel (d'/d axes).
    Defaults are the kernels' MXU-aligned defaults.
    """

    use_pallas: bool = False
    bm: int = 128
    bn: int = 128
    bk: int = 256
    qbm: int = 256
    qbk: int = 512

    @property
    def gemm_kw(self) -> dict:
        return {"bm": self.bm, "bn": self.bn, "bk": self.bk}

    @property
    def quant_kw(self) -> dict:
        return {"bm": self.qbm, "bk": self.qbk}


FUSED_KERNELS = KernelConfig(use_pallas=True)


def override(pattern: str, **delta) -> tuple:
    """Normalize one override to a hashable (pattern, ((key, value), ...))."""
    known = _QCFG_FIELDS | _ACFG_FIELDS | {
        "method", "rank", "packed", "per_expert_stats"}
    unknown = set(delta) - known
    if unknown:
        raise ValueError(f"unknown override field(s) {sorted(unknown)}; "
                         f"known: {sorted(known)}")
    return (pattern, tuple(sorted(delta.items())))


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    method: str = "ttq"            # any name in repro.quant.registry
    qcfg: QuantConfig = QuantConfig(bits=4, group_size=32, layout="row")
    acfg: AWQConfig = AWQConfig()
    rank: int = 0                  # low-rank residual rank r (0 = off)
    skip: tuple = ("embed*", "lm_head", "*norm*", "router*",  # fnmatch patterns
                   "w_gate*", "conv*", "pos_embed",           # tiny/elementwise
                   "gamma", "beta")                           # norm params
    packed: bool = False           # real int path (Pallas kernel) vs fake-quant
    per_expert_stats: bool = True  # MoE: accumulate D per expert
    overrides: tuple = ()          # ((pattern, ((field, value), ...)), ...)
    # KV-cache memory layout (global, not per-path: the cache is allocated
    # once per engine — see DESIGN.md §"KV-cache layout").  Orthogonal to the
    # weight method: NO_QUANT weights + int8 cache is a valid combination.
    kvcache: KVCacheConfig = KVCacheConfig()
    # weight-kernel dispatch (global, like kvcache: one decode program per
    # engine) — Pallas ttq_gemm on packed weights vs the jnp fallback, plus
    # the fused single-dispatch requantization kernel (DESIGN.md §7).
    kernel: KernelConfig = KernelConfig()

    @property
    def quantizer(self):
        """The registered method object for ``self.method``."""
        from repro.quant.registry import get_quantizer
        return get_quantizer(self.method)

    @property
    def enabled(self) -> bool:
        return self.quantizer.enabled

    def methods(self) -> tuple:
        """All method names this policy can resolve to (base + overrides)."""
        names = [self.method]
        for _, delta in self.overrides:
            for k, v in delta:
                if k == "method" and v not in names:
                    names.append(v)
        return tuple(names)

    @property
    def any_enabled(self) -> bool:
        """True if the base method or any override-reachable method is on."""
        from repro.quant.registry import get_quantizer
        return any(get_quantizer(m).enabled for m in self.methods())

    def quantizes(self, name: str) -> bool:
        if not self.enabled:
            return False
        return not any(fnmatch.fnmatch(name, pat) for pat in self.skip)

    def with_(self, **kw) -> "QuantPolicy":
        return dataclasses.replace(self, **kw)

    # ----------------------------------------------------- per-layer overrides

    def with_overrides(self, *ovr) -> "QuantPolicy":
        """Append overrides (``override(...)`` tuples or (pattern, dict))."""
        norm = tuple(
            o if isinstance(o[1], tuple) else override(o[0], **o[1])
            for o in ovr)
        return dataclasses.replace(self, overrides=self.overrides + norm)

    def _apply(self, delta: tuple) -> "QuantPolicy":
        top, qkw, akw = {}, {}, {}
        for k, v in delta:
            if k in _QCFG_FIELDS:
                qkw[k] = v
            elif k in _ACFG_FIELDS:
                akw[k] = v
            else:
                top[k] = v
        if qkw:
            top["qcfg"] = dataclasses.replace(self.qcfg, **qkw)
        if akw:
            top["acfg"] = dataclasses.replace(self.acfg, **akw)
        return dataclasses.replace(self, **top)

    def resolve(self, path: str) -> "QuantPolicy":
        """Effective policy for one parameter path (all matches, in order)."""
        eff = self
        for pat, delta in self.overrides:
            if fnmatch.fnmatch(path, pat):
                eff = eff._apply(delta)
        return eff

    def draft_variant(self, bits: int = 4, group_size: int = 0) -> "QuantPolicy":
        """Uniform low-bit sibling for self-speculative drafting
        (DESIGN.md §11): same method / skip set / KV-cache layout / kernel
        dispatch, but one flat ``bits`` everywhere (``group_size`` 0 keeps
        the base group), rank 0 and no per-layer overrides — the draft tree
        quantizes as ONE family-light pass and its decode matmuls skip the
        low-rank correction, which is what makes drafting cheap."""
        if not self.enabled:
            return self
        gs = group_size or self.qcfg.group_size
        return dataclasses.replace(
            self, qcfg=dataclasses.replace(self.qcfg, bits=bits,
                                           group_size=gs),
            rank=0, overrides=())


NO_QUANT = QuantPolicy(method="none")


def ttq_policy(bits: int = 4, group_size: int = 32, rank: int = 16,
               packed: bool = False, kv_dtype: str = "bf16",
               kv_group_size: int = 0, **kw) -> QuantPolicy:
    kw.setdefault("kvcache", KVCacheConfig(dtype=kv_dtype,
                                           group_size=kv_group_size))
    return QuantPolicy(
        method="ttq",
        qcfg=QuantConfig(bits=bits, group_size=group_size, layout="row"),
        rank=rank, packed=packed, **kw,
    )
