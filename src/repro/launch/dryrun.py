import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run — .lower().compile() for every (arch × shape × mesh).

Proves the distribution config is coherent without hardware: 512 placeholder
host devices build the production meshes; every cell's step is lowered with
explicit in/out shardings, compiled (SPMD partitioner runs for real), and its
memory/cost/collective analysis is cached to results/dryrun/<cell>.json.

    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--force] [--quant none|ttq4|ttq4r16]

Skipped cells (long_500k on full-attention archs — the sub-quadratic skip
rule in ``configs.cells``) are recorded with their skip reason.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get, skip_reason
from repro.core import ttq_policy
from repro.launch import steps as S
from repro.launch.analysis import roofline
from repro.launch.mesh import make_ctx, make_production_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def cell_id(arch, shape, mesh_kind, quant):
    tag = ""
    lvl = os.environ.get("REPRO_OPT_LEVEL")
    if lvl is not None and lvl != "1":
        tag = f"__opt{lvl}"
    return f"{arch}__{shape}__{mesh_kind}__{quant}{tag}"


def run_cell(arch: str, shape: str, mesh_kind: str, quant: str = "ttq4",
             force: bool = False, extra_tag: str = "") -> dict:
    os.makedirs(RESULTS, exist_ok=True)
    cid = cell_id(arch, shape, mesh_kind, quant) + extra_tag
    path = os.path.join(RESULTS, cid + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get(arch)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "quant": quant,
           "opt_level": int(os.environ.get("REPRO_OPT_LEVEL", "1"))}
    reason = skip_reason(cfg, shape)
    if reason:
        rec["skipped"] = reason
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    pctx = make_ctx(mesh)
    n_chips = mesh.devices.size
    seq, gbatch, kind = SHAPES[shape]
    t0 = time.time()
    try:
        if kind == "train":
            fn, args, meta = S.build_train_cell(cfg, pctx, shape)
        elif kind == "prefill":
            fn, args, meta = S.build_prefill_cell(cfg, pctx, shape)
        else:
            policy = {"none": None,
                      "ttq4": ttq_policy(bits=4, group_size=32, rank=0, packed=True),
                      "ttq4r16": ttq_policy(bits=4, group_size=32, rank=16, packed=True),
                      "bf16": ttq_policy(bits=4, group_size=32).with_(method="none"),
                      }[quant]
            fn, args, meta = S.build_decode_cell(cfg, pctx, shape, policy=policy)
        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        try:  # cache post-SPMD HLO → roofline re-analysis without recompiling
            import zstandard as zstd
            with open(os.path.join(RESULTS, cid + ".hlo.zst"), "wb") as zf:
                zf.write(zstd.ZstdCompressor(level=3).compress(
                    compiled.as_text().encode()))
        except Exception:
            pass
        mf = 0.0
        toks = gbatch * (seq if kind != "decode" else 1)
        n_active = cfg.active_param_count()
        mf = (6.0 if kind == "train" else 2.0) * n_active * toks
        rec.update(meta)
        from repro.launch.napkin import analytic_terms
        rec.update({
            "seq": seq, "global_batch": gbatch, "kind": kind,
            "n_chips": n_chips, "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "roofline": roofline(compiled, n_chips, model_flops=mf),
            "analytic": analytic_terms(cfg, shape, n_chips),
        })
        print(f"[OK] {cid}: compile {t_compile:.0f}s "
              f"dominant={rec['roofline']['dominant']}")
        print("  memory_analysis:", rec["roofline"]["memory_analysis"])
        ca = {k: v for k, v in rec["roofline"].items() if k.startswith("t_")}
        print("  roofline terms:", ca)
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[FAIL] {cid}: {rec['error']}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--quant", default="ttq4")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_ok = n_fail = n_skip = 0
    for a in archs:
        for s in shapes:
            for m in meshes:
                rec = run_cell(a, s, m, args.quant, force=args.force)
                if "error" in rec:
                    n_fail += 1
                elif "skipped" in rec:
                    n_skip += 1
                else:
                    n_ok += 1
    print(f"dry-run: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
