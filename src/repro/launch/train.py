"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma_7b --smoke \
        --steps 20 --data-parallel 2 --model-parallel 2

On a real TPU fleet this process runs per host (jax.distributed.initialize
picks up the coordinator from the environment); in this container the mesh
axes map onto however many host devices XLA_FLAGS exposes.  XLA flags for the
latency-hiding scheduler (collective overlap on TPU) are recorded here and
applied when the backend is TPU.
"""
import argparse
import os

TPU_XLA_FLAGS = (
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--deadline-s", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    if jax.default_backend() == "tpu":
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " " + TPU_XLA_FLAGS

    from repro.configs import get
    from repro.data import DataConfig, token_stream
    from repro.parallel import ParallelCtx
    from repro.training import TrainConfig, Trainer

    cfg = get(args.arch, smoke=args.smoke)
    pctx = None
    if args.data_parallel * args.model_parallel > 1:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(args.data_parallel, args.model_parallel)
        pctx = ParallelCtx(mesh=mesh, data_axes=("data",))
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch, seed=0)
    tc = TrainConfig(n_microbatches=args.microbatches, remat=True, zero1=True,
                     total_steps=max(args.steps, 100),
                     warmup=max(5, args.steps // 10),
                     checkpoint_every=max(10, args.steps // 3),
                     checkpoint_dir=args.ckpt,
                     step_deadline_s=args.deadline_s)

    def run():
        tr = Trainer(cfg, tc, token_stream(dc, 0), pctx=pctx)
        if args.resume:
            tr.restore_if_available()
        log = tr.run(args.steps)
        for m in log[:3] + log[-3:]:
            print({k: (round(v, 4) if isinstance(v, float) else v)
                   for k, v in m.items()})
        if tr.skipped_steps:
            print(f"straggler violations: {len(tr.skipped_steps)}")

    if pctx is not None:
        with pctx.mesh:
            run()
    else:
        run()


if __name__ == "__main__":
    main()
