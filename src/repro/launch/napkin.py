"""Analytic (napkin-math) roofline terms per cell — the cross-check for the
HLO-walker numbers.

The HLO walker counts op-level traffic at *CPU* fusion granularity and CPU
lowering (bf16 dots upcast to f32, defensive copies around scatters), which
over-states HBM traffic vs a TPU lowering.  This module computes the
TPU-ideal lower bound from first principles:

decode (per step, per device):
    weights: active-param bytes at the quantized width (+ scales/zeros/dinv
             [+ low-rank]) / model_shards, read once
    cache:   KV/state bytes / shards, read once + token-write
    acts:    negligible (B tokens)
prefill: weights once + activations O(B·S·D·L) + cache write + score traffic
train:   fwd+bwd weight reads (×2) + grad write/read + ZeRO-1 opt update +
         remat boundary activations (×3 traversals of layer I/O)

compute: 2·N_active·tokens (decode/prefill; ×3 for train) + attention
         2·2·S_kv·H·hd per query token per layer (×3 train).
"""
from __future__ import annotations

from repro.configs import SHAPES
from repro.models.config import ModelConfig

from .analysis import HBM_BW, ICI_BW, PEAK_FLOPS


def _cache_bytes_per_layer(cfg: ModelConfig, S: int, B: int) -> float:
    """Decode-state bytes per layer (bf16 KV / f32 recurrent states)."""
    if cfg.family == "ssm":
        s = cfg.ssm
        di = s.expand * cfg.d_model
        nh = di // s.head_dim
        return B * (nh * s.head_dim * s.d_state * 4            # h (f32)
                    + (s.conv_width - 1) * (di + 2 * s.n_groups * s.d_state) * 2)
    if cfg.mla is not None:
        return B * S * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim) * 2
    kv = 2 * B * cfg.n_kv_heads * cfg.hd * 2                    # k+v bf16/tok
    if cfg.family == "hybrid":
        # pattern-average: attn layers window-capped, rec layers O(d_rnn)
        pat = cfg.hybrid.pattern
        n_attn = sum(1 for k in pat if k == "attn")
        n_rec = len(pat) - n_attn
        w = min(S, cfg.hybrid.window)
        dr = cfg.hybrid.d_rnn or cfg.d_model
        per_attn = kv * w
        per_rec = B * (dr * 4 + (cfg.hybrid.conv_width - 1) * dr * 2)
        return (n_attn * per_attn + n_rec * per_rec) / len(pat)
    return kv * S


def _attn_flops_per_qtok(cfg: ModelConfig, S_kv: int) -> float:
    if cfg.family == "ssm":
        s = cfg.ssm
        di = s.expand * cfg.d_model
        return 2 * 2 * di * s.d_state                           # h update + Ch
    H, hd = max(cfg.n_heads, 1), cfg.hd
    if cfg.family == "hybrid":
        pat = cfg.hybrid.pattern
        frac_attn = sum(1 for k in pat if k == "attn") / len(pat)
        return frac_attn * 2 * 2 * min(S_kv, cfg.hybrid.window) * H * hd
    return 2 * 2 * S_kv * H * hd


def analytic_terms(cfg: ModelConfig, shape: str, n_chips: int,
                   bits: int = 4, group: int = 32, model_shards: int = 16,
                   data_shards: int = 16) -> dict:
    S, B, kind = SHAPES[shape]
    N = cfg.param_count()
    Na = cfg.active_param_count()
    L = cfg.n_layers
    D = cfg.d_model

    if kind == "decode":
        toks = B
        wbytes = Na * (bits / 8 + 2 * 4 / group + 0.002)        # int + S/Z
        emb = cfg.vocab * D * 2 * (1 if cfg.tie_embeddings else 2)
        wbytes += emb                                            # fp head/embed
        mem = wbytes / model_shards + \
            L * _cache_bytes_per_layer(cfg, S, B) / min(B, data_shards) / \
            (model_shards if cfg.n_kv_heads and
             cfg.n_kv_heads % model_shards == 0 else 1)
        flops = (2 * Na * toks + toks * L * _attn_flops_per_qtok(cfg, S)) / n_chips
        coll = toks * D * 2 * 2 * L / model_shards               # TP allreduce
    elif kind == "prefill":
        toks = B * S
        wbytes = N * 2 / model_shards
        acts = toks * D * 2 * 8 * L / n_chips                    # ~8 tensors/layer
        cache = L * _cache_bytes_per_layer(cfg, S, B) / n_chips * \
            (model_shards if False else 1)
        mem = wbytes + acts + cache / n_chips
        flops = (2 * Na * toks + toks * L * _attn_flops_per_qtok(cfg, S) / 2) / n_chips
        coll = toks * D * 2 * 2 * L / n_chips
    else:  # train
        toks = B * S
        weight_traffic = 3 * N * 2 / model_shards                # fwd+bwd+remat
        grads = N * 2 / model_shards * 2                         # write + read
        opt = 3 * N * 4 / (model_shards * data_shards) * 2       # m,v,master r/w
        acts = toks * D * 2 * 10 * L / n_chips
        mem = weight_traffic + grads + opt + acts
        flops = (6 * Na * toks + 3 * toks * L * _attn_flops_per_qtok(cfg, S) / 2) / n_chips
        # collectives: Megatron TP activation ARs dominate —
        # fwd (2/layer) + bwd (2/layer), ~2× size on the wire, per local token
        toks_local = toks / data_shards
        act_ar = 2 * 2 * 2 * toks_local * D * 2 * L
        coll = (act_ar
                + 2 * N * 2 / model_shards                       # grad AR (bf16)
                + N * 2 / model_shards)                          # param AG (bf16)
    return {
        "t_compute_s": flops / PEAK_FLOPS,
        "t_memory_s": mem / HBM_BW,
        "t_collective_s": coll / ICI_BW,
        "flops_per_device": flops,
        "bytes_per_device": mem,
        "collective_bytes_per_device": coll,
    }
