"""Production serving launcher — TTQEngine with a synthetic request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma_7b --smoke \
        --requests 8 --bits 4 --rank 16 --kv-dtype int8

Mixed precision is declared through policy overrides (repro.quant), e.g.
``--attn-bits 4 --mlp-bits 3`` gives attention projections 4-bit and MLPs
3-bit (outlier-heavy projections tolerate fewer bits worse — keep them wide).
``--kv-dtype int8|int4`` switches the engine's KV-cache memory layout to
quantized codes + per-(head, token) scales, read by the fused Pallas
dequant-attention kernel (``--kv-no-pallas`` forces the jnp fallback).

``--decode-chunk K`` fuses K decode steps into one on-device block
(``lm.decode_many``) — one host sync per K tokens instead of one per token
(``0`` picks the bench-calibrated default per slot count);
``--recal-tokens N`` drives the requantization cadence by a token budget
instead of per-admission (DESIGN.md §"Serving architecture").

``--use-kernels`` turns on the packed-weight fast path end to end: weights
quantize to packed int codes and every decode matmul dispatches the Pallas
``ttq_gemm``; ``--requant-threshold T`` arms the delta gate — only layers
whose activation diagonal drifted ≥ T (relative L2) re-quantize, the rest
reuse their previous packed tensors.  The end-of-run summary reports the
gate's skip counts and the requantization wall time next to
``host_syncs/token``.

``--kv-paged`` switches the slot caches to the block-paged pool (DESIGN.md
§8): ``--kv-block-size`` sets the block granularity, ``--kv-pool-blocks``
the per-layer pool budget (0 = capacity-equivalent to the dense slab;
smaller budgets oversubscribe — admissions preempt running slots under
pressure instead of stalling), and ``--no-prefix-cache`` disables shared
prompt-prefix block reuse.  The summary then adds ``kv_pool_util`` (peak),
``prefix_hit_rate`` and the preemption count.

``--prefill-chunk C`` ingests long prompts in C-token chunks interleaved
with decode rounds (DESIGN.md §13) so a long arrival cannot stall running
streams for a whole monolithic prefill; ``--prefill-budget N`` bounds the
padded prefill tokens per round, ``--max-queue D`` bounds the admission
queue (``QueueFull`` past D).  The summary then adds TTFT/ITL p50/p99 and
the chunk/queue counters.

``--deadline-s T`` gives every request a T-second deadline (expired
requests fail cleanly, never stall the drain loop); ``--inject NAME``
runs a named deterministic fault recipe (``serving.faults.demo_injector``)
against the live engine and the summary reports what fired and what the
guards caught; ``--no-guards`` strips the robustness layer entirely
(DESIGN.md §12) — byte-identical to the pre-guard engine.
"""
import argparse
import time


def build_policy(args):
    """CLI flags → QuantPolicy with per-layer mixed-precision overrides."""
    from repro.quant import (KVCacheConfig, KernelConfig, NO_QUANT, override,
                             ttq_policy)

    kvcache = KVCacheConfig(dtype=args.kv_dtype,
                            group_size=args.kv_group_size,
                            use_pallas=not args.kv_no_pallas)
    kernel = KernelConfig(use_pallas=args.use_kernels)
    if args.no_quant:
        return NO_QUANT.with_(kvcache=kvcache, kernel=kernel)
    policy = ttq_policy(bits=args.bits, group_size=args.group_size,
                        rank=args.rank, kvcache=kvcache, kernel=kernel,
                        packed=args.use_kernels or args.packed)
    ovr = []
    if args.attn_bits:
        ovr.append(override("*.mix.*", bits=args.attn_bits))
    if args.mlp_bits:
        ovr.append(override("*.mlp.*", bits=args.mlp_bits))
    return policy.with_overrides(*ovr) if ovr else policy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=32)
    ap.add_argument("--rank", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--decode-chunk", type=int, default=0,
                    help="K fused on-device decode steps per host sync "
                         "(lm.decode_many; 1 = per-token round trips; "
                         "0 = auto per slot count, bench_engine crossover)")
    ap.add_argument("--recal-tokens", type=int, default=0,
                    help="requantize every N processed tokens instead of "
                         "every --recal-every admissions (0 = off)")
    ap.add_argument("--recal-every", type=int, default=1,
                    help="requantize after every N admissions")
    ap.add_argument("--use-kernels", action="store_true",
                    help="packed int weights + Pallas ttq_gemm on every "
                         "decode matmul (the paper's fast path end to end)")
    ap.add_argument("--packed", action="store_true",
                    help="pack weight codes (implied by --use-kernels)")
    ap.add_argument("--requant-threshold", type=float, default=-1.0,
                    help="delta gate: requantize only layers whose "
                         "activation diagonal drifted >= T in relative L2 "
                         "(<0 = always requantize everything)")
    ap.add_argument("--double-buffer", action="store_true",
                    help="readiness-gated requant swap: decode keeps the "
                         "previous tree until the new one is device-ready "
                         "(tokens become device-timing-dependent)")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--attn-bits", type=int, default=0,
                    help="override bits for attention projections (0 = base)")
    ap.add_argument("--mlp-bits", type=int, default=0,
                    help="override bits for MLP projections (0 = base)")
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=("bf16", "int8", "int4"),
                    help="KV-cache storage dtype (int4 is packed 8/int32)")
    ap.add_argument("--kv-group-size", type=int, default=0,
                    help="KV scale group along head dim (0 = per head-token)")
    ap.add_argument("--kv-no-pallas", action="store_true",
                    help="jnp fallback for the dequant-attention read")
    ap.add_argument("--kv-paged", action="store_true",
                    help="block-paged KV pool + per-slot block tables with "
                         "prefix caching and preemption (plain-attention "
                         "families)")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="tokens per paged pool block")
    ap.add_argument("--kv-pool-blocks", type=int, default=0,
                    help="per-layer pool blocks incl. the sink (0 = "
                         "capacity-equivalent to the dense slab)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable shared prompt-prefix block reuse")
    ap.add_argument("--speculate-k", type=int, default=0,
                    help="self-speculative decoding (DESIGN.md §11): draft "
                         "W tokens per window with the int4 draft tree, "
                         "verify in one batched dispatch (0 = off; greedy "
                         "only — ignored when temperature > 0)")
    ap.add_argument("--draft-bits", type=int, default=0,
                    help="explicit draft-tree precision (rank-0, g32) for "
                         "--speculate-k; 0 = the policy's int4 draft_variant."
                         "  With --no-quant this is draft-only quantization:"
                         " the quantized draft speculates for the fp model")
    ap.add_argument("--mesh", type=int, default=1,
                    help="model-parallel mesh size (tensor/expert parallel "
                         "serving, DESIGN.md §10); 1 = single device")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request deadline in seconds (DESIGN.md §12); "
                         "expired requests fail cleanly with error="
                         "'deadline exceeded' (0 = no deadline)")
    ap.add_argument("--inject", default="",
                    help="named fault-injection recipe (serving.faults."
                         "demo_injector): nan-stats, outlier-stats, "
                         "bad-requant, pool-steal, poison-lane.  Seeded and "
                         "deterministic; the summary reports what fired and "
                         "what the guards caught")
    ap.add_argument("--no-guards", action="store_true",
                    help="disable the robustness layer (calibration guards, "
                         "requant health gate, lane fault isolation, "
                         "degradation ladder) — restores the exact pre-guard "
                         "engine")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill (DESIGN.md §13): ingest prompt "
                         "tails longer than C tokens in C-sized chunks "
                         "interleaved with decode rounds, bounding the "
                         "per-round stall a long prompt inflicts on running "
                         "streams (0 = monolithic; paged pools need C to "
                         "divide --kv-block-size)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="padded prefill tokens dispatched per engine round "
                         "across all chunk-ingesting requests (0 = one "
                         "chunk per round)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the admission queue: submit() raises "
                         "QueueFull at this depth (the async TTQServer "
                         "awaits instead; 0 = unbounded)")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get
    from repro.models import lm
    from repro.serving import EngineConfig, TTQEngine

    pctx = None
    if args.mesh > 1:
        from repro.launch.mesh import make_ctx, make_mesh
        pctx = make_ctx(make_mesh(1, args.mesh))
    cfg = get(args.arch, smoke=args.smoke)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    policy = build_policy(args)
    faults = None
    if args.inject:
        from repro.serving import demo_injector
        faults = demo_injector(args.inject)
    draft_policy = None
    if args.speculate_k > 0 and args.draft_bits > 0:
        from repro.quant import ttq_policy
        draft_policy = ttq_policy(bits=args.draft_bits, group_size=32,
                                  rank=0, kvcache=policy.kvcache,
                                  kernel=policy.kernel,
                                  packed=args.use_kernels or args.packed)
    eng = TTQEngine(cfg, params, policy,
                    EngineConfig(max_slots=args.slots, max_len=args.max_len,
                                 decode_chunk=args.decode_chunk,
                                 recalibrate_every=args.recal_every,
                                 recalibrate_tokens=args.recal_tokens,
                                 requant_threshold=args.requant_threshold,
                                 double_buffer=args.double_buffer,
                                 kv_paged=args.kv_paged or None,
                                 kv_block_size=args.kv_block_size
                                 if args.kv_paged else 0,
                                 kv_pool_blocks=args.kv_pool_blocks,
                                 prefix_cache=not args.no_prefix_cache,
                                 speculate_k=args.speculate_k,
                                 guards=not args.no_guards,
                                 deadline_s=args.deadline_s,
                                 prefill_chunk=args.prefill_chunk,
                                 prefill_budget=args.prefill_budget,
                                 max_queue=args.max_queue),
                    pctx=pctx, draft_policy=draft_policy, faults=faults)
    layout = (f"paged block={eng.kvcfg.block_size} "
              f"pool={eng.num_blocks} blocks/layer "
              f"prefix_cache={not args.no_prefix_cache}"
              if eng.kvcfg.paged else "dense slab")
    print(f"kv-cache: dtype={eng.kvcfg.dtype} "
          f"group_size={eng.kvcfg.group_size or 'per-head-token'} "
          f"pallas={eng.kvcfg.use_pallas} layout={layout}")
    gate = (f"delta-gate >= {args.requant_threshold}"
            if args.requant_threshold >= 0 else "always-full")
    print(f"weight kernels: pallas={eng.kncfg.use_pallas} "
          f"packed={policy.packed}, requant: {gate}")
    cadence = (f"every {args.recal_tokens} tokens" if args.recal_tokens
               else f"every {args.recal_every} admissions")
    unit = "windows" if eng.ecfg.speculate_k > 0 else "tokens"
    print(f"decode-chunk: {eng.ecfg.decode_chunk} {unit}/dispatch, "
          f"requant cadence: {cadence}")
    if eng.ecfg.speculate_k > 0:
        dp = eng.draft_policy
        dd = (f"int{dp.qcfg.bits} g{dp.qcfg.group_size}"
              if dp is not None and dp.any_enabled else "fp (no-quant)")
        print(f"speculate: W={eng.ecfg.speculate_k} drafted tokens/window, "
              f"draft tree {dd}")
    if pctx is not None:
        print(f"mesh: (1, {args.mesh}) data×model over "
              f"{jax.device_count()} device(s)")
    dl = f"{args.deadline_s:.1f}s" if args.deadline_s > 0 else "none"
    print(f"guards: {'off' if args.no_guards else 'on'} deadline={dl} "
          f"inject={args.inject or 'none'}")
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, min(24, args.max_len // 2)))
        prompt = list(rng.integers(1, cfg.vocab, size=plen))
        kw = {}
        if cfg.family == "encdec":
            kw["frames"] = np.asarray(rng.standard_normal(
                (cfg.encdec.n_frames, cfg.d_model)), np.float32)
        eng.submit(prompt, max_new=args.max_new, **kw)
    outs = eng.run_all()
    dt = time.time() - t0
    toks = sum(len(v) for v in outs.values())
    skipped = eng.layers_skipped
    total_layers = eng.layers_skipped + eng.layers_requantized
    print(f"arch={cfg.name} requests={len(outs)} tokens={toks} "
          f"wall={dt:.1f}s requants={eng.n_requants} "
          f"host_syncs/token={eng.host_syncs / max(toks, 1):.2f} "
          f"requant_wall={eng.requant_wall_s:.2f}s "
          f"gate_skipped_layers={skipped}/{total_layers}")
    lat = eng.latency_percentiles()
    print(f"latency: ttft p50/p99 {lat['ttft_p50'] * 1e3:.1f}/"
          f"{lat['ttft_p99'] * 1e3:.1f} ms, itl p50/p99 "
          f"{lat['itl_p50'] * 1e3:.1f}/{lat['itl_p99'] * 1e3:.1f} ms "
          f"({lat['n_streams']} streams)")
    if eng.ecfg.prefill_chunk > 0 or eng.ecfg.max_queue > 0:
        print(f"slo: prefill_chunks={eng.prefill_chunks} "
              f"queue_rejections={eng.queue_rejections} "
              f"queue_depth={eng.queue_depth}")
    if eng.ecfg.speculate_k > 0:
        print(f"speculate: windows={eng.spec_windows} "
              f"acceptance={eng.spec_acceptance_rate:.2f} "
              f"(accepted drafts / drafted tokens)")
    if eng.kvcfg.paged:
        print(f"kv-pool: util_peak={eng.kv_pool_utilization:.2f} "
              f"prefix_hit_rate={eng.prefix_hit_rate:.2f} "
              f"preemptions={eng.preemptions} "
              f"prefill_tokens={eng.prefill_tokens:.0f}")
    if not args.no_guards:
        print(f"guards: calib_rejections={eng.calib_rejections} "
              f"requant_rejections={eng.requant_rejections} "
              f"lane_faults={eng.lane_faults} "
              f"deadline_expirations={eng.deadline_expirations} "
              f"admission_failures={eng.admission_failures} "
              f"degrade_events={eng.degrade_events}")
    if faults is not None:
        fired = ", ".join(f"{s}@{n}" for s, n, _ in faults.fired) or "none"
        print(f"faults fired: {fired}")
        failed = [r for r, v in sorted(outs.items()) if v.error]
        if failed:
            print(f"  failed rids: {failed}")
    for rid, v in sorted(outs.items())[:4]:
        print(f"  rid={rid}: {v[:10]}{'…' if len(v) > 10 else ''}")


if __name__ == "__main__":
    main()
