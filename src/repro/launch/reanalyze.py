"""Re-derive roofline terms from cached .hlo.zst texts (no recompilation).

    PYTHONPATH=src python -m repro.launch.reanalyze
"""
import glob
import json
import os

import zstandard as zstd

from repro.launch.analysis import HBM_BW, ICI_BW, PEAK_FLOPS, HloCost

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def reanalyze_cell(json_path: str) -> bool:
    hlo_path = json_path.replace(".json", ".hlo.zst")
    if not os.path.exists(hlo_path):
        return False
    with open(json_path) as f:
        rec = json.load(f)
    if "roofline" not in rec:
        return False
    with open(hlo_path, "rb") as f:
        text = zstd.ZstdDecompressor().decompress(f.read()).decode()
    flops, byts, coll = HloCost(text).cost()
    cbytes = sum(coll.values())
    rl = rec["roofline"]
    rl.update({
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "collective_bytes_per_device": cbytes,
        "collectives": {k: int(v) for k, v in coll.items()},
        "t_compute_s": flops / PEAK_FLOPS,
        "t_memory_s": byts / HBM_BW,
        "t_collective_s": cbytes / ICI_BW,
    })
    terms = [("compute", rl["t_compute_s"]), ("memory", rl["t_memory_s"]),
             ("collective", rl["t_collective_s"])]
    rl["dominant"] = max(terms, key=lambda kv: kv[1])[0]
    if rl.get("model_flops"):
        tot = flops * rl["n_chips"]
        rl["useful_flop_ratio"] = rl["model_flops"] / tot if tot else 0.0
    with open(json_path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return True


def main():
    n = 0
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        if reanalyze_cell(p):
            n += 1
    print(f"re-analyzed {n} cells")


if __name__ == "__main__":
    main()
