"""Roofline-term extraction from compiled XLA artifacts.

    compute term    = HLO_FLOPs / peak_FLOP/s          (per device)
    memory term     = HLO_bytes / HBM_bw               (per device)
    collective term = collective_bytes / link_bw       (per device)

``compiled.cost_analysis()`` counts each ``while`` body ONCE (HloCostAnalysis
does not multiply by trip count), which undercounts scanned-layer models by
O(depth × microbatches).  We therefore walk the post-SPMD HLO text ourselves:

* ``dot``            → 2 · prod(out) · prod(contracted dims) FLOPs
* ``fusion``         → operand+output bytes once (one HBM pass), inner dots
                       counted compute-only
* ``while``          → (body + cond) × ``known_trip_count`` from
                       backend_config (scan/fori loops carry it)
* collectives        → output bytes per kind, trip-multiplied
* everything else    → operands+output bytes, 1 FLOP/elem

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import re
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?(%[\w\.\-]+)\s*=\s*((?:\([^()]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+([\w\-\$]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=(%[\w\.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w\.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w\.\-]+)")
_OPERAND_RE = re.compile(r"(%[\w\.\-]+)")


def _shape_info(shape_str: str):
    """'f32[8,16]{1,0}' or tuple '(f32[2], s32[])' → (elems, bytes, dims-of-first)."""
    total_e = total_b = 0
    first_dims = None
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in dims_s.split(",") if x]
        n = 1
        for d in dims:
            n *= d
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
        if first_dims is None:
            first_dims = dims
    return total_e, total_b, (first_dims or [])


class HloCost:
    """Trip-count-aware cost walker over post-optimization HLO text."""

    def __init__(self, text: str, collect: bool = False):
        self.comps: Dict[str, list] = {}
        self.entry = None
        self.collect = collect
        self.attributions: list = []     # (eff_bytes, eff_flops, kind, snippet)
        self._mult = 1.0                 # current loop-trip multiplier
        cur = None
        for line in text.splitlines():
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                self.comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                    continue
                self.comps[cur].append(line)
        self._memo: Dict[tuple, tuple] = {}

    def _note(self, bytes_, flops_, kind, line):
        if self.collect and (bytes_ * self._mult > 0 or flops_ * self._mult > 0):
            meta = re.search(r'op_name="([^"]+)"', line)
            snippet = meta.group(1) if meta else line.strip()[:120]
            self.attributions.append(
                (bytes_ * self._mult, flops_ * self._mult, kind, snippet[:160]))

    def _symbols(self, comp):
        syms = {}
        for line in self.comps.get(comp, []):
            m = _INSTR_RE.match(line)
            if m:
                syms[m.group(2)] = m.group(3)
        return syms

    def _fusion_hbm(self, called: str, operands, syms_caller) -> float:
        """HBM bytes of one fusion execution.

        A fusion reads each parameter ONCE — unless the parameter is only
        consumed by slicing ops (dynamic-slice/gather), in which case it reads
        only the slices (the loop-body cache-update pattern: without this the
        stacked KV cache is charged in full × trip count).  Similarly a
        root dynamic-update-slice writes only the updated region (XLA updates
        in place when input/output alias).
        """
        lines = self.comps.get(called, [])
        parsed = []
        param_idx = {}
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                pm = re.match(
                    r"^\s*(ROOT\s+)?(%[\w\.\-]+)\s*=\s*(\S+(?:\{[^}]*\})?)\s+parameter\((\d+)\)",
                    line)
                if pm:
                    param_idx[pm.group(2)] = int(pm.group(4))
                    parsed.append((pm.group(2), pm.group(3), "parameter", "",
                                   bool(pm.group(1))))
                continue
            parsed.append((m.group(2), m.group(3), m.group(4), m.group(5),
                           bool(m.group(1))))
        total = 0.0
        # parameter read bytes (slice-aware)
        for pname, pshape, op, _, _ in parsed:
            if op != "parameter":
                continue
            consumers = [(n, s, o, rest) for (n, s, o, rest, _) in parsed
                         if o != "parameter" and pname in _OPERAND_RE.findall(rest)]
            if consumers and all(o in ("dynamic-slice", "gather", "scatter",
                                       "dynamic-update-slice", "bitcast",
                                       "get-tuple-element")
                                 for (_, _, o, _) in consumers):
                for (_, cshape, o, rest) in consumers:
                    if o in ("dynamic-update-slice", "scatter"):
                        ops_in = _OPERAND_RE.findall(rest)
                        upd = ops_in[-1] if len(ops_in) > 1 else None
                        ub = 0
                        for (n2, s2, _, _, _) in parsed:
                            if n2 == upd:
                                ub = _shape_info(s2)[1]
                                break
                        total += 2.0 * ub          # read+write the region
                    else:
                        total += _shape_info(cshape)[1]
            else:
                total += _shape_info(pshape)[1]
        # output write bytes (in-place DUS writes only the slice)
        roots = [(n, s, o, rest) for (n, s, o, rest, is_root) in parsed if is_root]
        inplace = ("dynamic-update-slice", "scatter")
        for (n, s, o, rest) in roots:
            if o in inplace:
                continue                            # already charged above
            if o == "tuple":
                for el in _OPERAND_RE.findall(rest):
                    for (n2, s2, o2, _, _) in parsed:
                        if n2 == el and o2 not in inplace:
                            total += _shape_info(s2)[1]
            else:
                total += _shape_info(s)[1]
        return total

    def cost(self, comp=None, fused=False):
        """→ (flops, hbm_bytes, {collective_kind: bytes})."""
        comp = comp or self.entry
        key = (comp, fused)
        if key in self._memo and not self.collect:
            return self._memo[key]
        flops = hbm = 0.0
        coll: Dict[str, float] = {}
        syms = self._symbols(comp)
        for line in self.comps.get(comp, []):
            m = _INSTR_RE.match(line)
            if not m:
                continue
            _, name, out_shape, op, rest = m.groups()
            out_e, out_b, out_dims = _shape_info(out_shape)
            operands = [o for o in _OPERAND_RE.findall(rest.split(", calls=")[0]
                                                       .split(", body=")[0])
                        if o in syms]
            opnd_b = sum(_shape_info(syms[o])[1] for o in operands)
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "iota", "partition-id",
                      "replica-id"):
                continue
            if op == "while":
                trip = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                body = _BODY_RE.search(line)
                cond = _COND_RE.search(line)
                self._mult *= trip
                for sub in (body, cond):
                    if sub:
                        f, b, c = self.cost(sub.group(1))
                        flops += trip * f
                        hbm += trip * b
                        for k, v in c.items():
                            coll[k] = coll.get(k, 0.0) + trip * v
                self._mult /= trip
                continue
            if op in ("call", "conditional", "async-start"):
                cm = _CALLS_RE.search(line)
                if cm:
                    f, b, c = self.cost(cm.group(1))
                    flops += f
                    hbm += b
                    for k, v in c.items():
                        coll[k] = coll.get(k, 0.0) + v
                continue
            if op == "fusion":
                cm = _CALLS_RE.search(line)
                if cm:
                    f, _, c = self.cost(cm.group(1), fused=True)
                    flops += f
                    for k, v in c.items():
                        coll[k] = coll.get(k, 0.0) + v
                    if not fused:
                        fb = self._fusion_hbm(cm.group(1), operands, syms)
                        hbm += fb
                        self._note(fb, f, "fusion", line)
                elif not fused:
                    hbm += out_b + opnd_b
                continue
            base = op.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES:
                if op.endswith("-done"):
                    continue
                coll[base] = coll.get(base, 0.0) + out_b
                self._note(out_b, 0, f"coll:{base}", line)
                if not fused:
                    hbm += out_b + opnd_b
                continue
            if op in ("dot", "convolution"):
                contract = 1
                lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                if lc and operands:
                    lhs_dims = _shape_info(syms[operands[0]])[2]
                    for i in (int(x) for x in lc.group(1).split(",") if x):
                        if i < len(lhs_dims):
                            contract *= lhs_dims[i]
                flops += 2.0 * out_e * contract
                if not fused:
                    hbm += out_b + opnd_b
                    self._note(out_b + opnd_b, 2.0 * out_e * contract, "dot", line)
                continue
            if op == "copy":
                if not fused:
                    hbm += out_b + opnd_b
                    self._note(out_b + opnd_b, 0, "copy", line)
                continue
            if op in ("dynamic-slice", "gather"):
                if not fused:
                    hbm += 2.0 * out_b              # read the slice, write it
                    self._note(2.0 * out_b, 0, op, line)
                continue
            if op in ("dynamic-update-slice", "scatter"):
                # in-place region update: traffic = the updated rows, not the
                # whole buffer (XLA TPU scatters in place on dead operands —
                # the per-slot KV-cache write pattern)
                if not fused and len(operands) > 1:
                    ub = _shape_info(syms[operands[-1]])[1]
                    hbm += 2.0 * ub
                    self._note(2.0 * ub, 0, op, line)
                continue
            # generic elementwise / data-movement op
            flops += out_e
            if not fused:
                hbm += out_b + opnd_b
                self._note(out_b + opnd_b, out_e, op, line)
        self._memo[key] = (flops, hbm, coll)
        return self._memo[key]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Trip-count-aware per-kind collective bytes (per device)."""
    _, _, coll = HloCost(hlo_text).cost()
    return {k: int(v) for k, v in coll.items()}


def roofline(compiled, n_chips: int, model_flops: float = 0.0) -> dict:
    text = compiled.as_text()
    hc = HloCost(text)
    flops, byts, coll = hc.cost()
    cbytes = sum(coll.values())
    # raw cost_analysis kept for reference (known while-undercount)
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        ca_flops = float(ca.get("flops", 0.0))
        ca_bytes = float(ca.get("bytes accessed", 0.0))
    except Exception:
        ca_flops = ca_bytes = -1.0
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = cbytes / ICI_BW
    dominant = max(
        [("compute", t_compute), ("memory", t_memory), ("collective", t_coll)],
        key=lambda kv: kv[1])[0]
    out = {
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "collective_bytes_per_device": cbytes,
        "collectives": {k: int(v) for k, v in coll.items()},
        "cost_analysis_flops_raw": ca_flops,
        "cost_analysis_bytes_raw": ca_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "n_chips": n_chips,
    }
    if model_flops:
        out["model_flops"] = model_flops
        total_hlo = flops * n_chips
        out["useful_flop_ratio"] = model_flops / total_hlo if total_hlo else 0.0
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)
    out["memory_analysis"] = mem
    return out
