"""Production mesh construction (functions, never module-level constants —
importing this module must not touch jax device state)."""
from __future__ import annotations

import jax

from repro.parallel import ParallelCtx


def make_mesh(data: int = 1, model: int = 1):
    """General (data, model) mesh — THE mesh-construction entry for launchers
    and serving (tracecheck TC405 pins `jax.make_mesh` to this module)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_ctx(mesh, *, moe_impl: str = "a2a") -> ParallelCtx:
    axes = mesh.axis_names
    data_axes = tuple(a for a in axes if a in ("pod", "data"))
    return ParallelCtx(mesh=mesh, data_axes=data_axes, model_axis="model",
                       moe_impl=moe_impl)


def make_test_mesh(data: int = 2, model: int = 2):
    return make_mesh(data, model)
