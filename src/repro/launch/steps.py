"""Per-cell step builders for the dry-run: (arch × shape × mesh) → jitted fn +
abstract inputs + shardings.  Nothing here allocates device memory — params,
optimizer state, caches and stats are all ``jax.eval_shape`` products.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES
from repro.core import AWQConfig, QuantPolicy
from repro.quant import quantize_params, ttq_policy
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw_init
from repro.parallel import ParallelCtx, param_sharding, state_sharding
from repro.parallel.rules import divisible_spec
from repro.training.trainer import TrainConfig, make_train_step, opt_sharding

P = jax.sharding.PartitionSpec


def _ns(mesh, spec):
    return jax.sharding.NamedSharding(mesh, spec)


def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    seq, gbatch, kind = SHAPES[shape_name]
    if kind == "train":
        b = {"tokens": jax.ShapeDtypeStruct((gbatch, seq), jnp.int32)}
        if cfg.family == "encdec":
            b["frames"] = jax.ShapeDtypeStruct(
                (gbatch, cfg.encdec.n_frames, cfg.d_model), jnp.bfloat16)
        return b
    if kind == "prefill":
        b = {"tokens": jax.ShapeDtypeStruct((gbatch, seq), jnp.int32)}
        if cfg.family == "encdec":
            b["frames"] = jax.ShapeDtypeStruct(
                (gbatch, cfg.encdec.n_frames, cfg.d_model), jnp.bfloat16)
        return b
    # decode: one new token against a seq-long cache
    return {"token": jax.ShapeDtypeStruct((gbatch, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((gbatch,), jnp.int32)}


def params_abstract(cfg: ModelConfig):
    return jax.eval_shape(lambda k: lm.init_params(cfg, k),
                          jax.random.PRNGKey(0))


def _batch_shardings(batch_sds, pctx):
    dp = pctx.dp
    return jax.tree.map(
        lambda s: _ns(pctx.mesh, divisible_spec(
            P(dp, *([None] * (s.ndim - 1))), s.shape, pctx.mesh)), batch_sds)


# --------------------------------------------------------------------- train

def build_train_cell(cfg: ModelConfig, pctx: ParallelCtx, shape_name: str,
                     n_microbatches: Optional[int] = None):
    seq, gbatch, kind = SHAPES[shape_name]
    assert kind == "train"
    mesh = pctx.mesh
    dp_size = 1
    for a in pctx.data_axes:
        dp_size *= mesh.shape[a]
    nmb = n_microbatches or max(1, gbatch // dp_size)
    tcfg = TrainConfig(n_microbatches=nmb, remat=True, zero1=True)
    opt_sds = jax.eval_shape(
        lambda k: adamw_init(lm.init_params(cfg, k)), jax.random.PRNGKey(0))
    batch_sds = input_specs(cfg, shape_name)
    pshard = param_sharding(opt_sds["master"], pctx)
    oshard = opt_sharding(opt_sds, pshard, pctx, tcfg.zero1)
    bshard = _batch_shardings(batch_sds, pctx)
    step = make_train_step(cfg, tcfg, pctx)
    fn = jax.jit(step, in_shardings=(oshard, bshard),
                 out_shardings=(oshard, None), donate_argnums=(0,))
    return fn, (opt_sds, batch_sds), {"n_microbatches": nmb}


# ------------------------------------------------------------------- prefill

def build_prefill_cell(cfg: ModelConfig, pctx: ParallelCtx, shape_name: str):
    seq, gbatch, kind = SHAPES[shape_name]
    assert kind == "prefill"
    mesh = pctx.mesh
    params_sds = params_abstract(cfg)
    batch_sds = input_specs(cfg, shape_name)
    pshard = param_sharding(params_sds, pctx)
    bshard = _batch_shardings(batch_sds, pctx)
    pf = partial(lm.prefill, cfg, pctx=pctx, collect_stats=True,
                 full_logits=False)
    _, state_sds, stats_sds = jax.eval_shape(
        lambda p, b: pf(p, b, max_len=seq), params_sds, batch_sds)
    sshard = state_sharding(state_sds, pctx)
    stats_shard = jax.tree.map(lambda s: _ns(mesh, P(*([None] * s.ndim))),
                               stats_sds)
    logits_shard = _ns(mesh, divisible_spec(P(pctx.dp, "model"),
                                            (gbatch, cfg.vocab), mesh))
    fn = jax.jit(lambda p, b: pf(p, b, max_len=seq),
                 in_shardings=(pshard, bshard),
                 out_shardings=(logits_shard, sshard, stats_shard))
    return fn, (params_sds, batch_sds), {}


# -------------------------------------------------------------------- decode

def quantized_params_abstract(cfg: ModelConfig, policy: QuantPolicy, seq: int,
                              gbatch: int):
    """Abstract quantized param tree = eval_shape(prefill → quantize)."""
    params_sds = params_abstract(cfg)
    batch_sds = {"tokens": jax.ShapeDtypeStruct((gbatch, seq), jnp.int32)}
    if cfg.family == "encdec":
        batch_sds["frames"] = jax.ShapeDtypeStruct(
            (gbatch, cfg.encdec.n_frames, cfg.d_model), jnp.bfloat16)
    _, state_sds, stats_sds = jax.eval_shape(
        lambda p, b: lm.prefill(cfg, p, b, max_len=seq, collect_stats=True,
                                full_logits=False),
        params_sds, batch_sds)
    if not policy.enabled:
        return params_sds, state_sds
    qparams_sds = jax.eval_shape(
        lambda p, s: quantize_params(p, s, policy, count=float(seq * gbatch)),
        params_sds, stats_sds)
    return qparams_sds, state_sds


def build_decode_cell(cfg: ModelConfig, pctx: ParallelCtx, shape_name: str,
                      policy: Optional[QuantPolicy] = None,
                      seq_shard_kv: Optional[bool] = None):
    seq, gbatch, kind = SHAPES[shape_name]
    assert kind == "decode"
    mesh = pctx.mesh
    if policy is None:
        policy = ttq_policy(bits=4, group_size=32, rank=0, packed=True)
    qparams_sds, state_sds = quantized_params_abstract(cfg, policy, seq, gbatch)
    batch_sds = input_specs(cfg, shape_name)
    pshard = param_sharding(qparams_sds, pctx)
    if seq_shard_kv is None:
        seq_shard_kv = gbatch == 1          # long_500k: engage the data axis
    sshard = state_sharding(state_sds, pctx,
                            seq_axis="data" if seq_shard_kv else None)
    tshard = _batch_shardings(batch_sds, pctx)
    logits_shard = _ns(mesh, divisible_spec(P(pctx.dp, "model"),
                                            (gbatch, cfg.vocab), mesh))
    fn = jax.jit(partial(lm.decode_step, cfg, pctx=pctx),
                 in_shardings=(pshard, sshard, tshard["token"], tshard["pos"]),
                 out_shardings=(logits_shard, sshard),
                 donate_argnums=(1,))
    return fn, (qparams_sds, state_sds, batch_sds["token"], batch_sds["pos"]), \
        {"policy": dataclasses.asdict(policy)}
