"""Shared model building blocks — norms, RoPE, attention, MLPs, stats taps.

Conventions
-----------
* All linear weights are (out_features, in_features); matmuls go through
  :func:`linear` which dispatches on plain arrays vs ``QuantizedTensor`` and
  optionally taps the TTQ activation statistic (Σ_t x_t² per input feature).
* Activations are bf16 by default; normalization/softmax/rope run in f32.
* ``stats`` is a flat dict {projection_name: (d_in,) f32}; inside a layer scan
  the dict becomes a scan output so leaves stack to (L, d_in).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.ttq import QuantizedTensor, ttq_matmul

Array = jnp.ndarray
ACT = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


# ---------------------------------------------------------------------------
# linear + stats tap
# ---------------------------------------------------------------------------

def linear(x: Array, w, stats: Optional[dict] = None, name: str = "",
           kcfg=None, pctx=None, tp=None) -> Array:
    """y = x @ wᵀ (w: (out,in) array or QuantizedTensor). Taps Σx² if stats dict given.

    ``kcfg`` (:class:`~repro.core.policy.KernelConfig`) selects the Pallas
    ``ttq_gemm`` path for packed QuantizedTensors (None → jnp fallback).
    ``pctx``/``tp`` ('row'|'col') shard_map the kernel dispatch over the
    model axis; fp weights ignore both (GSPMD shards the einsum)."""
    if stats is not None:
        xf = x.astype(jnp.float32)
        s = jnp.sum(xf * xf, axis=tuple(range(x.ndim - 1)))
        stats[name] = stats.get(name, 0.0) + s
    if isinstance(w, QuantizedTensor):
        return ttq_matmul(x, w, kcfg=kcfg, pctx=pctx, tp=tp).astype(x.dtype)
    return jnp.einsum("...d,od->...o", x, w.astype(x.dtype))


def init_linear(key, d_out: int, d_in: int, dtype=jnp.bfloat16, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_out, d_in), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    nx = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (nx * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: Array, gamma: Array, beta: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    nx = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (nx * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def norm(x: Array, p: dict) -> Array:
    return layernorm(x, p["gamma"], p["beta"]) if "beta" in p else rmsnorm(x, p["gamma"])


def init_norm(d: int, kind: str = "rms"):
    if kind == "rms":
        return {"gamma": jnp.zeros((d,), jnp.float32)}
    return {"gamma": jnp.ones((d,), jnp.float32), "beta": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: Array, pos: Array, theta: float = 10000.0) -> Array:
    """x: (..., S, Dh); pos: (S,) or (..., S) absolute positions."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    ang = pos[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : dh // 2], xf[..., dh // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def rope_decode(x: Array, pos: Array, theta: float = 10000.0) -> Array:
    """Single-token RoPE with per-batch positions. x: (B,H,1,Dh), pos: (B,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)
    ang = pos.astype(jnp.float32)[:, None, None, None] * freqs  # (B,1,1,Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : dh // 2], xf[..., dh // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cache_update_batched(cache: Array, new: Array, pos: Array) -> Array:
    """cache (B,Hkv,Smax,Dh) ← new (B,Hkv,1,Dh) at per-batch seq position pos (B,)."""
    return jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice(c, n.astype(c.dtype), (0, p, 0))
    )(cache, new, pos)


def seq_update_batched(cache: Array, new: Array, pos: Array) -> Array:
    """cache (B,Smax,D) ← new (B,1,D) at per-batch position pos (B,)."""
    return jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice(c, n.astype(c.dtype), (p, 0))
    )(cache, new, pos)


def sinusoidal_pos(n: int, d: int) -> Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10000.0 ** (jnp.arange(0, d, 2, jnp.float32) / d))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# attention — full (masked) / chunked (online-softmax) / decode (cache)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def opt_level() -> int:
    """Perf-iteration switch (EXPERIMENTS.md §Perf).

    0 — baseline: GQA expands KV to H heads, attention math materializes f32.
    1 — optimized (default): grouped-query einsums read the KV cache once at
        its storage dtype; dots accumulate f32 via preferred_element_type.
    """
    import os
    return int(os.environ.get("REPRO_OPT_LEVEL", "1"))


def _expand_kv(k: Array, H: int) -> Array:
    """GQA: (B,Hkv,S,Dh) → (B,H,S,Dh). Keeping the einsum head dim equal to
    q's head dim lets TP shard all attention intermediates on `model` without
    GSPMD reshards (the (Hkv,G) grouped form breaks when Hkv < tp)."""
    Hkv = k.shape[1]
    if Hkv == H:
        return k
    return jnp.repeat(k, H // Hkv, axis=1)


def full_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                   window: int = 0, q_offset: int = 0, scale: float | None = None,
                   soft_cap: float = 0.0) -> Array:
    """q: (B,H,S,Dh), k/v: (B,Hkv,Sk,Dh) → (B,H,S,Dh_v). Masks built from indices."""
    B, H, S, Dh = q.shape
    Sk = k.shape[2]
    scale = scale if scale is not None else Dh ** -0.5
    qi = jnp.arange(S) + q_offset
    ki = jnp.arange(Sk)
    mask = jnp.ones((S, Sk), bool)
    if causal:
        mask &= qi[:, None] >= ki[None, :]
    if window > 0:
        mask &= qi[:, None] - ki[None, :] < window
    if opt_level() >= 1:
        Hkv = k.shape[1]
        G = H // Hkv
        qg = (q.astype(jnp.float32) * scale).astype(k.dtype)
        qg = qg.reshape(B, Hkv, G, S, Dh)
        s = jnp.einsum("bhgsd,bhkd->bhgsk", qg, k,
                       preferred_element_type=jnp.float32)
        if soft_cap > 0:
            s = soft_cap * jnp.tanh(s / soft_cap)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgsk,bhkd->bhgsd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, H, S, -1).astype(q.dtype)
    kf = _expand_kv(k, H).astype(jnp.float32)
    vf = _expand_kv(v, H).astype(jnp.float32)
    s = jnp.einsum("bhsd,bhkd->bhsk", q.astype(jnp.float32) * scale, kf)
    if soft_cap > 0:
        s = soft_cap * jnp.tanh(s / soft_cap)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhsk,bhkd->bhsd", p, vf)
    return o.astype(q.dtype)


def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                      window: int = 0, kv_chunk: int = 1024,
                      scale: float | None = None, soft_cap: float = 0.0) -> Array:
    """Online-softmax attention, O(S·chunk) live memory — used for long context.

    Scans over KV chunks carrying (running-max, denom, accum); numerically
    identical to :func:`full_attention` up to fp error.
    """
    B, H, S, Dh = q.shape
    Sk = k.shape[2]
    if Sk % kv_chunk:
        raise ValueError(f"Sk={Sk} must divide by kv_chunk={kv_chunk}")
    scale = scale if scale is not None else Dh ** -0.5
    qf = q.astype(jnp.float32) * scale
    nck = Sk // kv_chunk
    Hkv = k.shape[1]
    kc = k.reshape(B, Hkv, nck, kv_chunk, Dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, nck, kv_chunk, v.shape[-1]).transpose(2, 0, 1, 3, 4)
    qi = jnp.arange(S)

    grouped = opt_level() >= 1
    G = H // Hkv
    if grouped:
        qf = qf.astype(k.dtype).reshape(B, Hkv, G, S, Dh)

    def step(carry, xs):
        m, l, acc = carry
        ci, kci, vci = xs
        ki = ci * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.ones((S, kv_chunk), bool)
        if causal:
            mask &= qi[:, None] >= ki[None, :]
        if window > 0:
            mask &= qi[:, None] - ki[None, :] < window
        if grouped:
            s = jnp.einsum("bhgsd,bhkd->bhgsk", qf, kci,
                           preferred_element_type=jnp.float32)
            if soft_cap > 0:
                s = soft_cap * jnp.tanh(s / soft_cap)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        else:
            kcf = _expand_kv(kci, H).astype(jnp.float32)
            s = jnp.einsum("bhsd,bhkd->bhsk", qf, kcf)
            if soft_cap > 0:
                s = soft_cap * jnp.tanh(s / soft_cap)
            s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        if grouped:
            pv = jnp.einsum("bhgsk,bhkd->bhgsd", p.astype(vci.dtype), vci,
                            preferred_element_type=jnp.float32)
        else:
            vcf = _expand_kv(vci, H).astype(jnp.float32)
            pv = jnp.einsum("bhsk,bhkd->bhsd", p, vcf)
        acc = acc * alpha[..., None] + pv
        return (m_new, l, acc), None

    hshape = (B, Hkv, G, S) if grouped else (B, H, S)
    m0 = jnp.full(hshape, NEG_INF, jnp.float32)
    l0 = jnp.zeros(hshape, jnp.float32)
    a0 = jnp.zeros((*hshape, v.shape[-1]), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (jnp.arange(nck), kc, vc))
    o = acc / jnp.maximum(l[..., None], 1e-30)
    if grouped:
        o = o.reshape(B, H, S, -1)
    return o.astype(q.dtype)


def attention(q, k, v, *, causal=True, window=0, scale=None, soft_cap=0.0,
              q_offset: int = 0, chunk_threshold: int = 8192,
              kv_chunk: int = 1024):
    """Dispatch full vs chunked by KV length (chunked for long context).

    A nonzero ``q_offset`` (queries starting mid-context: tail prefill over
    a cached prefix) routes to the full path — the chunked scan's masks
    assume query position 0."""
    if (q_offset == 0 and k.shape[2] > chunk_threshold
            and k.shape[2] % kv_chunk == 0):
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 kv_chunk=kv_chunk, scale=scale, soft_cap=soft_cap)
    return full_attention(q, k, v, causal=causal, window=window, scale=scale,
                          soft_cap=soft_cap, q_offset=q_offset)


def decode_attention(q: Array, k_cache: Array, v_cache: Array, cur_pos: Array,
                     *, window: int = 0, scale: float | None = None,
                     soft_cap: float = 0.0) -> Array:
    """Single-token attention over a (B,Hkv,Smax,Dh) cache; positions > cur_pos masked.

    q: (B,H,1,Dh) → (B,H,1,Dh_v).  f32 softmax, memory-bound (the decode roofline).

    Optimized path (opt_level ≥ 1): grouped-query einsum — the cache is read
    ONCE at bf16 (no G× head expansion, no f32 materialization); both dots
    accumulate in f32 (preferred_element_type).  §Perf iteration 1.
    """
    B, H, _, Dh = q.shape
    Smax = k_cache.shape[2]
    scale = scale if scale is not None else Dh ** -0.5
    ki = jnp.arange(Smax)
    mask = ki[None, :] <= cur_pos[:, None]                     # (B, Smax)
    if window > 0:
        mask &= ki[None, :] > cur_pos[:, None] - window
    if opt_level() >= 1:
        Hkv = k_cache.shape[1]
        G = H // Hkv
        qg = (q[:, :, 0].astype(jnp.float32) * scale).astype(k_cache.dtype)
        qg = qg.reshape(B, Hkv, G, Dh)
        s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache,
                       preferred_element_type=jnp.float32)
        if soft_cap > 0:
            s = soft_cap * jnp.tanh(s / soft_cap)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgk,bhkd->bhgd", p.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, H, -1)[:, :, None].astype(q.dtype)
    kf = _expand_kv(k_cache, H).astype(jnp.float32)
    vf = _expand_kv(v_cache, H).astype(jnp.float32)
    qf = q[:, :, 0].astype(jnp.float32) * scale                # (B,H,Dh)
    s = jnp.einsum("bhd,bhkd->bhk", qf, kf)
    if soft_cap > 0:
        s = soft_cap * jnp.tanh(s / soft_cap)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhk,bhkd->bhd", p, vf)
    return o[:, :, None].astype(q.dtype)


def suffix_attention(q: Array, k_cache: Array, v_cache: Array, pos: Array,
                     *, scale: float | None = None,
                     soft_cap: float = 0.0) -> Array:
    """Multi-query decode attention for a speculated window (DESIGN.md §11).

    q: (B,H,S,Dh) — S in-window queries per slot at absolute positions
    ``pos[b]..pos[b]+S-1`` over a (B,Hkv,Smax,Dh) cache whose window rows
    were just written (write-then-read).  Query s attends rows ≤ pos[b]+s.
    Key-axis layout, masking, and einsum/dtype discipline mirror
    :func:`decode_attention` exactly so a verify pass over the window
    reproduces sequential decode logits bit-for-bit.
    """
    B, H, S, Dh = q.shape
    Smax = k_cache.shape[2]
    scale = scale if scale is not None else Dh ** -0.5
    ki = jnp.arange(Smax)
    qi = pos[:, None] + jnp.arange(S)                          # (B, S)
    mask = ki[None, None, :] <= qi[:, :, None]                 # (B, S, Smax)
    if opt_level() >= 1:
        Hkv = k_cache.shape[1]
        G = H // Hkv
        qg = (q.astype(jnp.float32) * scale).astype(k_cache.dtype)
        qg = qg.reshape(B, Hkv, G, S, Dh)
        s = jnp.einsum("bhgsd,bhkd->bhgsk", qg, k_cache,
                       preferred_element_type=jnp.float32)
        if soft_cap > 0:
            s = soft_cap * jnp.tanh(s / soft_cap)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgsk,bhkd->bhgsd", p.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, H, S, -1).astype(q.dtype)
    kf = _expand_kv(k_cache, H).astype(jnp.float32)
    vf = _expand_kv(v_cache, H).astype(jnp.float32)
    s = jnp.einsum("bhsd,bhkd->bhsk", q.astype(jnp.float32) * scale, kf)
    if soft_cap > 0:
        s = soft_cap * jnp.tanh(s / soft_cap)
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhsk,bhkd->bhsd", p, vf)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def glu_mlp(x, p, stats=None, prefix="mlp", act="silu", kcfg=None, pctx=None):
    """Gated MLP (SwiGLU/GeGLU): (act(x@Wg) * (x@Wu)) @ Wd."""
    g = linear(x, p["wg"], stats, f"{prefix}.wg", kcfg, pctx=pctx, tp="row")
    u = linear(x, p["wu"], None, kcfg=kcfg, pctx=pctx,
               tp="row")  # same input stats as wg — tap once
    h = ACT[act](g.astype(jnp.float32)).astype(x.dtype) * u
    return linear(h, p["wd"], stats, f"{prefix}.wd", kcfg, pctx=pctx, tp="col")


def plain_mlp(x, p, stats=None, prefix="mlp", act="gelu", kcfg=None, pctx=None):
    h = linear(x, p["w1"], stats, f"{prefix}.w1", kcfg, pctx=pctx, tp="row")
    h = ACT[act](h.astype(jnp.float32)).astype(x.dtype)
    return linear(h, p["w2"], stats, f"{prefix}.w2", kcfg, pctx=pctx, tp="col")


def init_glu_mlp(key, d: int, d_ff: int, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wg": init_linear(k1, d_ff, d, dtype),
            "wu": init_linear(k2, d_ff, d, dtype),
            "wd": init_linear(k3, d, d_ff, dtype)}


def init_plain_mlp(key, d: int, d_ff: int, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key, 2)
    return {"w1": init_linear(k1, d_ff, d, dtype),
            "w2": init_linear(k2, d, d_ff, dtype)}


# ---------------------------------------------------------------------------
# cache helpers
# ---------------------------------------------------------------------------

def cache_update(cache: Array, new: Array, pos: Array) -> Array:
    """cache (B, Hkv, Smax, Dh) ← new (B, Hkv, 1, Dh) at seq position pos (scalar)."""
    return jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype),
                                        (0, 0, pos, 0))


def vocab_logits(x: Array, w_head, stats=None) -> Array:
    """LM head in f32 accumulation (w: (V, D))."""
    return linear(x, w_head, stats, "lm_head").astype(jnp.float32)


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def sample_logits(logits: Array, key=None, temperature: float = 0.0,
                  top_k: int = 0) -> Array:
    """logits (B, V) → (B,) int32. temperature 0 → greedy.

    Lives here (not in ``repro.serving``) so the on-device decode loop
    (``lm.decode_many``) can sample inside its scan; ``serving.sampling``
    re-exports it as the public ``sample``.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(lg, top_k)
        lg = jnp.where(lg < vals[..., -1:], -jnp.inf, lg)
    return jax.random.categorical(key, lg).astype(jnp.int32)
