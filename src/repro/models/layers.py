"""Layer components — mixers (attn / MLA / RG-LRU / SSD) and MLPs (dense / MoE).

Every component exposes:
  init_<kind>(key, cfg)                       → param dict
  <kind>_apply(cfg, p, x, stats, prefix, ...) → sequence-mode output (train/prefill)
  <kind>_decode(cfg, p, x, state, pos, ...)   → (y, new_state) single-token
  <kind>_init_state(cfg, batch, max_len)      → decode-state ShapeDtype/zeros

Stats taps use param-path-aligned names (``prefix + "attn.wq"``) so the TTQ
quantizer can join stats ↔ weights by path (see core/ttq.quantize_tree).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import (ACT, Array, attention, cache_update, cache_update_batched,
                     decode_attention, glu_mlp, init_glu_mlp, init_linear,
                     init_norm, init_plain_mlp, linear, norm, plain_mlp,
                     rmsnorm, rope_decode, seq_update_batched, apply_rope,
                     suffix_attention)
from .config import ModelConfig

DTYPE = jnp.bfloat16


# ===========================================================================
# GQA/MQA attention (dense, vlm, hybrid-attn, encdec self/cross)
# ===========================================================================

def init_attn(key, cfg: ModelConfig, cross: bool = False):
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], H * hd, D),
        "wk": init_linear(ks[1], Hkv * hd, D),
        "wv": init_linear(ks[2], Hkv * hd, D),
        "wo": init_linear(ks[3], D, H * hd),
    }
    if cfg.qk_norm:
        p["qnorm"] = init_norm(hd)
        p["knorm"] = init_norm(hd)
    return p


def _qkv(cfg: ModelConfig, p, xq: Array, xkv: Array, stats, prefix: str,
         kcfg=None, pctx=None):
    B = xq.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = linear(xq, p["wq"], stats, prefix + "wq", kcfg, pctx=pctx,
               tp="row").reshape(B, -1, H, hd)
    k = linear(xkv, p["wk"], None, kcfg=kcfg, pctx=pctx,
               tp="row").reshape(B, -1, Hkv, hd)
    v = linear(xkv, p["wv"], None, kcfg=kcfg, pctx=pctx,
               tp="row").reshape(B, -1, Hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["qnorm"]["gamma"])
        k = rmsnorm(k, p["knorm"]["gamma"])
    # (B, H, S, hd)
    return q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def attn_apply(cfg: ModelConfig, p, x: Array, stats, prefix: str, *,
               causal: bool = True, window: int = 0, pos0: int = 0,
               x_cross: Optional[Array] = None, return_kv: bool = False,
               kv_prefix=None, kvcfg=None, kcfg=None):
    """Sequence-mode attention. x: (B,S,D). Cross-attn if x_cross given.

    ``kv_prefix`` = (k, v) each (B, Hkv, P, Dh): already-cached context
    (post-rope, e.g. a shared prompt prefix gathered from the paged pool)
    prepended to this call's keys/values; the queries then start at absolute
    position ``pos0 == P`` and the causal mask offsets accordingly (tail
    prefill for prefix-cache hits — DESIGN.md §8).  ``return_kv`` returns
    only the *new* k/v (the prefix is already cached).

    With a *quantized* ``kvcfg`` (prefill contexts only) the attention read
    runs over the quantize→dequantize of k/v — exactly the values the cache
    will hold and every later decode step will read.  This keeps a
    preemption-resumed re-prefill on the same numbers the evicted slot's
    decode saw, so the greedy stream continues identically."""
    xkv = x_cross if x_cross is not None else x
    q, k, v = _qkv(cfg, p, x, xkv, stats, prefix, kcfg)
    S = x.shape[1]
    pos = jnp.arange(S) + pos0
    if cfg.pos == "rope" and x_cross is None:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, jnp.arange(k.shape[2]) + pos0, cfg.rope_theta)
    kf, vf = k, v
    if kvcfg is not None and kvcfg.quantized and x_cross is None:
        from repro.core.kvquant import dequantize_kv, quantize_kv
        kf, vf = (dequantize_kv(*quantize_kv(t, bits=kvcfg.bits,
                                             group_size=kvcfg.group_size),
                                jnp.float32, bits=kvcfg.bits,
                                group_size=kvcfg.group_size) for t in (k, v))
    q_off = 0
    if kv_prefix is not None:
        pk, pv = kv_prefix
        kf = jnp.concatenate([pk.astype(kf.dtype), kf], axis=2)
        vf = jnp.concatenate([pv.astype(vf.dtype), vf], axis=2)
        q_off = pk.shape[2]
    o = attention(q, kf, vf, causal=causal and x_cross is None, window=window,
                  soft_cap=cfg.attn_soft_cap, q_offset=q_off)
    y = linear(o.transpose(0, 2, 1, 3).reshape(x.shape[0], S, -1), p["wo"],
               stats, prefix + "wo", kcfg)
    if return_kv:
        return y, (k, v)
    return y


def attn_init_state(cfg: ModelConfig, batch: int, max_len: int, kvcfg=None,
                    num_blocks: int = 0):
    """Decode-state cache for one attention layer.

    bf16 (kvcfg None / dtype='bf16'): {'k','v'} (B,Hkv,Smax,Dh) — the seed
    layout.  Quantized: {'k_q','k_s','v_q','v_s'} with int8 / packed-int4
    codes plus f32 per-(head, token, group) scales (DESIGN.md §"KV-cache
    layout").

    Paged (``kvcfg.paged``): the same leaf names hold a shared block *pool*
    (num_blocks, Hkv, block_size, ·) instead of per-slot slabs; per-slot
    block tables live at the decode-state top level (DESIGN.md §8).
    """
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    if kvcfg is not None and kvcfg.paged:
        lead = (num_blocks, Hkv, kvcfg.block_size)
    else:
        lead = (batch, Hkv, max_len)
    if kvcfg is None or not kvcfg.quantized:
        z = jnp.zeros((*lead, hd), DTYPE)
        return {"k": z, "v": z}
    cz = jnp.zeros((*lead, kvcfg.code_shape(hd)), kvcfg.code_dtype)
    sz = jnp.zeros((*lead, kvcfg.groups(hd)), jnp.float32)
    return {"k_q": cz, "k_s": sz, "v_q": cz, "v_s": sz}


def build_kv_state(cfg: ModelConfig, batch: int, max_len: int, k: Array,
                   v: Array, kvcfg=None):
    """Prefill write point: materialize the decode cache from sequence-mode
    k/v (B,Hkv,S,Dh), quantizing at the cache's storage dtype."""
    z = attn_init_state(cfg, batch, max_len, kvcfg)
    if kvcfg is None or not kvcfg.quantized:
        return {"k": jax.lax.dynamic_update_slice(z["k"], k.astype(DTYPE),
                                                  (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(z["v"], v.astype(DTYPE),
                                                  (0, 0, 0, 0))}
    from repro.core.kvquant import quantize_kv
    out = {}
    for name, t in (("k", k), ("v", v)):
        codes, scales = quantize_kv(t, bits=kvcfg.bits,
                                    group_size=kvcfg.group_size)
        out[name + "_q"] = jax.lax.dynamic_update_slice(
            z[name + "_q"], codes, (0, 0, 0, 0))
        out[name + "_s"] = jax.lax.dynamic_update_slice(
            z[name + "_s"], scales, (0, 0, 0, 0))
    return out


def _kv_append(state, k: Array, v: Array, pos, kvcfg):
    """Per-decode-step append: quantize one token's k/v and write both the
    codes and the per-slot scale rows at position ``pos``."""
    from repro.core.kvquant import quantize_kv
    out = {}
    for name, t in (("k", k), ("v", v)):
        codes, scales = quantize_kv(t, bits=kvcfg.bits,
                                    group_size=kvcfg.group_size)
        out[name + "_q"] = cache_update_batched(state[name + "_q"], codes, pos)
        out[name + "_s"] = cache_update_batched(state[name + "_s"], scales, pos)
    return out


def build_kv_compact(k: Array, v: Array, kvcfg):
    """Paged prefill write point: the prompt's k/v (B,Hkv,S,Dh) at the
    cache's storage dtype, *compact* (no max_len slab) — the runner scatters
    these rows into the slot's pool blocks (DESIGN.md §8)."""
    if kvcfg is None or not kvcfg.quantized:
        return {"k": k.astype(DTYPE), "v": v.astype(DTYPE)}
    from repro.core.kvquant import quantize_kv
    out = {}
    for name, t in (("k", k), ("v", v)):
        codes, scales = quantize_kv(t, bits=kvcfg.bits,
                                    group_size=kvcfg.group_size)
        out[name + "_q"], out[name + "_s"] = codes, scales
    return out


def _pool_row_write(pool: Array, row: Array, phys: Array, off: Array) -> Array:
    """pool (NB,Hkv,bs,D·) ← row (B,Hkv,1,D·) at (phys (B,), off (B,)).

    A vectorized scatter: distinct live slots own distinct blocks, so the
    only duplicate index is the sink block 0 (done/empty lanes), where any
    write order is acceptable."""
    return pool.at[phys, :, off].set(row[:, :, 0].astype(pool.dtype))


def _kv_append_paged(state, k: Array, v: Array, pos, block_table, kvcfg):
    """Paged decode append: one token's k/v row lands in pool block
    ``block_table[b, pos // block_size]`` at offset ``pos % block_size``."""
    bs = kvcfg.block_size
    pos = jnp.asarray(pos, jnp.int32)
    nblk = block_table.shape[1]
    blk = jnp.clip(pos // bs, 0, nblk - 1)
    phys = jnp.take_along_axis(block_table, blk[:, None], axis=1)[:, 0]
    off = pos % bs
    if not kvcfg.quantized:
        return {"k": _pool_row_write(state["k"], k, phys, off),
                "v": _pool_row_write(state["v"], v, phys, off)}
    from repro.core.kvquant import quantize_kv
    out = {}
    for name, t in (("k", k), ("v", v)):
        codes, scales = quantize_kv(t, bits=kvcfg.bits,
                                    group_size=kvcfg.group_size)
        out[name + "_q"] = _pool_row_write(state[name + "_q"], codes, phys, off)
        out[name + "_s"] = _pool_row_write(state[name + "_s"], scales, phys, off)
    return out


def _kv_attention_paged(q: Array, state, block_table, cur, kvcfg, *,
                        soft_cap: float = 0.0, pctx=None):
    """Decode read over the paged pool.  Quantized pools go through the
    fused paged kernel (``use_pallas`` escape hatch routes to the gather
    oracle); the bf16 pool gathers its block-table view and reuses the
    dense ``decode_attention`` bit-for-bit.  With a mesh, the dispatch is
    shard_map'd over KV heads (kernels/ops.py TP wrappers)."""
    if kvcfg.quantized:
        from repro.kernels import ops as kops
        return kops.kv_paged_decode_attention_tp(
            q, state["k_q"], state["k_s"], state["v_q"], state["v_s"],
            block_table, cur, bits=kvcfg.bits, group_size=kvcfg.group_size,
            soft_cap=soft_cap, use_pallas=kvcfg.use_pallas, pctx=pctx)
    from repro.kernels.ref import gather_paged_kv
    kc = gather_paged_kv(state["k"], block_table)
    vc = gather_paged_kv(state["v"], block_table)
    return decode_attention(q, kc, vc, cur, soft_cap=soft_cap)


def _kv_attention(q: Array, state, cur, kvcfg, *, soft_cap: float = 0.0,
                  window: int = 0, pctx=None):
    """Fused dequant attention read over the quantized cache (a nonzero
    ``window`` routes to the jnp oracle, which applies the window mask)."""
    from repro.kernels import ops as kops
    return kops.kv_decode_attention_tp(
        q, state["k_q"], state["k_s"], state["v_q"], state["v_s"], cur,
        bits=kvcfg.bits, group_size=kvcfg.group_size, soft_cap=soft_cap,
        window=window, use_pallas=kvcfg.use_pallas, pctx=pctx)


def attn_decode(cfg: ModelConfig, p, x: Array, state, pos, *, window: int = 0,
                cross_kv=None, kvcfg=None, kcfg=None, block_table=None,
                pctx=None):
    """x: (B,1,D); state: bf16 {'k','v'} or quantized {'k_q','k_s','v_q',
    'v_s'} caches (``kvcfg`` selects); pos: (B,) per-slot positions.
    ``block_table`` (B, nblk) routes the paged pool layout (DESIGN.md §8).
    ``pctx``: head-parallel TP — wq/wk/wv row-split, wo column-split, and
    the quantized-cache attention reads shard over KV heads."""
    if cross_kv is not None:
        k, v = cross_kv
        B = x.shape[0]
        H, hd = cfg.n_heads, cfg.hd
        q = linear(x, p["wq"], kcfg=kcfg, pctx=pctx, tp="row").reshape(B, 1, H, hd)
        if cfg.qk_norm:
            q = rmsnorm(q, p["qnorm"]["gamma"])
        q = q.transpose(0, 2, 1, 3)
        o = attention(q, k, v, causal=False, soft_cap=cfg.attn_soft_cap)
        y = linear(o.transpose(0, 2, 1, 3).reshape(B, 1, -1), p["wo"],
                   kcfg=kcfg, pctx=pctx, tp="col")
        return y, state
    q, k, v = _qkv(cfg, p, x, x, None, "", kcfg, pctx=pctx)
    if cfg.pos == "rope":
        q = rope_decode(q, pos, cfg.rope_theta)
        k = rope_decode(k, pos, cfg.rope_theta)
    if kvcfg is not None and kvcfg.paged:
        st = _kv_append_paged(state, k, v, pos, block_table, kvcfg)
        o = _kv_attention_paged(q, st, block_table, pos, kvcfg,
                                soft_cap=cfg.attn_soft_cap, pctx=pctx)
        y = linear(o.reshape(x.shape[0], 1, -1), p["wo"], kcfg=kcfg,
                   pctx=pctx, tp="col")
        return y, st
    if kvcfg is not None and kvcfg.quantized:
        st = _kv_append(state, k, v, pos, kvcfg)
        o = _kv_attention(q, st, pos, kvcfg, soft_cap=cfg.attn_soft_cap,
                          window=window, pctx=pctx)
        y = linear(o.reshape(x.shape[0], 1, -1), p["wo"], kcfg=kcfg,
                   pctx=pctx, tp="col")
        return y, st
    kc = cache_update_batched(state["k"], k, pos)
    vc = cache_update_batched(state["v"], v, pos)
    o = decode_attention(q, kc, vc, pos, window=window,
                         soft_cap=cfg.attn_soft_cap)
    y = linear(o.reshape(x.shape[0], 1, -1), p["wo"], kcfg=kcfg, pctx=pctx,
               tp="col")
    return y, {"k": kc, "v": vc}


def attn_decode_rolling(cfg: ModelConfig, p, x: Array, state, pos,
                        window: int, kvcfg=None, kcfg=None, pctx=None):
    """Windowed decode with a rolling (B,Hkv,W,hd) cache — O(W) per step.

    Slot validity needs no ordering (softmax is set-wise): slot i is valid iff
    i ≤ pos (cache fills left-to-right before wrapping). pos: (B,).
    """
    q, k, v = _qkv(cfg, p, x, x, None, "", kcfg, pctx=pctx)
    if cfg.pos == "rope":
        q = rope_decode(q, pos, cfg.rope_theta)
        k = rope_decode(k, pos, cfg.rope_theta)
    wpos = jnp.mod(pos, window)
    # validity: min(pos, W-1) marks the highest filled slot
    cur = jnp.minimum(pos, window - 1)
    if kvcfg is not None and kvcfg.quantized:
        st = _kv_append(state, k, v, wpos, kvcfg)
        o = _kv_attention(q, st, cur, kvcfg, soft_cap=cfg.attn_soft_cap,
                          pctx=pctx)
        y = linear(o.reshape(x.shape[0], 1, -1), p["wo"], kcfg=kcfg,
                   pctx=pctx, tp="col")
        return y, st
    kc = cache_update_batched(state["k"], k, wpos)
    vc = cache_update_batched(state["v"], v, wpos)
    o = decode_attention(q, kc, vc, cur, soft_cap=cfg.attn_soft_cap)
    y = linear(o.reshape(x.shape[0], 1, -1), p["wo"], kcfg=kcfg, pctx=pctx,
               tp="col")
    return y, {"k": kc, "v": vc}


def _kv_write_rows(cache: Array, new: Array, pos: Array) -> Array:
    """cache (B,Hkv,Smax,D·) ← new (B,Hkv,S,D·) at rows pos[b]..pos[b]+S-1.

    Window scatter for speculative verify (DESIGN.md §11).  Rows are NOT
    clamped: a row landing at or beyond Smax is dropped (``mode='drop'``) —
    clamping would let a later in-window write corrupt row Smax-1 before a
    still-valid query at the capacity boundary reads it."""
    B, _, S = new.shape[:3]
    rows = pos[:, None] + jnp.arange(S)[None, :]               # (B, S)
    bidx = jnp.arange(B)[:, None]
    return cache.at[bidx, :, rows].set(
        new.transpose(0, 2, 1, 3).astype(cache.dtype), mode="drop")


def _kv_append_rows(state, k: Array, v: Array, pos, kvcfg):
    """Quantized-slab window append: the whole (B,Hkv,S,Dh) drafted window's
    codes and scale rows land at positions pos..pos+S-1 (per-row math is
    identical to :func:`_kv_append`'s single-token quantize)."""
    from repro.core.kvquant import quantize_kv
    out = {}
    for name, t in (("k", k), ("v", v)):
        codes, scales = quantize_kv(t, bits=kvcfg.bits,
                                    group_size=kvcfg.group_size)
        out[name + "_q"] = _kv_write_rows(state[name + "_q"], codes, pos)
        out[name + "_s"] = _kv_write_rows(state[name + "_s"], scales, pos)
    return out


def _pool_rows_write(pool: Array, new: Array, phys: Array, off: Array) -> Array:
    """pool (NB,Hkv,bs,D·) ← new (B,Hkv,S,D·) at (phys (B,S), off (B,S)).

    Multi-row sibling of :func:`_pool_row_write`; in-window rows of one slot
    hit distinct (block, offset) cells, so the only duplicate index is the
    sink block 0 (done lanes / over-capacity rows), where write order is
    irrelevant."""
    return pool.at[phys, :, off].set(new.transpose(0, 2, 1, 3).astype(pool.dtype))


def _kv_append_rows_paged(state, k: Array, v: Array, pos, block_table, kvcfg):
    """Paged window append: row j of the window lands in pool block
    ``block_table[b, (pos+j) // bs]`` at offset ``(pos+j) % bs``.  Rows at or
    beyond the slot's logical capacity route to the sink block 0 instead of
    clamping (same capacity rule as :func:`_kv_write_rows`)."""
    bs = kvcfg.block_size
    pos = jnp.asarray(pos, jnp.int32)
    S = k.shape[2]
    nblk = block_table.shape[1]
    rows = pos[:, None] + jnp.arange(S)[None, :]               # (B,S) absolute
    blk = jnp.clip(rows // bs, 0, nblk - 1)
    phys = jnp.take_along_axis(block_table, blk, axis=1)       # (B,S)
    phys = jnp.where(rows < nblk * bs, phys, 0)                # sink overflow
    off = rows % bs
    if not kvcfg.quantized:
        return {"k": _pool_rows_write(state["k"], k, phys, off),
                "v": _pool_rows_write(state["v"], v, phys, off)}
    from repro.core.kvquant import quantize_kv
    out = {}
    for name, t in (("k", k), ("v", v)):
        codes, scales = quantize_kv(t, bits=kvcfg.bits,
                                    group_size=kvcfg.group_size)
        out[name + "_q"] = _pool_rows_write(state[name + "_q"], codes, phys, off)
        out[name + "_s"] = _pool_rows_write(state[name + "_s"], scales, phys, off)
    return out


def attn_verify(cfg: ModelConfig, p, x: Array, state, pos, *, kvcfg=None,
                kcfg=None, block_table=None, pctx=None):
    """Speculative-verify attention: score a whole drafted window at once.

    x: (B,S,D) — the window's token embeddings at absolute positions
    ``pos[b]..pos[b]+S-1`` (pos: (B,) per-slot window starts).  Writes the
    window's k/v rows at the cache's storage dtype FIRST (overwriting the
    draft pass's rows), then runs the multi-query suffix read over the
    updated cache — write-then-read keeps the key axis identical to
    sequential decode, so greedy verify logits match ``attn_decode``
    bit-for-bit and KV rollback of rejected tokens is just a position
    rewind (DESIGN.md §11).  Returns (y (B,S,D), new_state)."""
    B, S, _ = x.shape
    q, k, v = _qkv(cfg, p, x, x, None, "", kcfg, pctx=pctx)
    if cfg.pos == "rope":
        qpos = (pos[:, None] + jnp.arange(S))[:, None, :]      # (B,1,S)
        q = apply_rope(q, qpos, cfg.rope_theta)
        k = apply_rope(k, qpos, cfg.rope_theta)
    cap = cfg.attn_soft_cap
    if kvcfg is not None and kvcfg.paged:
        from repro.kernels import ops as kops
        st = _kv_append_rows_paged(state, k, v, pos, block_table, kvcfg)
        if kvcfg.quantized:
            o = kops.kv_paged_suffix_attention_tp(
                q, st["k_q"], st["k_s"], st["v_q"], st["v_s"], block_table,
                pos, bits=kvcfg.bits, group_size=kvcfg.group_size,
                soft_cap=cap, use_pallas=kvcfg.use_pallas, pctx=pctx)
        else:
            from repro.kernels.ref import gather_paged_kv
            o = suffix_attention(q, gather_paged_kv(st["k"], block_table),
                                 gather_paged_kv(st["v"], block_table), pos,
                                 soft_cap=cap)
    elif kvcfg is not None and kvcfg.quantized:
        from repro.kernels import ops as kops
        st = _kv_append_rows(state, k, v, pos, kvcfg)
        o = kops.kv_suffix_attention_tp(
            q, st["k_q"], st["k_s"], st["v_q"], st["v_s"], pos,
            bits=kvcfg.bits, group_size=kvcfg.group_size, soft_cap=cap,
            use_pallas=kvcfg.use_pallas, pctx=pctx)
    else:
        kc = _kv_write_rows(state["k"], k, pos)
        vc = _kv_write_rows(state["v"], v, pos)
        st = {"k": kc, "v": vc}
        o = suffix_attention(q, kc, vc, pos, soft_cap=cap)
    y = linear(o.transpose(0, 2, 1, 3).reshape(B, S, -1), p["wo"], kcfg=kcfg,
               pctx=pctx, tp="col")
    return y, st


# ===========================================================================
# MLA — DeepSeek-V2 multi-head latent attention (compressed KV cache)
# ===========================================================================

def init_mla(key, cfg: ModelConfig):
    m, D, H = cfg.mla, cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": init_linear(ks[0], H * qd, D),
        "wkv_a": init_linear(ks[1], m.kv_lora_rank + m.qk_rope_dim, D),
        "kv_norm": init_norm(m.kv_lora_rank),
        "wkv_b": init_linear(ks[2], H * (m.qk_nope_dim + m.v_head_dim), m.kv_lora_rank),
        "wo": init_linear(ks[3], D, H * m.v_head_dim),
    }


def _mla_expand(cfg, p, latent, stats=None, prefix="", kcfg=None, pctx=None):
    """latent (B,S,r) → k_nope (B,H,S,nope), v (B,H,S,vd)."""
    m, H = cfg.mla, cfg.n_heads
    kv = linear(latent, p["wkv_b"], stats, prefix + "wkv_b", kcfg, pctx=pctx,
                tp="row")
    B, S = kv.shape[0], kv.shape[1]
    kv = kv.reshape(B, S, H, m.qk_nope_dim + m.v_head_dim).transpose(0, 2, 1, 3)
    return kv[..., : m.qk_nope_dim], kv[..., m.qk_nope_dim:]


def mla_apply(cfg: ModelConfig, p, x: Array, stats, prefix: str, *,
              pos0: int = 0, return_cache: bool = False, kcfg=None):
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    qd = m.qk_nope_dim + m.qk_rope_dim
    q = linear(x, p["wq"], stats, prefix + "wq", kcfg).reshape(B, S, H, qd).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    a = linear(x, p["wkv_a"], None, kcfg=kcfg)            # shares input with wq
    latent = rmsnorm(a[..., : m.kv_lora_rank], p["kv_norm"]["gamma"])
    k_rope = a[..., m.kv_lora_rank:][:, None]             # (B,1,S,rope) shared head
    pos = jnp.arange(S) + pos0
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    k_rope = apply_rope(k_rope, pos, cfg.rope_theta)
    k_nope, v = _mla_expand(cfg, p, latent, stats, prefix, kcfg)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, H, S, m.qk_rope_dim))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = attention(qf, k, v, causal=True, scale=qd ** -0.5)
    y = linear(o.transpose(0, 2, 1, 3).reshape(B, S, -1), p["wo"], stats,
               prefix + "wo", kcfg)
    if return_cache:
        return y, {"latent": latent, "k_rope": k_rope[:, 0]}
    return y


def mla_init_state(cfg: ModelConfig, batch: int, max_len: int):
    m = cfg.mla
    return {"latent": jnp.zeros((batch, max_len, m.kv_lora_rank), DTYPE),
            "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), DTYPE)}


def mla_decode(cfg: ModelConfig, p, x: Array, state, pos, kcfg=None,
               pctx=None):
    """Decode with the compressed cache (latent+rope per token — the MLA win).

    pos: (B,) per-slot positions.
    """
    m, H = cfg.mla, cfg.n_heads
    B = x.shape[0]
    qd = m.qk_nope_dim + m.qk_rope_dim
    q = linear(x, p["wq"], kcfg=kcfg, pctx=pctx,
               tp="row").reshape(B, 1, H, qd).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    a = linear(x, p["wkv_a"], kcfg=kcfg)
    latent_t = rmsnorm(a[..., : m.kv_lora_rank], p["kv_norm"]["gamma"])
    k_rope_t = a[..., m.kv_lora_rank:]
    q_rope = rope_decode(q_rope, pos, cfg.rope_theta)
    k_rope_t = rope_decode(k_rope_t[:, None], pos, cfg.rope_theta)[:, 0]
    latent = seq_update_batched(state["latent"], latent_t, pos)
    k_rope = seq_update_batched(state["k_rope"], k_rope_t[:, None]
                                if k_rope_t.ndim == 2 else k_rope_t, pos)
    k_nope, v = _mla_expand(cfg, p, latent, kcfg=kcfg, pctx=pctx)  # expand full cache
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None], (B, H, k_rope.shape[1], m.qk_rope_dim))],
        axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = decode_attention(qf, k, v, pos, scale=qd ** -0.5)
    y = linear(o.reshape(B, 1, -1), p["wo"], kcfg=kcfg, pctx=pctx, tp="col")
    return y, {"latent": latent, "k_rope": k_rope}


# ===========================================================================
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# ===========================================================================

_RG_BLOCKS = 16   # block-diagonal gates (Griffin §2.4) — TP-local per shard
_RG_C = 8.0


def init_rec(key, cfg: ModelConfig):
    h = cfg.hybrid
    D, dr = cfg.d_model, (h.d_rnn or cfg.d_model)
    nb = _RG_BLOCKS
    ks = jax.random.split(key, 6)
    gate = lambda k: (jax.random.normal(k, (nb, dr // nb, dr // nb), jnp.float32)
                      * (dr // nb) ** -0.5).astype(DTYPE)
    return {
        "w_branch": init_linear(ks[0], dr, D),            # gelu branch
        "w_in": init_linear(ks[1], dr, D),                # recurrent branch
        "conv_w": (jax.random.normal(ks[2], (h.conv_width, dr), jnp.float32) * 0.1).astype(DTYPE),
        "w_gate_a": gate(ks[3]),                          # recurrence gate (block-diag)
        "w_gate_x": gate(ks[4]),                          # input gate (block-diag)
        "log_lambda": jnp.log(jnp.expm1(                  # softplus⁻¹ of decay
            -jnp.log(jax.random.uniform(ks[5], (dr,), jnp.float32, 0.9, 0.999)))),
        "w_out": init_linear(jax.random.fold_in(key, 7), D, dr),
    }


def _block_diag(u: Array, w: Array) -> Array:
    """u: (B,S,dr), w: (nb, o, i) block-diagonal → (B,S,dr). TP-local on dr."""
    nb = w.shape[0]
    ub = u.reshape(*u.shape[:-1], nb, u.shape[-1] // nb)
    return jnp.einsum("bsgi,goi->bsgo", ub, w.astype(u.dtype)).reshape(u.shape)


def _rglru_coeffs(p, u: Array):
    """u: (B,S,dr) conv output → per-step (a, b) of h_t = a·h_{t-1} + b."""
    rf = jax.nn.sigmoid(_block_diag(u, p["w_gate_a"]).astype(jnp.float32))
    inp = jax.nn.sigmoid(_block_diag(u, p["w_gate_x"]).astype(jnp.float32))
    log_a = -_RG_C * jax.nn.softplus(p["log_lambda"])[None, None] * rf
    a = jnp.exp(log_a)
    gated = inp * u.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    return a, b


def _causal_conv(u: Array, w: Array, state: Optional[Array] = None):
    """Depthwise causal conv. u: (B,S,dr), w: (W,dr). state: (B,W-1,dr) history."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    ext = jnp.concatenate([pad, u], axis=1)
    out = sum(ext[:, i: i + u.shape[1]] * w[i][None, None] for i in range(W))
    return out, ext[:, -(W - 1):]                          # (B,S,dr), new history


def rec_apply(cfg: ModelConfig, p, x: Array, stats, prefix: str, *,
              h0: Optional[Array] = None, return_state: bool = False,
              kcfg=None):
    """Sequence mode via associative scan (O(log S) depth — SP/long-context safe)."""
    br = jax.nn.gelu(linear(x, p["w_branch"], stats, prefix + "w_branch",
                            kcfg).astype(jnp.float32))
    u = linear(x, p["w_in"], None, kcfg=kcfg)
    u, conv_state = _causal_conv(u, p["conv_w"])
    a, b = _rglru_coeffs(p, u)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def comb(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    y = linear((br * h).astype(x.dtype), p["w_out"], stats,
               prefix + "w_out", kcfg)
    if return_state:
        return y, {"h": h[:, -1].astype(jnp.float32), "conv": conv_state}
    return y


def rec_init_state(cfg: ModelConfig, batch: int, max_len: int):
    h = cfg.hybrid
    dr = h.d_rnn or cfg.d_model
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, h.conv_width - 1, dr), DTYPE)}


def rec_decode(cfg: ModelConfig, p, x: Array, state, pos, kcfg=None,
               pctx=None):
    br = jax.nn.gelu(linear(x, p["w_branch"], kcfg=kcfg, pctx=pctx,
                            tp="row").astype(jnp.float32))
    u = linear(x, p["w_in"], kcfg=kcfg, pctx=pctx, tp="row")
    u, conv_state = _causal_conv(u, p["conv_w"], state["conv"])
    a, b = _rglru_coeffs(p, u)
    h = a[:, 0] * state["h"] + b[:, 0]                     # (B, dr)
    y = linear((br[:, 0] * h)[:, None].astype(x.dtype), p["w_out"], kcfg=kcfg,
               pctx=pctx, tp="col")
    return y, {"h": h, "conv": conv_state}


# ===========================================================================
# Mamba2 SSD (state-space duality, chunked)
# ===========================================================================

def init_ssd(key, cfg: ModelConfig):
    """Projections are split (z/x/B/C/dt) so TP shards z,x on heads while the
    small shared B,C,dt stay replicated — a fused in_proj would force mixed
    sharding of one weight (DESIGN.md §4)."""
    s, D = cfg.ssm, cfg.d_model
    di = s.expand * D
    nh = di // s.head_dim
    gn = s.n_groups * s.d_state
    ks = jax.random.split(key, 8)
    return {
        "w_z": init_linear(ks[0], di, D),
        "w_x": init_linear(ks[1], di, D),
        "w_B": init_linear(ks[2], gn, D),
        "w_C": init_linear(ks[3], gn, D),
        "w_dt": init_linear(ks[4], nh, D),
        "conv_x": (jax.random.normal(ks[5], (s.conv_width, di), jnp.float32) * 0.1).astype(DTYPE),
        "conv_B": (jax.random.normal(ks[6], (s.conv_width, gn), jnp.float32) * 0.1).astype(DTYPE),
        "conv_C": (jax.random.normal(ks[7], (s.conv_width, gn), jnp.float32) * 0.1).astype(DTYPE),
        "A_log": jnp.log(jax.random.uniform(jax.random.fold_in(key, 8), (nh,), jnp.float32, 1.0, 16.0)),
        "Dskip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jax.random.uniform(jax.random.fold_in(key, 9), (nh,), jnp.float32, 1e-3, 0.1))),
        "norm": init_norm(di),
        "w_out": init_linear(jax.random.fold_in(key, 10), D, di),
    }


def _ssd_split(cfg: ModelConfig, p, x, stats, prefix, kcfg=None, pctx=None):
    """Five projections; stats tapped once on w_x (w_z/w_B/w_C/w_dt alias it)."""
    s, D = cfg.ssm, cfg.d_model
    di = s.expand * D
    nh = di // s.head_dim
    gn = s.n_groups * s.d_state
    z = linear(x, p["w_z"], None, kcfg=kcfg, pctx=pctx, tp="row")
    xr = linear(x, p["w_x"], stats, prefix + "w_x", kcfg, pctx=pctx, tp="row")
    Br = linear(x, p["w_B"], None, kcfg=kcfg)
    Cr = linear(x, p["w_C"], None, kcfg=kcfg)
    dt = linear(x, p["w_dt"], None, kcfg=kcfg)
    return z, xr, Br, Cr, dt, di, nh, gn


def _segsum(a: Array) -> Array:
    """a: (..., Q) log-decays → (..., Q, Q) lower-tri cumulative sums."""
    Q = a.shape[-1]
    c = jnp.cumsum(a, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    ii, jj = jnp.arange(Q)[:, None], jnp.arange(Q)[None, :]
    return jnp.where(ii >= jj, diff, -jnp.inf)


def ssd_scan(xh: Array, dt: Array, A: Array, Bm: Array, Cm: Array, chunk: int,
             h0: Optional[Array] = None):
    """Chunked SSD (Mamba2 alg. 1). xh:(B,S,H,P), dt:(B,S,H), A:(H,),
    Bm/Cm:(B,S,G,N) → y:(B,S,H,P), h_last:(B,H,P,N)."""
    Bsz, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    nc = S // Q
    rep = H // G
    xf = xh.astype(jnp.float32) * dt[..., None]
    la = (-A[None, None] * dt)                                       # (B,S,H) log decay
    xc = xf.reshape(Bsz, nc, Q, H, P)
    lc = la.reshape(Bsz, nc, Q, H)
    Bc = jnp.repeat(Bm.astype(jnp.float32).reshape(Bsz, nc, Q, G, N), rep, axis=3)
    Cc = jnp.repeat(Cm.astype(jnp.float32).reshape(Bsz, nc, Q, G, N), rep, axis=3)
    cum = jnp.cumsum(lc, axis=2)                                     # (B,nc,Q,H)
    # intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(lc.transpose(0, 1, 3, 2)))                   # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)
    y_diag = jnp.einsum("bchqk,bchqk,bckhp->bcqhp", scores, L,
                        xc)
    # chunk states
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)                  # (B,nc,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bc, decay_states, xc)
    # inter-chunk recurrence over chunk boundary states
    chunk_decay = jnp.exp(cum[:, :, -1, :])                          # (B,nc,H)

    def comb(l, r):
        return (r[0] * l[0], r[1] + r[0][..., None, None] * l[1])

    if h0 is not None:
        states = states.at[:, 0].add(chunk_decay[:, 0][..., None, None] * h0)
    _, run = jax.lax.associative_scan(comb, (chunk_decay, states), axis=1)
    h_last = run[:, -1]                                              # (B,H,P,N)
    prev = jnp.concatenate([jnp.zeros_like(run[:, :1]) if h0 is None
                            else h0[:, None], run[:, :-1]], axis=1)
    y_off = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", Cc, jnp.exp(cum), prev)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, h_last


def ssd_apply(cfg: ModelConfig, p, x: Array, stats, prefix: str, *,
              state=None, return_state: bool = False, kcfg=None):
    s = cfg.ssm
    z, xr, Br, Cr, dt, di, nh, gn = _ssd_split(cfg, p, x, stats, prefix, kcfg)
    st = state or {}
    xc, cs_x = _causal_conv(xr, p["conv_x"], st.get("conv_x"))
    Bc, cs_B = _causal_conv(Br, p["conv_B"], st.get("conv_B"))
    Cc, cs_C = _causal_conv(Cr, p["conv_C"], st.get("conv_C"))
    xi = jax.nn.silu(xc.astype(jnp.float32)).reshape(*x.shape[:2], nh, s.head_dim)
    Bm = jax.nn.silu(Bc.astype(jnp.float32)).reshape(*x.shape[:2], s.n_groups, s.d_state)
    Cm = jax.nn.silu(Cc.astype(jnp.float32)).reshape(*x.shape[:2], s.n_groups, s.d_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = jnp.exp(p["A_log"])
    h0 = st.get("h")
    Sq = x.shape[1]
    padn = (-Sq) % min(s.chunk, max(Sq, 1))
    if padn:
        # pad with dt=0 steps: decay=1, contribution=0 → state passes through
        pad4 = [(0, 0), (0, padn), (0, 0), (0, 0)]
        y, h_last = ssd_scan(jnp.pad(xi, pad4), jnp.pad(dtv, [(0, 0), (0, padn), (0, 0)]),
                             A, jnp.pad(Bm, pad4), jnp.pad(Cm, pad4), s.chunk, h0)
        y = y[:, :Sq]
    else:
        y, h_last = ssd_scan(xi, dtv, A, Bm, Cm, s.chunk, h0)
    y = y + p["Dskip"][None, None, :, None] * xi                    # D·x skip
    y = y.reshape(*x.shape[:2], di)
    y = rmsnorm(y.astype(x.dtype), p["norm"]["gamma"]) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = linear(y, p["w_out"], stats, prefix + "w_out", kcfg)
    if return_state:
        return out, {"h": h_last, "conv_x": cs_x, "conv_B": cs_B, "conv_C": cs_C}
    return out


def ssd_init_state(cfg: ModelConfig, batch: int, max_len: int):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    gn = s.n_groups * s.d_state
    w = s.conv_width - 1
    return {"h": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
            "conv_x": jnp.zeros((batch, w, di), DTYPE),
            "conv_B": jnp.zeros((batch, w, gn), DTYPE),
            "conv_C": jnp.zeros((batch, w, gn), DTYPE)}


def ssd_decode(cfg: ModelConfig, p, x: Array, state, pos, kcfg=None,
               pctx=None):
    """Single-step SSM recurrence h ← e^{-A·dt}h + dt·B⊗x ; y = C·h + D·x."""
    s = cfg.ssm
    z, xr, Br, Cr, dt, di, nh, gn = _ssd_split(cfg, p, x, None, "", kcfg,
                                               pctx=pctx)
    xc, cs_x = _causal_conv(xr, p["conv_x"], state["conv_x"])
    Bc, cs_B = _causal_conv(Br, p["conv_B"], state["conv_B"])
    Cc, cs_C = _causal_conv(Cr, p["conv_C"], state["conv_C"])
    B = x.shape[0]
    xi = jax.nn.silu(xc.astype(jnp.float32))[:, 0].reshape(B, nh, s.head_dim)
    Bm = jax.nn.silu(Bc.astype(jnp.float32))[:, 0].reshape(B, s.n_groups, s.d_state)
    Cm = jax.nn.silu(Cc.astype(jnp.float32))[:, 0].reshape(B, s.n_groups, s.d_state)
    rep = nh // s.n_groups
    Bm = jnp.repeat(Bm, rep, axis=1)                                # (B,H,N)
    Cm = jnp.repeat(Cm, rep, axis=1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32)[:, 0] + p["dt_bias"][None])  # (B,H)
    decay = jnp.exp(-jnp.exp(p["A_log"])[None] * dtv)               # (B,H)
    h = state["h"] * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dtv, xi, Bm)
    y = jnp.einsum("bhpn,bhn->bhp", h, Cm) + p["Dskip"][None, :, None] * xi
    y = y.reshape(B, 1, di)
    y = rmsnorm(y.astype(x.dtype), p["norm"]["gamma"]) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = linear(y, p["w_out"], kcfg=kcfg, pctx=pctx, tp="col")
    return out, {"h": h, "conv_x": cs_x, "conv_B": cs_B, "conv_C": cs_C}


# ===========================================================================
# MoE MLP — dense-compute (exact, tiny tests/training) and a2a (production)
# ===========================================================================

def init_moe(key, cfg: ModelConfig):
    e, D = cfg.moe, cfg.d_model
    ks = jax.random.split(key, 3)
    def expert_stack(k):
        kk = jax.random.split(k, e.n_experts)
        return jax.vmap(lambda kq: init_glu_mlp(kq, D, e.d_ff_expert))(kk)
    p = {"router": init_linear(ks[0], e.n_experts, D, dtype=jnp.float32),
         "experts": expert_stack(ks[1])}
    if e.n_shared:
        p["shared"] = init_glu_mlp(ks[2], D, e.d_ff_expert * e.n_shared)
    return p


def _router(cfg, p, x2, stats, prefix):
    e = cfg.moe
    logits = linear(x2.astype(jnp.float32), p["router"], stats, prefix + "router")
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, e.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_i


def _expert_mm(h, w, kcfg=None):
    """Per-expert matmul: h (E,C,D) × w (E,F,D) → (E,C,F). QT-aware: the
    vmapped kernel path batches the Pallas ttq_gemm over the expert dim
    (one dispatch with a leading batch grid axis, not E dispatches)."""
    from repro.core.ttq import QuantizedTensor, ttq_matmul
    if isinstance(w, QuantizedTensor):
        return jax.vmap(lambda hh, ww: ttq_matmul(hh, ww, kcfg=kcfg))(
            h, w).astype(h.dtype)
    return jnp.einsum("ecd,efd->ecf", h, w.astype(h.dtype))


def _expert_glu(w, h, act, stats=None, prefix="", wts=None, kcfg=None):
    """w: stacked expert params {wg,wu,wd} (E,·,·); h: (E,C,D).

    ``wts`` (E,C) optionally weights the TTQ stats accumulation (dense path:
    routing mass, so unrouted tokens don't pollute the per-expert diagonal).
    """
    g = _expert_mm(h, w["wg"], kcfg)
    u = _expert_mm(h, w["wu"], kcfg)
    a = ACT[act](g.astype(jnp.float32)).astype(h.dtype) * u
    if stats is not None:
        hf, af = h.astype(jnp.float32), a.astype(jnp.float32)
        wt = jnp.ones(h.shape[:2], jnp.float32) if wts is None else wts
        stats[prefix + "experts.wg"] = stats.get(prefix + "experts.wg", 0.0) + \
            jnp.einsum("ec,ecd,ecd->ed", wt, hf, hf)
        stats[prefix + "experts.wd"] = stats.get(prefix + "experts.wd", 0.0) + \
            jnp.einsum("ec,ecf,ecf->ef", wt, af, af)
    return _expert_mm(a, w["wd"], kcfg)


def moe_apply_dense(cfg: ModelConfig, p, x: Array, stats, prefix: str,
                    kcfg=None):
    """Exact MoE: every expert computes every token, combined by gates.

    O(E/topk) extra FLOPs — for tests, training of small models, and as the
    oracle for the a2a path.  Shared experts are added by the caller.
    """
    e = cfg.moe
    B, S, D = x.shape
    x2 = x.reshape(-1, D)
    top_p, top_i = _router(cfg, p, x2, stats, prefix)
    gate = jnp.zeros((x2.shape[0], e.n_experts), jnp.float32)
    gate = jax.vmap(lambda g, i, v: g.at[i].add(v))(gate, top_i, top_p)
    h = jnp.broadcast_to(x2[None], (e.n_experts, x2.shape[0], D))
    y_all = _expert_glu(p["experts"], h, cfg.act, stats, prefix, wts=gate.T,
                        kcfg=kcfg)
    y = jnp.einsum("etd,te->td", y_all.astype(jnp.float32), gate).astype(x.dtype)
    return y.reshape(B, S, D)


def moe_a2a(cfg: ModelConfig, p, x: Array, stats_on: bool, prefix: str, pctx):
    """shard_map wrapper around :func:`moe_apply_a2a` (EP over the model axis).

    x: (B,S,D) global, batch on data axes; experts E-sharded on model.
    Returns (y, stats_dict) — stats replicated (psum'd inside).
    """
    e = cfg.moe
    mesh = pctx.mesh
    P = jax.sharding.PartitionSpec
    dp = pctx.dp
    pr = {"router": p["router"], "experts": p["experts"]}
    espec = jax.tree.map(
        lambda l: P(pctx.model_axis, *([None] * (l.ndim - 1))), pr["experts"])
    in_specs = (P(dp, None, None), {"router": P(None, None), "experts": espec})
    if stats_on:
        out_specs = (P(dp, None, None), {prefix + "experts.wg": P(None, None),
                                         prefix + "experts.wd": P(None, None)})
    else:
        out_specs = (P(dp, None, None), {})

    def fn(xx, pp):
        st = {} if stats_on else None
        y = moe_apply_a2a(cfg, pp, xx, st, prefix,
                          model_axis=pctx.model_axis, data_axes=pctx.data_axes)
        return y, (st if stats_on else {})

    from repro.parallel.compat import shard_map
    y, st = shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_vma=False)(x, pr)
    return y, st


def moe_apply_a2a(cfg: ModelConfig, p, x: Array, stats, prefix: str, *,
                  model_axis: str, data_axes: tuple):
    """Production EP path — runs INSIDE shard_map over the full mesh.

    x: (B_loc, S, D) (replicated over `model_axis`). Experts are sharded over
    `model_axis` (leading E dim). Tokens are round-robin split over model
    ranks, dispatched to expert-owning ranks with all_to_all, processed with
    dense per-expert matmuls, and returned. Capacity-dropped tokens fall back
    to zero (standard); gates renormalized locally.
    """
    e = cfg.moe
    from repro.parallel.compat import axis_size
    tp = axis_size(model_axis)
    my = jax.lax.axis_index(model_axis)
    B, S, D = x.shape
    x2 = x.reshape(-1, D)
    T = x2.shape[0]
    Tc = -(-T // tp)                                   # this rank's token chunk
    if Tc * tp != T:                                   # pad tokens to tp multiple
        x2 = jnp.pad(x2, ((0, Tc * tp - T), (0, 0)))
    xm = jax.lax.dynamic_slice(x2, (my * Tc, 0), (Tc, D))
    top_p, top_i = _router(cfg, p, xm, None, prefix)   # (Tc,k)
    k = e.top_k
    E = e.n_experts
    E_loc = E // tp
    C = max(1, int(Tc * k / E * e.capacity_factor))
    flat_e = top_i.reshape(-1)                         # (Tc·k,) target expert
    # position of each assignment within its target expert (stable order)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1          # (Tc·k, E)
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    valid = slot < C
    dest_rank = flat_e // E_loc
    dest_eloc = flat_e % E_loc
    flat_idx = (dest_rank * E_loc + dest_eloc) * C + jnp.where(valid, slot, 0)
    send = jnp.zeros((tp * E_loc * C, D), x2.dtype)
    src_tok = jnp.repeat(jnp.arange(Tc), k)
    send = send.at[flat_idx].add(jnp.where(valid[:, None], xm[src_tok], 0))
    send = send.reshape(tp, E_loc, C, D)
    recv = jax.lax.all_to_all(send, model_axis, split_axis=0, concat_axis=0,
                              tiled=False)             # (tp, E_loc, C, D)
    h = recv.transpose(1, 0, 2, 3).reshape(E_loc, tp * C, D)
    w_loc = p["experts"]                               # (E_loc, ·, ·) shard
    loc_stats = {} if stats is not None else None
    y_exp = _expert_glu(w_loc, h, cfg.act, loc_stats, prefix)  # (E_loc, tp·C, D)
    if stats is not None:
        for key, s_loc in loc_stats.items():           # (E_loc, ·) local shards
            s_all = jax.lax.all_gather(s_loc, model_axis, axis=0)
            s_all = s_all.reshape(E, s_loc.shape[-1])
            s_all = jax.lax.psum(s_all, data_axes)
            stats[key] = stats.get(key, 0.0) + s_all
    y_back = y_exp.reshape(E_loc, tp, C, D).transpose(1, 0, 2, 3)
    y_recv = jax.lax.all_to_all(y_back, model_axis, split_axis=0, concat_axis=0,
                                tiled=False)           # (tp, E_loc, C, D) at source
    y_flat = y_recv.reshape(tp * E_loc * C, D)
    contrib = y_flat[flat_idx] * jnp.where(valid, top_p.reshape(-1), 0.0)[:, None].astype(x2.dtype)
    y_m = jax.ops.segment_sum(contrib, src_tok, num_segments=Tc)
    y = jax.lax.all_gather(y_m, model_axis, axis=0).reshape(Tc * tp, D)[:T]
    return y.reshape(B, S, D).astype(x.dtype)
