"""Model configuration — one dataclass covering all 10 assigned families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0          # per-expert hidden
    n_shared: int = 0             # shared experts (deepseek-style), d_ff_expert each
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class HybridCfg:
    """RecurrentGemma: repeating block pattern, e.g. ('rec','rec','attn')."""
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    window: int = 2048            # local attention window
    d_rnn: int = 0                # RG-LRU width (defaults to d_model)
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    """Mamba2 SSD."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    n_enc_layers: int = 24
    n_frames: int = 1500          # whisper-medium encoder positions (stub frontend)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 → d_model // n_heads
    act: str = "silu"
    mlp: str = "glu"              # glu | plain
    norm: str = "rms"             # rms | layer
    pos: str = "rope"             # rope | learned | sinusoidal
    rope_theta: float = 10000.0
    qk_norm: bool = False
    attn_soft_cap: float = 0.0
    tie_embeddings: bool = True
    max_seq: int = 8192           # learned-pos table size
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    hybrid: Optional[HybridCfg] = None
    ssm: Optional[SSMCfg] = None
    encdec: Optional[EncDecCfg] = None
    dtype: str = "bfloat16"
    # notes for DESIGN/EXPERIMENTS bookkeeping
    subquadratic: bool = False    # supports long_500k
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline bookkeeping)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            s = self.ssm
            di = s.expand * D
            nh = di // s.head_dim
            conv_ch = di + 2 * s.n_groups * s.d_state
            per_layer = (D * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj
                         + conv_ch * s.conv_width + nh * 2               # conv, A, D
                         + di * D)                                        # out_proj
            return emb + L * (per_layer + D)
        H, Hkv, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = D * H * hd + 2 * D * Hkv * hd + H * hd * D
        if self.mla is not None:
            m = self.mla
            qd = m.qk_nope_dim + m.qk_rope_dim
            attn = (D * H * qd                                    # q proj
                    + D * (m.kv_lora_rank + m.qk_rope_dim)        # kv down
                    + m.kv_lora_rank * H * (m.qk_nope_dim + m.v_head_dim)  # kv up
                    + H * m.v_head_dim * D)                       # out
        mlp = 3 * D * F if self.mlp == "glu" else 2 * D * F
        if self.moe is not None:
            e = self.moe
            expert = (3 * D * e.d_ff_expert if self.mlp == "glu" else 2 * D * e.d_ff_expert)
            mlp = e.n_experts * expert + e.n_shared * expert + D * e.n_experts
        if self.family == "hybrid":
            h = self.hybrid
            dr = h.d_rnn or D
            rec = 2 * D * dr + dr * D + dr * h.conv_width + 3 * dr  # in×2, out, conv, gates+Λ
            n_rec = sum(1 for _ in range(L) if self._block_kind(_) == "rec")
            n_att = L - n_rec
            return emb + n_att * (attn + mlp + 2 * D) + n_rec * (rec + mlp + 2 * D)
        if self.family == "encdec":
            enc_l = self.encdec.n_enc_layers
            cross = attn
            return emb + L * (attn + cross + mlp + 3 * D) + enc_l * (attn + mlp + 2 * D)
        return emb + L * (attn + mlp + 2 * D)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        expert = 3 * self.d_model * e.d_ff_expert
        dense_like = dataclasses.replace(
            self, moe=None, d_ff=0)
        base = dense_like.param_count()  # attn + norms + embed (d_ff=0 → mlp=0)
        return base + self.n_layers * (e.top_k + e.n_shared) * expert

    def _block_kind(self, i: int) -> str:
        if self.family != "hybrid":
            return "attn"
        pat = self.hybrid.pattern
        return pat[i % len(pat)]
