"""Layer-stack machinery — scan over homogeneous units, heterogeneous patterns.

A stack is a list of *runs*; each run repeats a *unit* (tuple of layer kinds)
``n`` times and is executed with one ``lax.scan`` whose xs are the stacked
unit params — HLO size stays O(#distinct units), not O(depth), which keeps the
88-layer × 512-device dry-run compilable (EXPERIMENTS.md §Roofline, dry-run
tables).

Layer kinds:  attn | lattn (windowed) | enc (non-causal) | xdec (self+cross)
              mla | rec (RG-LRU) | ssd (Mamba2)
MLP kinds per layer are derived from the config (glu | plain | moe | none).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import layers as L
from jax.ad_checkpoint import checkpoint_name as _ckpt_name
from .common import glu_mlp, init_glu_mlp, init_norm, init_plain_mlp, linear, norm, plain_mlp
from .config import ModelConfig


# ---------------------------------------------------------------------------
# stack spec
# ---------------------------------------------------------------------------

def stack_spec(cfg: ModelConfig):
    """[(unit_kinds, n_repeat)] for the decoder stack."""
    if cfg.family == "hybrid":
        pat = tuple(cfg.hybrid.pattern)
        pat = tuple("lattn" if k == "attn" else k for k in pat)
        n_full = cfg.n_layers // len(pat)
        runs = [(pat, n_full)] if n_full else []
        rem = cfg.n_layers % len(pat)
        if rem:
            runs.append((pat[:rem], 1))
        return runs
    kind = {"ssm": "ssd", "encdec": "xdec"}.get(cfg.family, None)
    if kind is None:
        kind = "mla" if cfg.mla is not None else "attn"
    return [((kind,), cfg.n_layers)]


def enc_spec(cfg: ModelConfig):
    return [(("enc",), cfg.encdec.n_enc_layers)]


def mlp_kind(cfg: ModelConfig, layer_kind: str) -> str:
    if layer_kind == "ssd":
        return "none"
    if cfg.moe is not None and layer_kind != "enc":
        return "moe"
    return cfg.mlp


# ---------------------------------------------------------------------------
# per-layer init / apply / decode / state
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, kind: str):
    D = cfg.d_model
    nk = "rms" if cfg.norm == "rms" else "layer"
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": init_norm(D, nk)}
    if kind in ("attn", "lattn", "enc", "xdec"):
        p["mix"] = L.init_attn(ks[0], cfg)
    elif kind == "mla":
        p["mix"] = L.init_mla(ks[0], cfg)
    elif kind == "rec":
        p["mix"] = L.init_rec(ks[0], cfg)
    elif kind == "ssd":
        p["mix"] = L.init_ssd(ks[0], cfg)
    else:
        raise ValueError(kind)
    if kind == "xdec":
        p["lnx"] = init_norm(D, nk)
        p["xattn"] = L.init_attn(ks[1], cfg, cross=True)
    mk = mlp_kind(cfg, kind)
    if mk == "glu":
        p["ln2"] = init_norm(D, nk)
        p["mlp"] = init_glu_mlp(ks[2], D, cfg.d_ff)
    elif mk == "plain":
        p["ln2"] = init_norm(D, nk)
        p["mlp"] = init_plain_mlp(ks[2], D, cfg.d_ff)
    elif mk == "moe":
        p["ln2"] = init_norm(D, nk)
        p["mlp"] = L.init_moe(ks[2], cfg)
    return p


def layer_state(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                kvcfg=None, num_blocks: int = 0):
    if kvcfg is not None and kvcfg.paged and kind != "attn":
        raise ValueError(
            f"paged KV cache supports plain attention layers only, got "
            f"{kind!r} (windowed/latent/recurrent states stay dense — "
            f"DESIGN.md §8)")
    if kind in ("attn", "lattn"):
        ml = min(max_len, cfg.hybrid.window) if (kind == "lattn" and cfg.hybrid) else max_len
        return L.attn_init_state(cfg, batch, ml, kvcfg, num_blocks)
    if kind == "xdec":
        st = L.attn_init_state(cfg, batch, max_len, kvcfg)
        # cross k/v are computed once from the encoder and stay bf16 — the
        # quantized layout targets the growing self-attention cache
        nf = cfg.encdec.n_frames
        st["xk"] = jnp.zeros((batch, cfg.n_kv_heads, nf, cfg.hd), L.DTYPE)
        st["xv"] = jnp.zeros((batch, cfg.n_kv_heads, nf, cfg.hd), L.DTYPE)
        return st
    if kind == "mla":
        return L.mla_init_state(cfg, batch, max_len)
    if kind == "rec":
        return L.rec_init_state(cfg, batch, max_len)
    if kind == "ssd":
        return L.ssd_init_state(cfg, batch, max_len)
    raise ValueError(kind)


def _mlp_apply(cfg, kind, p, x, stats, prefix, pctx, kcfg=None):
    mk = mlp_kind(cfg, kind)
    if mk == "none":
        return x
    h = norm(x, p["ln2"])
    if mk == "glu":
        y = glu_mlp(h, p["mlp"], stats, prefix + "mlp", cfg.act, kcfg,
                    pctx=pctx)
    elif mk == "plain":
        y = plain_mlp(h, p["mlp"], stats, prefix + "mlp", cfg.act, kcfg,
                      pctx=pctx)
    else:  # moe
        pp = prefix + "mlp."
        if pctx is not None and pctx.moe_impl == "a2a" and pctx.mesh is not None:
            y, moe_stats = L.moe_a2a(cfg, p["mlp"], h, stats is not None, pp, pctx)
            if stats is not None:
                for k_, v_ in moe_stats.items():
                    stats[k_] = stats.get(k_, 0.0) + v_
        else:
            y = L.moe_apply_dense(cfg, p["mlp"], h, stats, pp, kcfg=kcfg)
        if cfg.moe.n_shared:
            # outside the a2a shard_map: TP wrap on the shared expert is legal
            y = y + glu_mlp(h, p["mlp"]["shared"], stats, pp + "shared",
                            cfg.act, kcfg, pctx=pctx)
    y = _ckpt_name(y, "mlp_out")   # post-AR activation
    return x + y


def apply_layer_seq(cfg: ModelConfig, kind: str, p, x, stats, prefix, *,
                    pctx=None, enc_out=None, want_state: bool = False,
                    max_len: int = 0, pos0: int = 0, state=None, kvcfg=None,
                    kcfg=None, kv_prefix=None, compact_state: bool = False):
    """Sequence mode (train / prefill).  Returns (x, state|None).

    ``kv_prefix`` (plain-attn only): cached (k, v) context prepended to the
    attention read — tail prefill over a shared prompt prefix, with
    ``pos0`` = prefix length (DESIGN.md §8).  Paged caches return a
    *compact* state (this call's k/v rows at storage dtype); the runner
    scatters it into pool blocks.  ``compact_state`` forces the same
    compact layout for dense caches (chunked prefill, DESIGN.md §13: the
    runner writes the chunk's rows into the slot's slab itself).
    """
    h = norm(x, p["ln1"])
    st = None
    if kind in ("attn", "lattn", "enc"):
        window = cfg.hybrid.window if (kind == "lattn" and cfg.hybrid) else 0
        if want_state:
            y, (k, v) = L.attn_apply(cfg, p["mix"], h, stats, prefix + "mix.",
                                     causal=kind != "enc", window=window,
                                     pos0=pos0, return_kv=True,
                                     kv_prefix=kv_prefix, kvcfg=kvcfg,
                                     kcfg=kcfg)
            if (kvcfg is not None and kvcfg.paged) or compact_state:
                st = L.build_kv_compact(k, v, kvcfg)
            else:
                ml = min(max_len, window) if window else max_len
                S = min(k.shape[2], ml)
                kk, vv = k[:, :, -S:], v[:, :, -S:]
                if window and k.shape[2] >= window:
                    # rolling layout: absolute position p lives at slot p % window
                    kk = jnp.roll(kk, k.shape[2] % window, axis=2)
                    vv = jnp.roll(vv, k.shape[2] % window, axis=2)
                st = L.build_kv_state(cfg, x.shape[0], ml, kk, vv, kvcfg)
        else:
            y = L.attn_apply(cfg, p["mix"], h, stats, prefix + "mix.",
                             causal=kind != "enc", window=window, pos0=pos0,
                             kv_prefix=kv_prefix, kvcfg=kvcfg, kcfg=kcfg)
    elif kind == "xdec":
        if want_state:
            y, (k, v) = L.attn_apply(cfg, p["mix"], h, stats, prefix + "mix.",
                                     causal=True, pos0=pos0, return_kv=True,
                                     kvcfg=kvcfg, kcfg=kcfg)
            st = L.build_kv_state(cfg, x.shape[0], max_len, k, v, kvcfg)
        else:
            y = L.attn_apply(cfg, p["mix"], h, stats, prefix + "mix.",
                             causal=True, pos0=pos0, kcfg=kcfg)
        x = x + y
        hx = norm(x, p["lnx"])
        if want_state:
            yx, (xk, xv) = L.attn_apply(cfg, p["xattn"], hx, stats,
                                        prefix + "xattn.", x_cross=enc_out,
                                        return_kv=True, kcfg=kcfg)
            st["xk"], st["xv"] = xk.astype(L.DTYPE), xv.astype(L.DTYPE)
        else:
            yx = L.attn_apply(cfg, p["xattn"], hx, stats, prefix + "xattn.",
                              x_cross=enc_out, kcfg=kcfg)
        x = x + yx
        return _mlp_apply(cfg, kind, p, x, stats, prefix, pctx, kcfg), st
    elif kind == "mla":
        if want_state:
            y, cache = L.mla_apply(cfg, p["mix"], h, stats, prefix + "mix.",
                                   pos0=pos0, return_cache=True, kcfg=kcfg)
            z = L.mla_init_state(cfg, x.shape[0], max_len)
            st = {k_: jax.lax.dynamic_update_slice(z[k_], cache[k_].astype(L.DTYPE), (0, 0, 0))
                  for k_ in ("latent", "k_rope")}
        else:
            y = L.mla_apply(cfg, p["mix"], h, stats, prefix + "mix.",
                            pos0=pos0, kcfg=kcfg)
    elif kind == "rec":
        if want_state:
            y, st = L.rec_apply(cfg, p["mix"], h, stats, prefix + "mix.",
                                return_state=True, kcfg=kcfg)
        else:
            y = L.rec_apply(cfg, p["mix"], h, stats, prefix + "mix.",
                            kcfg=kcfg)
    elif kind == "ssd":
        if want_state:
            y, st = L.ssd_apply(cfg, p["mix"], h, stats, prefix + "mix.",
                                return_state=True, kcfg=kcfg)
        else:
            y = L.ssd_apply(cfg, p["mix"], h, stats, prefix + "mix.",
                            kcfg=kcfg)
    else:
        raise ValueError(kind)
    y = _ckpt_name(y, "mix_out")    # post-AR activation
    x = x + y
    return _mlp_apply(cfg, kind, p, x, stats, prefix, pctx, kcfg), st


def apply_layer_decode(cfg: ModelConfig, kind: str, p, x, state, pos, *,
                       pctx=None, kvcfg=None, kcfg=None, block_table=None):
    """Single-token decode; pos: (B,) per-slot positions. Returns (x, new_state)."""
    h = norm(x, p["ln1"])
    if kind in ("attn", "lattn"):
        window = cfg.hybrid.window if (kind == "lattn" and cfg.hybrid) else 0
        if window:
            y, st = L.attn_decode_rolling(cfg, p["mix"], h, state, pos, window,
                                          kvcfg, kcfg, pctx=pctx)
        else:
            y, st = L.attn_decode(cfg, p["mix"], h, state, pos, kvcfg=kvcfg,
                                  kcfg=kcfg, block_table=block_table,
                                  pctx=pctx)
    elif kind == "xdec":
        self_kv = {k_: v_ for k_, v_ in state.items() if k_ not in ("xk", "xv")}
        y, st = L.attn_decode(cfg, p["mix"], h, self_kv, pos, kvcfg=kvcfg,
                              kcfg=kcfg, pctx=pctx)
        x = x + y
        hx = norm(x, p["lnx"])
        yx, _ = L.attn_decode(cfg, p["xattn"], hx, None, pos,
                              cross_kv=(state["xk"], state["xv"]), kcfg=kcfg,
                              pctx=pctx)
        x = x + yx
        st = {**st, "xk": state["xk"], "xv": state["xv"]}
        return _mlp_apply(cfg, kind, p, x, None, "", pctx, kcfg), st
    elif kind == "mla":
        y, st = L.mla_decode(cfg, p["mix"], h, state, pos, kcfg, pctx=pctx)
    elif kind == "rec":
        y, st = L.rec_decode(cfg, p["mix"], h, state, pos, kcfg, pctx=pctx)
    elif kind == "ssd":
        y, st = L.ssd_decode(cfg, p["mix"], h, state, pos, kcfg, pctx=pctx)
    else:
        raise ValueError(kind)
    x = x + y
    return _mlp_apply(cfg, kind, p, x, None, "", pctx, kcfg), st


def apply_layer_verify(cfg: ModelConfig, kind: str, p, x, state, pos, *,
                       pctx=None, kvcfg=None, kcfg=None, block_table=None):
    """Speculative-verify pass: x (B,S,D) is a drafted window at per-slot
    positions pos..pos+S-1 (DESIGN.md §11). Returns (x, new_state)."""
    if kind != "attn":
        raise ValueError(
            f"self-speculative decoding supports plain attention layers "
            f"only, got {kind!r} (windowed/latent/recurrent decode states "
            f"mutate destructively and cannot roll back rejected drafts — "
            f"DESIGN.md §11)")
    h = norm(x, p["ln1"])
    y, st = L.attn_verify(cfg, p["mix"], h, state, pos, kvcfg=kvcfg,
                          kcfg=kcfg, block_table=block_table, pctx=pctx)
    x = x + y
    return _mlp_apply(cfg, kind, p, x, None, "", pctx, kcfg), st


def apply_stack_verify(cfg: ModelConfig, run_params, spec, run_states, x, pos,
                       *, pctx=None, kvcfg=None, kcfg=None, block_table=None):
    """:func:`apply_stack_decode` with an S-wide token window per slot —
    one batched dispatch scores every drafted position (DESIGN.md §11)."""
    new_states = []
    for (kinds, n), rp, rs in zip(spec, run_params, run_states):
        def body(carry, xs):
            up, st_in = xs
            h = carry
            st_out = {}
            for j, kind in enumerate(kinds):
                h, st = apply_layer_verify(cfg, kind, up[f"u{j}"], h,
                                           st_in[f"u{j}"], pos, pctx=pctx,
                                           kvcfg=kvcfg, kcfg=kcfg,
                                           block_table=block_table)
                st_out[f"u{j}"] = st
            return h, st_out

        x, st_new = jax.lax.scan(body, x, (rp, rs))
        new_states.append(st_new)
    return x, new_states


# ---------------------------------------------------------------------------
# stack init / apply (scan over runs)
# ---------------------------------------------------------------------------

def init_stack(key, cfg: ModelConfig, spec):
    runs = []
    for ri, (kinds, n) in enumerate(spec):
        rk = jax.random.fold_in(key, ri)

        def unit_init(k):
            kk = jax.random.split(k, len(kinds))
            return {f"u{j}": init_layer(kk[j], cfg, kind)
                    for j, kind in enumerate(kinds)}

        runs.append(jax.vmap(unit_init)(jax.random.split(rk, n)))
    return runs


def init_stack_state(cfg: ModelConfig, spec, batch: int, max_len: int,
                     kvcfg=None, num_blocks: int = 0):
    out = []
    for kinds, n in spec:
        unit = {f"u{j}": layer_state(cfg, kind, batch, max_len, kvcfg,
                                     num_blocks)
                for j, kind in enumerate(kinds)}
        out.append(jax.tree.map(lambda x: jnp.broadcast_to(x, (n, *x.shape)), unit))
    return out


def apply_stack_seq(cfg: ModelConfig, run_params, spec, x, *, stats_on=False,
                    pctx=None, enc_out=None, want_state=False, max_len=0,
                    remat=False, kvcfg=None, kcfg=None, pos0: int = 0,
                    prefix_kv=None, compact_state: bool = False):
    """Train / prefill over all runs. Returns (x, stats_list, state_list).

    With remat, the mixer/MLP outputs are checkpoint-tagged: saving the
    *post-all-reduce* activations means the backward pass does NOT re-execute
    the TP collectives of the forward (≈33% of train collective bytes on the
    granite cell — EXPERIMENTS.md §Perf iteration 4). Memory cost: 2 saved
    (B,S,D) tensors per layer.

    ``prefix_kv`` (tail prefill over a cached prefix, DESIGN.md §8): a
    per-run list of (k, v) arrays with a leading layer dim — each rides the
    layer scan as xs so every layer attends to its own cached context;
    ``pos0`` is the shared prefix length.  Single-attention-unit runs only.
    """
    all_stats, all_states = [], []
    for ri, ((kinds, n), rp) in enumerate(zip(spec, run_params)):
        pk = None if prefix_kv is None else prefix_kv[ri]

        def body(carry, xs):
            up, kvp = xs if pk is not None else (xs, None)
            h = carry
            stats = {} if stats_on else None
            states = {}
            for j, kind in enumerate(kinds):
                h, st = apply_layer_seq(cfg, kind, up[f"u{j}"], h, stats,
                                        f"u{j}.", pctx=pctx, enc_out=enc_out,
                                        want_state=want_state, max_len=max_len,
                                        kvcfg=kvcfg, kcfg=kcfg, pos0=pos0,
                                        kv_prefix=kvp,
                                        compact_state=compact_state)
                if st is not None:
                    states[f"u{j}"] = st
            return h, (stats, states)

        if remat:
            from .common import opt_level
            if opt_level() >= 1:
                policy = jax.checkpoint_policies.save_only_these_names(
                    "mix_out", "mlp_out")
                body = jax.checkpoint(body, prevent_cse=False, policy=policy)
            else:   # baseline: full remat (backward re-runs forward ARs)
                body = jax.checkpoint(body, prevent_cse=False)
        x, (stats, states) = jax.lax.scan(body, x,
                                          rp if pk is None else (rp, pk))
        all_stats.append(stats)
        all_states.append(states)
    return x, all_stats, all_states


def apply_stack_decode(cfg: ModelConfig, run_params, spec, run_states, x, pos,
                       *, pctx=None, kvcfg=None, kcfg=None, block_table=None):
    new_states = []
    for (kinds, n), rp, rs in zip(spec, run_params, run_states):
        def body(carry, xs):
            up, st_in = xs
            h = carry
            st_out = {}
            for j, kind in enumerate(kinds):
                h, st = apply_layer_decode(cfg, kind, up[f"u{j}"], h,
                                           st_in[f"u{j}"], pos, pctx=pctx,
                                           kvcfg=kvcfg, kcfg=kcfg,
                                           block_table=block_table)
                st_out[f"u{j}"] = st
            return h, st_out

        x, st_new = jax.lax.scan(body, x, (rp, rs))
        new_states.append(st_new)
    return x, new_states
