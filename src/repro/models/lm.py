"""Top-level language model — embed → stack(s) → norm → vocab head.

Uniform API across all 10 assigned families:

    init_params(cfg, key)                       → params pytree
    forward(cfg, params, batch, ...)            → (logits, stats, states)
    loss_fn(cfg, params, batch, ...)            → (loss, aux)
    init_decode_state(cfg, batch, max_len)      → DecodeState
    prefill(cfg, params, batch, max_len, ...)   → (last_logits, state, stats)
    decode_step(cfg, params, state, token, pos) → (logits, state)
    decode_many(cfg, params, state, token, pos, done, remaining, key, K=...)
                                                → ((tokens, valid), carry)

``batch`` is a dict: {'tokens': (B,S) int32} and, for encdec, also
{'frames': (B, n_frames, d_model)} — the spec'd stub modality frontend.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import stack as S
from .common import linear, norm, init_norm, sample_logits, sinusoidal_pos
from .config import ModelConfig

P = jax.sharding.PartitionSpec


def _wsc(x, spec, pctx):
    if pctx is None or pctx.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(pctx.mesh, spec))


def init_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 6)
    D = cfg.d_model
    p: dict = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, D), jnp.float32)
                  * D ** -0.5).astype(jnp.bfloat16),
        "stack": S.init_stack(ks[1], cfg, S.stack_spec(cfg)),
        "final_norm": init_norm(D, "rms" if cfg.norm == "rms" else "layer"),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(ks[2], (cfg.vocab, D), jnp.float32)
                        * D ** -0.5).astype(jnp.bfloat16)
    if cfg.pos == "learned":
        p["pos_embed"] = (jax.random.normal(ks[3], (cfg.max_seq, D), jnp.float32)
                          * 0.02).astype(jnp.bfloat16)
    if cfg.family == "encdec":
        p["enc_stack"] = S.init_stack(ks[4], cfg, S.enc_spec(cfg))
        p["enc_norm"] = init_norm(D, "rms" if cfg.norm == "rms" else "layer")
    return p


def _embed(cfg, params, tokens, pctx, pos0: int = 0):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.pos == "learned":
        S_ = tokens.shape[1]
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos0, S_, 0)[None]
    dp = None if pctx is None else pctx.data_axes
    return _wsc(x, P(dp, None, None), pctx)


def _head(cfg, params, x, pctx, kcfg=None):
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = linear(x, w, kcfg=kcfg, pctx=pctx, tp="row").astype(jnp.float32)
    dp = None if pctx is None else pctx.data_axes
    mp = None if pctx is None else pctx.model_axis
    return _wsc(logits, P(dp, None, mp), pctx)


def _encode(cfg, params, frames, pctx, stats_on=False):
    x = frames.astype(jnp.bfloat16) + sinusoidal_pos(frames.shape[1], cfg.d_model)[None]
    x, st, _ = S.apply_stack_seq(cfg, params["enc_stack"], S.enc_spec(cfg), x,
                                 stats_on=stats_on, pctx=pctx)
    return norm(x, params["enc_norm"]), st


def forward(cfg: ModelConfig, params, batch, *, collect_stats=False, pctx=None,
            want_state=False, max_len=0, remat=False, kcfg=None):
    """Full-sequence forward. Returns (logits, stats, states).

    stats: {'stack': [per-run dict], 'enc_stack': [...]} of Σx² leaves
    (leading run-repeat dim), path-aligned with params for the TTQ join.
    """
    tokens = batch["tokens"]
    enc_out = None
    stats: dict = {}
    if cfg.family == "encdec":
        enc_out, enc_stats = _encode(cfg, params, batch["frames"], pctx,
                                     stats_on=collect_stats)
        if collect_stats:
            stats["enc_stack"] = enc_stats
    x = _embed(cfg, params, tokens, pctx)
    x, run_stats, states = S.apply_stack_seq(
        cfg, params["stack"], S.stack_spec(cfg), x, stats_on=collect_stats,
        pctx=pctx, enc_out=enc_out, want_state=want_state, max_len=max_len,
        remat=remat, kcfg=kcfg)
    if collect_stats:
        stats["stack"] = run_stats
    x = norm(x, params["final_norm"])
    logits = _head(cfg, params, x, pctx, kcfg)
    return logits, (stats if collect_stats else None), states


def loss_fn(cfg: ModelConfig, params, batch, *, pctx=None, remat=False):
    """Next-token cross-entropy (vocab-sharded logsumexp — no full gather)."""
    logits, _, _ = forward(cfg, params, batch, pctx=pctx, remat=remat)
    targets = batch["tokens"][:, 1:]
    lg = logits[:, :-1]
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(targets, jnp.float32))
    if mask.shape[1] == batch["tokens"].shape[1]:
        mask = mask[:, 1:]
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    return loss, {"loss": loss, "tokens": denom}


# ---------------------------------------------------------------------------
# serving entry points
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, kvcfg=None,
                      num_blocks: int = 0):
    """``kvcfg`` (:class:`repro.core.KVCacheConfig`) selects the attention
    cache layout: None/bf16 → the seed {'k','v'} bf16 slots; int8/int4 →
    quantized codes + per-(head, token) scales (DESIGN.md §"KV-cache layout").

    With ``kvcfg.paged`` the per-layer caches become shared block pools of
    ``num_blocks`` blocks and the state carries a per-slot ``block_table``
    (B, max_len/block_size) int32 — rows map logical to physical blocks; 0
    is the sink block for unallocated entries and done-lane writes
    (DESIGN.md §8)."""
    paged = kvcfg is not None and kvcfg.paged
    if paged:
        if max_len % kvcfg.block_size:
            raise ValueError(f"max_len={max_len} must divide by "
                             f"block_size={kvcfg.block_size}")
        if num_blocks < 2:
            raise ValueError("paged cache needs num_blocks >= 2 "
                             "(block 0 is the reserved sink)")
    st: dict = {"stack": S.init_stack_state(cfg, S.stack_spec(cfg), batch,
                                            max_len, kvcfg, num_blocks)}
    if paged:
        st["block_table"] = jnp.zeros((batch, max_len // kvcfg.block_size),
                                      jnp.int32)
    if cfg.family == "encdec":
        st["enc_out"] = jnp.zeros((batch, cfg.encdec.n_frames, cfg.d_model),
                                  jnp.bfloat16)
    return st


def prefill(cfg: ModelConfig, params, batch, max_len: int, *,
            collect_stats=True, pctx=None, full_logits=False, kvcfg=None,
            prefix_kv=None, pos0: int = 0, compact_state: bool = False):
    """Run the prompt, build decode state + TTQ activation statistics.

    ``prefix_kv``/``pos0`` (paged prefix-cache hits, DESIGN.md §8): the
    tokens are the prompt *tail*, attending to the cached prefix k/v (a
    per-run list of (k, v) with leading layer dim, post-rope) at absolute
    offset ``pos0``.  The returned paged state is compact — this call's
    rows only; the cached prefix stays where it is.  ``compact_state``
    forces the compact layout for dense caches too (chunked prefill,
    DESIGN.md §13 — the runner owns the row writes)."""
    tokens = batch["tokens"]
    enc_out = None
    stats: dict = {}
    if cfg.family == "encdec":
        enc_out, enc_stats = _encode(cfg, params, batch["frames"], pctx,
                                     stats_on=collect_stats)
        if collect_stats:
            stats["enc_stack"] = enc_stats
    x = _embed(cfg, params, tokens, pctx, pos0=pos0)
    x, run_stats, states = S.apply_stack_seq(
        cfg, params["stack"], S.stack_spec(cfg), x, stats_on=collect_stats,
        pctx=pctx, enc_out=enc_out, want_state=True, max_len=max_len,
        kvcfg=kvcfg, pos0=pos0, prefix_kv=prefix_kv,
        compact_state=compact_state)
    if collect_stats:
        stats["stack"] = run_stats
    x = norm(x, params["final_norm"])
    if full_logits:
        logits = _head(cfg, params, x, pctx)
    else:
        logits = _head(cfg, params, x[:, -1:], pctx)[:, 0]
    state: dict = {"stack": states}
    if enc_out is not None:
        state["enc_out"] = enc_out
    return logits, state, (stats if collect_stats else None)


def decode_step(cfg: ModelConfig, params, state, token, pos, *, pctx=None,
                kvcfg=None, kcfg=None):
    """token: (B,1) int32; pos: (B,) int32 per-slot positions (scalar ok).

    ``kvcfg`` must match the layout ``state`` was initialized with (it is a
    static jit arg — the engine threads the same config everywhere)."""
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (token.shape[0],))
    x = jnp.take(params["embed"], token, axis=0)
    if cfg.pos == "learned":
        x = x + jnp.take(params["pos_embed"], pos, axis=0)[:, None]
    dp = None if pctx is None else pctx.data_axes
    x = _wsc(x, P(dp, None, None), pctx)
    x, new_states = S.apply_stack_decode(cfg, params["stack"], S.stack_spec(cfg),
                                         state["stack"], x, pos, pctx=pctx,
                                         kvcfg=kvcfg, kcfg=kcfg,
                                         block_table=state.get("block_table"))
    x = norm(x, params["final_norm"])
    logits = _head(cfg, params, x, pctx, kcfg)
    new_state = dict(state)
    new_state["stack"] = new_states
    return logits[:, 0], new_state


def decode_many(cfg: ModelConfig, params, state, token, pos, done, remaining,
                key, poison=None, *, K: int, max_len: int,
                temperature: float = 0.0, eos_token: int = -1,
                detect_faults: bool = False, pctx=None, kvcfg=None,
                kcfg=None):
    """Fused multi-token decode: ``lax.scan`` over ``K`` decode steps keeping
    sampling, EOS detection, per-slot done-masking, budget accounting, and
    position advance entirely on device — one host transfer per K tokens
    instead of one per token per slot.

    Inputs (all device arrays; B = slot count):
      token     (B, 1) int32  current token per slot
      pos       (B,)   int32  cache write position per slot
      done      (B,)   bool   True = inactive/finished lane (computes but
                              emits nothing; pos/token held)
      remaining (B,)   int32  generation budget left per slot
      key       PRNG key — split once per step, mirroring the host loop

    A live slot finishes when it emits ``eos_token``, exhausts ``remaining``,
    or its cache fills (``pos`` reaching ``max_len``): the request *ends* at
    capacity rather than clipping ``pos`` and silently overwriting the last
    KV row.  Done lanes keep stepping with ``pos`` clamped in-bounds; their
    garbage writes land in slots the next admission fully overwrites.

    Returns ``((tokens (B, K) int32, valid (B, K) bool), (state, token, pos,
    done, remaining, key))``.  ``valid[b, k]`` marks tokens actually emitted
    by a live slot; with greedy sampling those tokens are identical to ``K``
    repeated :func:`decode_step` calls.

    **Fault isolation (DESIGN.md §12):** with ``detect_faults=True`` the
    per-step logits are checked for finiteness on device; a lane whose
    logits go non-finite emits *nothing* from that step on (its done flag
    trips, position/token hold) and the output triple gains a per-slot
    ``fault (B,) bool`` — ``((tokens, valid, fault), carry)`` — so the
    scheduler can fail just that lane.  ``poison`` ((B,) bool or None) is
    the deterministic injection site: flagged lanes get their logits forced
    to NaN post-projection, exercising the exact detection path a real
    numerical fault would take.  Both default off, preserving the original
    signature and program for every existing caller.
    """
    def step_fn(carry, _):
        st, tok, p, dn, rem, k = carry
        p_in = jnp.minimum(p, max_len - 1)      # done lanes: in-bounds writes
        logits, st = decode_step(cfg, params, st, tok, p_in, pctx=pctx,
                                 kvcfg=kvcfg, kcfg=kcfg)
        if poison is not None:
            logits = jnp.where(poison[:, None], jnp.float32(jnp.nan), logits)
        k, sk = jax.random.split(k)
        live = ~dn
        if detect_faults:
            flt = live & ~jnp.isfinite(logits).all(axis=-1)
            live = live & ~flt                  # faulted lane: emit nothing,
            dn = dn | flt                       # hold token/pos, trip done
        nxt = sample_logits(logits, sk, temperature)
        nxt = jnp.where(live, nxt, tok[:, 0])
        rem = rem - live.astype(jnp.int32)
        p = p + live.astype(jnp.int32)
        stop = (nxt == eos_token) | (p >= max_len) | (rem <= 0)
        dn = dn | (live & stop)
        ys = (nxt, live, flt) if detect_faults else (nxt, live)
        return (st, nxt[:, None], p, dn, rem, k), ys

    carry = (state, token, pos, done, remaining, key)
    carry, ys = jax.lax.scan(step_fn, carry, None, length=K)
    if detect_faults:
        toks, valid, flts = ys
        return (toks.T, valid.T, flts.any(axis=0)), carry
    toks, valid = ys
    return (toks.T, valid.T), carry


def verify_window(cfg: ModelConfig, params, state, tokens, pos, *, pctx=None,
                  kvcfg=None, kcfg=None):
    """Score a drafted window in one batched dispatch (DESIGN.md §11).

    tokens: (B,S) int32 — per slot, the current token followed by S-1 drafted
    tokens, fed at absolute positions ``pos[b]..pos[b]+S-1``.  Writes the
    window's KV rows with THIS tree's k/v (overwriting whatever the draft
    pass stored there), then reads the updated cache, so the returned logits
    (B,S,V) match S sequential :func:`decode_step` calls bit-for-bit.
    """
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (tokens.shape[0],))
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.pos == "learned":
        idx = pos[:, None] + jnp.arange(tokens.shape[1])
        x = x + jnp.take(params["pos_embed"], idx, axis=0)
    dp = None if pctx is None else pctx.data_axes
    x = _wsc(x, P(dp, None, None), pctx)
    x, new_states = S.apply_stack_verify(cfg, params["stack"], S.stack_spec(cfg),
                                         state["stack"], x, pos, pctx=pctx,
                                         kvcfg=kvcfg, kcfg=kcfg,
                                         block_table=state.get("block_table"))
    x = norm(x, params["final_norm"])
    logits = _head(cfg, params, x, pctx, kcfg)
    new_state = dict(state)
    new_state["stack"] = new_states
    return logits, new_state


def speculate_many(cfg: ModelConfig, draft_params, params, state, token, pos,
                   done, remaining, key, poison=None, *, K: int, W: int,
                   max_len: int, eos_token: int = -1,
                   detect_faults: bool = False, pctx=None, kvcfg=None,
                   kcfg=None):
    """Self-speculative fused decode: ``K`` draft/verify windows per dispatch
    (DESIGN.md §11).  Greedy only — the engine auto-disables speculation when
    sampling temperature > 0.

    Each window drafts ``W`` tokens with ``draft_params`` (a ``lax.scan`` of
    cheap :func:`decode_step` calls), then scores the whole window — current
    token plus the W drafts — with ``params`` in ONE batched
    :func:`verify_window` dispatch.  On-device greedy acceptance keeps the
    longest agreeing prefix plus the verifier's next token (the standard
    bonus/correction), so every window emits between 1 and W+1 tokens per
    live slot.  KV rollback is positional: the verify pass rewrites the
    window's rows at verify quality, and rejected rows sit at or beyond the
    new frontier where the next window's write-then-read overwrites them
    before any valid query reads them — block tables never move (blocks are
    pre-reserved for ``max_new``), dense slabs just rewind positions.

    Same carry protocol as :func:`decode_many`; returns ``((tokens
    (B, K·(W+1)) int32, valid (B, K·(W+1)) bool), carry)`` — the acceptance
    length per window is recoverable from ``valid``, folding it into the
    existing one-host-transfer-per-chunk protocol.

    ``poison`` / ``detect_faults`` mirror :func:`decode_many` (DESIGN.md
    §12): the *verify* logits are the checked (and poisoned) site — the
    verify tree decides every emitted token, so a non-finite draft can only
    lower acceptance while a non-finite verify window trips the lane's
    fault flag and emits nothing.  With ``detect_faults`` the output triple
    gains the per-slot ``fault (B,) bool``.
    """
    B = token.shape[0]

    def window_fn(carry, _):
        st, tok, p, dn, rem, k = carry

        def draft_step(c, _):
            st_d, tk, pp = c
            p_in = jnp.minimum(pp, max_len - 1)
            logits, st_d = decode_step(cfg, draft_params, st_d, tk, p_in,
                                       pctx=pctx, kvcfg=kvcfg, kcfg=kcfg)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return (st_d, nxt[:, None], pp + 1), nxt

        (st, _, _), drafts = jax.lax.scan(draft_step, (st, tok, p), None,
                                          length=W)
        drafts = drafts.T                                   # (B, W)
        win = jnp.concatenate([tok, drafts], axis=1)        # (B, W+1)
        logits, st = verify_window(cfg, params, st, win, p, pctx=pctx,
                                   kvcfg=kvcfg, kcfg=kcfg)
        if poison is not None:
            logits = jnp.where(poison[:, None, None], jnp.float32(jnp.nan),
                               logits)
        flt = jnp.zeros((B,), bool)
        if detect_faults:
            flt = (~dn) & ~jnp.isfinite(logits).all(axis=(-2, -1))
            dn = dn | flt                   # faulted lane: whole window out
        v = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, W+1)
        # longest agreeing draft prefix; candidate i (0-based) is the
        # verifier's token for position p+i+1 and is emitted iff i <= a
        agree = (drafts == v[:, :W]).astype(jnp.int32)
        a = jnp.cumprod(agree, axis=1).sum(axis=1)          # (B,)

        def emit_step(c, xs):
            tk, pp, d2, rm = c
            vi, i = xs
            use = (~d2) & (i <= a)
            nxt = jnp.where(use, vi, tk[:, 0])
            rm = rm - use.astype(jnp.int32)
            pp = pp + use.astype(jnp.int32)
            stop = (nxt == eos_token) | (pp >= max_len) | (rm <= 0)
            d2 = d2 | (use & stop)
            return (nxt[:, None], pp, d2, rm), (nxt, use)

        (tok, p, dn, rem), (toks_w, valid_w) = jax.lax.scan(
            emit_step, (tok, p, dn, rem), (v.T, jnp.arange(W + 1)))
        ys = (toks_w, valid_w, flt) if detect_faults else (toks_w, valid_w)
        return (st, tok, p, dn, rem, k), ys

    carry = (state, token, pos, done, remaining, key)
    carry, ys = jax.lax.scan(window_fn, carry, None, length=K)
    toks, valid = ys[0], ys[1]
    # (K, W+1, B) → (B, K·(W+1)), window-major per slot
    toks = toks.transpose(2, 0, 1).reshape(B, K * (W + 1))
    valid = valid.transpose(2, 0, 1).reshape(B, K * (W + 1))
    if detect_faults:
        return (toks, valid, ys[2].any(axis=0)), carry
    return (toks, valid), carry
