"""Model zoo public API."""
from . import lm
from .config import (EncDecCfg, HybridCfg, MLACfg, ModelConfig, MoECfg, SSMCfg)

__all__ = ["lm", "ModelConfig", "MoECfg", "MLACfg", "HybridCfg", "SSMCfg",
           "EncDecCfg"]
