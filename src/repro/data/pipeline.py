"""Deterministic synthetic multi-domain token pipeline.

No datasets ship in this container, so the quality experiments need corpora
with (a) learnable sequential structure and (b) *controllable domain shift*
(the paper's central axis: AWQ calibrated on domain A, evaluated on domain B).

Each domain is a random-parameter order-2 Markov chain over the vocabulary
with a domain-specific sparse transition graph and unigram skew.  Different
domains → different activation statistics → measurable AWQ calibration
mismatch, exactly the WT2/PTB/C4 role in the paper.

Everything is derived from (seed, domain_id, step) → fully deterministic,
restart-safe (the trainer checkpoint stores only the step counter), and
host-shardable (host h of H draws batch rows [h·B/H, (h+1)·B/H)).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 256
    seq_len: int = 128
    batch: int = 8
    branch: int = 8          # out-degree of the transition graph
    seed: int = 0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DomainSpec:
    """Per-domain transition structure (device-resident, O(vocab·branch))."""
    succ: jnp.ndarray        # (V, branch) int32 allowed successors
    probs: jnp.ndarray       # (V, branch) f32 transition probabilities
    start: jnp.ndarray       # (V,) f32 start distribution


def make_domain(cfg: DataConfig, domain_id: int) -> DomainSpec:
    rng = np.random.default_rng(cfg.seed * 1000 + domain_id)
    V, B = cfg.vocab, cfg.branch
    succ = rng.integers(0, V, size=(V, B)).astype(np.int32)
    raw = rng.gamma(0.5, size=(V, B)).astype(np.float32) + 1e-3
    probs = raw / raw.sum(1, keepdims=True)
    start = rng.gamma(0.3, size=(V,)).astype(np.float32) + 1e-3
    start = start / start.sum()
    return DomainSpec(jnp.asarray(succ), jnp.asarray(probs), jnp.asarray(start))


@partial(jax.jit, static_argnames=("batch", "seq_len"))
def sample_batch(spec: DomainSpec, key, batch: int, seq_len: int):
    """(batch, seq_len) int32 token matrix from the domain's Markov chain."""
    k0, k1 = jax.random.split(key)
    t0 = jax.random.categorical(k0, jnp.log(spec.start)[None], shape=(batch, 1))[:, 0]

    def step(tok, k):
        logp = jnp.log(spec.probs[tok])                  # (batch, branch)
        pick = jax.random.categorical(k, logp)
        nxt = jnp.take_along_axis(spec.succ[tok], pick[:, None], axis=1)[:, 0]
        return nxt, nxt

    keys = jax.random.split(k1, seq_len - 1)
    _, rest = jax.lax.scan(step, t0, keys)
    return jnp.concatenate([t0[:, None], rest.T], axis=1)


def token_stream(cfg: DataConfig, domain_id: int, start_step: int = 0,
                 host_id: int = 0, n_hosts: int = 1):
    """Infinite deterministic iterator of {'tokens': (B_local, S)} batches."""
    spec = make_domain(cfg, domain_id)
    b_local = cfg.batch // n_hosts
    step = start_step
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step * 65521 + domain_id)
        full = sample_batch(spec, key, cfg.batch, cfg.seq_len)
        yield {"tokens": full[host_id * b_local:(host_id + 1) * b_local]}
        step += 1
