from .pipeline import DataConfig, DomainSpec, make_domain, sample_batch, token_stream

__all__ = ["DataConfig", "DomainSpec", "make_domain", "sample_batch",
           "token_stream"]
