"""Mesh-sharded serving equivalence tier.

Greedy-token equality between a single-device engine and the same engine on a
(1, n) tensor-parallel mesh, across kernels on/off × KV cache dtype × dense vs
paged KV.  Every test runs its workload in a 4-virtual-device CPU subprocess
(``mesh_subproc``) so the parent pytest process stays single-device.

Why greedy *tokens* and not bitwise logits: column-parallel projections
(wo/wd/w2/w_out) psum partial products over the model axis, which reorders the
f32 accumulation.  The argmax is stable under that reordering for every seed
and shape used here; the KV caches, row-parallel outputs and the requantized
weights themselves ARE bitwise identical (see
``test_requant_bit_equality_on_mesh``).
"""
import pytest

# Shared preamble: tiny dense model + engine runner, greedy decode.
_SETUP = """
import jax
import numpy as np
from repro.serving import TTQEngine, EngineConfig
from repro.models import ModelConfig, lm
from repro.core import ttq_policy
from repro.launch.mesh import make_mesh, make_ctx

cfg = ModelConfig(name='t', family='dense', n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=128, vocab=128)
params = lm.init_params(cfg, jax.random.PRNGKey(0))
PROMPTS = [[5, 9, 17, 3], [8, 8, 1], [100, 50, 25, 12, 6, 3, 7, 9, 2, 4]]
BUDGETS = [6, 4, 7]

def run(pctx, kernels, kv, paged):
    eng = TTQEngine(cfg, params, ttq_policy(bits=4, group_size=16, packed=True),
                    EngineConfig(max_slots=4, max_len=64, decode_chunk=2,
                                 kv_dtype=kv, kv_paged=paged, kv_block_size=16,
                                 use_kernels=kernels),
                    pctx=pctx, key=jax.random.PRNGKey(7))
    rids = [eng.submit(p, max_new=b) for p, b in zip(PROMPTS, BUDGETS)]
    eng.run_all()
    return [list(eng.scheduler.results()[r]) for r in rids]
"""

_SWEEP = _SETUP + """
assert jax.device_count() == 4, jax.device_count()
for kv, paged in (('bf16', False), ('int8', True), ('int4', False)):
    base = run(None, KERNELS, kv, paged)
    for n in (2, 4):
        got = run(make_ctx(make_mesh(1, n)), KERNELS, kv, paged)
        assert got == base, (KERNELS, kv, paged, n, got, base)
        print('OK', KERNELS, kv, paged, n)
print('SWEEP_OK')
"""


@pytest.mark.slow
@pytest.mark.parametrize("kernels", [False, True])
def test_mesh_greedy_equality(mesh_subproc, kernels):
    """mesh=1 tokens == mesh∈{2,4} tokens for all KV dtype/layout combos."""
    out = mesh_subproc(f"KERNELS = {kernels}\n" + _SWEEP, timeout=900)
    assert "SWEEP_OK" in out


def test_mesh_greedy_equality_smoke(mesh_subproc):
    """Fast tier-1 slice of the sweep: kernels on, int8 paged KV, mesh=2."""
    out = mesh_subproc(_SETUP + """
base = run(None, True, 'int8', True)
got = run(make_ctx(make_mesh(1, 2)), True, 'int8', True)
assert got == base, (got, base)
print('SMOKE_OK')
""", timeout=900)
    assert "SMOKE_OK" in out


def test_mesh_speculative_equality(mesh_subproc):
    """Sharded self-speculative decoding (DESIGN.md §11): speculate_k on a
    (1, 2) mesh emits the same greedy tokens as the single-device
    non-speculative engine — draft scan, batched verify and the dual
    requant trees all run shard-local."""
    out = mesh_subproc(_SETUP + """
def run_spec(pctx, W):
    eng = TTQEngine(cfg, params, ttq_policy(bits=8, group_size=16),
                    EngineConfig(max_slots=4, max_len=64, kv_dtype='bf16',
                                 speculate_k=W),
                    pctx=pctx, key=jax.random.PRNGKey(7))
    rids = [eng.submit(p, max_new=b) for p, b in zip(PROMPTS, BUDGETS)]
    eng.run_all()
    assert eng.qmodel.compiled_programs > 0
    return [list(eng.scheduler.results()[r]) for r in rids]

base = run_spec(None, 0)
for n in (2,):
    got = run_spec(make_ctx(make_mesh(1, n)), 2)
    assert got == base, (n, got, base)
print('SPEC_MESH_OK')
""", timeout=900)
    assert "SPEC_MESH_OK" in out


def test_requant_bit_equality_on_mesh(mesh_subproc):
    """Shard-local FusedRequantPlan == single-device quantize_params, bitwise.

    The requant math is per-output-row / per-group with a per-*column*
    activation diagonal, so quantizing each weight shard in place touches
    exactly the same numbers as the gathered single-device path — every
    QuantizedTensor child must match bit-for-bit."""
    out = mesh_subproc(_SETUP + """
from repro.quant.api import FusedRequantPlan, quantize_params
from repro.quant.session import CalibrationSession
from repro.core.ttq import QuantizedTensor

policy = ttq_policy(bits=4, group_size=16, packed=True)
sess = CalibrationSession()
_, _, stats = lm.prefill(cfg, params, {"tokens": np.array([PROMPTS[2]])}, 64)
sess.update(stats, float(len(PROMPTS[2])))
stats, count = sess.as_calib()

ref = quantize_params(params, stats, policy, count=count)
pctx = make_ctx(make_mesh(1, 4))
plan = FusedRequantPlan(params, stats, policy, pctx=pctx)
got = plan.run(params, stats, count, None)

is_qt = lambda x: isinstance(x, QuantizedTensor)
refs = [l for l in jax.tree.leaves(ref, is_leaf=is_qt) if is_qt(l)]
gots = [l for l in jax.tree.leaves(got, is_leaf=is_qt) if is_qt(l)]
assert len(refs) == len(gots) and refs
for r, g in zip(refs, gots):
    for f in ('wint', 'packed', 'scale', 'zero', 'dinv'):
        a, b = getattr(r, f), getattr(g, f)
        assert (a is None) == (b is None), f
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f)
print('BITEQ_OK', len(refs))
""", timeout=900)
    assert "BITEQ_OK" in out
