"""Fault tolerance (DESIGN.md §12): calibration-poisoning defense, the
requant health gate, request isolation with deadlines, and the seeded
fault-injection harness.

Unit layers first (session guard, qt health gate, the guarded
``decode_many`` program), then engine-level scenarios driven through
``serving/faults.py`` — the same injector the robustness bench uses, at
test-sized workloads.  The bitwise recovery-equality gates live in
``benchmarks/bench_robustness.py``; here the focus is each mechanism's
contract: rejected updates never fold, rejected trees never swap, a faulted
lane fails alone, expired requests fail with ``error == "deadline"``, and
nothing leaks blocks (``assert_quiescent``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NO_QUANT, ttq_policy
from repro.models import ModelConfig, lm
from repro.quant import (CalibrationSession, GuardConfig, QuantizedModel,
                         QuarantineRecord)
from repro.serving import (EngineConfig, Fault, FaultInjector, TTQEngine,
                           VirtualClock)
from repro.serving.faults import demo_injector

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=96, vocab=128)
GUARD = GuardConfig()


@pytest.fixture(scope="module")
def params():
    return lm.init_params(CFG, jax.random.PRNGKey(0))


def _stats(scale=1.0):
    return {"w": jnp.full((8,), float(scale), jnp.float32)}


# --------------------------------------------------- calibration-session guard


def test_session_rejects_nonfinite_stats():
    s = CalibrationSession(guard=GUARD)
    s.update(_stats(1.0), tokens=4)
    s.update(_stats(float("nan")), tokens=4, provenance=(7, 9))
    assert s.n_updates == 1 and s.n_rejected == 1
    rec = s.quarantine[-1]
    assert isinstance(rec, QuarantineRecord)
    assert rec.reason == "non-finite-stats"
    assert rec.provenance == (7, 9)
    # the poisoned update left the running stats untouched
    assert bool(jnp.isfinite(s.stats["w"]).all())
    s.update(_stats(float("inf")), tokens=4)
    assert s.n_rejected == 2 and s.count == 4.0


def test_session_rejects_bad_token_count():
    s = CalibrationSession(guard=GUARD)
    for bad in (0, -3, float("nan")):
        s.update(_stats(), tokens=bad)
    assert s.n_updates == 0 and s.n_rejected == 3
    assert all(r.reason == "bad-token-count" for r in s.quarantine)


def test_session_outlier_gate_arms_after_warmup():
    s = CalibrationSession(guard=GUARD)
    s.update(_stats(1.0), tokens=4)            # warmup: defines the scale
    s.update(_stats(1e6), tokens=4)            # 1e6x the running rate
    assert s.n_rejected == 1
    assert s.quarantine[-1].reason == "outlier-stats"
    s.update(_stats(2.0), tokens=4)            # in-family: accepted
    assert s.n_updates == 2 and s.n_rejected == 1


def test_session_outlier_gate_respects_warmup_window():
    g = GuardConfig(calib_warmup_updates=3)
    s = CalibrationSession(guard=g)
    for scale in (1.0, 50.0, 0.1):             # within warmup: all accepted
        s.update(_stats(scale), tokens=4)
    assert s.n_updates == 3 and s.n_rejected == 0


def test_session_rollback_ring_bounded():
    g = GuardConfig(snapshot_ring=2)
    s = CalibrationSession(guard=g)
    for i in range(4):
        s.update(_stats(1.0), tokens=2)
    assert s.n_updates == 4
    assert s.rollback(5) == 2                  # ring holds only the last 2
    assert s.n_updates == 2 and s.count == 4.0
    assert s.rollback() == 0                   # drained


def test_unguarded_session_behaves_as_before():
    s = CalibrationSession()
    s.update(_stats(float("nan")), tokens=4)   # no guard: folds verbatim
    assert s.n_updates == 1 and s.n_rejected == 0
    assert s.rollback() == 0                   # no ring without a guard


def test_quarantine_log_bounded():
    g = GuardConfig(quarantine_max=3)
    s = CalibrationSession(guard=g)
    for _ in range(6):
        s.update(_stats(), tokens=0)
    assert s.n_rejected == 6 and len(s.quarantine) == 3


# ------------------------------------------------------- requant health gate


def _nan_tree(tree):
    def leaf(x):
        if hasattr(x, "dtype") and np.issubdtype(x.dtype, np.floating):
            return x * float("nan")
        return x
    return jax.tree.map(leaf, tree)


def _prefill_stats(params):
    toks = jnp.asarray([[5, 9, 17, 3]], jnp.int32)
    _, _, stats = lm.prefill(CFG, params, {"tokens": toks}, max_len=32)
    return stats


def test_health_gate_blocks_sustained_corruption(params):
    qm = QuantizedModel(params, ttq_policy(bits=8, group_size=32, rank=0),
                        session=CalibrationSession(guard=GUARD),
                        health_gate=GUARD)
    qm.calibrate(_prefill_stats(params), tokens=4.0)
    qm._fault_hook = _nan_tree                 # every candidate corrupted
    assert qm.requantize() is None
    assert qm.requant_rejections == 2          # first try + the clean retry
    assert qm.n_requants == 0                  # cadence re-arms
    # the suspect calibration update was rolled back and nothing swapped
    assert qm.session.n_updates == 0
    assert qm.decode_params is params
    # clean recovery on the next cycle
    qm._fault_hook = None
    qm.calibrate(_prefill_stats(params), tokens=4.0)
    assert qm.requantize() is not None
    assert qm.n_requants == 1


def test_health_gate_transient_corruption_retries_in_step(params):
    qm = QuantizedModel(params, ttq_policy(bits=8, group_size=32, rank=0),
                        session=CalibrationSession(guard=GUARD),
                        health_gate=GUARD)
    qm.calibrate(_prefill_stats(params), tokens=4.0)
    calls = {"n": 0}

    def once(tree):
        calls["n"] += 1
        return _nan_tree(tree) if calls["n"] == 1 else tree

    qm._fault_hook = once
    tree = qm.requantize()                     # reject → immediate clean retry
    assert tree is not None
    assert qm.requant_rejections == 1
    assert qm.session.n_updates == 1           # nothing rolled back


def test_health_gate_off_keeps_legacy_behavior(params):
    qm = QuantizedModel(params, ttq_policy(bits=8, group_size=32, rank=0))
    qm.calibrate(_prefill_stats(params), tokens=4.0)
    qm._fault_hook = _nan_tree
    tree = qm.requantize()                     # ungated: corruption passes
    assert tree is not None and qm.requant_rejections == 0


# ------------------------------------------------- guarded decode_many program


def test_decode_many_detect_faults_isolates_lane(params):
    from functools import partial

    toks = jnp.asarray([[5, 9, 17, 3], [100, 50, 25, 12]], jnp.int32)
    _, state, _ = lm.prefill(CFG, params, {"tokens": toks}, max_len=32)
    tok0 = jnp.full((2, 1), 7, jnp.int32)
    pos0 = jnp.asarray([4, 4], jnp.int32)
    done0 = jnp.zeros((2,), bool)
    budget = jnp.full((2,), 100, jnp.int32)
    key = jax.random.PRNGKey(1)
    fn = jax.jit(partial(lm.decode_many, CFG, K=4, max_len=32,
                         detect_faults=True))
    clean = jnp.zeros((2,), bool)
    (t0_, v0, f0), _ = fn(params, state, tok0, pos0, done0, budget, key,
                          clean)
    assert not bool(f0.any()) and bool(v0.all())
    poison = jnp.asarray([False, True])
    (t1, v1, f1), carry = fn(params, state, tok0, pos0, done0, budget, key,
                             poison)
    f1, v1 = jax.device_get((f1, v1))
    assert list(f1) == [False, True]           # only the poisoned lane
    assert not v1[1].any()                     # it emitted nothing valid
    np.testing.assert_array_equal(np.asarray(t1)[0], np.asarray(t0_)[0])
    assert bool(carry[3][1])                   # done flag set for the lane


def test_decode_many_poison_none_matches_legacy(params):
    """poison=None keeps the original two-output program — the guarded
    signature is a strict extension."""
    from functools import partial

    toks = jnp.asarray([[5, 9, 17, 3]], jnp.int32)
    _, state, _ = lm.prefill(CFG, params, {"tokens": toks}, max_len=32)
    args = (jnp.full((1, 1), 7, jnp.int32), jnp.asarray([4], jnp.int32),
            jnp.zeros((1,), bool), jnp.full((1,), 100, jnp.int32),
            jax.random.PRNGKey(1))
    legacy = jax.jit(partial(lm.decode_many, CFG, K=4, max_len=32))
    ys, _ = legacy(params, state, *args)
    assert len(ys) == 2                        # (tokens, valid) — no fault row


# ----------------------------------------------------- engine-level scenarios


def _engine(params, policy=NO_QUANT, faults=(), clock=None, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("decode_chunk", 2)
    return TTQEngine(CFG, params, policy, EngineConfig(**kw),
                     faults=FaultInjector(faults, clock=clock))


PROMPTS = [[5, 9, 17, 3], [8, 8, 1], [100, 50, 25, 12], [7, 7, 7, 2]]


def test_lane_fault_retries_and_recovers(params):
    eng = _engine(params, faults=[Fault("decode.logits", rid=1, count=1)])
    ref = _engine(params)
    rids = [eng.submit(p, max_new=6) for p in PROMPTS[:2]]
    refs = [ref.submit(p, max_new=6) for p in PROMPTS[:2]]
    out, exp = eng.run_all(), ref.run_all()
    assert eng.lane_faults == 1
    for r, e in zip(rids, refs):
        assert list(out[r]) == list(exp[e]) and not out[r].error


def test_lane_fault_without_retry_fails_alone(params):
    eng = _engine(params, faults=[Fault("decode.logits", rid=1, count=1)],
                  guard_cfg=GuardConfig(max_retries=0))
    ref = _engine(params)
    rids = [eng.submit(p, max_new=6) for p in PROMPTS[:2]]
    refs = [ref.submit(p, max_new=6) for p in PROMPTS[:2]]
    out, exp = eng.run_all(), ref.run_all()
    assert out[rids[1]].error == "non-finite logits"
    assert out[rids[1]].unfinished
    assert list(out[rids[0]]) == list(exp[refs[0]])   # neighbor untouched


def test_lane_fault_releases_blocks(params):
    eng = _engine(params, faults=[Fault("decode.logits", rid=0, count=1)],
                  guard_cfg=GuardConfig(max_retries=0),
                  kv_dtype="int8", kv_paged=True, kv_block_size=16)
    eng.submit(PROMPTS[0], max_new=6)
    eng.run_all()
    eng.allocator.assert_quiescent()


def test_deadline_expires_running_request(params):
    clk = VirtualClock()
    eng = _engine(params, faults=[Fault("clock.skew", at=2, magnitude=5.0)],
                  clock=clk)
    r0 = eng.submit(PROMPTS[0], max_new=20)            # no deadline
    r1 = eng.submit(PROMPTS[1], max_new=20, deadline_s=1.0)
    out = eng.run_all()
    assert eng.deadline_expirations == 1
    assert out[r1].error == "deadline" and out[r1].unfinished
    assert len(out[r1]) > 0                            # partial output kept
    assert len(out[r0]) == 20 and not out[r0].error
    if eng.allocator is not None:
        eng.allocator.assert_quiescent()


def test_deadline_expires_queued_request(params):
    clk = VirtualClock()
    eng = _engine(params, faults=[Fault("clock.skew", at=1, magnitude=5.0)],
                  clock=clk, max_slots=1)
    r0 = eng.submit(PROMPTS[0], max_new=12)
    # EDF admission (DESIGN.md §13) would otherwise run the deadlined
    # request first — a less-urgent priority class keeps it queued behind
    # r0 so the expiry happens with no output produced
    r1 = eng.submit(PROMPTS[1], max_new=12, deadline_s=1.0, priority=1)
    out = eng.run_all()
    assert out[r1].error == "deadline" and list(out[r1]) == []
    assert len(out[r0]) == 12


def test_engine_default_deadline_from_config(params):
    clk = VirtualClock(tick=1.0)
    eng = _engine(params, clock=clk, deadline_s=2.5)
    r0 = eng.submit(PROMPTS[0], max_new=50)
    out = eng.run_all()
    assert out[r0].error == "deadline"
    assert eng.deadline_expirations == 1


def test_admission_retry_cap_fails_cleanly(params):
    """Satellite: the MemoryError→retry loop is bounded.  Blocks stolen for
    longer than the attempt cap → the queued request fails with a clean
    error instead of spinning the planner forever."""
    inj = FaultInjector([Fault("pool.steal", at=0, magnitude=64, count=500)])
    eng = TTQEngine(CFG, params, NO_QUANT,
                    EngineConfig(max_slots=1, max_len=64, decode_chunk=2,
                                 kv_dtype="int8", kv_paged=True,
                                 kv_block_size=16,
                                 guard_cfg=GuardConfig(
                                     max_admission_attempts=4)),
                    faults=inj)
    rid = eng.submit(PROMPTS[0], max_new=4)
    out = eng.run_all()
    assert out[rid].error == "admission retries exhausted"
    assert eng.admission_failures == 1
    # hand the stolen blocks back; the pool must reconcile exactly
    for _, alloc, blocks in inj._stolen:
        alloc.free.extend(blocks)
    inj._stolen.clear()
    eng.allocator.assert_quiescent()


def test_degradation_ladder_climbs_and_tokens_unchanged(params):
    """Sustained pool pressure climbs the ladder (speculation off → K=1
    chunks → cached-prefix eviction) — all token-preserving degradations,
    so outputs match an unpressured engine bitwise."""
    gcfg = GuardConfig(degrade_pressure=0.2, recover_pressure=0.05)
    eng = _engine(params, guard_cfg=gcfg, kv_dtype="int8", kv_paged=True,
                  kv_block_size=16)
    ref = _engine(params, kv_dtype="int8", kv_paged=True, kv_block_size=16)
    rids = [eng.submit(p, max_new=8) for p in PROMPTS]
    refs = [ref.submit(p, max_new=8) for p in PROMPTS]
    out, exp = eng.run_all(), ref.run_all()
    assert eng.degrade_events > 0
    assert eng.runner._decode_small is not None        # K=1 program built
    for r, e in zip(rids, refs):
        assert list(out[r]) == list(exp[e])
    eng.allocator.assert_quiescent()


def test_drop_cached_reclaims_prefix_blocks(params):
    eng = _engine(params, kv_dtype="int8", kv_paged=True, kv_block_size=16,
                  prefix_cache=True)
    sysp = list(range(1, 33))                          # two full blocks
    eng.submit(sysp + [40], max_new=2)
    eng.run_all()
    a = eng.allocator
    assert len(a.cached) > 0
    n = a.drop_cached()
    assert n > 0 and len(a.cached) == 0 and len(a.trie) == 0
    a.assert_quiescent()
    # dropped blocks are plain-free again: a new admission reuses them
    eng.submit(sysp + [41], max_new=2)
    eng.run_all()
    a.assert_quiescent()


def test_guards_off_restores_preguard_engine(params):
    """guards=False: no detection program, no poison lane, counters dark —
    and the injector's decode site is never consulted."""
    eng = TTQEngine(CFG, params, NO_QUANT,
                    EngineConfig(max_slots=2, max_len=64, decode_chunk=2,
                                 guards=False),
                    faults=FaultInjector([Fault("decode.logits", rid=0)]))
    assert not eng.runner.detect_faults and eng.runner._poison is None
    rid = eng.submit(PROMPTS[0], max_new=6)
    out = eng.run_all()
    assert list(out[rid]) and not out[rid].error
    assert eng.lane_faults == 0
    with pytest.raises(RuntimeError):
        eng.runner.set_poison([0])


# ----------------------------------------------- cancel: no-op-safe, leak-free


def test_cancel_queued_request_is_leak_free(params):
    eng = _engine(params, max_slots=1, kv_dtype="int8", kv_paged=True,
                  kv_block_size=16)
    r0 = eng.submit(PROMPTS[0], max_new=4)
    r1 = eng.submit(PROMPTS[1], max_new=4)             # still queued
    assert eng.cancel(r1) is True
    out = eng.run_all()
    assert out[r1].cancelled and list(out[r1]) == []
    assert len(out[r0]) == 4
    eng.allocator.assert_quiescent()


def test_cancel_after_finish_is_noop(params):
    eng = _engine(params, kv_dtype="int8", kv_paged=True, kv_block_size=16)
    r0 = eng.submit(PROMPTS[0], max_new=4)
    out = eng.run_all()
    tokens = list(out[r0])
    assert eng.cancel(r0) is False                     # already finished
    assert eng.cancel(10_000) is False                 # unknown rid
    res = eng.scheduler.results()[r0]
    assert list(res) == tokens and not res.cancelled
    eng.allocator.assert_quiescent()


# ------------------------------------------------------------ injector harness


def test_injector_is_deterministic(params):
    def run():
        eng = _engine(params,
                      faults=[Fault("decode.logits", rid=1, count=1)])
        rids = [eng.submit(p, max_new=6) for p in PROMPTS[:2]]
        out = eng.run_all()
        return [list(out[r]) for r in rids], eng.faults.fired

    (o1, f1), (o2, f2) = run(), run()
    assert o1 == o2 and f1 == f2


def test_injector_swallows_harness_bugs(params):
    class BadClock(VirtualClock):
        def advance(self, dt):
            raise RuntimeError("broken harness")

    inj = FaultInjector([Fault("clock.skew", at=0, magnitude=1.0)],
                        clock=BadClock())
    eng = TTQEngine(CFG, params, NO_QUANT,
                    EngineConfig(max_slots=1, max_len=64, decode_chunk=2),
                    faults=inj)
    rid = eng.submit(PROMPTS[0], max_new=4)
    out = eng.run_all()
    assert list(out[rid]) and not out[rid].error       # serving unharmed
    assert inj.errors and "broken harness" in inj.errors[0]


def test_demo_injector_recipes():
    inj = demo_injector("nan-stats")
    assert inj.faults[0].site == "calib.stats"
    with pytest.raises(ValueError):
        demo_injector("nonsense")
