"""TTQServer: async streaming front end (DESIGN.md §13).

The contract under test: the server is a pure transport — tokens stream
out exactly as the batch engine would produce them, backpressure awaits
instead of erroring, and a consumer that walks away cancels its request
on the engine without disturbing other streams.  No pytest-asyncio:
each test drives its own ``asyncio.run``.
"""
import asyncio

import jax
import pytest

from repro.core import NO_QUANT
from repro.models import ModelConfig, lm
from repro.serving import EngineConfig, TTQEngine, TTQServer

CFG = ModelConfig(name="t", family="dense", n_layers=3, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=96, vocab=128)

PROMPTS = [[((7 * i + s) % 126) + 1 for i in range(n)]
           for s, n in ((3, 8), (5, 40), (1, 12))]


@pytest.fixture(scope="module")
def params():
    return lm.init_params(CFG, jax.random.PRNGKey(0))


def _ecfg(**kw):
    base = dict(max_slots=2, max_len=96, decode_chunk=1, temperature=0.0,
                recalibrate_tokens=10**9, prompt_buckets=(16, 32, 64),
                prefill_chunk=16, max_queue=8)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def reference(params):
    eng = TTQEngine(CFG, params, NO_QUANT, _ecfg())
    rids = [eng.submit(p, max_new=6) for p in PROMPTS]
    outs = eng.run_all()
    return [list(outs[r]) for r in rids]


def test_streams_match_batch_engine(params, reference):
    """Concurrent async streams produce exactly the batch-mode tokens."""
    eng = TTQEngine(CFG, params, NO_QUANT, _ecfg())

    async def main():
        async with TTQServer(eng) as server:
            async def stream(p):
                return [t async for t in server.generate(p, max_new=6)]
            return await asyncio.gather(*[stream(p) for p in PROMPTS])

    outs = asyncio.run(main())
    assert outs == reference
    assert eng.allocator is None or not eng.allocator.ref


def test_complete_returns_genresult(params, reference):
    eng = TTQEngine(CFG, params, NO_QUANT, _ecfg())

    async def main():
        async with TTQServer(eng) as server:
            return await server.complete(PROMPTS[0], max_new=6)

    res = asyncio.run(main())
    assert list(res) == reference[0]
    assert not res.unfinished and not res.error


def test_backpressure_awaits_at_capacity(params, reference):
    """With the engine queue bounded at 1, concurrent submitters await at
    the semaphore instead of bouncing off QueueFull — every stream
    completes, and correctly."""
    eng = TTQEngine(CFG, params, NO_QUANT, _ecfg(max_slots=1, max_queue=1))

    async def main():
        async with TTQServer(eng) as server:
            async def stream(p):
                return [t async for t in server.generate(p, max_new=6)]
            return await asyncio.gather(*[stream(p) for p in PROMPTS])

    outs = asyncio.run(main())
    for got, want, prompt in zip(outs, reference, PROMPTS):
        assert got == want, prompt
    assert eng.queue_rejections == 0            # awaited, never rejected


def test_disconnect_cancels_without_disturbing_others(params, reference):
    """Closing a stream mid-generation cancels it on the engine (even
    mid-chunked-prefill); a concurrent stream is unaffected and the block
    pool ends quiescent."""
    eng = TTQEngine(CFG, params, NO_QUANT,
                    _ecfg(kv_paged=True, kv_block_size=16))

    async def main():
        async with TTQServer(eng) as server:
            survivor = asyncio.ensure_future(
                server.complete(PROMPTS[0], max_new=6))
            agen = server.generate(PROMPTS[1], max_new=6)
            first = await agen.__anext__()
            await agen.aclose()                 # client walks away
            return first, await survivor

    first, res = asyncio.run(main())
    assert first == reference[1][0]
    assert list(res) == reference[0]
    cancelled = [r for r in eng.scheduler.finished.values() if r.cancelled]
    assert len(cancelled) == 1
    eng.allocator.assert_quiescent()


def test_immediate_disconnect_cancels_mid_prefill(params):
    """A consumer that leaves before the first token cancels a request
    that is still chunk-ingesting its prompt; blocks are released."""
    eng = TTQEngine(CFG, params, NO_QUANT,
                    _ecfg(kv_paged=True, kv_block_size=16))

    async def main():
        async with TTQServer(eng) as server:
            task = asyncio.ensure_future(
                server.complete(PROMPTS[1], max_new=6))
            await asyncio.sleep(0)              # let the submit land
            task.cancel()
            try:
                await task
            except asyncio.CancelledError:
                pass
            # server still serves afterwards
            return await server.complete(PROMPTS[0], max_new=3)

    res = asyncio.run(main())
    assert len(res) == 3 and not res.error
    eng.allocator.assert_quiescent()


def test_stop_drains_inflight_work(params, reference):
    """Leaving the ``async with`` waits for in-flight requests instead of
    dropping them."""
    eng = TTQEngine(CFG, params, NO_QUANT, _ecfg())

    async def main():
        server = TTQServer(eng)
        await server.start()
        task = asyncio.ensure_future(server.complete(PROMPTS[2], max_new=6))
        await asyncio.sleep(0)
        res = await task
        await server.stop()
        return res

    res = asyncio.run(main())
    assert list(res) == reference[2]
    assert eng.scheduler.has_work() is False


def test_worker_crash_fails_open_streams(params):
    """An engine fault past containment lands in every open stream as an
    error result instead of hanging the consumers."""
    eng = TTQEngine(CFG, params, NO_QUANT, _ecfg())
    def boom():
        raise RuntimeError("injected engine crash")
    eng.step = boom

    async def main():
        async with TTQServer(eng) as server:
            res = await asyncio.wait_for(
                server.complete(PROMPTS[0], max_new=4), timeout=30)
            return res, server.error

    res, err = asyncio.run(main())
    assert res.unfinished and "crash" in res.error
    assert isinstance(err, RuntimeError)
