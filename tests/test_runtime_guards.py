"""Runtime guard rails: zero implicit transfers, bounded compilation.

The static passes (tools/tracecheck) prove the *code* has no host-sync or
recompile hazards; these tests prove the *runtime* agrees
(DESIGN.md §"Static analysis & runtime invariants"):

* steady-state engine decode runs under ``jax.transfer_guard("disallow")``
  — every implicit host↔device transfer raises, so the loop's only
  boundary crossings are the runner's explicit ``device_get``/
  ``device_put`` (EXPERIMENTS.md §"Transfer-guard methodology");
* a mixed-length paged workload compiles a bounded number of XLA
  programs, and REPEATING the workload compiles zero new ones — bucketing
  or requant changes that silently explode the jit caches trip here
  before any benchmark notices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KVCacheConfig, NO_QUANT, ttq_policy
from repro.models import ModelConfig, lm
from repro.serving import EngineConfig, TTQEngine

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=96, vocab=128)

# mixed lengths across two buckets; budgets staggered so slots finish (and
# release) at different chunk boundaries inside the guarded region
PROMPTS = [[5, 9, 17, 3], [8, 8, 1], [100, 50, 25, 12, 6, 3, 7, 9, 2, 4],
           [7, 7, 7, 2, 1]]
BUDGETS = [9, 4, 7, 12]


@pytest.fixture(scope="module")
def params():
    return lm.init_params(CFG, jax.random.PRNGKey(0))


def _serve(eng, guard=False):
    """Submit the workload; admission + first block warm (compile), the
    rest of the decode loop optionally under the disallow guard."""
    rids = [eng.submit(p, max_new=b) for p, b in zip(PROMPTS, BUDGETS)]
    assert eng.step()                    # admission + first decode block
    if guard:
        with jax.transfer_guard("disallow"):
            while eng.scheduler.has_work():
                if not eng.step():
                    break
    else:
        eng.run_all()
    return [list(eng.scheduler.results()[r]) for r in rids]


@pytest.mark.parametrize("kv_dtype,paged",
                         [("bf16", False), ("int8", True)])
def test_steady_state_decode_under_transfer_guard(params, kv_dtype, paged):
    """The engine's steady-state decode loop does ZERO implicit transfers:
    chunked decode, mid-loop slot releases (explicit device_put + resident
    constants) and empty admission rounds all run guarded; tokens match
    the unguarded engine exactly.  Admission is the one sanctioned
    boundary crossing (prompts enter the device there), so all requests
    are admitted in the unguarded warmup step."""
    def make():
        return TTQEngine(CFG, params, NO_QUANT, EngineConfig(
            max_slots=len(PROMPTS), max_len=64, decode_chunk=2,
            kv_dtype=kv_dtype, kv_paged=paged, kv_block_size=16))

    guarded = _serve(make(), guard=True)
    plain = _serve(make(), guard=False)
    assert guarded == plain


def test_decode_many_direct_under_transfer_guard(params):
    """The fused decode block itself (as the runner jits it) is
    transfer-clean after warmup — the per-chunk device_get is the only
    boundary crossing and it is explicit."""
    from functools import partial

    kvcfg = KVCacheConfig(dtype="int8")
    toks = jnp.asarray([[5, 9, 17, 3], [100, 50, 25, 12]], jnp.int32)
    _, state, _ = lm.prefill(CFG, params, {"tokens": toks}, max_len=32,
                             kvcfg=kvcfg)
    tok0 = jnp.full((2, 1), 7, jnp.int32)
    pos0 = jnp.asarray([4, 4], jnp.int32)
    done0 = jnp.zeros((2,), bool)
    budget = jnp.full((2,), 100, jnp.int32)
    key = jax.random.PRNGKey(1)
    fn = jax.jit(partial(lm.decode_many, CFG, K=4, max_len=32, kvcfg=kvcfg))
    out = fn(params, state, tok0, pos0, done0, budget, key)   # compile
    jax.block_until_ready(out)
    with jax.transfer_guard("disallow"):
        (blk, valid), carry = fn(params, state, tok0, pos0, done0, budget,
                                 key)
        host = jax.device_get((blk, valid))                   # explicit: ok
    ref = jax.device_get(out[0])
    np.testing.assert_array_equal(host[0], ref[0])
    np.testing.assert_array_equal(host[1], ref[1])


def test_guarded_decode_with_lane_fault_under_transfer_guard(params):
    """The fault-detection machinery (poison mask, per-lane isfinite flag,
    its device_get) is transfer-clean: a lane poisoned MID-LOOP under
    ``transfer_guard("disallow")`` fails alone — explicit ``set_poison``
    placement and the widened decode fetch raise nothing, the surviving
    lanes' tokens match the unguarded fault-free engine, and the fault
    wave compiles zero new programs after warmup."""
    from repro.quant import GuardConfig
    from repro.serving import Fault, FaultInjector

    def make(faults=()):
        # max_retries=0: the faulted lane must fail terminally, because a
        # retry would re-admit (prompt staging — the sanctioned boundary
        # crossing) inside the guarded region
        return TTQEngine(CFG, params, NO_QUANT, EngineConfig(
            max_slots=len(PROMPTS), max_len=64, decode_chunk=2,
            kv_dtype="int8", kv_paged=True, kv_block_size=16,
            guard_cfg=GuardConfig(max_retries=0)),
            faults=FaultInjector(faults))

    eng = make([Fault("decode.logits", rid=2, at=1, count=1)])
    rids = [eng.submit(p, max_new=b) for p, b in zip(PROMPTS, BUDGETS)]
    assert eng.step()                    # admission + first block: compiles
    warm = eng.compiled_programs
    with jax.transfer_guard("disallow"):
        while eng.scheduler.has_work():
            if not eng.step():
                break
    assert eng.compiled_programs == warm
    assert eng.lane_faults == 1
    out = eng.scheduler.results()
    assert out[rids[2]].error == "non-finite logits"
    plain = _serve(make(), guard=False)
    for i, r in enumerate(rids):
        if i != 2:
            assert list(out[r]) == plain[i]
    eng.allocator.assert_quiescent()


def test_mixed_length_paged_workload_bounded_compiles(params):
    """ISSUE 6 regression gate: a TTQ engine serving a mixed-length paged
    workload compiles a bounded number of programs, and identical repeat
    waves compile ZERO new ones (prefix-cache hits change admission shapes
    once, between wave 1 and 2, then the shape set is closed)."""
    buckets = (8, 16)
    eng = TTQEngine(CFG, params, ttq_policy(), EngineConfig(
        max_slots=2, max_len=64, decode_chunk=2, kv_paged=True,
        kv_block_size=16, prompt_buckets=buckets))
    base = eng.compiled_programs         # shared prefix-gather jit cache may
    _serve(eng)                          # be warm from earlier tests
    after_wave1 = eng.compiled_programs - base
    _serve(eng)                          # warm prefix cache: new tail shapes
    after_wave2 = eng.compiled_programs - base
    _serve(eng)                          # identical to wave 2
    after_wave3 = eng.compiled_programs - base
    assert after_wave3 == after_wave2, (
        f"steady-state wave compiled {after_wave3 - after_wave2} new "
        f"program(s) — a recompile regression")

    # analytic ceiling: 1 decode program; prefills bounded by
    # (tail-bucket × group-size × cold/warm-prefix) combos; one prefix
    # gather per (rows, prefix-blocks) shape; requant jits once per family
    n_fams = eng.qmodel.compiled_programs
    nblk = 64 // 16
    prefill_bound = len(buckets) * eng.ecfg.max_slots * 2
    gather_bound = eng.ecfg.max_slots * nblk
    bound = 1 + prefill_bound + gather_bound + n_fams
    assert after_wave1 <= bound and after_wave2 <= bound, (
        f"{after_wave2} programs > analytic bound {bound}")
    # the requant plan stays one program per family across repeated
    # requants (the single-dispatch invariant)
    assert n_fams == len(eng.qmodel._plan._family_fns)


# Sharded variants: the guard rails must survive a tensor-parallel mesh.
# Subprocess with 4 virtual CPU devices (the parent stays single-device).

_MESH_SETUP = """
import jax
from repro.core import NO_QUANT, ttq_policy
from repro.models import ModelConfig, lm
from repro.serving import EngineConfig, TTQEngine
from repro.launch.mesh import make_mesh, make_ctx

CFG = ModelConfig(name='t', family='dense', n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=96, vocab=128)
params = lm.init_params(CFG, jax.random.PRNGKey(0))
PROMPTS = [[5, 9, 17, 3], [8, 8, 1], [100, 50, 25, 12, 6, 3, 7, 9, 2, 4],
           [7, 7, 7, 2, 1]]
BUDGETS = [9, 4, 7, 12]
pctx = make_ctx(make_mesh(1, 2))

def serve(eng, guard=False):
    rids = [eng.submit(p, max_new=b) for p, b in zip(PROMPTS, BUDGETS)]
    assert eng.step()
    if guard:
        with jax.transfer_guard('disallow'):
            while eng.scheduler.has_work():
                if not eng.step():
                    break
    else:
        eng.run_all()
    return [list(eng.scheduler.results()[r]) for r in rids]
"""


def test_sharded_decode_under_transfer_guard(mesh_subproc):
    """Steady-state decode on a (1, 2) mesh stays transfer-clean: sharded
    state, replicated control lanes and the post-admission ``_repin`` are all
    explicit placements, so the guarded loop emits tokens identical to the
    unguarded sharded engine."""
    out = mesh_subproc(_MESH_SETUP + """
def make():
    return TTQEngine(CFG, params, NO_QUANT, EngineConfig(
        max_slots=len(PROMPTS), max_len=64, decode_chunk=2,
        kv_dtype='int8', kv_paged=True, kv_block_size=16), pctx=pctx)

guarded = serve(make(), guard=True)
plain = serve(make(), guard=False)
assert guarded == plain, (guarded, plain)
print('GUARD_OK')
""", timeout=900)
    assert "GUARD_OK" in out


def test_requant_program_bound_on_mesh(mesh_subproc):
    """The fused requant plan keeps ONE program per weight family on a mesh —
    shard-local quantization must not multiply jit entries per shard — and a
    repeated identical wave compiles zero new engine programs."""
    out = mesh_subproc(_MESH_SETUP + """
eng = TTQEngine(CFG, params, ttq_policy(), EngineConfig(
    max_slots=2, max_len=64, decode_chunk=2, kv_paged=True,
    kv_block_size=16, prompt_buckets=(8, 16)), pctx=pctx)
serve(eng)
n_fams = eng.qmodel.compiled_programs
assert n_fams == len(eng.qmodel._plan._family_fns), (
    n_fams, len(eng.qmodel._plan._family_fns))
serve(eng)                       # warm prefix cache: tail shapes settle
w2 = eng.compiled_programs
serve(eng)                       # identical wave: zero new programs
w3 = eng.compiled_programs
assert w3 == w2, (w2, w3)
assert eng.qmodel.compiled_programs == n_fams   # still 1 program / family
print('BOUND_OK', n_fams)
""", timeout=900)
    assert "BOUND_OK" in out


def test_compiled_programs_accounting(params):
    """The facade counter grows only with new shapes.  Deltas, not
    absolutes: the prefix-gather term is a module-level jit cache shared
    across engines (and across earlier tests in a full suite run)."""
    eng = TTQEngine(CFG, params, NO_QUANT,
                    EngineConfig(max_slots=2, max_len=64, decode_chunk=2))
    base = eng.compiled_programs
    eng.submit(PROMPTS[0], max_new=4)
    eng.run_all()
    first = eng.compiled_programs
    assert first - base >= 2             # >= one prefill + one decode
    eng.submit(PROMPTS[0], max_new=4)    # identical shapes: no growth
    eng.run_all()
    assert eng.compiled_programs == first
