"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see exactly 1 device;
multi-device tests spawn subprocesses with their own flags."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, devices: int = 1, timeout: int = 600):
    """Run python code in a subprocess with N fake devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
