"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see exactly 1 device;
multi-device tests spawn subprocesses with their own flags."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, devices: int = 1, timeout: int = 600):
    """Run python code in a subprocess with N fake devices; returns stdout.

    Any pre-existing --xla_force_host_platform_device_count in the caller's
    XLA_FLAGS is stripped (ours wins); other flags are preserved.
    """
    env = dict(os.environ)
    kept = [f for f in env.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    kept.append(f"--xla_force_host_platform_device_count={devices}")
    env["XLA_FLAGS"] = " ".join(kept)
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess


@pytest.fixture(scope="session")
def mesh_subproc():
    """Four-virtual-device CPU backend runner for the mesh test tier.

    The parent pytest process stays single-device; every mesh test runs its
    workload in a child with XLA_FLAGS=--xla_force_host_platform_device_count=4.
    """
    def run(code: str, timeout: int = 600):
        return run_subprocess(code, devices=4, timeout=timeout)
    return run
