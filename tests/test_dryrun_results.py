"""Deliverable (e) gate: every (arch × shape × mesh) dry-run cell in the
results cache must have compiled (or carry a DESIGN.md-sanctioned skip)."""
import glob
import json
import os

import pytest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def _load(mesh):
    rows = {}
    for p in glob.glob(os.path.join(RESULTS, f"*__{mesh}__*.json")):
        with open(p) as f:
            r = json.load(f)
        rows[(r["arch"], r["shape"])] = r
    return rows


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_all_cells_compiled(mesh):
    rows = _load(mesh)
    if not rows:
        pytest.skip("dry-run cache not built (run repro.launch.dryrun)")
    from repro.configs import ARCH_IDS, SHAPES
    missing, errors = [], []
    for a in ARCH_IDS:
        for s in SHAPES:
            r = rows.get((a, s))
            if r is None:
                missing.append((a, s))
            elif "error" in r:
                errors.append((a, s, r["error"]))
            elif "skipped" not in r:
                assert r["roofline"]["t_memory_s"] > 0
    assert not errors, errors
    assert len(missing) == 0, f"missing cells: {missing}"


def test_skips_are_justified():
    rows = _load("single")
    if not rows:
        pytest.skip("dry-run cache not built")
    for (a, s), r in rows.items():
        if "skipped" in r:
            assert s == "long_500k", (a, s)
            assert "full-attention" in r["skipped"]
