"""Per-assigned-architecture smoke tests — reduced same-family configs:
one forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get
from repro.models import lm
from repro.optim import adamw_init
from repro.training.trainer import TrainConfig, make_train_step


def _batch(cfg, B=2, S=16, seed=0):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0, cfg.vocab)
    b = {"tokens": toks}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.encdec.n_frames, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get(arch, smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, stats, _ = lm.forward(cfg, params, batch, collect_stats=True)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert stats["stack"], "stats tap empty"
    for run in stats["stack"]:
        for k, v in run.items():
            assert not bool(jnp.isnan(v).any()), k


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get(arch, smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    tcfg = TrainConfig(n_microbatches=2, remat=True)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, B=4, S=16)
    opt2, m = step(opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"])) and float(m["grad_norm"]) > 0
    # params actually moved
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(opt["master"]),
                                jax.tree.leaves(opt2["master"])))
    assert delta > 0


@pytest.mark.parametrize("arch", ["gemma_7b", "deepseek_v2_lite_16b",
                                  "mamba2_1p3b", "recurrentgemma_9b",
                                  "whisper_medium"])
def test_smoke_decode_consistency(arch):
    """prefill+decode == forward on the appended token (per-family decode)."""
    cfg = get(arch, smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    S = 12
    batch = _batch(cfg, B=2, S=S, seed=3)
    last, state, _ = lm.prefill(cfg, params, batch, max_len=S + 4)
    nt = batch["tokens"][:, -1:] * 0 + 7
    lg, _ = lm.decode_step(cfg, params, state, nt, jnp.full((2,), S, jnp.int32))
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([batch["tokens"], nt], 1)
    lgf, _, _ = lm.forward(cfg, params, b2)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lgf[:, -1]),
                               rtol=8e-2, atol=8e-2)
