"""Core quantization science: RTN/QDQ, AWQ closed form, TTQ ordering, GPTQ."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AWQConfig, QuantConfig, activation_diag, awq_qdq,
                        dequantize, gptq_qdq, qdq, quantize, rtn, svd_factors,
                        ttq_lowrank_qdq)
from repro.core.awq import awq_loss

RNG = np.random.default_rng(0)


def _w(dp=32, d=64):
    return jnp.asarray(RNG.standard_normal((dp, d)).astype("float32"))


def _x_heavytail(d=64, T=256, sigma=2.0, seed=1):
    r = np.random.default_rng(seed)
    chan = np.exp(r.standard_normal(d) * sigma).astype("float32")
    return jnp.asarray(r.standard_normal((T, d)).astype("float32") * chan)


@pytest.mark.parametrize("bits", [2, 3, 4, 5, 8])
@pytest.mark.parametrize("layout", ["flat", "row"])
def test_qdq_error_bound(bits, layout):
    """|W − Q[W]| ≤ S/2 per element (within clip range)."""
    W = _w()
    cfg = QuantConfig(bits=bits, group_size=32, layout=layout)
    Wint, S, Z = quantize(W, cfg)
    What = dequantize(Wint, S, Z, cfg)
    if layout == "row":
        Sfull = jnp.repeat(S, 32, axis=1)
    else:
        Sfull = jnp.repeat(S[:, None], 32, axis=1).reshape(W.shape)
    assert float((jnp.abs(W - What) - Sfull / 2 - 1e-5).max()) <= 0.0


def test_qdq_idempotent():
    W = _w()
    cfg = QuantConfig(bits=4, group_size=32)
    W1 = qdq(W, cfg)
    W2 = qdq(W1, cfg)
    np.testing.assert_allclose(np.array(W1), np.array(W2), atol=1e-6)


def test_more_bits_less_error():
    W = _w()
    errs = [float(jnp.mean((W - rtn(W, b, 32)) ** 2)) for b in (2, 3, 4, 5, 8)]
    assert all(a > b for a, b in zip(errs, errs[1:])), errs


def test_smaller_group_less_error():
    W = _w(32, 1024)
    errs = [float(jnp.mean((W - rtn(W, 3, g)) ** 2)) for g in (8, 32, 128, 512)]
    assert all(a < b for a, b in zip(errs, errs[1:])), errs


def test_symmetric_worse_or_equal_than_asymmetric():
    W = _w()
    ea = float(jnp.mean((W - qdq(W, QuantConfig(bits=3, group_size=32))) ** 2))
    es = float(jnp.mean((W - qdq(W, QuantConfig(bits=3, group_size=32,
                                                symmetric=True))) ** 2))
    assert es >= ea * 0.9   # symmetric has fewer degrees of freedom


def test_awq_scale_invariance():
    """Q[W∘cD]∘(cD)⁻¹ == Q[W∘D]∘D⁻¹ — global D scale cancels (asym QDQ is
    positively homogeneous)."""
    W, X = _w(), _x_heavytail()
    cfg = QuantConfig(bits=4, group_size=32, layout="row")
    D = activation_diag(X)
    a = awq_qdq(W, D, cfg)
    b = awq_qdq(W, 3.7 * D, cfg)
    np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-4, atol=1e-5)


def test_activation_aware_ordering():
    """Heavy-tailed activations: loss(RTN) > loss(AWQ); TTQ+LR ≤ AWQ (blend)."""
    cfg = QuantConfig(bits=3, group_size=32, layout="row")
    r_rtn, r_awq, r_lr = [], [], []
    for t in range(4):
        rng = np.random.default_rng(100 + t)
        W = jnp.asarray(rng.standard_normal((64, 128)).astype("float32") * 0.05)
        X = _x_heavytail(128, 256, seed=200 + t)
        Cd = jnp.mean(X ** 2, axis=0)
        D = activation_diag(X)
        r_rtn.append(float(awq_loss(W, qdq(W, cfg), Cd)))
        r_awq.append(float(awq_loss(W, awq_qdq(W, D, cfg), Cd)))
        B, A = svd_factors(W, 16)
        r_lr.append(float(awq_loss(W, ttq_lowrank_qdq(W, B, A, D, cfg), Cd)))
    assert np.mean(r_awq) < np.mean(r_rtn)
    assert np.mean(r_lr) < np.mean(r_rtn)


def test_exact_stats_beat_mismatched_stats():
    """TTQ's premise: D from the *test* activations beats D from a shifted
    calibration domain (the paper's domain-shift argument, Table 3)."""
    cfg = QuantConfig(bits=3, group_size=32, layout="row")
    wins = 0
    for t in range(6):
        rng = np.random.default_rng(300 + t)
        W = jnp.asarray(rng.standard_normal((64, 128)).astype("float32"))
        X_test = _x_heavytail(128, 256, seed=400 + t)
        X_cal = _x_heavytail(128, 256, seed=500 + t)   # different domain
        Cd = jnp.mean(X_test ** 2, axis=0)
        l_ttq = awq_loss(W, awq_qdq(W, activation_diag(X_test), cfg), Cd)
        l_awq = awq_loss(W, awq_qdq(W, activation_diag(X_cal), cfg), Cd)
        wins += int(float(l_ttq) < float(l_awq))
    assert wins >= 4, f"TTQ won only {wins}/6"


def test_gptq_beats_rtn_on_activation_loss():
    """GPTQ minimizes the *full-covariance* loss ‖(W−Ŵ)X‖² — measure that."""
    cfg = QuantConfig(bits=3, group_size=32)
    rng = np.random.default_rng(7)
    W = jnp.asarray(rng.standard_normal((48, 96)).astype("float32"))
    X = _x_heavytail(96, 512, sigma=1.5, seed=8)
    l_rtn = float(jnp.sum(((W - qdq(W, cfg)) @ X.T) ** 2))
    l_gptq = float(jnp.sum(((W - gptq_qdq(W, X, cfg)) @ X.T) ** 2))
    assert l_gptq < l_rtn


def test_lowrank_factors_reconstruct():
    W = _w(40, 64)
    B, A = svd_factors(W, 40)   # full rank → exact
    np.testing.assert_allclose(np.array(B @ A), np.array(W), atol=1e-3)
