"""The fused TTQ hot loop: kernel-backed decode matmuls (KernelConfig),
single-dispatch requantization (FusedRequantPlan), and the delta gate.

Greedy equality is the contract: flipping the Pallas kernels on must not
change a single emitted token for any covered policy; the fused requant
must reproduce the eager per-leaf tree bit-for-bit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KVCacheConfig, KernelConfig, QuantizedTensor, dequant,
                        quantize_params, quantize_weight, ttq_matmul,
                        ttq_policy)
from repro.models import ModelConfig, MoECfg, lm
from repro.quant import QuantizedModel, override
from repro.quant.api import FusedRequantPlan, lowrank_tree
from repro.serving import EngineConfig, TTQEngine

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=96, vocab=128)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prefilled(params):
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, CFG.vocab)
    _, _, stats = lm.prefill(CFG, params, {"tokens": toks}, max_len=20)
    return params, stats, float(toks.size)


def _qts(tree):
    return [l for l in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(l, QuantizedTensor)]


# ---------------------------------------------------------------------------
# e2e: greedy decode bit-identical with kernels on vs off
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", ["bf16", "int8", "int4"])
@pytest.mark.parametrize("bits", [4, 8])
def test_engine_greedy_identical_kernels_on_off(params, kv_dtype, bits):
    """Full engine decode over packed weights: the Pallas ttq_gemm path and
    the jnp fallback must emit the exact same greedy token streams for every
    KV-cache layout — the kernel is a pure perf knob."""
    pol = ttq_policy(bits=bits, group_size=32, rank=0, packed=True,
                     kvcache=KVCacheConfig(dtype=kv_dtype))
    prompts = [[5, 9, 17, 3], [8, 8, 1], [100, 50, 25, 12]]
    outs = {}
    for use in (False, True):
        eng = TTQEngine(CFG, params, pol,
                        EngineConfig(max_slots=2, max_len=48, decode_chunk=2,
                                     use_kernels=use))
        rids = [eng.submit(p, max_new=5) for p in prompts]
        o = eng.run_all()
        outs[use] = [o[r] for r in rids]
        assert eng.n_requants >= 1          # decode ran on quantized weights
        assert eng.kncfg.use_pallas is use
    assert outs[True] == outs[False]


def test_engine_greedy_identical_with_lowrank(params):
    """Low-rank residual + packed kernel path: still token-identical."""
    pol = ttq_policy(bits=4, group_size=32, rank=8, packed=True)
    outs = {}
    for use in (False, True):
        eng = TTQEngine(CFG, params, pol,
                        EngineConfig(max_slots=1, max_len=48,
                                     use_kernels=use))
        rid = eng.submit([5, 9, 17, 3], max_new=5)
        outs[use] = eng.run_all()[rid]
    assert outs[True] == outs[False]


def test_moe_expert_path_kernels_on_off():
    """The vmapped expert matmul dispatches one batched Pallas gemm; logits
    must match the jnp fallback closely and argmax exactly."""
    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=0, vocab=128,
                      moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=32,
                                 n_shared=0))
    mparams = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)
    _, state, stats = lm.prefill(cfg, mparams, {"tokens": toks}, max_len=12)
    qp = quantize_params(mparams, stats, ttq_policy(bits=4, group_size=16,
                                                    rank=0, packed=True),
                         count=float(toks.size))
    assert any(qt.packed is not None for qt in _qts(qp))
    tok = jnp.asarray([[7], [11]], jnp.int32)
    pos = jnp.asarray([8, 8], jnp.int32)
    lg_off, _ = lm.decode_step(cfg, qp, state, tok, pos)
    lg_on, _ = lm.decode_step(cfg, qp, state, tok, pos,
                              kcfg=KernelConfig(use_pallas=True))
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lg_off, -1)),
                                  np.asarray(jnp.argmax(lg_on, -1)))
    # bf16 residual activations: one-ulp rounding differences are expected
    np.testing.assert_allclose(np.asarray(lg_on), np.asarray(lg_off),
                               rtol=1e-1, atol=5e-2)


# ---------------------------------------------------------------------------
# fused single-dispatch requantization == eager per-leaf tree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pol", [
    ttq_policy(bits=4, group_size=32, rank=0),
    ttq_policy(bits=4, group_size=32, rank=8),
    ttq_policy(bits=4, group_size=32, rank=0, packed=True),
    ttq_policy(bits=3, group_size=32, rank=0).with_overrides(
        override("*.mix.*", bits=8), override("*.mlp.*", method="rtn")),
], ids=["base", "lowrank", "packed", "mixed"])
def test_fused_requant_matches_per_leaf(prefilled, pol):
    params, stats, count = prefilled
    lrt = lowrank_tree(params, pol)
    eager = quantize_params(params, stats, pol, count=count, lowrank_tree=lrt)
    plan = FusedRequantPlan(params, stats, pol, lowrank_tree=lrt)
    fused = plan.run(params, stats, count, lrt)
    ea, fu = _qts(eager), _qts(fused)
    assert len(ea) == len(fu) > 0
    for a, b in zip(ea, fu):
        assert (a.wint is None) == (b.wint is None)
        assert (a.packed is None) == (b.packed is None)
        codes_a = a.wint if a.wint is not None else a.packed
        codes_b = b.wint if b.wint is not None else b.packed
        np.testing.assert_array_equal(np.asarray(codes_a),
                                      np.asarray(codes_b))
        np.testing.assert_allclose(np.asarray(a.scale), np.asarray(b.scale),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(a.dinv), np.asarray(b.dinv),
                                   rtol=1e-6)
        assert (a.bits, a.group_size) == (b.bits, b.group_size)
    # full precision leaves stay identical objects
    fp_paths = [l for l in jax.tree.leaves(fused)
                if not isinstance(l, QuantizedTensor)]
    assert len(fp_paths) == len([l for l in jax.tree.leaves(eager)
                                 if not isinstance(l, QuantizedTensor)])


def test_fused_requant_moe_stacked_experts():
    """4-D (run, expert) stacked weights flatten into the family stack and
    come back per-expert — matching the eager vmapped driver exactly."""
    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=0, vocab=128,
                      moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=32,
                                 n_shared=1))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0, cfg.vocab)
    _, _, stats = lm.prefill(cfg, params, {"tokens": toks}, max_len=16)
    pol = ttq_policy(bits=4, group_size=16, rank=0)
    eager = quantize_params(params, stats, pol, count=float(toks.size))
    plan = FusedRequantPlan(params, stats, pol)
    fused = plan.run(params, stats, float(toks.size))
    for a, b in zip(_qts(eager), _qts(fused)):
        np.testing.assert_array_equal(np.asarray(a.wint), np.asarray(b.wint))
        np.testing.assert_allclose(np.asarray(a.dinv), np.asarray(b.dinv),
                                   rtol=1e-6)


def test_fused_requant_mixed_rank_overrides(prefilled):
    """Per-layer rank overrides put same-shape leaves in separate families
    (mixed B/A trailing dims cannot share one stacked dispatch) — regression
    for the family-key-missing-rank crash."""
    params, stats, count = prefilled
    pol = ttq_policy(bits=4, group_size=32, rank=8).with_overrides(
        override("*.mix.wq", rank=16))
    lrt = lowrank_tree(params, pol)
    eager = quantize_params(params, stats, pol, count=count, lowrank_tree=lrt)
    plan = FusedRequantPlan(params, stats, pol, lowrank_tree=lrt)
    fused = plan.run(params, stats, count, lrt)
    for a, b in zip(_qts(eager), _qts(fused)):
        np.testing.assert_array_equal(np.asarray(a.wint), np.asarray(b.wint))
        assert (a.B is None) == (b.B is None)
        if a.B is not None:
            assert a.B.shape == b.B.shape
    wq = fused["stack"][0]["u0"]["mix"]["wq"]
    wg = fused["stack"][0]["u0"]["mlp"]["wg"]
    assert wq.B.shape[-1] == 16 and wg.B.shape[-1] == 8


def test_fused_requant_pallas_quantize_kernel(prefilled):
    """policy.kernel.use_pallas + packed routes the family programs through
    the vmapped Pallas ttq_quantize — codes match the jnp closed form up to
    rounding-boundary ties (the test_kernels tolerance), and a full-model
    decode over the kernel-quantized tree stays finite and kernel-served."""
    from repro.core import FUSED_KERNELS
    from repro.core.qdq import unpack_bits

    params, stats, count = prefilled
    pol = ttq_policy(bits=4, group_size=32, rank=0, packed=True,
                     kernel=FUSED_KERNELS)
    plan = FusedRequantPlan(params, stats, pol)
    fused = plan.run(params, stats, count)
    ref = quantize_params(params, stats, pol.with_(kernel=KernelConfig()),
                          count=count)
    n_packed = 0
    for a, b in zip(_qts(ref), _qts(fused)):
        assert b.packed is not None
        n_packed += 1
        ua = np.asarray(unpack_bits(a.packed, a.in_features, a.bits))
        ub = np.asarray(unpack_bits(b.packed, b.in_features, b.bits))
        assert (ua != ub).mean() < 2e-3          # boundary ties only
        assert np.abs(ua.astype(int) - ub.astype(int)).max() <= 1
        np.testing.assert_allclose(np.asarray(a.scale), np.asarray(b.scale),
                                   rtol=1e-5)
    assert n_packed > 0
    toks = jax.random.randint(jax.random.PRNGKey(11), (2, 8), 0, CFG.vocab)
    lg, _, _ = lm.forward(CFG, fused, {"tokens": toks},
                          kcfg=pol.kernel)
    assert bool(jnp.isfinite(lg).all())


def test_fused_plan_is_single_dispatch_per_family(prefilled, monkeypatch):
    """One compiled-program call per weight family — not one per leaf."""
    params, stats, count = prefilled
    pol = ttq_policy(bits=4, group_size=32, rank=0)
    plan = FusedRequantPlan(params, stats, pol)
    calls = []
    for key, fn in plan._family_fns.items():
        plan._family_fns[key] = (lambda *a, _f=fn, _k=key:
                                 calls.append(_k) or _f(*a))
    plan.run(params, stats, count)
    assert len(calls) == len(plan.families)
    assert plan.n_layers == 7 and len(plan.families) < plan.n_layers


# ---------------------------------------------------------------------------
# delta gate
# ---------------------------------------------------------------------------

def test_drift_gate_threshold_semantics(prefilled):
    params, stats, count = prefilled
    qm = QuantizedModel(params, ttq_policy(bits=4, group_size=32, rank=0))
    qm.calibrate(stats, count)
    assert qm.requantize() is not None          # baseline snapshot
    n_all = qm.last_requant_layers
    assert n_all > 0 and qm.last_skipped_layers == 0

    qm.calibrate(stats, count)
    qm.requantize(threshold=0.0)                # 0 ⇒ every layer requantizes
    assert qm.last_requant_layers == n_all
    assert qm.last_skipped_layers == 0

    before = dict(qm._qt_by_path)
    qm.calibrate(stats, count)
    out = qm.requantize(threshold=float("inf"))  # ∞ ⇒ none; QTs reused
    assert qm.last_requant_layers == 0
    assert qm.last_skipped_layers == n_all
    for ps, qt in qm._qt_by_path.items():
        assert qt is before[ps]
    assert out is not None                       # tree still returned


def test_drift_gate_partial_on_domain_shift(params):
    """Stable stream → mass skips; a shifted stream wakes drifted layers."""
    toks_a = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, CFG.vocab)
    toks_b = jnp.full((2, 16), 3, jnp.int32)    # degenerate shifted domain
    _, _, st_a = lm.prefill(CFG, params, {"tokens": toks_a}, max_len=20)
    _, _, st_b = lm.prefill(CFG, params, {"tokens": toks_b}, max_len=20)
    qm = QuantizedModel(params, ttq_policy(bits=4, group_size=32, rank=0),
                        halflife=1.0)
    qm.calibrate(st_a, 32.0)
    qm.requantize()
    qm.calibrate(st_a, 32.0)                    # same domain again
    qm.requantize(threshold=0.05)
    stable_requants = qm.last_requant_layers
    qm.calibrate(st_b, 32.0)                    # domain shift
    qm.requantize(threshold=0.05)
    assert qm.last_requant_layers > stable_requants
    assert qm.last_skipped_layers < qm._plan.n_layers


def test_gated_decode_matches_full(prefilled):
    """A gate-skipped tree still decodes: greedy tokens equal the full
    requant (stats unchanged ⇒ reused QTs are the same quantization)."""
    params, stats, count = prefilled
    outs = {}
    for thr in (-1.0, float("inf")):
        eng = TTQEngine(CFG, params, ttq_policy(bits=8, group_size=32, rank=0),
                        EngineConfig(max_slots=1, max_len=48,
                                     requant_threshold=thr))
        for p in ([5, 9, 17, 3], [8, 8, 1]):
            eng.submit(p, max_new=4)
        o = eng.run_all()
        outs[thr] = [o[r] for r in sorted(o)]
        if thr == float("inf"):
            assert eng.layers_skipped > 0
    assert outs[-1.0] == outs[float("inf")]


def test_double_buffer_swap_semantics(prefilled):
    """Default: the requantize call swaps deterministically.  Opt-in
    double_buffer: the previous tree keeps serving until the pending one is
    device-ready, then decode_params swaps to it."""
    params, stats, count = prefilled
    pol = ttq_policy(bits=4, group_size=32, rank=0)
    qm = QuantizedModel(params, pol)
    qm.calibrate(stats, count)
    t1 = qm.requantize()
    t2 = qm.requantize()
    assert qm.decode_params is t2 and qm._pending is None   # deterministic

    db = QuantizedModel(params, pol, double_buffer=True)
    db.calibrate(stats, count)
    b1 = db.requantize()
    assert db.decode_params is b1                # first tree serves directly
    b2 = db.requantize()
    assert db._pending is b2 or db.qparams is b2  # parked until ready
    jax.block_until_ready(jax.tree.leaves(b2))
    assert db.decode_params is b2                # ready → swapped
    assert db._pending is None


# ---------------------------------------------------------------------------
# bits=8 code-dtype regression (the int8 overflow hazard)
# ---------------------------------------------------------------------------

def test_bits8_roundtrip_packed_vs_unpacked():
    """8-bit codes span 0..255: the packed path must dequantize and matmul
    identically to the unpacked path (a signed-int8 cast would wrap codes
    ≥ 128 and corrupt half the range)."""
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.standard_normal((32, 64)).astype("float32")) * 4.0
    D = jnp.asarray(np.exp(rng.standard_normal(64) * 0.3).astype("float32"))
    pol_packed = ttq_policy(bits=8, group_size=32, rank=0, packed=True)
    pol_plain = ttq_policy(bits=8, group_size=32, rank=0, packed=False)
    qt_p = quantize_weight(W, D, pol_packed)
    qt_u = quantize_weight(W, D, pol_plain)
    assert qt_p.packed is not None and qt_u.wint is not None
    assert int(jnp.max(qt_u.wint)) > 127        # codes really use 128..255
    Wp, Wu = dequant(qt_p), dequant(qt_u)
    np.testing.assert_allclose(np.asarray(Wp), np.asarray(Wu),
                               rtol=1e-6, atol=1e-6)
    x = jnp.asarray(rng.standard_normal((3, 64)).astype("float32"))
    yp = ttq_matmul(x, qt_p)
    yu = ttq_matmul(x, qt_u)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yu),
                               rtol=1e-5, atol=1e-5)
    # and the Pallas kernel path agrees with both
    yk = ttq_matmul(x, qt_p, kcfg=KernelConfig(use_pallas=True))
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yu),
                               rtol=1e-4, atol=1e-4)
