"""Roofline analysis utilities: trip-count-aware HLO costing + term math."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.analysis import (HBM_BW, PEAK_FLOPS, HloCost,
                                   collective_bytes, roofline)


def test_shape_info():
    from repro.launch.analysis import _shape_info
    assert _shape_info("bf16[2,4096,512]{2,1,0}")[1] == 2 * 4096 * 512 * 2
    assert _shape_info("f32[1024]")[1] == 4096
    assert _shape_info("(bf16[8,8], f32[4])")[1] == 128 + 16


def test_while_trip_count_flops():
    """scan of 10 matmuls → ~10× the single-matmul flops (cost_analysis
    famously reports 1× — the reason this walker exists)."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    comp = jax.jit(f).lower(x, x).compile()
    fl, by, coll = HloCost(comp.as_text()).cost()
    expect = 2 * 128 ** 3 * 10
    assert expect <= fl <= expect * 1.1
    assert by > 10 * 128 * 128 * 4          # body touches the buffers per trip
    assert coll == {}


def test_nested_scan_flops():
    def g(x, w):
        def outer(c, _):
            def inner(cc, __):
                return cc @ w, None
            y, _ = jax.lax.scan(inner, c, None, length=5)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    comp = jax.jit(g).lower(x, x).compile()
    fl, _, _ = HloCost(comp.as_text()).cost()
    expect = 2 * 64 ** 3 * 15
    assert expect <= fl <= expect * 1.1


def test_collective_bytes_multidevice(subproc):
    """psum inside scan: collective bytes multiply by the trip count."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.launch.analysis import collective_bytes
P = jax.sharding.PartitionSpec
mesh = jax.make_mesh((4,), ('d',))
def f(x):
    def body(c, _):
        y = c @ c
        return jax.lax.with_sharding_constraint(
            y, jax.sharding.NamedSharding(mesh, P(None, None))), None
    out, _ = jax.lax.scan(body, x, None, length=7)
    return out
x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
sh = jax.sharding.NamedSharding(mesh, P('d', None))
with mesh:
    comp = jax.jit(f, in_shardings=sh).lower(x).compile()
cb = collective_bytes(comp.as_text())
total = sum(cb.values())
print('CB', cb)
assert total > 0
print('OK')
""", devices=4)
    assert "OK" in out


def test_roofline_on_real_compile():
    fn = jax.jit(lambda a, b: a @ b)
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    comp = fn.lower(a, a).compile()
    r = roofline(comp, n_chips=1, model_flops=2 * 512 ** 3)
    assert r["flops_per_device"] >= 2 * 512 ** 3
    assert r["t_compute_s"] == r["flops_per_device"] / PEAK_FLOPS
    assert r["bytes_per_device"] >= 3 * 512 * 512 * 4
    assert r["dominant"] in ("compute", "memory", "collective")
    assert 0 < r["useful_flop_ratio"] <= 1.0 + 1e-6
