"""Checkpoint manager: roundtrip, atomic commit, keep-N, mesh resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nest": {"b": jnp.ones((2, 2), jnp.bfloat16)},
            "lst": [jnp.zeros((5,), jnp.int32)]}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(10, t)
    out = mgr.restore(10, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, _tree())
    # simulate a crash mid-write: directory exists, no commit marker
    os.makedirs(os.path.join(str(tmp_path), "step_00000009"))
    assert mgr.latest_step() == 5


def test_reshard_restore_subprocess(subproc):
    """Save on a (2,2) mesh, restore onto (4,1) — elastic re-mesh."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.checkpoint import CheckpointManager, reshard_restore
P = jax.sharding.PartitionSpec
mesh_a = jax.make_mesh((2, 2), ('data', 'model'))
mesh_b = jax.make_mesh((4, 1), ('data', 'model'))
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
xs = jax.device_put(x, jax.sharding.NamedSharding(mesh_a, P('data', 'model')))
d = tempfile.mkdtemp()
mgr = CheckpointManager(d)
mgr.save(1, {'x': xs})
tgt = {'x': jax.sharding.NamedSharding(mesh_b, P('model', 'data'))}
out = reshard_restore(mgr, 1, {'x': x}, tgt)
np.testing.assert_array_equal(np.asarray(out['x']), np.asarray(x))
assert out['x'].sharding.spec == P('model', 'data')
print('OK')
""", devices=4)
    assert "OK" in out
