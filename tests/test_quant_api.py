"""The unified repro.quant API: method registry, CalibrationSession,
per-layer mixed-precision overrides, QuantizedModel lifecycle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AWQConfig, QuantizedTensor, quantize_params
from repro.models import ModelConfig, lm
from repro.quant import (CalibrationSession, NO_QUANT, QuantizedModel,
                         get_quantizer, override, registered_methods,
                         register_quantizer, ttq_policy)

CFG = ModelConfig(name="t", family="dense", n_layers=3, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=96, vocab=128)


@pytest.fixture(scope="module")
def prefilled():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, CFG.vocab)
    _, _, stats = lm.prefill(CFG, params, {"tokens": toks}, max_len=20)
    return params, stats, float(toks.size)


def _qts(tree):
    return [l for l in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(l, QuantizedTensor)]


# ------------------------------------------------------------------ registry

def test_registry_builtins_present():
    for m in ("ttq", "rtn", "awq", "gptq", "none"):
        assert m in registered_methods()
    assert get_quantizer("ttq").requires_stats
    assert not get_quantizer("rtn").requires_stats
    assert not get_quantizer("none").enabled


def test_registry_unknown_method_raises():
    with pytest.raises(KeyError, match="unknown quantization method"):
        get_quantizer("int2point5")


def test_register_custom_quantizer_roundtrip(prefilled):
    """A user-registered method flows through the tree driver untouched."""
    from repro.quant.registry import RTNQuantizer

    @register_quantizer("rtn_test_alias")
    class _Alias(RTNQuantizer):
        pass

    params, stats, count = prefilled
    pol = ttq_policy(bits=4, group_size=32, rank=0)
    qp_a = quantize_params(params, None, pol.with_(method="rtn_test_alias"))
    qp_b = quantize_params(params, None, pol.with_(method="rtn"))
    wa, wb = _qts(qp_a), _qts(qp_b)
    assert len(wa) == len(wb) > 0
    for a, b in zip(wa, wb):
        np.testing.assert_array_equal(np.asarray(a.wint), np.asarray(b.wint))


def test_registry_matches_closed_form_bit_exact(prefilled):
    """Registry-dispatched ttq == direct quantize_weight closed form."""
    from repro.core.awq import diag_from_stats
    from repro.core.ttq import quantize_weight

    params, stats, count = prefilled
    pol = ttq_policy(bits=4, group_size=32, rank=0)
    qp = quantize_params(params, stats, pol, count=count)
    W = params["stack"][0]["u0"]["mix"]["wq"][1]
    stat = stats["stack"][0]["u0.mix.wq"][1]
    D = diag_from_stats(stat, jnp.float32(count), pol.acfg)
    expect = quantize_weight(W, D, pol)
    got = jax.tree.map(lambda l: l[1], qp["stack"][0]["u0"]["mix"]["wq"])
    np.testing.assert_array_equal(np.asarray(got.wint), np.asarray(expect.wint))
    np.testing.assert_allclose(np.asarray(got.scale), np.asarray(expect.scale))


# ---------------------------------------------------------- CalibrationSession

def _fake_stats(v):
    return {"stack": [{"u0.mix.wq": jnp.full((4,), float(v))}]}


def test_session_accumulates_and_counts():
    s = CalibrationSession()
    s.update(_fake_stats(1.0), tokens=10).update(_fake_stats(2.0), tokens=5)
    assert s.count == 15 and s.n_updates == 2
    np.testing.assert_allclose(
        np.asarray(s.stats["stack"][0]["u0.mix.wq"]), 3.0)


def test_session_halflife_decay():
    s = CalibrationSession(halflife=1.0)   # each update halves the past
    s.update(_fake_stats(8.0), tokens=8)
    s.update(_fake_stats(0.0), tokens=0)
    s.update(_fake_stats(0.0), tokens=0)
    np.testing.assert_allclose(
        np.asarray(s.stats["stack"][0]["u0.mix.wq"]), 2.0)
    assert s.count == pytest.approx(2.0)


def test_session_merge_is_sum():
    a = CalibrationSession().update(_fake_stats(1.0), 4)
    b = CalibrationSession().update(_fake_stats(5.0), 6)
    m = a.merge(b)
    np.testing.assert_allclose(
        np.asarray(m.stats["stack"][0]["u0.mix.wq"]), 6.0)
    assert m.count == 10 and m.n_updates == 2
    # merge with an empty (fresh) session is identity
    e = CalibrationSession().merge(a)
    np.testing.assert_allclose(
        np.asarray(e.stats["stack"][0]["u0.mix.wq"]), 1.0)


def test_session_merge_halflife_mismatch_raises():
    """Stats under different decay schedules are weighted incompatibly —
    summing them silently misweights one stream, so merge refuses."""
    a = CalibrationSession(halflife=4.0).update(_fake_stats(1.0), 4)
    b = CalibrationSession(halflife=8.0).update(_fake_stats(1.0), 4)
    with pytest.raises(ValueError, match="halflives"):
        a.merge(b)
    with pytest.raises(ValueError, match="halflives"):
        CalibrationSession(halflife=0.0).merge(b)
    # matching halflives still merge fine
    m = a.merge(CalibrationSession(halflife=4.0).update(_fake_stats(2.0), 2))
    assert m.halflife == 4.0 and m.count == 6


def test_session_snapshot_isolated_from_updates():
    s = CalibrationSession().update(_fake_stats(1.0), 1)
    snap = s.snapshot()
    s.update(_fake_stats(100.0), 1)
    np.testing.assert_allclose(
        np.asarray(snap.stats["stack"][0]["u0.mix.wq"]), 1.0)
    assert snap.count == 1


def test_session_merge_equals_one_big_session(prefilled):
    """Additivity: chunked merge == single accumulation (exact)."""
    params, _, _ = prefilled
    toks = jax.random.randint(jax.random.PRNGKey(7), (4, 16), 0, CFG.vocab)
    whole = CalibrationSession()
    _, _, st = lm.prefill(CFG, params, {"tokens": toks}, max_len=20)
    whole.update(st, toks.size)
    parts = CalibrationSession()
    for i in range(2):
        chunk = toks[2 * i:2 * i + 2]
        _, _, st = lm.prefill(CFG, params, {"tokens": chunk}, max_len=20)
        parts = parts.merge(CalibrationSession().update(st, chunk.size))
    assert parts.count == whole.count
    for a, b in zip(jax.tree.leaves(parts.stats), jax.tree.leaves(whole.stats)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5)


# ------------------------------------------------------- mixed precision

def test_mixed_precision_overrides(prefilled):
    """Two fnmatch patterns → different bits in the resulting tree."""
    params, stats, count = prefilled
    pol = ttq_policy(bits=3, group_size=32, rank=0).with_overrides(
        override("*.mix.*", bits=8),
        override("*.mlp.*", bits=2, group_size=16))
    qp = quantize_params(params, stats, pol, count=count)
    wq = qp["stack"][0]["u0"]["mix"]["wq"]
    wg = qp["stack"][0]["u0"]["mlp"]["wg"]
    assert isinstance(wq, QuantizedTensor) and isinstance(wg, QuantizedTensor)
    assert wq.bits == 8 and wq.group_size == 32
    assert wg.bits == 2 and wg.group_size == 16
    # int codes actually live in the overridden ranges
    assert int(wq.wint.max()) > 15          # 8-bit codes exceed 4-bit range
    assert int(wg.wint.max()) <= 3          # 2-bit codes


def test_override_later_wins():
    pol = ttq_policy(bits=3).with_overrides(
        override("stack.*", bits=4),
        override("*.mlp.*", bits=8))
    assert pol.resolve("stack.0.u0.mlp.wg").qcfg.bits == 8
    assert pol.resolve("stack.0.u0.mix.wq").qcfg.bits == 4
    assert pol.resolve("embed").qcfg.bits == 3


def test_override_can_disable_per_path(prefilled):
    """method='none' in an override keeps matching layers full precision."""
    params, stats, count = prefilled
    pol = ttq_policy(bits=4, group_size=32, rank=0).with_overrides(
        override("*.mlp.*", method="none"))
    qp = quantize_params(params, stats, pol, count=count)
    assert isinstance(qp["stack"][0]["u0"]["mix"]["wq"], QuantizedTensor)
    assert not isinstance(qp["stack"][0]["u0"]["mlp"]["wg"], QuantizedTensor)


def test_override_unknown_field_rejected():
    with pytest.raises(ValueError, match="unknown override field"):
        override("*", bitz=4)


# --------------------------------------------------------- QuantizedModel

def test_quantized_model_lifecycle(prefilled):
    params, stats, count = prefilled
    qm = QuantizedModel(params, ttq_policy(bits=4, group_size=32, rank=0))
    assert qm.decode_params is params          # not calibrated yet
    assert qm.requantize() is None             # ttq needs stats
    qm.calibrate(stats, tokens=count)
    qp = qm.requantize()
    assert qp is not None and qm.n_requants == 1
    assert len(_qts(qp)) == 7
    assert qm.decode_params is qp


def test_quantized_model_none_policy(prefilled):
    params, _, _ = prefilled
    qm = QuantizedModel(params, NO_QUANT)
    assert qm.requantize() is None and qm.decode_params is params


def test_quantized_model_override_enables_disabled_base(prefilled):
    """A 'none' base with an enabling override must still requantize the
    matching layers (the facade gate considers override-reachable methods)."""
    params, stats, count = prefilled
    pol = NO_QUANT.with_overrides(override("*.mix.*", method="rtn", bits=4))
    assert pol.any_enabled and not pol.enabled
    qm = QuantizedModel(params, pol)
    qp = qm.requantize()           # rtn override is stats-free → works now
    assert qp is not None
    assert isinstance(qp["stack"][0]["u0"]["mix"]["wq"], QuantizedTensor)
    assert not isinstance(qp["stack"][0]["u0"]["mlp"]["wg"], QuantizedTensor)


def test_quantized_model_fork_join(prefilled):
    """Fork per stream, join at requant time — additive stats make it exact."""
    params, stats, count = prefilled
    qm = QuantizedModel(params, ttq_policy(bits=4, group_size=32, rank=0))
    child_a, child_b = qm.fork(), qm.fork()
    child_a.calibrate(stats, count)
    child_b.calibrate(stats, count)
    qm.adopt(child_a.session).adopt(child_b.session)
    assert qm.session.count == 2 * count
    assert qm.requantize() is not None


def test_no_svd_rerun_on_requantize(prefilled, monkeypatch):
    """Low-rank factors are computed once; requantization must reuse them."""
    import repro.quant.api as api

    params, stats, count = prefilled
    qm = QuantizedModel(params, ttq_policy(bits=4, group_size=32, rank=8))
    assert qm.lowrank_tree is not None
    calls = []
    real = api.svd_factors
    monkeypatch.setattr(api, "svd_factors",
                        lambda *a, **kw: calls.append(1) or real(*a, **kw))
    for _ in range(3):
        qm.calibrate(stats, tokens=count)
        assert qm.requantize() is not None
    assert not calls, f"requantize re-ran SVD {len(calls)} times"
    qt = qm.qparams["stack"][0]["u0"]["mlp"]["wg"]
    assert qt.B is not None and qt.A is not None


def test_no_svd_rerun_with_override_rank(prefilled, monkeypatch):
    """rank set only via an override must still precompute factors once."""
    import repro.quant.api as api

    params, stats, count = prefilled
    pol = ttq_policy(bits=4, group_size=32, rank=0).with_overrides(
        override("*.mlp.*", rank=8))
    qm = QuantizedModel(params, pol)
    assert qm.lowrank_tree is not None
    calls = []
    real = api.svd_factors
    monkeypatch.setattr(api, "svd_factors",
                        lambda *a, **kw: calls.append(1) or real(*a, **kw))
    qm.calibrate(stats, tokens=count)
    qp = qm.requantize()
    assert not calls, "override-rank requantize re-ran SVD"
    assert qp["stack"][0]["u0"]["mlp"]["wg"].B is not None
    assert qp["stack"][0]["u0"]["mix"]["wq"].B is None   # base rank 0


def test_engine_requantize_reuses_lowrank(prefilled, monkeypatch):
    """The serving engine's requant path must not re-run SVD either."""
    import repro.quant.api as api
    from repro.serving import EngineConfig, TTQEngine

    params, _, _ = prefilled
    eng = TTQEngine(CFG, params, ttq_policy(bits=4, group_size=32, rank=8),
                    EngineConfig(max_slots=1, max_len=32))
    calls = []
    real = api.svd_factors
    monkeypatch.setattr(api, "svd_factors",
                        lambda *a, **kw: calls.append(1) or real(*a, **kw))
    for p in ([3, 1, 4], [1, 5, 9]):
        eng.submit(p, max_new=2)
    eng.run_all()
    assert eng.n_requants >= 2
    assert not calls, f"engine requant re-ran SVD {len(calls)} times"
