"""Chunked prefill + SLO scheduling (DESIGN.md §13).

Equality contract: splitting prompt ingestion into chunks is a pure
scheduling change — under a quiescent requant cadence the greedy token
stream is bitwise identical to monolithic prefill, across dense/paged
layouts, every KV precision and with speculation on.  (Per-chunk Σx²
calibration updates are additive, so only the *timing* of requants can
differ; the quiescent cadence removes that one degree of freedom.)
"""
import jax
import pytest

from repro.core import NO_QUANT
from repro.models import ModelConfig, lm
from repro.models.config import HybridCfg
from repro.serving import EngineConfig, QueueFull, Request, Scheduler, TTQEngine

CFG = ModelConfig(name="t", family="dense", n_layers=3, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=96, vocab=128)

LONG = [((7 * i + 3) % 126) + 1 for i in range(40)]     # > chunk: gets chunked
SHORT = [((11 * i + 5) % 126) + 1 for i in range(8)]    # <= chunk: classic path


@pytest.fixture(scope="module")
def params():
    return lm.init_params(CFG, jax.random.PRNGKey(0))


def _ecfg(**kw):
    base = dict(max_slots=2, max_len=96, decode_chunk=1, temperature=0.0,
                recalibrate_tokens=10**9, prompt_buckets=(16, 32, 64))
    base.update(kw)
    return EngineConfig(**base)


def _run(params, ecfg, prompts, max_new=6):
    eng = TTQEngine(CFG, params, NO_QUANT, ecfg)
    rids = [eng.submit(p, max_new=max_new) for p in prompts]
    outs = eng.run_all()
    if eng.allocator is not None:
        eng.allocator.assert_quiescent()
    return [list(outs[r]) for r in rids], eng


# ------------------------------------------------------------------ equality


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
@pytest.mark.parametrize("kv", ["bf16", "int8", "int4"])
@pytest.mark.parametrize("spec", [0, 2], ids=["nospec", "spec2"])
def test_chunked_matches_unchunked(params, paged, kv, spec):
    """Greedy outputs are bitwise equal with and without chunked prefill,
    across KV layout × KV precision × speculation."""
    kw = dict(kv_dtype=kv, speculate_k=spec)
    if paged:
        kw.update(kv_paged=True, kv_block_size=16)
    ref, _ = _run(params, _ecfg(**kw), [LONG, SHORT])
    got, eng = _run(params, _ecfg(prefill_chunk=16, **kw), [LONG, SHORT])
    assert got == ref
    assert eng.prefill_chunks >= 3          # 40-token prompt → 16+16+8


def test_chunking_lifts_bucket_cap(params):
    """Prompts past the largest bucket are accepted when chunking is on
    (chunks are what gets padded, not the whole prompt) and still match
    the reference greedy stream."""
    long100 = [((5 * i + 1) % 126) + 1 for i in range(100)]
    eng = TTQEngine(CFG, params, NO_QUANT,
                    _ecfg(max_len=128, prefill_chunk=16))
    rid = eng.submit(long100, max_new=4)
    out = list(eng.run_all()[rid])

    toks = list(long100)
    for _ in range(4):
        lg, _, _ = lm.forward(CFG, params,
                              {"tokens": jax.numpy.asarray(toks)[None]})
        toks.append(int(jax.numpy.argmax(lg[0, -1])))
    assert out == toks[100:]

    # the same submit bounces off the bucket cap when chunking is off
    eng2 = TTQEngine(CFG, params, NO_QUANT, _ecfg(max_len=128))
    with pytest.raises(ValueError):
        eng2.submit(long100, max_new=4)


# -------------------------------------------------------------- interleaving


def test_decode_interleaves_with_chunked_prefill(params):
    """A running stream keeps emitting while a long prompt is being
    ingested — the whole point of chunking (ITL protection)."""
    eng = TTQEngine(CFG, params, NO_QUANT, _ecfg(prefill_chunk=16))
    r_short = eng.submit(SHORT, max_new=12)
    eng.step()                                  # short admitted, decoding
    r_long = eng.submit(LONG, max_new=4)
    eng.step()                                  # long admitted → mid-prefill
    assert eng.scheduler.prefilling
    short_req = next(r for r in eng.slot_req if r and r.rid == r_short)
    seen_interleave = False
    while eng.scheduler.prefilling:
        n0 = len(short_req.out)
        eng.step()
        if len(short_req.out) > n0:
            seen_interleave = True
    assert seen_interleave
    outs = eng.run_all()
    assert list(outs[r_short])                  # both streams land
    assert list(outs[r_long])


def test_prefill_budget_bounds_chunks_per_round(params):
    """prefill_budget caps padded prefill tokens dispatched per round;
    the default (0) is one chunk per round."""
    for budget, per_round in ((0, 1), (16, 2), (40, 5)):
        eng = TTQEngine(CFG, params, NO_QUANT,
                        _ecfg(prefill_chunk=8, prefill_budget=budget))
        eng.submit(LONG, max_new=2)             # 40 tokens → 5 chunks of 8
        eng.step()                              # admission parks the lane
        prev = eng.prefill_chunks
        while eng.scheduler.prefilling:
            eng.step()
            assert eng.prefill_chunks - prev <= per_round
            prev = eng.prefill_chunks
        eng.run_all()


# ------------------------------------------------- cancellation / leak checks


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_cancel_mid_chunked_prefill_releases_blocks(params, paged):
    """Cancelling a request mid-ingestion frees its partially written
    blocks immediately; the pool is quiescent afterwards."""
    kw = dict(kv_paged=True, kv_block_size=16) if paged else {}
    eng = TTQEngine(CFG, params, NO_QUANT, _ecfg(prefill_chunk=16, **kw))
    rid = eng.submit(LONG, max_new=4)
    eng.step()                                  # admit + first chunk
    assert eng.scheduler.prefilling             # still mid-prefill
    eng.cancel(rid)
    assert not eng.scheduler.prefilling
    r2 = eng.submit(SHORT, max_new=3)           # pool immediately reusable
    outs = eng.run_all()
    assert outs[rid].cancelled and outs[rid].unfinished
    assert len(outs[r2]) == 3
    if eng.allocator is not None:
        eng.allocator.assert_quiescent()


def test_chunked_prefix_sharing(params):
    """Deferred trie registration: a second identical prompt shares the
    first one's blocks — but only blocks whose rows were actually written
    ever enter the trie, so the hit is safe mid-ingestion too."""
    ecfg = _ecfg(kv_paged=True, kv_block_size=16, prefill_chunk=16)
    ref, _ = _run(params, ecfg, [LONG])
    eng = TTQEngine(CFG, params, NO_QUANT, ecfg)
    r1 = eng.submit(LONG, max_new=6)
    eng.run_all()
    r2 = eng.submit(LONG, max_new=6)
    outs = eng.run_all()
    assert list(outs[r2]) == ref[0]
    assert eng.allocator.prefix_hits > 0        # second pass hit the trie
    eng.allocator.assert_quiescent()


# ----------------------------------------------------------- SLO scheduling


def test_priority_admission_order(params):
    """With one slot occupied, the urgent class (lower number) jumps the
    queue regardless of arrival order."""
    eng = TTQEngine(CFG, params, NO_QUANT, _ecfg(max_slots=1))
    blocker = eng.submit(SHORT, max_new=2)
    eng.step()                                  # blocker owns the slot
    r_low = eng.submit([1, 2, 3], max_new=2, priority=5)
    r_high = eng.submit([4, 5, 6], max_new=2, priority=0)
    eng.run_all()
    fin = eng.scheduler.finished
    assert fin[blocker].admit_seq < fin[r_high].admit_seq < fin[r_low].admit_seq


def test_deadline_class_order(params):
    """Within a priority class, earliest absolute deadline admits first;
    no deadline sorts last."""
    eng = TTQEngine(CFG, params, NO_QUANT, _ecfg(max_slots=1))
    blocker = eng.submit(SHORT, max_new=2)
    eng.step()
    r_none = eng.submit([1, 2, 3], max_new=2)                   # no deadline
    r_late = eng.submit([4, 5, 6], max_new=2, deadline_s=1000.0)
    r_soon = eng.submit([7, 8, 9], max_new=2, deadline_s=500.0)
    eng.run_all()
    fin = eng.scheduler.finished
    assert (fin[r_soon].admit_seq < fin[r_late].admit_seq
            < fin[r_none].admit_seq)


def test_priority_eviction_classes():
    """Victim pick: lowest class loses first, youngest within it; a
    requester never evicts a lane more urgent than itself."""
    sched = Scheduler(EngineConfig(max_slots=3, max_len=32))
    for slot, (pri, seq) in enumerate([(0, 0), (2, 1), (2, 2)]):
        r = Request(rid=slot, prompt=[1], max_new=1, admit_seq=seq)
        r.priority = pri
        sched.slot_req[slot] = r
    # an urgent requester evicts the least-urgent, youngest lane
    assert sched._pick_victim(set(), limit_priority=0) == 2
    assert sched._pick_victim({2}, limit_priority=0) == 1
    # a background requester (priority 5) cannot evict anyone more urgent
    assert sched._pick_victim(set(), limit_priority=5) is None
    # equal-class preemption stays allowed (pre-priority behaviour)
    assert sched._pick_victim(set(), limit_priority=2) == 2


def test_max_queue_rejects(params):
    eng = TTQEngine(CFG, params, NO_QUANT, _ecfg(max_slots=1, max_queue=2))
    eng.submit(SHORT, max_new=2)
    eng.step()                                  # drain one into the slot
    eng.submit([1, 2], max_new=1)
    eng.submit([3, 4], max_new=1)
    with pytest.raises(QueueFull):
        eng.submit([5, 6], max_new=1)
    assert eng.queue_rejections == 1
    eng.run_all()
    assert eng.queue_rejections == 1            # counter survives the run


# ----------------------------------------------------------------- validation


def test_prefill_chunk_rejects_non_attention_family():
    cfg = ModelConfig(name="h", family="hybrid", n_layers=3, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=96, vocab=128,
                      hybrid=HybridCfg(pattern=("rec", "attn"), window=32))
    p = lm.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="prefill_chunk"):
        TTQEngine(cfg, p, NO_QUANT, EngineConfig(prefill_chunk=16))


def test_prefill_chunk_must_divide_block_size(params):
    with pytest.raises(ValueError, match="block"):
        TTQEngine(CFG, params, NO_QUANT,
                  _ecfg(kv_paged=True, kv_block_size=16, prefill_chunk=12))


def test_latency_percentiles_shape(params):
    _, eng = _run(params, _ecfg(prefill_chunk=16), [LONG, SHORT], max_new=5)
    lat = eng.latency_percentiles()
    assert set(lat) >= {"ttft_p50", "ttft_p99", "itl_p50", "itl_p99",
                        "n_streams", "n_itl"}
    assert lat["n_streams"] == 2
    assert lat["n_itl"] == 2 * 4                # 5 tokens → 4 gaps each
    assert lat["ttft_p99"] >= lat["ttft_p50"] >= 0.0
