"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import QuantConfig, dequantize, pack_bits, qdq, quantize, unpack_bits

SET = settings(max_examples=25, deadline=None)


@SET
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]),
       st.integers(1, 8))
def test_pack_unpack_roundtrip(seed, bits, rows):
    rng = np.random.default_rng(seed)
    per = 32 // bits
    d = per * rng.integers(1, 8)
    w = rng.integers(0, 2 ** bits, size=(rows, d)).astype(np.int32)
    p = pack_bits(jnp.asarray(w), bits)
    u = unpack_bits(p, d, bits)
    assert (np.asarray(u) == w).all()


@SET
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 3, 4, 5]),
       st.sampled_from([8, 16, 32]))
def test_qdq_projection(seed, bits, g):
    """QDQ is a projection: applying it twice equals applying it once."""
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.standard_normal((8, 64)).astype("float32"))
    cfg = QuantConfig(bits=bits, group_size=g)
    W1 = qdq(W, cfg)
    W2 = qdq(W1, cfg)
    np.testing.assert_allclose(np.asarray(W1), np.asarray(W2),
                               rtol=1e-5, atol=1e-5)


@SET
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 10.0))
def test_qdq_positive_homogeneity(seed, c):
    """Q[cW] == c·Q[W] for c > 0 (asymmetric min/max scaling)."""
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.standard_normal((4, 32)).astype("float32"))
    cfg = QuantConfig(bits=4, group_size=16)
    a = qdq(W * c, cfg)
    b = qdq(W, cfg) * c
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


@SET
@given(st.integers(0, 2**31 - 1))
def test_quantize_int_range(seed):
    rng = np.random.default_rng(seed)
    W = jnp.asarray((rng.standard_normal((8, 64)) * 100).astype("float32"))
    for bits in (2, 4, 8):
        cfg = QuantConfig(bits=bits, group_size=16)
        Wint, S, Z = quantize(W, cfg)
        assert int(Wint.min()) >= 0 and int(Wint.max()) <= (1 << bits) - 1


@SET
@given(st.integers(0, 1000), st.integers(0, 3))
def test_data_pipeline_deterministic(step, domain):
    from repro.data import DataConfig, make_domain, sample_batch
    import jax
    cfg = DataConfig(vocab=64, seq_len=16, batch=4, seed=3)
    spec = make_domain(cfg, domain)
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    a = sample_batch(spec, key, cfg.batch, cfg.seq_len)
    b = sample_batch(spec, key, cfg.batch, cfg.seq_len)
    assert (np.asarray(a) == np.asarray(b)).all()
    assert int(a.min()) >= 0 and int(a.max()) < 64
