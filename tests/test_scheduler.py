"""Scheduler/runner split: FIFO fairness, requant cadence, fused decode.

The engine-behaviour tests (greedy exactness, continuous batching, TTQ
lifecycle) live in test_serving.py; this file covers the pieces the split
introduced — admission planning, the token-budget requantization cadence,
and ``lm.decode_many``'s equivalence with repeated single-step decode.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KVCacheConfig, NO_QUANT, ttq_policy
from repro.models import ModelConfig, lm
from repro.serving import EngineConfig, Scheduler, TTQEngine

CFG = ModelConfig(name="t", family="dense", n_layers=3, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=96, vocab=128)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(CFG, jax.random.PRNGKey(0))


def ref_greedy(params, prompt, n):
    toks = list(prompt)
    for _ in range(n):
        lg, _, _ = lm.forward(CFG, params, {"tokens": jnp.asarray(toks)[None]})
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks[len(prompt):]


# ---------------------------------------------------------------------------
# lm.decode_many — the fused on-device decode block
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", ["bf16", "int8", "int4"])
def test_decode_many_matches_repeated_decode_step(params, kv_dtype):
    """K fused steps emit the exact greedy tokens of K single decode_step
    calls, with identical position advance, for every KV-cache layout."""
    K = 5
    kvcfg = KVCacheConfig(dtype=kv_dtype)
    toks = jnp.asarray([[5, 9, 17, 3], [100, 50, 25, 12]], jnp.int32)
    lg, state, _ = lm.prefill(CFG, params, {"tokens": toks}, max_len=32,
                              kvcfg=kvcfg)
    tok0 = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
    pos0 = jnp.asarray([4, 4], jnp.int32)

    # reference: K repeated single-token decode steps
    ref, st, tok, pos = [], state, tok0, pos0
    for _ in range(K):
        lg1, st = lm.decode_step(CFG, params, st, tok, pos, kvcfg=kvcfg)
        tok = jnp.argmax(lg1, axis=-1)[:, None].astype(jnp.int32)
        ref.append(tok[:, 0])
        pos = pos + 1
    ref = jnp.stack(ref, axis=1)                         # (B, K)

    # jitted exactly as DeviceRunner jits it; warm once (compile-time
    # constant transfers happen here), then the steady-state call must be
    # free of implicit host↔device transfers (EXPERIMENTS.md
    # §"Transfer-guard methodology")
    fused = jax.jit(functools.partial(lm.decode_many, CFG, K=K, max_len=32,
                                      kvcfg=kvcfg))
    args = (params, state, tok0, pos0, jnp.zeros((2,), bool),
            jnp.full((2,), 100, jnp.int32), jax.random.PRNGKey(1))
    jax.block_until_ready(fused(*args))
    with jax.transfer_guard("disallow"):
        (blk, valid), (st2, tok2, pos2, done2, rem2, _) = fused(*args)
    np.testing.assert_array_equal(np.asarray(blk), np.asarray(ref))
    assert bool(valid.all())
    np.testing.assert_array_equal(np.asarray(pos2), np.asarray(pos0) + K)
    assert not bool(done2.any())
    # final carried token continues the sequence
    np.testing.assert_array_equal(np.asarray(tok2), np.asarray(tok))


def test_decode_many_budget_and_done_masking(params):
    """Slots stop at their per-slot budget; done lanes emit nothing and hold
    their position."""
    toks = jnp.asarray([[5, 9, 17, 3], [8, 8, 1, 2]], jnp.int32)
    lg, state, _ = lm.prefill(CFG, params, {"tokens": toks}, max_len=32)
    tok0 = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
    (blk, valid), (_, _, pos2, done2, _, _) = lm.decode_many(
        CFG, params, state, tok0, jnp.asarray([4, 4], jnp.int32),
        jnp.zeros((2,), bool), jnp.asarray([2, 6], jnp.int32),
        jax.random.PRNGKey(1), K=4, max_len=32)
    v = np.asarray(valid)
    assert v[0].tolist() == [True, True, False, False]   # budget 2
    assert v[1].tolist() == [True] * 4
    assert bool(done2[0]) and not bool(done2[1])
    assert int(pos2[0]) == 6 and int(pos2[1]) == 8       # held after done


# ---------------------------------------------------------------------------
# engine: chunked decode equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_engine_chunked_matches_per_token(params, kv_dtype):
    """decode_chunk > 1 (fused blocks, re-admission at chunk boundaries)
    produces the same greedy outputs as the per-token engine."""
    pol = NO_QUANT.with_(kvcache=KVCacheConfig(dtype=kv_dtype))
    prompts = [[5, 9, 17, 3], [8, 8, 1], [100, 50, 25, 12, 6, 3],
               [7, 7, 7, 2]]
    outs = {}
    for K in (1, 3):
        eng = TTQEngine(CFG, params, pol,
                        EngineConfig(max_slots=2, max_len=64, decode_chunk=K))
        rids = [eng.submit(p, max_new=9) for p in prompts]
        o = eng.run_all()
        outs[K] = [o[r] for r in rids]
    assert outs[1] == outs[3]


def test_engine_chunked_fewer_host_syncs(params):
    """The point of the split: host transfers per generated token drop from
    ~1 (per-token blocks) towards 1/K."""
    prompts = [[5, 9, 17, 3], [8, 8, 1], [100, 50, 25, 12]]
    syncs, toks = {}, {}
    for K in (1, 4):
        eng = TTQEngine(CFG, params, NO_QUANT,
                        EngineConfig(max_slots=4, max_len=64, decode_chunk=K))
        for p in prompts:
            eng.submit(p, max_new=12)
        o = eng.run_all()
        syncs[K] = eng.host_syncs
        toks[K] = sum(len(v) for v in o.values())
    assert toks[1] == toks[4]
    assert syncs[4] < syncs[1]
    assert syncs[4] / toks[4] <= 1.0 / 4 + 0.1   # ≤ ~1/K (+admission syncs)


# ---------------------------------------------------------------------------
# scheduler policy: FIFO fairness, bucketing, requant cadence
# ---------------------------------------------------------------------------

def test_fifo_fairness_across_slots(params):
    """Requests are admitted and completed in submission order when their
    generation lengths are equal — no slot starves the queue."""
    eng = TTQEngine(CFG, params, NO_QUANT,
                    EngineConfig(max_slots=2, max_len=64))
    prompts = [[5, 9, 17, 3], [8, 8, 1], [100, 50, 25, 12], [7, 7, 7, 2]]
    rids = [eng.submit(p, max_new=6) for p in prompts]
    outs = eng.run_all()
    assert list(eng.finished.keys()) == rids          # completion order
    for rid, p in zip(rids, prompts):
        assert outs[rid] == ref_greedy(params, p, 6)


def test_admission_groups_batch_compatible_prompts(params):
    """Same-bucket prompts admitted in one round share ONE prefill dispatch;
    distinct buckets dispatch separately."""
    eng = TTQEngine(CFG, params, NO_QUANT,
                    EngineConfig(max_slots=4, max_len=64))
    calls = []
    real = eng.runner._prefill_jit
    eng.runner._prefill_jit = \
        lambda *a, **kw: calls.append(kw["max_len"]) or real(*a, **kw)
    for p in ([5, 9, 17, 3], [8, 8, 1], [1] * 20):    # buckets 16, 16, 32
        eng.submit(p, max_new=2)
    eng.admit()
    assert len(calls) == 2


def test_scheduler_unit_plan_and_buckets():
    sch = Scheduler(EngineConfig(max_slots=3, max_len=64,
                                 prompt_buckets=(8, 16, 32)))
    for n in (4, 5, 20, 7):
        sch.submit(list(range(1, n + 1)), max_new=2)
    groups = sch.plan_admissions()
    by_bucket = {g.bucket: [r.rid for r in g.requests] for g in groups}
    assert by_bucket == {8: [0, 1], 32: [2]}          # rid 3 waits (FIFO)
    assert [r.rid for r in sch.queue] == [3]
    assert sch.slot_req[0].rid == 0 and sch.slot_req[1].rid == 1 \
        and sch.slot_req[2].rid == 2


def test_requant_cadence_token_budget(params):
    """recalibrate_tokens switches the cadence from per-admission to a token
    budget: 3 admissions processing 19 tokens each (16 prefill-bucket + 3
    decoded) trip a 20-token budget twice (at 35 and again at 22 tokens
    since the last requant), not once per admission."""
    pol = ttq_policy(bits=8, group_size=32, rank=0)
    prompts = ([3, 1, 4], [1, 5, 9, 2], [6, 5, 3, 5])
    eng = TTQEngine(CFG, params, pol,
                    EngineConfig(max_slots=1, max_len=64,
                                 recalibrate_tokens=20, decode_chunk=4))
    for p in prompts:
        eng.submit(p, max_new=4)
    eng.run_all()
    assert eng.n_requants == 2
    # control: per-admission cadence requantizes every admission
    eng2 = TTQEngine(CFG, params, pol,
                     EngineConfig(max_slots=1, max_len=64,
                                  recalibrate_every=1, decode_chunk=4))
    for p in prompts:
        eng2.submit(p, max_new=4)
    eng2.run_all()
    assert eng2.n_requants == 3
