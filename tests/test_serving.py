"""TTQEngine behaviour: exact fp greedy, continuous batching, TTQ lifecycle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NO_QUANT, QuantizedTensor, ttq_policy
from repro.models import ModelConfig, lm
from repro.serving import EngineConfig, TTQEngine

CFG = ModelConfig(name="t", family="dense", n_layers=3, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=96, vocab=128)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(CFG, jax.random.PRNGKey(0))


def ref_greedy(params, prompt, n):
    toks = list(prompt)
    for _ in range(n):
        lg, _, _ = lm.forward(CFG, params, {"tokens": jnp.asarray(toks)[None]})
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_reference_greedy(params):
    eng = TTQEngine(CFG, params, NO_QUANT, EngineConfig(max_slots=3, max_len=64))
    prompts = [[5, 9, 17, 3], [8, 8, 1], [100, 50, 25, 12, 6, 3]]
    rids = [eng.submit(p, max_new=6) for p in prompts]
    outs = eng.run_all()
    for rid, p in zip(rids, prompts):
        assert outs[rid] == ref_greedy(params, p, 6)


def test_engine_continuous_batching_staggered(params):
    """Requests arriving mid-generation produce the same outputs."""
    eng = TTQEngine(CFG, params, NO_QUANT, EngineConfig(max_slots=2, max_len=64))
    r1 = eng.submit([5, 9, 17, 3], max_new=8)
    for _ in range(3):
        eng.step()                      # r1 decoding alone
    r2 = eng.submit([8, 8, 1], max_new=5)
    outs = eng.run_all()
    assert outs[r1] == ref_greedy(params, [5, 9, 17, 3], 8)
    assert outs[r2] == ref_greedy(params, [8, 8, 1], 5)


def test_engine_requantizes_per_prompt(params):
    eng = TTQEngine(CFG, params, ttq_policy(bits=8, group_size=32, rank=0),
                    EngineConfig(max_slots=1, max_len=64, recalibrate_every=1))
    for p in ([3, 1, 4], [1, 5, 9, 2], [6, 5, 3, 5]):
        eng.submit(p, max_new=3)
    eng.run_all()
    assert eng.n_requants == 3
    leaves = jax.tree.leaves(
        eng.qparams, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    assert any(isinstance(l, QuantizedTensor) for l in leaves)


def test_engine_quantized_outputs_reasonable(params):
    """8-bit engine: decoded distribution stays close to fp (KL on step 1)."""
    eng = TTQEngine(CFG, params, ttq_policy(bits=8, group_size=32, rank=0),
                    EngineConfig(max_slots=1, max_len=64))
    eng.submit([5, 9, 17, 3], max_new=1)
    eng.run_all()
    # after run, decode params exist and dequantize near the fp weights
    from repro.core import dequant
    qt = None
    for leaf in jax.tree.leaves(eng.qparams,
                                is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            qt = jax.tree.map(lambda l: l[0], leaf)   # first layer of the stack
            break
    assert qt is not None
    W = dequant(qt)
    assert np.isfinite(np.asarray(W)).all()


def test_engine_lowrank_policy(params):
    eng = TTQEngine(CFG, params, ttq_policy(bits=4, group_size=32, rank=8),
                    EngineConfig(max_slots=1, max_len=64))
    rid = eng.submit([5, 9, 17, 3], max_new=2)
    outs = eng.run_all()
    assert len(outs[rid]) == 2
    lr = [l for l in jax.tree.leaves(
        eng.lowrank_tree) if l is not None]
    assert lr, "low-rank factors missing"
