"""TTQEngine behaviour: exact fp greedy, continuous batching, TTQ lifecycle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NO_QUANT, QuantizedTensor, ttq_policy
from repro.models import ModelConfig, lm
from repro.serving import EngineConfig, TTQEngine

CFG = ModelConfig(name="t", family="dense", n_layers=3, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=96, vocab=128)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(CFG, jax.random.PRNGKey(0))


def ref_greedy(params, prompt, n):
    toks = list(prompt)
    for _ in range(n):
        lg, _, _ = lm.forward(CFG, params, {"tokens": jnp.asarray(toks)[None]})
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_reference_greedy(params):
    eng = TTQEngine(CFG, params, NO_QUANT, EngineConfig(max_slots=3, max_len=64))
    prompts = [[5, 9, 17, 3], [8, 8, 1], [100, 50, 25, 12, 6, 3]]
    rids = [eng.submit(p, max_new=6) for p in prompts]
    outs = eng.run_all()
    for rid, p in zip(rids, prompts):
        assert outs[rid] == ref_greedy(params, p, 6)


def test_engine_continuous_batching_staggered(params):
    """Requests arriving mid-generation produce the same outputs."""
    eng = TTQEngine(CFG, params, NO_QUANT, EngineConfig(max_slots=2, max_len=64))
    r1 = eng.submit([5, 9, 17, 3], max_new=8)
    for _ in range(3):
        eng.step()                      # r1 decoding alone
    r2 = eng.submit([8, 8, 1], max_new=5)
    outs = eng.run_all()
    assert outs[r1] == ref_greedy(params, [5, 9, 17, 3], 8)
    assert outs[r2] == ref_greedy(params, [8, 8, 1], 5)


def test_engine_requantizes_per_prompt(params):
    eng = TTQEngine(CFG, params, ttq_policy(bits=8, group_size=32, rank=0),
                    EngineConfig(max_slots=1, max_len=64, recalibrate_every=1))
    for p in ([3, 1, 4], [1, 5, 9, 2], [6, 5, 3, 5]):
        eng.submit(p, max_new=3)
    eng.run_all()
    assert eng.n_requants == 3
    leaves = jax.tree.leaves(
        eng.qparams, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    assert any(isinstance(l, QuantizedTensor) for l in leaves)


def test_engine_quantized_outputs_reasonable(params):
    """8-bit engine: decoded distribution stays close to fp (KL on step 1)."""
    eng = TTQEngine(CFG, params, ttq_policy(bits=8, group_size=32, rank=0),
                    EngineConfig(max_slots=1, max_len=64))
    eng.submit([5, 9, 17, 3], max_new=1)
    eng.run_all()
    # after run, decode params exist and dequantize near the fp weights
    from repro.core import dequant
    qt = None
    for leaf in jax.tree.leaves(eng.qparams,
                                is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            qt = jax.tree.map(lambda l: l[0], leaf)   # first layer of the stack
            break
    assert qt is not None
    W = dequant(qt)
    assert np.isfinite(np.asarray(W)).all()


def test_submit_rejects_oversized_prompt(params):
    """A prompt longer than min(largest bucket, max_len) is rejected at
    submit() with a clear error instead of crashing admission with a shape
    error; a boundary-length prompt is accepted."""
    eng = TTQEngine(CFG, params, NO_QUANT,
                    EngineConfig(max_slots=1, max_len=32,
                                 prompt_buckets=(16, 32)))
    with pytest.raises(ValueError, match="exceeds the engine's admissible"):
        eng.submit(list(range(1, 34)), max_new=2)      # 33 > max_len=32
    rid = eng.submit(list(range(1, 33)), max_new=2)    # exactly at the limit
    outs = eng.run_all()
    assert rid in outs and not outs[rid].unfinished
    # bucket ceiling binds too, independent of max_len
    eng2 = TTQEngine(CFG, params, NO_QUANT,
                     EngineConfig(max_slots=1, max_len=64,
                                  prompt_buckets=(8, 16)))
    with pytest.raises(ValueError, match="largest prompt bucket"):
        eng2.submit(list(range(1, 19)), max_new=2)     # 18 > bucket 16


def test_run_all_max_iters_returns_partials(params):
    """Hitting max_iters returns every submitted request: finished outputs
    plus in-flight/queued partials flagged ``unfinished``."""
    eng = TTQEngine(CFG, params, NO_QUANT,
                    EngineConfig(max_slots=1, max_len=64))
    r1 = eng.submit([1, 2, 3], max_new=50)
    r2 = eng.submit([4, 5, 6], max_new=5)
    outs = eng.run_all(max_iters=3)
    assert outs[r1].unfinished and len(outs[r1]) == 4   # prefill + 3 steps
    assert outs[r2].unfinished and len(outs[r2]) == 0   # still queued
    # draining the engine completes both; results compare as plain lists
    done = eng.run_all()
    assert not done[r1].unfinished and not done[r2].unfinished
    assert done[r1][:4] == outs[r1]
    assert done[r2] == ref_greedy(params, [4, 5, 6], 5)


def test_requests_finishing_at_admission_do_not_strand_queue(params):
    """A request over at admission (max_new=1: the prefill-sampled token is
    the whole output) frees its slot for the next queued request in the same
    round — run_all must not break with the queue non-empty."""
    eng = TTQEngine(CFG, params, NO_QUANT,
                    EngineConfig(max_slots=1, max_len=64))
    r1 = eng.submit([5, 9, 17], max_new=1)
    r2 = eng.submit([8, 8, 1], max_new=1)
    r3 = eng.submit([4, 2], max_new=3)
    outs = eng.run_all()
    assert len(outs[r1]) == 1 and not outs[r1].unfinished
    assert len(outs[r2]) == 1 and not outs[r2].unfinished
    assert outs[r3] == ref_greedy(params, [4, 2], 3)
    assert not outs[r3].unfinished


def test_slot_at_capacity_finishes_request(params):
    """A slot whose cache fills ends its request instead of clipping pos and
    overwriting the last KV row: the emitted tokens stay exactly greedy (an
    overwrite would corrupt the attention read for the final tokens)."""
    eng = TTQEngine(CFG, params, NO_QUANT,
                    EngineConfig(max_slots=1, max_len=16))
    prompt = [5, 9, 17, 3]
    rid = eng.submit(prompt, max_new=100)               # wants 100, fits 13
    outs = eng.run_all()
    want = eng.ecfg.max_len - len(prompt) + 1           # 12 cached + final
    assert len(outs[rid]) == want
    assert not outs[rid].unfinished                     # finished, not dropped
    assert outs[rid] == ref_greedy(params, prompt, want)
    assert int(eng.pos[0]) == eng.ecfg.max_len          # never clipped back


def test_engine_lowrank_policy(params):
    eng = TTQEngine(CFG, params, ttq_policy(bits=4, group_size=32, rank=8),
                    EngineConfig(max_slots=1, max_len=64))
    rid = eng.submit([5, 9, 17, 3], max_new=2)
    outs = eng.run_all()
    assert len(outs[rid]) == 2
    lr = [l for l in jax.tree.leaves(
        eng.lowrank_tree) if l is not None]
    assert lr, "low-rank factors missing"
