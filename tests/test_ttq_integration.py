"""Whole-model TTQ: quantize_params joins stats↔weights by path; dequant
matches the closed form; policy skip patterns honored; MoE per-expert stats."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AWQConfig, QuantizedTensor, awq_qdq, dequant,
                        quantize_params, ttq_policy)
from repro.core.awq import diag_from_stats
from repro.models import ModelConfig, MoECfg, lm

CFG = ModelConfig(name="t", family="dense", n_layers=3, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=96, vocab=128)


def _prefilled(cfg, seed=0, B=2, S=16):
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, S), 0, cfg.vocab)
    _, state, stats = lm.prefill(cfg, params, {"tokens": toks}, max_len=S + 4)
    return params, stats, B * S


def test_quantize_params_joins_by_path():
    params, stats, count = _prefilled(CFG)
    pol = ttq_policy(bits=4, group_size=32, rank=0)
    qp = quantize_params(params, stats, pol, count=count)
    qts = [l for l in jax.tree.leaves(
        qp, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(l, QuantizedTensor)]
    # dense layer: wq, wk, wv, wo, wg, wu, wd = 7
    assert len(qts) == 7
    # embed / lm_head / norms untouched
    assert qp["embed"].dtype == params["embed"].dtype


def test_dequant_matches_closed_form():
    """vmapped whole-tree quantization == per-weight awq_qdq closed form."""
    params, stats, count = _prefilled(CFG)
    pol = ttq_policy(bits=4, group_size=32, rank=0)
    qp = quantize_params(params, stats, pol, count=count)
    layer = 1
    W = params["stack"][0]["u0"]["mix"]["wq"][layer].astype(jnp.float32)
    stat = stats["stack"][0]["u0.mix.wq"][layer]
    D = diag_from_stats(stat, jnp.float32(count), pol.acfg)
    expect = awq_qdq(W, D, pol.qcfg)
    qt_stack = qp["stack"][0]["u0"]["mix"]["wq"]
    qt = jax.tree.map(lambda l: l[layer], qt_stack)
    got = dequant(qt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_skip_patterns():
    params, stats, count = _prefilled(CFG)
    pol = ttq_policy(bits=4, group_size=32).with_(
        skip=("embed*", "lm_head", "*norm*", "router*", "*wq", "*wk", "*wv"))
    qp = quantize_params(params, stats, pol, count=count)
    wq = qp["stack"][0]["u0"]["mix"]["wq"]
    assert not isinstance(wq, QuantizedTensor)
    wo = qp["stack"][0]["u0"]["mix"]["wo"]
    assert isinstance(wo, QuantizedTensor)


def test_moe_per_expert_quantization():
    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=0, vocab=128,
                      moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=48,
                                 n_shared=1))
    params, stats, count = _prefilled(cfg)
    st = stats["stack"][0]
    assert st["u0.mlp.experts.wg"].shape == (2, 4, 64)   # (L, E, D)
    assert st["u0.mlp.experts.wd"].shape == (2, 4, 48)
    pol = ttq_policy(bits=4, group_size=16, rank=0)
    qp = quantize_params(params, stats, pol, count=count)
    qt = qp["stack"][0]["u0"]["mlp"]["experts"]["wg"]
    assert isinstance(qt, QuantizedTensor)
    assert qt.wint.shape == (2, 4, 48, 64)               # (L, E, F, D)
    assert qt.dinv.shape == (2, 4, 64)                   # per-expert D!
    # per-expert diagonals differ (different token subsets)
    d0, d1 = np.asarray(qt.dinv[0, 0]), np.asarray(qt.dinv[0, 1])
    assert not np.allclose(d0, d1)


def test_lowrank_residual_quantization():
    params, stats, count = _prefilled(CFG)
    pol = ttq_policy(bits=4, group_size=32, rank=8)
    qp = quantize_params(params, stats, pol, count=count)
    qt_stack = qp["stack"][0]["u0"]["mlp"]["wg"]
    assert qt_stack.B is not None and qt_stack.A is not None
    assert qt_stack.B.shape == (3, 96, 8) and qt_stack.A.shape == (3, 8, 64)
    # effective weight closer to original than rank-0 version
    pol0 = ttq_policy(bits=4, group_size=32, rank=0)
    qp0 = quantize_params(params, stats, pol0, count=count)
    W = params["stack"][0]["u0"]["mlp"]["wg"][0].astype(jnp.float32)
    e_lr = float(jnp.mean((dequant(jax.tree.map(lambda l: l[0], qt_stack)) - W) ** 2))
    e_0 = float(jnp.mean((dequant(jax.tree.map(
        lambda l: l[0], qp0["stack"][0]["u0"]["mlp"]["wg"])) - W) ** 2))
    assert e_lr < e_0


def test_rtn_protects_non_weight_params():
    """RTN (stats-free) must not mistake stacked 1-D params (norm scales)
    for 2-D weights — regression for the scan-axis-mismatch bug."""
    from repro.core import QuantPolicy
    params, _, _ = _prefilled(CFG)
    pol = QuantPolicy(method="rtn")
    qp = quantize_params(params, None, pol)
    g = qp["stack"][0]["u0"]["ln1"]["gamma"]
    assert not isinstance(g, QuantizedTensor)
    assert isinstance(qp["stack"][0]["u0"]["mix"]["wq"], QuantizedTensor)
    # quantized forward still runs
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, 128)
    lg, _, _ = lm.forward(CFG, qp, {"tokens": toks})
    assert not bool(jnp.isnan(lg).any())


def test_quantized_forward_runs():
    params, stats, count = _prefilled(CFG)
    pol = ttq_policy(bits=8, group_size=32, rank=0)
    qp = quantize_params(params, stats, pol, count=count)
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 8), 0, 128)
    lg_q, _, _ = lm.forward(CFG, qp, {"tokens": toks})
    lg_f, _, _ = lm.forward(CFG, params, {"tokens": toks})
    assert not bool(jnp.isnan(lg_q).any())
    # 8-bit forward stays close to fp in logit space
    assert float(jnp.abs(lg_q - lg_f).mean()) < 0.5
