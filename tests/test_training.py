"""Training substrate: learning, microbatch equivalence, FT, compression."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, token_stream
from repro.models import ModelConfig, lm
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime import FailureInjector
from repro.training import TrainConfig, Trainer, make_train_step

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=96, vocab=64)
DC = DataConfig(vocab=64, seq_len=32, batch=8, seed=1)


def test_adamw_matches_reference_math():
    """One AdamW step vs hand-computed update."""
    p = {"w": jnp.asarray([[1.0, -2.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.5, 0.5]], jnp.float32)}
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9)
    st = adamw_init(p)
    newp, st2, _ = adamw_update(g, st, cfg, params=p)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mh, vh = m / 0.1, v / 0.01
    expect = 1.0 - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(float(newp["w"][0, 0]), expect, rtol=1e-5)


def test_trainer_learns():
    tc = TrainConfig(n_microbatches=1, remat=False, total_steps=100, warmup=2)
    tr = Trainer(CFG, tc, token_stream(DC, 0))
    log = tr.run(15)
    assert log[-1]["loss"] < log[0]["loss"]


def test_microbatch_equivalence():
    """nmb=1 vs nmb=4 give the same update (grads are mean-accumulated)."""
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, 64)}
    outs = []
    for nmb in (1, 4):
        tc = TrainConfig(n_microbatches=nmb, remat=nmb > 1, total_steps=10,
                         warmup=1)
        params = lm.init_params(CFG, jax.random.PRNGKey(1))
        opt = adamw_init(params)
        step = jax.jit(make_train_step(CFG, tc))
        opt2, m = step(opt, batch)
        outs.append((opt2, m))
    a, b = outs
    # losses: mean-of-means with equal microbatch sizes == full mean
    np.testing.assert_allclose(float(a[1]["loss"]), float(b[1]["loss"]),
                               rtol=2e-2)
    la = jax.tree.leaves(a[0]["master"])
    lb = jax.tree.leaves(b[0]["master"])
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-2, atol=2e-3)


def test_crash_restart_resumes(tmp_path):
    tc = TrainConfig(n_microbatches=1, remat=False, checkpoint_every=4,
                     checkpoint_dir=str(tmp_path), total_steps=50, warmup=2)
    tr = Trainer(CFG, tc, token_stream(DC, 0))
    tr.failure_hook = FailureInjector({6})
    with pytest.raises(FailureInjector.Crash):
        tr.run(10)
    tr2 = Trainer(CFG, tc, token_stream(DC, 0, start_step=4))
    assert tr2.restore_if_available()
    assert tr2.step == 4
    tr2.run(4)
    assert tr2.step == 8


def test_straggler_deadline_logged():
    tc = TrainConfig(n_microbatches=1, remat=False, total_steps=10, warmup=1,
                     step_deadline_s=1e-9)   # everything is a straggler
    tr = Trainer(CFG, tc, token_stream(DC, 0))
    tr.run(3)
    assert len(tr.skipped_steps) == 3


def test_grad_compression_subprocess(subproc):
    """int8-EF compressed DP step ≈ uncompressed after a few steps (4 dev)."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import ModelConfig, lm
from repro.optim import adamw_init
from repro.optim.compress import compress_state_init
from repro.parallel import ParallelCtx
from repro.training.trainer import TrainConfig, make_compressed_dp_step, make_train_step
cfg = ModelConfig(name='t', family='dense', n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=1, d_ff=64, vocab=64)
mesh = jax.make_mesh((4,), ('data',))
pctx = ParallelCtx(mesh=mesh, data_axes=('data',))
tc = TrainConfig(n_microbatches=1, remat=False, total_steps=100, warmup=1)
params = lm.init_params(cfg, jax.random.PRNGKey(0))
opt_c = adamw_init(params); opt_u = adamw_init(params)
err = compress_state_init(params)
comp = make_compressed_dp_step(cfg, tc, pctx)
unc = jax.jit(make_train_step(cfg, tc, param_dtypes=jax.tree.map(lambda p: p.dtype, params)))
import numpy as np
for i in range(5):
    key = jax.random.PRNGKey(i)
    batch = {'tokens': jax.random.randint(key, (8, 16), 0, 64)}
    params_c, opt_c, err, mc = comp(params_c if i else params, opt_c, err, batch)
    opt_u, mu = unc(opt_u, batch)
mast_c = jax.tree.leaves(opt_c['master']); mast_u = jax.tree.leaves(opt_u['master'])
num = sum(float(jnp.sum((a-b)**2)) for a, b in zip(mast_c, mast_u))
den = sum(float(jnp.sum(b**2)) for b in mast_u)
rel = (num / den) ** 0.5
print('REL', rel)
assert rel < 0.05, rel
print('OK')
""", devices=4)
    assert "OK" in out
