"""KV-cache quantization: codec roundtrips, the fused Pallas dequant-attention
kernel vs its jnp oracle, and the engine integration (int8/int4 cache slots,
mixed-slot admission)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KVCacheConfig, NO_QUANT
from repro.core.kvquant import decode_attention_q8, dequantize_kv, quantize_kv
from repro.kernels import kv_decode_attention
from repro.kernels.ref import kv_attn_ref
from repro.models import ModelConfig, lm
from repro.models.common import decode_attention
from repro.serving import EngineConfig, TTQEngine

RNG = np.random.default_rng(3)


def _cache(B=2, Hkv=2, S=64, Dh=16):
    k = jnp.asarray(RNG.standard_normal((B, Hkv, S, Dh)).astype("float32"))
    v = jnp.asarray(RNG.standard_normal((B, Hkv, S, Dh)).astype("float32"))
    return k, v


# ---------------------------------------------------------------- codec

def test_kv_roundtrip_error_small():
    k, _ = _cache()
    q, s = quantize_kv(k)
    kd = dequantize_kv(q, s, jnp.float32)
    rel = float(jnp.abs(k - kd).max() / jnp.abs(k).max())
    assert rel < 0.02                      # ~1/127 per-row relative error


def test_kv_int4_roundtrip():
    k, _ = _cache()
    q, s = quantize_kv(k, bits=4)
    assert q.dtype == jnp.int32 and q.shape[-1] == k.shape[-1] // 8
    kd = dequantize_kv(q, s, jnp.float32, bits=4)
    rel = float(jnp.abs(k - kd).max() / jnp.abs(k).max())
    assert rel < 0.15                      # ~1/7 per-row relative error


def test_kv_grouped_scales_tighter():
    """Finer scale groups never lose to per-row scales (outlier rows win)."""
    k = jnp.asarray(RNG.standard_normal((1, 2, 8, 32)).astype("float32"))
    k = k.at[0, 0, :, 0].mul(50.0)         # one outlier channel per row
    err = {}
    for g in (0, 8):
        q, s = quantize_kv(k, bits=8, group_size=g)
        kd = dequantize_kv(q, s, jnp.float32, bits=8, group_size=g)
        # channels outside the outlier's scale group
        err[g] = float(jnp.abs(k - kd)[0, 0, :, 8:].mean())
    assert err[8] < err[0] * 0.5


def test_q8_attention_matches_fp():
    B, Hkv, S, Dh, H = 2, 2, 64, 16, 4
    k, v = _cache(B, Hkv, S, Dh)
    qv = jnp.asarray(RNG.standard_normal((B, H, 1, Dh)).astype("float32"))
    pos = jnp.asarray([40, 63], jnp.int32)
    o_fp = decode_attention(qv, k, v, pos)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    o_q8 = decode_attention_q8(qv, kq, ks, vq, vs, pos)
    np.testing.assert_allclose(np.asarray(o_fp, np.float32),
                               np.asarray(o_q8, np.float32),
                               rtol=0.05, atol=0.05)


def test_q8_halves_cache_bytes():
    k, _ = _cache(S=128, Dh=128)                       # production head dim
    q, s = quantize_kv(k)
    fp_bytes = k.size * 2                              # bf16 production cache
    q8_bytes = q.size * 1 + s.size * 4
    assert q8_bytes < 0.6 * fp_bytes


def test_kvcacheconfig_bytes_model():
    assert KVCacheConfig("int8").bytes_per_token_head(128) == 128 + 4
    assert KVCacheConfig("int4").bytes_per_token_head(128) == 64 + 4
    assert KVCacheConfig().bytes_per_token_head(128) == 256
    with pytest.raises(ValueError):
        KVCacheConfig("fp8")


# ------------------------------------------------- kernel vs jnp oracle

@pytest.mark.parametrize("bits,group_size", [(8, 0), (8, 16), (4, 0), (4, 16)])
def test_ttq_attn_kernel_matches_ref(bits, group_size):
    """Pallas fused dequant-attention (interpret on CPU) vs kv_attn_ref."""
    B, Hkv, S, Dh, H = 2, 2, 100, 32, 4
    k, v = _cache(B, Hkv, S, Dh)
    qv = jnp.asarray(RNG.standard_normal((B, H, 1, Dh)).astype("float32"))
    pos = jnp.asarray([37, 99], jnp.int32)
    kq, ks = quantize_kv(k, bits=bits, group_size=group_size)
    vq, vs = quantize_kv(v, bits=bits, group_size=group_size)
    o_ref = kv_attn_ref(qv, kq, ks, vq, vs, pos, bits=bits,
                        group_size=group_size)
    o_pl = kv_decode_attention(qv, kq, ks, vq, vs, pos, bits=bits,
                               group_size=group_size, bs=32)
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_pl, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_ttq_attn_kernel_soft_cap_and_single_tile():
    B, Hkv, S, Dh, H = 1, 2, 48, 16, 4
    k, v = _cache(B, Hkv, S, Dh)
    qv = jnp.asarray(RNG.standard_normal((B, H, 1, Dh)).astype("float32"))
    pos = jnp.asarray([20], jnp.int32)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    o_ref = kv_attn_ref(qv, kq, ks, vq, vs, pos, soft_cap=30.0)
    o_pl = kv_decode_attention(qv, kq, ks, vq, vs, pos, soft_cap=30.0, bs=64)
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_pl, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_ttq_attn_matches_fp_attention():
    """Fused int8 read stays within quantization tolerance of the bf16 path."""
    B, Hkv, S, Dh, H = 2, 2, 64, 16, 4
    k, v = _cache(B, Hkv, S, Dh)
    qv = jnp.asarray(RNG.standard_normal((B, H, 1, Dh)).astype("float32"))
    pos = jnp.asarray([40, 63], jnp.int32)
    o_fp = decode_attention(qv, k, v, pos)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    o = kv_decode_attention(qv, kq, ks, vq, vs, pos, bs=32)
    np.testing.assert_allclose(np.asarray(o_fp, np.float32),
                               np.asarray(o, np.float32),
                               rtol=0.05, atol=0.05)


# ------------------------------------------------- engine integration

CFG = ModelConfig(name="kv-t", family="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=96, vocab=128)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(CFG, jax.random.PRNGKey(0))


def _engine(params, kv_dtype, max_slots=2, use_pallas=True):
    pol = NO_QUANT.with_(kvcache=KVCacheConfig(dtype=kv_dtype,
                                               use_pallas=use_pallas))
    return TTQEngine(CFG, params, pol,
                     EngineConfig(max_slots=max_slots, max_len=64))


PROMPTS = [[5, 9, 17, 3], [8, 8, 1], [100, 50, 25, 12, 6, 3]]


def _run(eng, prompts=PROMPTS, max_new=6):
    rids = [eng.submit(p, max_new=max_new) for p in prompts]
    outs = eng.run_all()
    return [outs[r] for r in rids]


@pytest.mark.parametrize("kv_dtype", ["int8", "int4"])
def test_engine_quant_cache_decode_matches_bf16(params, kv_dtype):
    """End-to-end quality check on LOGITS (greedy tokens can legitimately
    flip on near-ties): prefill + decode steps, quantized cache vs bf16.
    The bound is *range-normalized* (max |Δlogit| as a fraction of the bf16
    logit spread — scale-free, so it stays meaningful): int8 ≤ 3%, int4
    ≤ 60% (measured ~1% / ~40% on this model; a broken codec or scale
    layout lands ≥ the full range).  EXPERIMENTS.md §Roofline "quality"
    rows — since the paged-KV PR the prefill read also sees the
    quantize→dequantize values, so its noise is included here."""
    toks = jnp.asarray([[5, 9, 17, 3]], jnp.int32)
    out = {}
    for kvd in ("bf16", kv_dtype):
        kvcfg = KVCacheConfig(dtype=kvd)
        lg, state, _ = lm.prefill(CFG, params, {"tokens": toks}, max_len=32,
                                  kvcfg=kvcfg)         # last-token logits (B,V)
        logits = [lg]
        tok = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        pos = jnp.asarray([toks.shape[1]], jnp.int32)
        for _ in range(4):
            lg1, state = lm.decode_step(CFG, params, state, tok, pos,
                                        kvcfg=kvcfg)
            logits.append(lg1)
            tok = jnp.argmax(lg1, axis=-1)[:, None].astype(jnp.int32)
            pos = pos + 1
        out[kvd] = jnp.stack(logits)
    ref = np.asarray(out["bf16"], np.float32)
    err = np.abs(np.asarray(out[kv_dtype], np.float32) - ref).max()
    spread = ref.max() - ref.min()
    tol = 0.03 if kv_dtype == "int8" else 0.6
    assert err <= tol * spread, (
        f"{kv_dtype} logits drift {err:.3f} exceeds {tol:.0%} of the bf16 "
        f"logit range {spread:.3f}")


def test_engine_int8_cache_end_to_end(params):
    """Greedy generations over the int8 engine match the bf16 engine on a
    well-separated model (same RNG, same admission order)."""
    o_bf = _run(_engine(params, "bf16"))
    o_i8 = _run(_engine(params, "int8"))
    assert o_bf == o_i8


def test_engine_int8_fallback_matches_pallas(params):
    """use_pallas=False (pure-jnp oracle read) is decode-path equivalent."""
    o_pl = _run(_engine(params, "int8", use_pallas=True))
    o_np = _run(_engine(params, "int8", use_pallas=False))
    assert o_pl == o_np


def test_engine_quant_cache_layout(params):
    eng = _engine(params, "int4")
    _run(eng, prompts=[[5, 9, 17, 3]], max_new=3)
    st = eng.state["stack"][0]["u0"]
    assert sorted(st.keys()) == ["k_q", "k_s", "v_q", "v_s"]
    assert st["k_q"].dtype == jnp.int32           # packed 8 nibbles / int32
    assert st["k_q"].shape[-1] == CFG.hd // 8
    assert st["k_s"].dtype == jnp.float32


def test_engine_mixed_slots_per_slot_scales(params):
    """A request admitted mid-generation lands in its own slot with its own
    scale rows: both outputs match their single-request int8 references, and
    the newly admitted slot's scales are populated while the other slot's
    rows are untouched."""
    eng = _engine(params, "int8", max_slots=2)
    r1 = eng.submit(PROMPTS[0], max_new=8)
    for _ in range(3):
        eng.step()                      # r1 decoding alone
    scales_before = np.asarray(eng.state["stack"][0]["u0"]["k_s"])
    pos0 = int(eng.pos[0])              # slot 0 writes THIS row next step
    r2 = eng.submit(PROMPTS[1], max_new=5)
    eng.step()                          # admits r2 into slot 1
    scales_after = np.asarray(eng.state["stack"][0]["u0"]["k_s"])
    assert scales_after.shape[1] == 2   # (R, B, Hkv, S, 1) — B is axis 1
    plen1 = len(PROMPTS[1])
    assert (scales_after[:, 1, :, :plen1] > 0).all()
    # slot 0's already-written rows untouched by slot-1 admission
    np.testing.assert_array_equal(scales_before[:, 0, :, :pos0],
                                  scales_after[:, 0, :, :pos0])
    outs = eng.run_all()
    ref1 = _run(_engine(params, "int8", max_slots=1),
                prompts=[PROMPTS[0]], max_new=8)[0]
    ref2 = _run(_engine(params, "int8", max_slots=1),
                prompts=[PROMPTS[1]], max_new=5)[0]
    assert outs[r1] == ref1
    assert outs[r2] == ref2
