"""int8 KV-cache quantization (beyond-paper extension)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kvquant import decode_attention_q8, dequantize_kv, quantize_kv
from repro.models.common import decode_attention

RNG = np.random.default_rng(3)


def _cache(B=2, Hkv=2, S=64, Dh=16):
    k = jnp.asarray(RNG.standard_normal((B, Hkv, S, Dh)).astype("float32"))
    v = jnp.asarray(RNG.standard_normal((B, Hkv, S, Dh)).astype("float32"))
    return k, v


def test_kv_roundtrip_error_small():
    k, _ = _cache()
    q, s = quantize_kv(k)
    kd = dequantize_kv(q, s, jnp.float32)
    rel = float(jnp.abs(k - kd).max() / jnp.abs(k).max())
    assert rel < 0.02                      # ~1/127 per-row relative error


def test_q8_attention_matches_fp():
    B, Hkv, S, Dh, H = 2, 2, 64, 16, 4
    k, v = _cache(B, Hkv, S, Dh)
    qv = jnp.asarray(RNG.standard_normal((B, H, 1, Dh)).astype("float32"))
    pos = jnp.asarray([40, 63], jnp.int32)
    o_fp = decode_attention(qv, k, v, pos)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    o_q8 = decode_attention_q8(qv, kq, ks, vq, vs, pos)
    np.testing.assert_allclose(np.asarray(o_fp, np.float32),
                               np.asarray(o_q8, np.float32),
                               rtol=0.05, atol=0.05)


def test_q8_halves_cache_bytes():
    k, _ = _cache(S=128, Dh=128)                       # production head dim
    q, s = quantize_kv(k)
    fp_bytes = k.size * 2                              # bf16 production cache
    q8_bytes = q.size * 1 + s.size * 4
    assert q8_bytes < 0.6 * fp_bytes
