"""Paged KV cache: pool/block-table layout, the paged Pallas kernel vs its
oracle, the block allocator + prefix trie lifecycle, and the engine-level
guarantees — paged ⇔ dense greedy equivalence (all KV dtypes, kernels
on/off), preemption + requeue, prefix-cache hits, and request cancellation
(DESIGN.md §8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KVCacheConfig, NO_QUANT
from repro.core.kvquant import quantize_kv
from repro.kernels import kv_paged_decode_attention
from repro.kernels.ref import gather_paged_kv, kv_attn_ref, kv_paged_attn_ref
from repro.models import ModelConfig, lm
from repro.serving import EngineConfig, TTQEngine
from repro.serving.blocks import SINK, BlockAllocator, chain_hashes

RNG = np.random.default_rng(7)

CFG = ModelConfig(name="paged-t", family="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=96, vocab=128)

PROMPTS = [[5, 9, 17, 3], [8, 8, 1], [100, 50, 25, 12, 6, 3], [7, 7, 7, 2]]


@pytest.fixture(scope="module")
def params():
    return lm.init_params(CFG, jax.random.PRNGKey(0))


def _engine(params, kv_dtype="bf16", paged=True, use_pallas=True, slots=2,
            **kw):
    pol = NO_QUANT.with_(kvcache=KVCacheConfig(dtype=kv_dtype, paged=paged,
                                               use_pallas=use_pallas))
    return TTQEngine(CFG, params, pol,
                     EngineConfig(max_slots=slots, max_len=64, **kw))


def _run(eng, prompts=PROMPTS, max_new=8):
    rids = [eng.submit(p, max_new=max_new) for p in prompts]
    outs = eng.run_all()
    return [outs[r] for r in rids]


_DENSE_REF = {}


def _dense_ref(params, kv_dtype):
    if kv_dtype not in _DENSE_REF:
        _DENSE_REF[kv_dtype] = _run(_engine(params, kv_dtype, paged=False))
    return _DENSE_REF[kv_dtype]


# ----------------------------------------------------------- paged kernel

@pytest.mark.parametrize("bits,group_size", [(8, 0), (8, 16), (4, 0), (4, 16)])
def test_paged_kernel_matches_ref(bits, group_size):
    """Pallas paged flash-decoding (scalar-prefetched block table) vs the
    gather-then-contiguous jnp oracle."""
    B, Hkv, H, Dh, bs, NB = 2, 2, 4, 32, 16, 9
    pk = jnp.asarray(RNG.standard_normal((NB, Hkv, bs, Dh)).astype("float32"))
    pv = jnp.asarray(RNG.standard_normal((NB, Hkv, bs, Dh)).astype("float32"))
    kq, ks = quantize_kv(pk, bits=bits, group_size=group_size)
    vq, vs = quantize_kv(pv, bits=bits, group_size=group_size)
    bt = jnp.asarray([[3, 1, 4, SINK], [5, 2, SINK, SINK]], jnp.int32)
    pos = jnp.asarray([41, 17], jnp.int32)
    q = jnp.asarray(RNG.standard_normal((B, H, 1, Dh)).astype("float32"))
    o_ref = kv_paged_attn_ref(q, kq, ks, vq, vs, bt, pos, bits=bits,
                              group_size=group_size)
    o_pl = kv_paged_decode_attention(q, kq, ks, vq, vs, bt, pos, bits=bits,
                                     group_size=group_size)
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_pl, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_paged_gather_equals_contiguous():
    """A block table laid out 0..n gathers back the contiguous cache, and
    the paged oracle equals the contiguous oracle on it."""
    B, Hkv, S, Dh, bs, H = 2, 2, 64, 16, 16, 4
    k = jnp.asarray(RNG.standard_normal((B, Hkv, S, Dh)).astype("float32"))
    # lay the contiguous cache into a pool, slot b owning blocks b*4..b*4+3
    pool = k.reshape(B, Hkv, S // bs, bs, Dh).transpose(0, 2, 1, 3, 4) \
            .reshape(B * (S // bs), Hkv, bs, Dh)
    bt = jnp.arange(B * (S // bs), dtype=jnp.int32).reshape(B, S // bs)
    np.testing.assert_array_equal(np.asarray(gather_paged_kv(pool, bt)),
                                  np.asarray(k))
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(k * 0.5)
    pq, psc = quantize_kv(pool), None
    pqv, pvs = quantize_kv(pool * 0.5)
    q = jnp.asarray(RNG.standard_normal((B, H, 1, Dh)).astype("float32"))
    pos = jnp.asarray([40, 63], jnp.int32)
    o_c = kv_attn_ref(q, kq, ks, vq, vs, pos)
    o_p = kv_paged_attn_ref(q, pq[0], pq[1], pqv, pvs, bt, pos)
    np.testing.assert_allclose(np.asarray(o_c, np.float32),
                               np.asarray(o_p, np.float32), rtol=1e-6,
                               atol=1e-6)


# ------------------------------------------------------------- allocator

def test_allocator_prefix_trie_walk_hand_computed():
    """Hit/miss accounting matches a hand-computed trie walk: only full
    blocks strictly before the last prompt token are shareable; the chain
    hash makes the match positional, not content-only."""
    a = BlockAllocator(num_blocks=32, block_size=4)
    p1 = list(range(100, 113))          # 13 tokens → 3 shareable blocks
    b1, pfx1 = a.allocate(p1, max_new=4, max_len=64)
    assert pfx1 == 0 and len(b1) == 5   # ceil((13+4)/4)
    assert (a.prefix_hits, a.prefix_misses) == (0, 3)
    # same first 8 tokens, diverges in block 2 → 2 hits, 1 miss
    p2 = p1[:8] + [1, 2, 3, 4, 5]
    b2, pfx2 = a.allocate(p2, max_new=4, max_len=64)
    assert pfx2 == 8 and b2[:2] == b1[:2] and b2[2] != b1[2]
    assert (a.prefix_hits, a.prefix_misses) == (2, 4)
    assert a.ref[b1[0]] == 2            # shared block ref-counted
    # same CONTENT in block 0 but shifted position → no hit (chain hash)
    p3 = [0] + p1[:7]
    b3, pfx3 = a.allocate(p3, max_new=1, max_len=64)
    assert pfx3 == 0
    assert (a.prefix_hits, a.prefix_misses) == (2, 5)
    a.free_request(b1)
    a.free_request(b2)
    a.free_request(b3)
    a.assert_quiescent()


def test_allocator_cached_blocks_survive_owner():
    """Prefix reuse survives the first owner's lifetime: freed shareable
    blocks park in the cached LRU pool and a later identical prompt revives
    them without re-prefill."""
    a = BlockAllocator(num_blocks=16, block_size=4)
    p = list(range(1, 10))              # 9 tokens → 2 shareable blocks
    b1, _ = a.allocate(p, max_new=2, max_len=64)
    a.free_request(b1)
    assert not a.ref and len(a.cached) == 2
    b2, pfx = a.allocate(p, max_new=2, max_len=64)
    assert pfx == 8 and b2[:2] == b1[:2]
    a.free_request(b2)
    a.assert_quiescent()


def test_allocator_exhaustion_is_atomic():
    """A failing allocation must not leak partial reservations — including
    the shared-cached-revival corner (a cached shared block is not 'still
    available' once revived)."""
    a = BlockAllocator(num_blocks=6, block_size=4)      # 5 allocatable
    p = list(range(1, 13))                              # 3 blocks, 2 shareable
    b1, _ = a.allocate(p, max_new=0, max_len=64)
    a.free_request(b1)                                  # 2 cached + 3 free
    b2, _ = a.allocate(p[:8], max_new=4, max_len=64)    # revives 1 + takes 2
    with pytest.raises(MemoryError):
        a.allocate(list(range(50, 62)), max_new=8, max_len=64)  # needs 5
    hits, misses = a.prefix_hits, a.prefix_misses
    with pytest.raises(MemoryError):                    # retry: same counts
        a.allocate(list(range(50, 62)), max_new=8, max_len=64)
    assert (a.prefix_hits, a.prefix_misses) == (hits, misses)
    a.free_request(b2)
    a.assert_quiescent()


def test_allocator_reregistration_keeps_trie_consistent():
    """A hash can be re-registered while its OLD block still sits cached
    (the chain broke earlier — the head was evicted — so the walk never
    reached it): the old block must be unhooked at registration, or its
    later reclaim tears down the NEW block's live trie entry and the new
    block's own reclaim then KeyErrors (regression: crashed the engine
    under pool pressure)."""
    a = BlockAllocator(num_blocks=10, block_size=4)     # 9 allocatable
    p = list(range(1, 10))                              # 2 shareable blocks
    b1, _ = a.allocate(p, max_new=0, max_len=64)
    a.free_request(b1)                                  # h0, h1 blocks cached
    # evict ONLY the chain head: an 8-block unshareable request (4-token
    # prompt → nothing registered) drains free (7) + the LRU cached head
    b2, _ = a.allocate([91, 92, 93, 94], max_new=28, max_len=64)
    assert b1[0] in b2 and b1[1] not in b2              # old h1 block cached
    a.free_request(b2)                                  # all straight to free
    # re-admit p: h0 misses → h0 AND h1 re-register from the free list
    # while the old h1 block still sits cached (stale reverse mapping)
    b3, pfx = a.allocate(p, max_new=0, max_len=64)
    assert pfx == 0                     # head was evicted → full re-prefill
    # reclaim the stale old-h1 block ...
    b4, _ = a.allocate([81, 82, 83, 84], max_new=20, max_len=64)
    a.free_request(b3)
    a.free_request(b4)
    # ... then churn enough to reclaim the NEW h1 block too — pre-fix this
    # raised KeyError in _take (its trie entry was already torn down)
    b5, _ = a.allocate([71, 72, 73, 74], max_new=32, max_len=64)
    a.free_request(b5)
    assert set(a.trie.values()) == set(a.block_hash)
    a.assert_quiescent()


def test_chain_hash_positional():
    h1 = chain_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4, 2)
    h2 = chain_hashes([1, 2, 3, 4, 9, 9, 9, 9], 4, 2)
    assert h1[0] == h2[0] and h1[1] != h2[1]


# --------------------------------------------------- engine: equivalence

@pytest.mark.parametrize("kv_dtype", ["bf16", "int8", "int4"])
def test_engine_paged_matches_dense(params, kv_dtype):
    """Greedy decode tokens identical with KVCacheConfig.paged on/off —
    the e2e smoke for every KV dtype (CI fast tier)."""
    assert _run(_engine(params, kv_dtype)) == _dense_ref(params, kv_dtype)


@pytest.mark.parametrize("kv_dtype", ["int8", "int4"])
def test_engine_paged_fallback_matches_pallas(params, kv_dtype):
    """use_pallas=False (gather + jnp oracle read) is decode-equivalent to
    the scalar-prefetch Pallas kernel."""
    o_pl = _run(_engine(params, kv_dtype, use_pallas=True),
                prompts=PROMPTS[:2], max_new=6)
    o_np = _run(_engine(params, kv_dtype, use_pallas=False),
                prompts=PROMPTS[:2], max_new=6)
    assert o_pl == o_np


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_preemption_requeue_matches_unconstrained(params, kv_dtype):
    """A pool too small for the workload preempts (evict + requeue) instead
    of crashing, and the multi-slot greedy outputs still match the
    unconstrained dense run exactly."""
    eng = _engine(params, kv_dtype, kv_block_size=4, kv_pool_blocks=7)
    out = _run(eng)
    assert out == _dense_ref(params, kv_dtype)
    assert eng.preemptions > 0
    assert eng.kv_pool_utilization == 1.0
    eng.allocator.assert_quiescent()        # every block freed after run_all


def test_paged_pool_and_block_table_layout(params):
    eng = _engine(params, "int4")
    _run(eng, prompts=[PROMPTS[0]], max_new=3)
    st = eng.state["stack"][0]["u0"]
    NB = eng.num_blocks
    bs = eng.kvcfg.block_size
    assert st["k_q"].shape[1:] == (NB, CFG.n_kv_heads, bs, CFG.hd // 8)
    assert st["k_q"].dtype == jnp.int32
    assert st["k_s"].shape[1:] == (NB, CFG.n_kv_heads, bs, 1)
    bt = np.asarray(eng.state["block_table"])
    assert bt.shape == (2, eng.ecfg.max_len // bs)
    assert (bt == SINK).all()               # finished slots point at the sink


# --------------------------------------------------- engine: prefix cache

@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_prefix_cache_outputs_unchanged(params, kv_dtype):
    """Two requests sharing a ≥1-block system prompt: the second prefills
    only its tail (prefix_hit_rate > 0) and both outputs match the cold
    (prefix_cache=False) engine exactly."""
    sysp = list(range(1, 21))               # 20 tokens → 1 shareable block
    ps = [sysp + [40, 41], sysp + [50, 51, 52]]
    cold_eng = _engine(params, kv_dtype, prefix_cache=False)
    cold = _run(cold_eng, prompts=ps, max_new=6)
    assert cold_eng.prefix_hit_rate == 0.0
    warm_eng = _engine(params, kv_dtype)
    warm = _run(warm_eng, prompts=ps, max_new=6)
    assert warm == cold
    assert warm_eng.prefix_hit_rate > 0
    warm_eng.allocator.assert_quiescent()


def test_same_round_prefix_hit_reads_written_blocks(params):
    """Group-ordering hazard (regression): in one admission round, D (old
    cached prefix) creates group (16, 32) first, then A registers fresh
    sysA blocks, then B's walk hits A's just-registered blocks and joins
    D's *earlier* group.  Groups must dispatch in ascending prefix_len
    order (reader prefix_len > writer prefix_len along a chain — a
    topological order), else B's gather reads A's still-zero pool blocks
    and silently emits wrong tokens.  Outputs must match the
    prefix_cache=False engine exactly AND B's same-round hit must count."""
    sysD, sysA = list(range(1, 33)), list(range(60, 92))
    eng = _engine(params, "bf16", slots=3)
    r0 = eng.submit(sysD + [40, 41], max_new=4)
    eng.run_all()                               # seeds D's cached prefix
    reqs = [sysD + [42, 43], sysA + [50, 51], sysA + [52, 53]]
    rids = [eng.submit(p, max_new=5) for p in reqs]
    outs = eng.run_all()
    cold = _engine(params, "bf16", slots=3, prefix_cache=False)
    c0 = cold.submit(sysD + [40, 41], max_new=4)
    cold.run_all()
    crids = [cold.submit(p, max_new=5) for p in reqs]
    couts = cold.run_all()
    assert [outs[r] for r in rids] == [couts[r] for r in crids]
    assert eng.allocator.prefix_hits == 4       # D: 2 old + B: 2 same-round
    eng.allocator.assert_quiescent()


def test_prefix_hits_across_request_lifetimes(params):
    """The second request arrives after the first finished — its prefix
    blocks come from the cached (ref 0) pool, not from a live request."""
    sysp = list(range(1, 33))               # 32 tokens → 1 shareable block
    eng = _engine(params, "bf16", slots=1)
    r1 = eng.submit(sysp + [40], max_new=3)
    o1 = eng.run_all()
    assert not o1[r1].unfinished
    r2 = eng.submit(sysp + [50, 51], max_new=3)
    eng.run_all()
    assert eng.allocator.prefix_hits == 2   # exactly the two sysp blocks
    eng.allocator.assert_quiescent()


# --------------------------------------------------------- engine: cancel

def test_cancel_queued_and_running(params):
    eng = _engine(params, "bf16")
    r1 = eng.submit(PROMPTS[0], max_new=20)
    r2 = eng.submit(PROMPTS[1], max_new=20)
    r3 = eng.submit(PROMPTS[3], max_new=5)      # queued behind 2 slots
    for _ in range(2):
        eng.step()
    assert eng.cancel(r3)                       # queued: never ran
    assert eng.cancel(r1)                       # running: slot + blocks free
    outs = eng.run_all()
    assert outs[r1].cancelled and outs[r1].unfinished
    assert outs[r3].cancelled and len(outs[r3]) == 0
    assert not outs[r2].cancelled and len(outs[r2]) == 20
    assert not eng.cancel(r1)                   # already finished → False
    assert not eng.cancel(9999)                 # unknown rid
    eng.allocator.assert_quiescent()


def test_cancel_dense_engine(params):
    """cancel() also works on the dense slab (slot freed, no allocator)."""
    eng = _engine(params, "bf16", paged=False)
    r1 = eng.submit(PROMPTS[0], max_new=20)
    eng.step()
    assert eng.cancel(r1)
    outs = eng.run_all()
    assert outs[r1].cancelled


# ------------------------------------------------------------ validation

def test_paged_validation(params):
    with pytest.raises(ValueError, match="divide"):
        _engine(params, "bf16", kv_block_size=48)   # 64 % 48 != 0
    from repro.models import stack as S
    with pytest.raises(ValueError, match="plain attention"):
        S.layer_state(CFG, "ssd", 1, 64, KVCacheConfig(paged=True), 5)
    eng = _engine(params, "bf16", kv_block_size=16, kv_pool_blocks=3)
    with pytest.raises(ValueError, match="KV blocks"):
        eng.submit(list(range(1, 50)), max_new=16)  # needs 4 > 2 allocatable
