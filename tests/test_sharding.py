"""Distribution: sharding rules, MoE a2a == dense, dry-run machinery on a
small mesh, multi-pod axis — all in subprocesses with fake devices."""
import numpy as np
import pytest

from repro.parallel.rules import spec_for_path


def test_spec_rules():
    import jax
    P = jax.sharding.PartitionSpec
    assert spec_for_path("stack.0.u0.mix.wq", 3, "model") == P(None, "model", None)
    assert spec_for_path("stack.0.u0.mix.wo", 3, "model") == P(None, None, "model")
    assert spec_for_path("embed", 2, "model", stacked=False) == P("model", None)
    assert spec_for_path("stack.0.u0.mlp.experts.wg", 4, "model") == \
        P(None, "model", None, None)
    assert spec_for_path("stack.0.u0.ln1.gamma", 2, "model") == P(None, None)


def test_moe_a2a_equals_dense(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import lm, ModelConfig, MoECfg
from repro.parallel import ParallelCtx
mesh = jax.make_mesh((2, 2), ('data', 'model'))
cfg = ModelConfig(name='t', family='moe', n_layers=2, d_model=64, n_heads=4,
      n_kv_heads=2, d_ff=0, vocab=128,
      moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=64, n_shared=1,
                 capacity_factor=8.0))
params = lm.init_params(cfg, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 128)
lg_d, st_d, _ = lm.forward(cfg, params, {'tokens': toks}, collect_stats=True)
pctx = ParallelCtx(mesh=mesh, data_axes=('data',), model_axis='model')
with mesh:
    lg_a, st_a, _ = lm.forward(cfg, params, {'tokens': toks},
                               collect_stats=True, pctx=pctx)
np.testing.assert_allclose(np.asarray(lg_d), np.asarray(lg_a), rtol=6e-2, atol=6e-2)
sd = np.asarray(st_d['stack'][0]['u0.mlp.experts.wg']).ravel()
sa = np.asarray(st_a['stack'][0]['u0.mlp.experts.wg']).ravel()
# dense weights stats by gate mass, a2a counts routed tokens with weight 1 —
# same assignment structure, different weighting: require strong correlation
assert np.corrcoef(sd, sa)[0, 1] > 0.9
print('OK')
""", devices=4)
    assert "OK" in out


def test_sharded_train_step_runs(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.models import ModelConfig
from repro.training import Trainer, TrainConfig
from repro.data import DataConfig, token_stream
from repro.parallel import ParallelCtx
mesh = jax.make_mesh((2, 4), ('data', 'model'))
pctx = ParallelCtx(mesh=mesh, data_axes=('data',))
cfg = ModelConfig(name='t', family='dense', n_layers=2, d_model=64, n_heads=8,
                  n_kv_heads=4, d_ff=128, vocab=64)
dc = DataConfig(vocab=64, seq_len=32, batch=8, seed=1)
tc = TrainConfig(n_microbatches=2, remat=True, zero1=True, total_steps=20, warmup=2)
with mesh:
    tr = Trainer(cfg, tc, token_stream(dc, 0), pctx=pctx)
    log = tr.run(4)
assert log[-1]['loss'] < log[0]['loss'] + 0.1
# ZeRO-1: master leaves carry a data-sharded dim
specs = [l.sharding.spec for l in jax.tree.leaves(tr.opt_state['m'])]
assert any('data' in str(s) for s in specs), specs
print('OK')
""", devices=8)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_machinery_multipod(subproc):
    """(pod, data, model) mesh: lower+compile train/prefill/decode for three
    representative smoke archs — the multi-pod axis proof at test scale."""
    out = subproc("""
import jax
import repro.configs as C
C.SHAPES = {'train_4k': (64, 8, 'train'), 'prefill_32k': (64, 4, 'prefill'),
            'decode_32k': (64, 8, 'decode'), 'long_500k': (128, 1, 'decode')}
import repro.launch.steps as S
S.SHAPES = C.SHAPES
from repro.launch.mesh import make_ctx
from repro.configs import get
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
pctx = make_ctx(mesh)
for arch in ['gemma_7b', 'deepseek_v2_lite_16b', 'mamba2_1p3b']:
    cfg = get(arch, smoke=True)
    for shape, kind in [('train_4k', 'train'), ('decode_32k', 'decode')]:
        if kind == 'train':
            fn, args, _ = S.build_train_cell(cfg, pctx, shape)
        else:
            fn, args, _ = S.build_decode_cell(cfg, pctx, shape)
        with mesh:
            fn.lower(*args).compile()
        print(arch, shape, 'OK')
print('ALLOK')
""", devices=8, timeout=900)
    assert "ALLOK" in out
