"""Distribution: sharding rules, MoE a2a == dense, dry-run machinery on a
small mesh, multi-pod axis — all in subprocesses with fake devices; plus
hypothesis property coverage of the pure spec logic (no devices needed)."""
import numpy as np
import pytest

from repro.parallel.rules import divisible_spec, qt_specs, spec_for_path


def test_spec_rules():
    import jax
    P = jax.sharding.PartitionSpec
    assert spec_for_path("stack.0.u0.mix.wq", 3, "model") == P(None, "model", None)
    assert spec_for_path("stack.0.u0.mix.wo", 3, "model") == P(None, None, "model")
    assert spec_for_path("embed", 2, "model", stacked=False) == P("model", None)
    assert spec_for_path("stack.0.u0.mlp.experts.wg", 4, "model") == \
        P(None, "model", None, None)
    assert spec_for_path("stack.0.u0.ln1.gamma", 2, "model") == P(None, None)


def test_moe_a2a_equals_dense(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import lm, ModelConfig, MoECfg
from repro.parallel import ParallelCtx
mesh = jax.make_mesh((2, 2), ('data', 'model'))
cfg = ModelConfig(name='t', family='moe', n_layers=2, d_model=64, n_heads=4,
      n_kv_heads=2, d_ff=0, vocab=128,
      moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=64, n_shared=1,
                 capacity_factor=8.0))
params = lm.init_params(cfg, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 128)
lg_d, st_d, _ = lm.forward(cfg, params, {'tokens': toks}, collect_stats=True)
pctx = ParallelCtx(mesh=mesh, data_axes=('data',), model_axis='model')
with mesh:
    lg_a, st_a, _ = lm.forward(cfg, params, {'tokens': toks},
                               collect_stats=True, pctx=pctx)
np.testing.assert_allclose(np.asarray(lg_d), np.asarray(lg_a), rtol=6e-2, atol=6e-2)
sd = np.asarray(st_d['stack'][0]['u0.mlp.experts.wg']).ravel()
sa = np.asarray(st_a['stack'][0]['u0.mlp.experts.wg']).ravel()
# dense weights stats by gate mass, a2a counts routed tokens with weight 1 —
# same assignment structure, different weighting: require strong correlation
assert np.corrcoef(sd, sa)[0, 1] > 0.9
print('OK')
""", devices=4)
    assert "OK" in out


def test_sharded_train_step_runs(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.models import ModelConfig
from repro.training import Trainer, TrainConfig
from repro.data import DataConfig, token_stream
from repro.parallel import ParallelCtx
mesh = jax.make_mesh((2, 4), ('data', 'model'))
pctx = ParallelCtx(mesh=mesh, data_axes=('data',))
cfg = ModelConfig(name='t', family='dense', n_layers=2, d_model=64, n_heads=8,
                  n_kv_heads=4, d_ff=128, vocab=64)
dc = DataConfig(vocab=64, seq_len=32, batch=8, seed=1)
tc = TrainConfig(n_microbatches=2, remat=True, zero1=True, total_steps=20, warmup=2)
with mesh:
    tr = Trainer(cfg, tc, token_stream(dc, 0), pctx=pctx)
    log = tr.run(4)
assert log[-1]['loss'] < log[0]['loss'] + 0.1
# ZeRO-1: master leaves carry a data-sharded dim
specs = [l.sharding.spec for l in jax.tree.leaves(tr.opt_state['m'])]
assert any('data' in str(s) for s in specs), specs
print('OK')
""", devices=8)
    assert "OK" in out


# --------------------------------------------------------------- properties
# Pure spec logic: qt_specs/divisible_spec only read mesh.shape, so a fake
# mesh object drives them without any devices (or even importing a backend).
# Module-level importorskip (the test_property.py idiom) would skip the whole
# file — including the non-hypothesis tests above — so gate only this section.

try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in minimal envs
    _HAS_HYPOTHESIS = False

    def given(*_a, **_k):    # decorators must exist for the defs below
        return lambda f: pytest.mark.skip(
            reason="property tests need hypothesis (requirements-dev.txt)")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class st:                # noqa: N801 - stand-in for hypothesis.strategies
        @staticmethod
        def integers(*_a, **_k):
            return None

        @staticmethod
        def sampled_from(*_a, **_k):
            return None

        @staticmethod
        def booleans(*_a, **_k):
            return None

SET = settings(max_examples=50, deadline=None)


class _FakeMesh:
    def __init__(self, data, model):
        self.shape = {"data": data, "model": model}


# representative param paths covering every rule family (row, col, expert,
# replicated) both inside and outside the layer stack
_PATHS = [
    "embed", "lm_head",
    "stack.0.u0.mix.wq", "stack.0.u0.mix.wo", "stack.0.u0.mix.wkv_b",
    "stack.0.u0.mix.w_in", "stack.0.u0.mix.w_out",
    "stack.0.u0.mlp.wg", "stack.0.u0.mlp.wd", "stack.0.u0.mlp.w1",
    "stack.0.u0.mlp.w2", "stack.0.u0.mlp.experts.wg",
    "stack.0.u0.mlp.experts.wd", "stack.0.u0.mlp.shared.wg",
    "stack.0.u0.ln1.gamma", "stack.0.u0.mix.qnorm.gamma",
]


def _axis_n(mesh, ax):
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


@SET
@given(st.integers(0, 2**31 - 1), st.sampled_from(_PATHS),
       st.integers(1, 4), st.sampled_from([1, 2, 3, 4, 8]))
def test_divisible_spec_always_divides(seed, path, ndim, model):
    """Every axis that survives divisible_spec divides its dim exactly."""
    rng = np.random.default_rng(seed)
    mesh = _FakeMesh(int(rng.integers(1, 5)), model)
    shape = tuple(int(rng.integers(1, 65)) for _ in range(ndim))
    spec = spec_for_path(path, ndim, "model", stacked="stack" in path)
    out = divisible_spec(spec, shape, mesh)
    assert len(out) == len(shape)
    for dim, ax in zip(shape, out):
        assert dim % _axis_n(mesh, ax) == 0, (path, shape, out)


def _placement(spec, i):
    return spec[i] if i < len(spec) else None


@SET
@given(st.integers(0, 2**31 - 1), st.sampled_from(_PATHS),
       st.sampled_from([1, 2, 4, 8]), st.booleans(), st.booleans())
def test_qt_specs_children_consistent(seed, path, model, lowrank, expert):
    """QuantizedTensor child specs stay mutually consistent and, with a mesh,
    always divide the child shapes.

    Consistency: wint/packed/scale/zero share the (row, col) placement; dinv
    sits on the col placement; B on rows, A on cols (mesh=None form — the
    divisibility fallback may legitimately drop an axis for one child whose
    narrower dim doesn't divide, e.g. scale's d/g columns)."""
    rng = np.random.default_rng(seed)
    lead = (1,) if "stack" in path else ()
    bits, per = 4, 8
    g = int(rng.choice([8, 16, 32]))
    d = g * per * int(rng.integers(1, 5))         # in-features
    dp = 8 * int(rng.integers(1, 9))              # out-features
    ex = (int(rng.choice([2, 4, 8])),) if expert else ()
    r = int(rng.integers(1, 9))
    shapes = {
        "wint": None, "packed": (*lead, *ex, dp, d // per),
        "scale": (*lead, *ex, dp, d // g), "zero": (*lead, *ex, dp, d // g),
        "dinv": (*lead, *ex, d),
        "B": (*lead, *ex, dp, r) if lowrank else None,
        "A": (*lead, *ex, r, d) if lowrank else None,
    }
    pure = qt_specs(path, shapes, "model")
    nd = len(shapes["packed"])
    row_i, col_i = nd - 2, nd - 1
    # shared (row, col) placement across the packed/scale/zero family
    for k in ("scale", "zero"):
        assert _placement(pure[k], row_i) == _placement(pure["packed"], row_i)
        assert _placement(pure[k], col_i) == _placement(pure["packed"], col_i)
    # dinv rides the input dim; B the output dim; A the input dim
    assert _placement(pure["dinv"], nd - 2) == _placement(pure["packed"], col_i)
    assert _placement(pure["B"], row_i) == _placement(pure["packed"], row_i)
    assert _placement(pure["A"], col_i) == _placement(pure["packed"], col_i)
    # leading (layer, expert) dims agree everywhere
    for i in range(nd - 2):
        want = _placement(pure["packed"], i)
        for k in ("scale", "zero", "B", "A"):
            assert _placement(pure[k], i) == want, (path, k, i)
    # with a mesh, every emitted spec divides its child's shape
    mesh = _FakeMesh(int(rng.integers(1, 5)), model)
    sized = qt_specs(path, shapes, "model", mesh)
    for k, shape in shapes.items():
        if shape is None:
            continue
        for dim, ax in zip(shape, sized[k]):
            assert dim % _axis_n(mesh, ax) == 0, (path, k, shape, sized[k])


@pytest.mark.slow
def test_dryrun_machinery_multipod(subproc):
    """(pod, data, model) mesh: lower+compile train/prefill/decode for three
    representative smoke archs — the multi-pod axis proof at test scale."""
    out = subproc("""
import jax
import repro.configs as C
C.SHAPES = {'train_4k': (64, 8, 'train'), 'prefill_32k': (64, 4, 'prefill'),
            'decode_32k': (64, 8, 'decode'), 'long_500k': (128, 1, 'decode')}
import repro.launch.steps as S
S.SHAPES = C.SHAPES
from repro.launch.mesh import make_ctx
from repro.configs import get
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
pctx = make_ctx(mesh)
for arch in ['gemma_7b', 'deepseek_v2_lite_16b', 'mamba2_1p3b']:
    cfg = get(arch, smoke=True)
    for shape, kind in [('train_4k', 'train'), ('decode_32k', 'decode')]:
        if kind == 'train':
            fn, args, _ = S.build_train_cell(cfg, pctx, shape)
        else:
            fn, args, _ = S.build_decode_cell(cfg, pctx, shape)
        with mesh:
            fn.lower(*args).compile()
        print(arch, shape, 'OK')
print('ALLOK')
""", devices=8, timeout=900)
    assert "ALLOK" in out
