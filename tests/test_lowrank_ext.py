"""Appendix-E extensions: factor quantization + alternating refinement."""
import jax.numpy as jnp
import numpy as np

from repro.core import (AWQConfig, QuantConfig, activation_diag,
                        alternating_refine, svd_factors, ttq_lowrank_qdq)
from repro.core.awq import awq_loss
from repro.core.lowrank import quantize_factors

RNG = np.random.default_rng(5)


def _setup(dp=64, d=128, T=256):
    W = jnp.asarray(RNG.standard_normal((dp, d)).astype("float32"))
    chan = np.exp(RNG.standard_normal(d) * 1.5).astype("float32")
    X = jnp.asarray(RNG.standard_normal((T, d)).astype("float32") * chan)
    return W, X, jnp.mean(X ** 2, axis=0)


def test_quantized_factors_close_to_fp():
    W, X, Cd = _setup()
    D = activation_diag(X)
    qcfg = QuantConfig(bits=3, group_size=32, layout="row")
    B, A = svd_factors(W, 8)
    l_fp = float(awq_loss(W, ttq_lowrank_qdq(W, B, A, D, qcfg), Cd))
    qB, qA = quantize_factors(B, A, QuantConfig(bits=8, group_size=16), "both")
    l_q = float(awq_loss(W, ttq_lowrank_qdq(W, qB, qA, D, qcfg), Cd))
    assert l_q < l_fp * 1.1, (l_fp, l_q)   # 8-bit factors ≈ free


def test_alternating_not_worse():
    W, X, Cd = _setup()
    D = activation_diag(X)
    qcfg = QuantConfig(bits=3, group_size=32, layout="row")
    B, A = svd_factors(W, 8)
    l_svd = float(awq_loss(W, ttq_lowrank_qdq(W, B, A, D, qcfg), Cd))
    Br, Ar = alternating_refine(W, D, qcfg, 8, iters=2)
    l_alt = float(awq_loss(W, ttq_lowrank_qdq(W, Br, Ar, D, qcfg), Cd))
    assert l_alt < l_svd * 1.05
