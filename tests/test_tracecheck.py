"""tools.tracecheck — the analyzer analyzed.

Every rule gets at least one *catch* fixture (the bug class it exists
for) and one *clean* fixture (the idiom it must not flag), written to a
tmp tree and scanned with a custom root.  The suite ends with the
self-run: the real ``src/repro`` must carry zero non-baselined findings
(the CI gate, DESIGN.md §"Static analysis & runtime invariants").
"""
import os
import sys
import textwrap

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from tools.tracecheck import core, hostsync, recompile  # noqa: E402
from tools.tracecheck import docs_links, kernelcontract, serving  # noqa: E402

REPO = os.path.join(os.path.dirname(__file__), "..")


def write_tree(root, files):
    for rel, src in files.items():
        path = root / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(textwrap.dedent(src))
    return str(root)


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------- host-sync


def _hostsync(tmp_path, src, roots):
    root = write_tree(tmp_path, {"mod.py": src})
    repo = core.parse_paths(["mod.py"], root)
    return hostsync.check(repo, roots=roots)


def test_tc101_item_in_hot_function(tmp_path):
    f = _hostsync(tmp_path, """
        import jax.numpy as jnp
        def hot(x):
            y = jnp.sum(x)
            return y.item()
        def cold(x):
            return x.item()
    """, roots=["mod.hot"])
    assert rules_of(f) == ["TC101"]
    assert len(f) == 1 and "hot" in f[0].message     # cold stays silent


def test_tc102_int_on_array_vs_config(tmp_path):
    f = _hostsync(tmp_path, """
        import os
        import jax.numpy as jnp
        def hot(x, n):
            y = jnp.max(x)
            lvl = int(os.environ.get("LVL", "1"))    # host data: clean
            k = int(n)                               # param: clean
            return int(y) + lvl + k                  # device value: catch
    """, roots=["mod.hot"])
    assert rules_of(f) == ["TC102"]
    assert len(f) == 1


def test_tc103_device_get_and_suppression(tmp_path):
    src = """
        import jax
        import jax.numpy as jnp
        def hot(x):
            return jax.device_get(x)
        def designed(x):
            return jax.device_get(x)  # tracecheck: ok[TC103] the boundary
    """
    root = write_tree(tmp_path, {"mod.py": src})
    f = [x for x in core.scan_paths(["mod.py"], root) if x.rule == "TC103"]
    # scan_paths applies suppressions but hostsync's default roots don't
    # exist here — call the pass directly, then filter suppressed lines
    repo = core.parse_paths(["mod.py"], root)
    raw = hostsync.check(repo, roots=["mod.hot", "mod.designed"])
    kept = [x for x in raw
            if not repo.modules[0].suppressed(x.line, x.rule)]
    assert len(raw) == 2 and len(kept) == 1
    assert "hot" in kept[0].message


def test_tc104_np_asarray_on_device_value(tmp_path):
    f = _hostsync(tmp_path, """
        import numpy as np
        import jax.numpy as jnp
        def hot(x, slots):
            y = jnp.dot(x, x)
            a = np.asarray(slots)      # host list: clean
            return np.asarray(y) + a   # device value: catch
    """, roots=["mod.hot"])
    assert rules_of(f) == ["TC104"]
    assert len(f) == 1


def test_tc105_python_if_on_traced_value(tmp_path):
    f = _hostsync(tmp_path, """
        import jax
        import jax.numpy as jnp
        from functools import partial

        @jax.jit
        def traced(x):
            y = jnp.sum(x)
            if y > 0:                  # catch: tracer branch
                return y
            return -y

        @partial(jax.jit, static_argnames=("mode",))
        def clean(x, cfg=None, mode=0):
            if cfg is None:            # is-None: clean
                cfg = 1.0
            if mode:                   # static arg: clean
                return x * cfg
            return x + cfg
    """, roots=[])
    assert rules_of(f) == ["TC105"]
    assert len(f) == 1 and "traced" in f[0].message


def test_tc105_scan_body_helper(tmp_path):
    """Traced-ness flows into a lax.scan body and the helper it calls."""
    f = _hostsync(tmp_path, """
        import jax
        import jax.numpy as jnp

        def helper(x):
            y = jnp.abs(x)
            while (y > 0).any():       # catch: two frames below the scan
                y = y - 1
            return y

        def outer(xs):
            def step(c, x):
                y = jnp.cumsum(x)
                return c, helper(y)
            return jax.lax.scan(step, 0, xs)
    """, roots=[])
    assert rules_of(f) == ["TC105"]
    assert "helper" in f[0].message


# --------------------------------------------------------- recompile-hazard


def _recompile(tmp_path, src):
    root = write_tree(tmp_path, {"mod.py": src})
    return recompile.check(core.parse_paths(["mod.py"], root))


def test_tc201_static_argnames_drift(tmp_path):
    f = _recompile(tmp_path, """
        import jax
        def f(a, b, max_len=8):
            return a + b
        good = jax.jit(f, static_argnames=("max_len",))
        bad = jax.jit(f, static_argnames=("maxlen",))
    """)
    assert rules_of(f) == ["TC201"]
    assert len(f) == 1 and "maxlen" in f[0].message


def test_tc201_partial_bound_args_consume_signature(tmp_path):
    f = _recompile(tmp_path, """
        import jax
        from functools import partial
        def f(cfg, params, batch, max_len=8):
            return params
        good = jax.jit(partial(f, None), static_argnames=("max_len",))
        bad = jax.jit(partial(f, None), static_argnames=("cfg",))
    """)
    assert rules_of(f) == ["TC201"]
    assert len(f) == 1 and "'cfg'" in f[0].message


def test_tc202_mutable_default_in_jitted_signature(tmp_path):
    f = _recompile(tmp_path, """
        import jax
        @jax.jit
        def bad(x, opts={}):
            return x
        @jax.jit
        def good(x, opts=()):
            return x
    """)
    assert rules_of(f) == ["TC202"]
    assert len(f) == 1


def test_tc203_unhashable_literal_at_static_callsite(tmp_path):
    f = _recompile(tmp_path, """
        import jax
        from functools import partial
        @partial(jax.jit, static_argnames=("shape",))
        def make(x, shape=(4,)):
            return x.reshape(shape)
        def caller_good(x):
            return make(x, shape=(2, 2))
        def caller_bad(x):
            return make(x, shape=[2, 2])
    """)
    assert rules_of(f) == ["TC203"]
    assert len(f) == 1


def test_tc204_nonfrozen_dataclass_static_arg(tmp_path):
    f = _recompile(tmp_path, """
        import dataclasses
        import jax
        from functools import partial

        @dataclasses.dataclass(frozen=True)
        class Good:
            bits: int = 4

        @dataclasses.dataclass
        class Bad:
            bits: int = 4

        @partial(jax.jit, static_argnames=("cfg",))
        def run(x, cfg=None):
            return x

        def caller(x):
            run(x, cfg=Good())
            run(x, cfg=Bad())
            c = Bad()
            return run(x, cfg=c)
    """)
    assert rules_of(f) == ["TC204"]
    assert len(f) == 2              # direct ctor + local name


# ---------------------------------------------------------- kernel-contract

_KERNEL_OK = {
    "kernels/__init__.py": "",
    "kernels/mykern.py": """
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _body(x_ref, o_ref):
            o_ref[...] = jax.lax.dot_general(
                x_ref[...], x_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        def mykern(x, bm=8):
            return pl.pallas_call(
                _body,
                grid=(2, 2),
                in_specs=[pl.BlockSpec((bm, bm), lambda i, j: (i, j))],
                out_specs=pl.BlockSpec((bm, bm), lambda i, j: (i, j)),
                out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
            )(x)
    """,
    "kernels/ref.py": """
        import jax.numpy as jnp
        def mykern_ref(x):
            return x @ x
    """,
    "kernels/ops.py": """
        from . import ref as _ref
        from .mykern import mykern as _mykern_pallas

        def mykern(x, *, use_pallas=True):
            if use_pallas:
                return _mykern_pallas(x)
            return _ref.mykern_ref(x)
    """,
}


def _kernelcheck(tmp_path, files):
    root = write_tree(tmp_path, files)
    rels = sorted(files)
    return kernelcontract.check(core.parse_paths(rels, root))


def test_kernel_contract_clean_tree(tmp_path):
    assert _kernelcheck(tmp_path, _KERNEL_OK) == []


def test_tc301_blockspec_arity_mismatch(tmp_path):
    files = dict(_KERNEL_OK)
    files["kernels/mykern.py"] = files["kernels/mykern.py"].replace(
        "in_specs=[pl.BlockSpec((bm, bm), lambda i, j: (i, j))]",
        "in_specs=[pl.BlockSpec((bm, bm), lambda i: (i, 0))]")
    f = _kernelcheck(tmp_path, files)
    assert rules_of(f) == ["TC301"]
    assert "grid rank is 2" in f[0].message


def test_tc301_scalar_prefetch_offset(tmp_path):
    """PrefetchScalarGridSpec index maps take grid + prefetch args."""
    files = dict(_KERNEL_OK)
    files["kernels/paged.py"] = """
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        def _body(tab_ref, x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def paged(tab, x):
            gs = pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(2, 2),
                in_specs=[pl.BlockSpec((8, 8),
                                       lambda i, j, tab_r: (tab_r[i], j))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            )
            return pl.pallas_call(
                _body, grid_spec=gs,
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            )(tab, x)
    """
    files["kernels/ops.py"] += """
        from .paged import paged as _paged_pallas

        def paged(tab, x, *, use_pallas=True):
            if use_pallas:
                return _paged_pallas(tab, x)
            return _ref.mykern_ref(x)
    """
    f = _kernelcheck(tmp_path, files)
    # the out_specs lambda misses the prefetch arg: 2 != 2 + 1
    assert rules_of(f) == ["TC301"]
    assert "scalar-prefetch" in f[0].message


def test_tc302_undispatched_kernel_entry(tmp_path):
    files = dict(_KERNEL_OK)
    files["kernels/ops.py"] = """
        from . import ref as _ref

        def mykern(x, *, use_pallas=True):
            return _ref.mykern_ref(x)
    """
    f = _kernelcheck(tmp_path, files)
    assert rules_of(f) == ["TC302"]


def test_tc303_missing_ref_fallback(tmp_path):
    files = dict(_KERNEL_OK)
    files["kernels/ops.py"] = """
        from .mykern import mykern as _mykern_pallas

        def mykern(x, *, use_pallas=True):
            return _mykern_pallas(x)
    """
    f = _kernelcheck(tmp_path, files)
    assert rules_of(f) == ["TC303"]


def test_tc304_silent_bf16_cast(tmp_path):
    files = dict(_KERNEL_OK)
    files["kernels/mykern.py"] = files["kernels/mykern.py"].replace(
        "            )(x)",
        "            )(x).astype(jnp.bfloat16)")
    f = _kernelcheck(tmp_path, files)
    assert rules_of(f) == ["TC304"]


def test_tc305_unpinned_dot_in_kernel_body(tmp_path):
    files = dict(_KERNEL_OK)
    files["kernels/mykern.py"] = files["kernels/mykern.py"].replace(
        ",\n                preferred_element_type=jnp.float32)", ")")
    f = _kernelcheck(tmp_path, files)
    assert rules_of(f) == ["TC305"]


# --------------------------------------------------------- serving-invariant


def test_tc401_tc402_alloc_and_table_outside_runner(tmp_path):
    files = {
        "src/repro/serving/scheduler.py": """
            import jax.numpy as jnp
            def plan(state, idx):
                state["block_table"] = idx          # TC401
                return jnp.zeros((4,), jnp.int32)   # TC402
        """,
        "src/repro/serving/runner.py": """
            import jax.numpy as jnp
            def admit(state, idx):
                state["block_table"] = idx          # runner: clean
                return jnp.zeros((4,), jnp.int32)   # runner: clean
        """,
    }
    root = write_tree(tmp_path, files)
    f = serving.check(core.parse_paths(sorted(files), root))
    assert rules_of(f) == ["TC401", "TC402"]
    assert all("scheduler.py" in x.path for x in f)


def test_tc403_decode_path_allocation(tmp_path):
    files = {
        "src/repro/serving/runner.py": """
            class DeviceRunner:
                def decode_block(self, params):
                    blocks = self.allocator.allocate(params, 1, 2)  # TC403
                    return blocks
                def admit_group(self, params, group):
                    return self.allocator.allocate(params, 1, 2)    # clean
        """,
    }
    root = write_tree(tmp_path, files)
    f = serving.check(core.parse_paths(sorted(files), root))
    assert rules_of(f) == ["TC403"]
    assert len(f) == 1 and "decode_block" in f[0].message


def test_tc404_facade_surface(tmp_path):
    body = "\n".join(f"    {a} = None" for a in serving.ENGINE_ATTRS)
    files = {
        "src/repro/serving/engine.py": (
            "class TTQEngine:\n" + body + "\n"),
    }
    root = write_tree(tmp_path, files)
    assert serving.check(core.parse_paths(sorted(files), root)) == []
    files["src/repro/serving/engine.py"] = (
        "class TTQEngine:\n" + body.replace("    host_syncs = None", "    pass")
        + "\n")
    write_tree(tmp_path, files)
    f = serving.check(core.parse_paths(sorted(files), root))
    assert rules_of(f) == ["TC404"]
    assert "host_syncs" in f[0].message


def test_tc405_placement_funnel(tmp_path):
    files = {
        "src/repro/serving/engine.py": """
            import jax
            def place(params, sh):
                return jax.tree.map(jax.device_put, params, sh)   # TC405
        """,
        "src/repro/launch/serve.py": """
            import jax
            def build():
                return jax.make_mesh((1, 2), ('data', 'model'))   # TC405
        """,
        # the three sanctioned doors stay clean
        "src/repro/parallel/rules.py": """
            import jax
            def shard(params, sh):
                return jax.tree.map(jax.device_put, params, sh)
        """,
        "src/repro/launch/mesh.py": """
            import jax
            def make_mesh(d, m):
                return jax.make_mesh((d, m), ('data', 'model'))
        """,
        "src/repro/serving/runner.py": """
            import jax
            def pin(x, sh):
                return jax.device_put(x, sh)
        """,
    }
    root = write_tree(tmp_path, files)
    f = [x for x in serving.check(core.parse_paths(sorted(files), root))
         if x.rule == "TC405"]
    assert len(f) == 2, f
    assert {x.path.rsplit("/", 1)[-1] for x in f} == {"engine.py", "serve.py"}


def test_tc406_broad_except_outside_fault_boundary(tmp_path):
    files = {
        "src/repro/serving/scheduler.py": """
            def plan(reqs):
                try:
                    reqs.pop()
                except Exception:                     # TC406
                    pass
                try:
                    reqs.pop()
                except:                               # TC406 (bare)
                    pass
                try:
                    reqs.pop()
                except (ValueError, BaseException):   # TC406 (tuple)
                    pass
                try:
                    reqs.pop()
                except MemoryError:                   # typed: clean
                    pass
        """,
        # the designated fault boundary is exempt by name
        "src/repro/serving/faults.py": """
            def on_step(engine):
                try:
                    engine.poke()
                except Exception:
                    pass
        """,
        # non-serving modules are out of scope for TC406
        "src/repro/quant/api.py": """
            def probe(x):
                try:
                    return x()
                except Exception:
                    return None
        """,
    }
    root = write_tree(tmp_path, files)
    f = [x for x in serving.check(core.parse_paths(sorted(files), root))
         if x.rule == "TC406"]
    assert len(f) == 3, f
    assert all("scheduler.py" in x.path for x in f)


def test_tc406_inline_suppression(tmp_path):
    files = {
        "src/repro/serving/engine.py": """
            def step(eng):
                try:
                    return eng.tick()
                except Exception:  # tracecheck: ok[TC406]
                    return None
        """,
    }
    root = write_tree(tmp_path, files)
    repo = core.parse_paths(sorted(files), root)
    raw = [x for x in serving.check(repo) if x.rule == "TC406"]
    assert len(raw) == 1                 # the pass still sees it...
    mod = next(m for m in repo if m.path == raw[0].path)
    assert mod.suppressed(raw[0].line, "TC406")   # ...the filter drops it


def test_tc407_no_device_work_in_coroutines(tmp_path):
    files = {
        "src/repro/serving/server.py": """
            import jax.numpy as jnp
            class Srv:
                async def handle(self, prompt):
                    rid = self.engine.submit(prompt)    # TC407
                    self.engine.step()                  # TC407
                    x = jnp.zeros((4,))                 # TC407
                    def forward(tok):                   # nested sync def:
                        self.engine.cancel(rid)         # worker-side, clean
                    await self.queue.put(x)             # non-engine: clean
                    return rid
                def drain(self):
                    return self.engine.step()           # sync method: clean
        """,
        # coroutines outside serving/ are out of scope
        "src/repro/launch/cli.py": """
            async def main(eng, prompt):
                return eng.submit(prompt)
        """,
    }
    root = write_tree(tmp_path, files)
    f = [x for x in serving.check(core.parse_paths(sorted(files), root))
         if x.rule == "TC407"]
    assert len(f) == 3, f
    assert all("server.py" in x.path for x in f)
    assert {x.line for x in f} == {5, 6, 7}


def test_tc407_real_server_is_clean():
    """The shipped async front end obeys its own threading contract."""
    repo = core.parse_paths(["src/repro/serving/server.py"], REPO)
    f = [x for x in serving.check(repo) if x.rule == "TC407"]
    assert f == []


# --------------------------------------------------------------- docs-links


def test_docs_links_pass(tmp_path):
    root = write_tree(tmp_path, {
        "README.md": "[ok](DESIGN.md) and [broken](missing.md)\n",
        "DESIGN.md": "# 1. Something\n",
        # § is the section sign — escaped so the repo-wide self-run
        # (which scans THIS file too) doesn't see the fixture's dangling
        # citation as a literal
        "src/mod.py": ('"""See DESIGN.md §1 and DESIGN.md §'
                       'Nope."""\n'),
    })
    f = docs_links.check(root)
    assert rules_of(f) == ["TCDOC1", "TCDOC2"]
    assert len(f) == 2


# ----------------------------------------------------- core: baseline, CLI


def test_baseline_matching(tmp_path):
    bl = tmp_path / "baseline.toml"
    bl.write_text(
        '# comment\n[[ignore]]\nrule = "TC103"\n'
        'path = "a.py"\ncontains = "decode"\nreason = "designed"\n')
    entries = core.load_baseline(str(bl))
    assert entries == [{"rule": "TC103", "path": "a.py",
                        "contains": "decode", "reason": "designed"}]
    hit = core.Finding("TC103", "a.py", 5, "sync in decode_block")
    miss_rule = core.Finding("TC104", "a.py", 5, "sync in decode_block")
    miss_msg = core.Finding("TC103", "a.py", 9, "sync in admit")
    assert core.baselined(hit, entries)
    assert not core.baselined(miss_rule, entries)
    assert not core.baselined(miss_msg, entries)
    assert core.load_baseline(str(tmp_path / "nope.toml")) == []


def test_cli_entry_point(subproc):
    out = subproc(
        "import subprocess, sys, os\n"
        f"os.chdir({REPO!r})\n"
        "r = subprocess.run([sys.executable, '-m', 'tools.tracecheck',\n"
        "                    'src/repro'], capture_output=True, text=True)\n"
        "print(r.stdout)\n"
        "assert r.returncode == 0, r.stdout + r.stderr\n")
    assert "tracecheck passed" in out


# ----------------------------------------------------------------- self-run


def test_self_run_src_repro_is_clean():
    """The CI gate: the real tree carries zero non-baselined findings."""
    new, old = core.run(["src/repro"], root=REPO)
    assert new == [], "\n".join(str(f) for f in new)
    # the baseline documents exactly the designed decode_block sync plus the
    # three pre-funnel placement sites (trainer ZeRO-1 reshard, checkpoint
    # restore ×2) grandfathered under TC405
    assert sorted(f.rule for f in old) == ["TC103"] + ["TC405"] * 3


def test_self_run_catches_real_bug_classes():
    """Sanity: the passes are live on the real tree — the hot set and the
    kernel registry are non-trivial (a refactor that silently empties the
    reachability roots would turn the suite into a no-op)."""
    from tools.tracecheck import callgraph
    repo = core.parse_paths(["src/repro"], REPO)
    cg = callgraph.build(repo)
    hot = cg.reachable(hostsync.HOT_ROOTS)
    assert "repro.models.lm.decode_many" in hot
    assert "repro.serving.runner.DeviceRunner.decode_block" in hot
    assert len(hot) > 20
    assert len(cg.traced) > 20
    kernels = [q for q in cg.funcs
               if q.startswith("repro.kernels.") and "ops" not in q]
    assert len(kernels) > 4
