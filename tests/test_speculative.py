"""Self-speculative decoding (DESIGN.md §11): draft/verify greedy
equivalence, the dual-tree requant budget, the speculation-aware chunk
heuristic, and scheduler interactions (cancel / preemption mid-window —
rolled-back tokens must never leak into GenResult or the prefix trie)."""
import jax
import numpy as np
import pytest

from repro.core import (KVCacheConfig, NO_QUANT, QuantizedTensor, ttq_policy)
from repro.models import ModelConfig, lm
from repro.quant.model import QuantizedModel
from repro.serving import EngineConfig, TTQEngine, pick_decode_chunk

CFG = ModelConfig(name="spec-t", family="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=96, vocab=128)

PROMPTS = [[5, 9, 17, 3], [8, 8, 1], [100, 50, 25, 12, 6, 3], [7, 7, 7, 2]]


@pytest.fixture(scope="module")
def params():
    return lm.init_params(CFG, jax.random.PRNGKey(0))


def _engine(params, policy=NO_QUANT, speculate_k=0, slots=3, **kw):
    return TTQEngine(CFG, params, policy,
                     EngineConfig(max_slots=slots, max_len=64,
                                  speculate_k=speculate_k, **kw))


def _run(eng, prompts=PROMPTS, max_new=8):
    rids = [eng.submit(p, max_new=max_new) for p in prompts]
    outs = eng.run_all()
    return [outs[r] for r in rids]


# ------------------------------------------------------- greedy equivalence

@pytest.mark.parametrize("W", [2, 4])
def test_spec_matches_nonspec_dense_fp(params, W):
    """Greedy outputs token-identical at every W — the verify tree decides
    every emitted token; the draft only proposes (CI fast tier)."""
    base = _run(_engine(params))
    eng = _engine(params, speculate_k=W)
    assert _run(eng) == base
    assert eng.spec_windows > 0
    assert 0.0 <= eng.spec_acceptance_rate <= 1.0


def test_spec_matches_nonspec_quantized(params):
    """int8 verify tree + default int4 draft companion: identical tokens."""
    pol = ttq_policy(bits=8, group_size=32, rank=0)
    base = _run(_engine(params, pol))
    assert _run(_engine(params, pol, speculate_k=3)) == base


@pytest.mark.parametrize("kv_dtype", ["int8", "bf16"])
def test_spec_matches_nonspec_paged(params, kv_dtype):
    """Paged pool: per-slot block-table row writes + rewind-by-overwrite
    keep speculative greedy outputs identical to the dense non-speculative
    engine."""
    pol = NO_QUANT.with_(kvcache=KVCacheConfig(dtype=kv_dtype, paged=True))
    base = _run(_engine(params))
    assert _run(_engine(params, pol, speculate_k=2, slots=2)) == base


def test_spec_uneven_lengths_and_eos(params):
    """Budgets that end mid-window: emitted counts stay exact per lane."""
    base_eng = _engine(params)
    rids = [base_eng.submit(p, max_new=n)
            for p, n in zip(PROMPTS, (1, 5, 9, 3))]
    base = [base_eng.run_all()[r] for r in rids]
    eng = _engine(params, speculate_k=3)
    rids = [eng.submit(p, max_new=n) for p, n in zip(PROMPTS, (1, 5, 9, 3))]
    outs = [eng.run_all()[r] for r in rids]
    assert outs == base
    assert [len(o) for o in outs] == [1, 5, 9, 3]


# ------------------------------------------------------------ engine gates

def test_spec_auto_off_when_sampling(params):
    eng = _engine(params, speculate_k=4, temperature=0.7)
    assert eng.ecfg.speculate_k == 0


def test_spec_rejects_non_attention_families():
    from repro.configs import get
    cfg = get("mamba2_1p3b", smoke=True)
    p = lm.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="attention"):
        TTQEngine(cfg, p, NO_QUANT,
                  EngineConfig(max_slots=1, max_len=64, speculate_k=2))


def test_pick_decode_chunk_speculation_aware():
    """Satellite pin: the chunk counts windows when speculating — effective
    tokens/dispatch is chunk × (W+1) × acceptance — and 1 slot stays
    per-window (the PR-3 per-token crossover, unchanged by speculation)."""
    assert pick_decode_chunk(1) == 1
    assert pick_decode_chunk(4) == 8
    assert pick_decode_chunk(1, 4) == 1          # 1-slot case pinned
    assert pick_decode_chunk(4, 1) == 4
    assert pick_decode_chunk(4, 3) == 2
    assert pick_decode_chunk(4, 7) == 1          # floor at 1 window
    assert pick_decode_chunk(8, 0) == pick_decode_chunk(8)


# ------------------------------------------------------ dual-tree requant

def test_draft_tree_program_budget(params):
    """Draft + verify plans together compile ≤ 2× the single-tree plan."""
    pol = ttq_policy(bits=8, group_size=32, rank=0)
    single = _engine(params, pol)
    _run(single, prompts=PROMPTS[:1], max_new=2)
    spec = _engine(params, pol, speculate_k=2)
    _run(spec, prompts=PROMPTS[:1], max_new=2)
    assert single.qmodel.compiled_programs > 0
    assert spec.qmodel.compiled_programs <= 2 * single.qmodel.compiled_programs
    # the draft tree really is a second quantized tree, not an alias
    dq = [l for l in jax.tree.leaves(
        spec.qmodel.draft_params,
        is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(l, QuantizedTensor)]
    assert dq, "draft tree has no quantized leaves"


def test_draft_params_fp_fallback(params):
    """A disabled draft policy (NO_QUANT) keeps draft_params on the fp
    weights while the verify tree quantizes — the maximally accurate
    speculator."""
    qm = QuantizedModel(params, ttq_policy(bits=8, group_size=32),
                        draft_policy=NO_QUANT)
    assert qm.draft_params is params
    toks = np.array([PROMPTS[0]])
    _, _, stats = lm.prefill(CFG, params, {"tokens": toks}, max_len=16)
    qm.calibrate(stats, float(toks.size))
    qm.requantize()
    assert qm.decode_params is not params
    assert qm.draft_params is params


def test_draft_only_quantization(params):
    """Disabled verify policy + enabled draft (the CPU-favourable config:
    a quantized draft speculates for the full-precision model).  The verify
    tree must stay on the fp weights, the draft tree must quantize, and
    greedy engine outputs must match the non-speculative fp run."""
    qm = QuantizedModel(params, NO_QUANT,
                        draft_policy=ttq_policy(bits=8, group_size=32,
                                                rank=0))
    toks = np.array([PROMPTS[0]])
    _, _, stats = lm.prefill(CFG, params, {"tokens": toks}, max_len=16)
    qm.calibrate(stats, float(toks.size))
    tree = qm.requantize()
    assert tree is not None          # cadence accounting still fires
    assert qm.qparams is None and qm.decode_params is params
    d_leaves = jax.tree_util.tree_leaves(
        qm.draft_qparams, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    assert any(isinstance(l, QuantizedTensor) for l in d_leaves)
    assert qm.compiled_programs > 0
    # end-to-end: greedy tokens identical to the plain fp engine
    base = _run(_engine(params, NO_QUANT))
    spec = _run(_engine(params, NO_QUANT, speculate_k=3))
    eng = TTQEngine(CFG, params, NO_QUANT,
                    EngineConfig(max_slots=3, max_len=64, speculate_k=3),
                    draft_policy=ttq_policy(bits=8, group_size=32, rank=0))
    rids = [eng.submit(p, max_new=8) for p in PROMPTS]
    outs = eng.run_all()
    got = [outs[r] for r in rids]
    assert got == base == spec
    assert eng.qmodel.qparams is None


def test_draft_policy_requires_fused_plan(params):
    with pytest.raises(ValueError, match="fused"):
        QuantizedModel(params, ttq_policy(bits=8, group_size=32),
                       fused=False,
                       draft_policy=ttq_policy(bits=4, group_size=32))


def test_draft_variant_policy():
    pol = ttq_policy(bits=8, group_size=32, rank=8)
    d = pol.draft_variant()
    assert d.qcfg.bits == 4 and d.rank == 0 and not d.overrides
    assert d.qcfg.group_size == pol.qcfg.group_size
    assert NO_QUANT.draft_variant() is NO_QUANT


# ---------------------------------------- scheduler: cancel / preemption

def test_cancel_mid_speculation_window(params):
    """cancel(rid) between speculative chunks: the cancelled lane's
    rolled-back tokens never reach GenResult; survivors are unaffected."""
    base = _run(_engine(params), prompts=[PROMPTS[1]], max_new=20)
    eng = _engine(params, speculate_k=3, slots=2)
    r1 = eng.submit(PROMPTS[0], max_new=20)
    r2 = eng.submit(PROMPTS[1], max_new=20)
    eng.step()                                  # admission + first chunk
    assert eng.cancel(r1)
    outs = eng.run_all()
    assert outs[r1].cancelled and outs[r1].unfinished
    assert len(outs[r1]) < 20
    assert list(outs[r2]) == list(base[0])


def test_preemption_mid_speculation_window(params):
    """An oversubscribed paged pool preempts lanes between speculative
    chunks; requeued requests replay their tokens and finish with outputs
    identical to the unconstrained non-speculative engine, and every block
    (incl. prefix-trie nodes touched by speculative writes) is freed."""
    base = _run(_engine(params))
    pol = NO_QUANT.with_(kvcache=KVCacheConfig(dtype="int8", paged=True))
    eng = _engine(params, pol, speculate_k=2, slots=2,
                  kv_block_size=4, kv_pool_blocks=7)
    out = _run(eng)
    assert out == base
    assert eng.preemptions > 0
    eng.allocator.assert_quiescent()


def test_spec_prefix_cache_not_polluted(params):
    """Speculative (draft-quality, later overwritten) rows must not be
    shared via the prefix trie: a follow-up request hitting the cached
    prefix still decodes exactly like the cold engine."""
    sysp = list(range(1, 21))
    ps = [sysp + [40, 41], sysp + [50, 51, 52]]
    pol = NO_QUANT.with_(kvcache=KVCacheConfig(dtype="bf16", paged=True))
    cold = _run(_engine(params, pol, prefix_cache=False, slots=2),
                prompts=ps, max_new=6)
    eng = _engine(params, pol, speculate_k=2, slots=2)
    warm = _run(eng, prompts=ps, max_new=6)
    assert warm == cold
    assert eng.prefix_hit_rate > 0
    eng.allocator.assert_quiescent()
