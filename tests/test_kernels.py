"""Pallas kernels vs pure-jnp oracles — shape/dtype/bits sweeps.

Comparisons are quantization-boundary tolerant: int codes may flip by 1 on
exact .5 ties (fp fusion differences between interpret and XLA paths)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qdq import unpack_bits
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)

SWEEP = [
    # (T, d, dp, bits, g)
    (16, 256, 128, 4, 32),
    (1, 512, 384, 4, 128),     # decode shape
    (9, 256, 256, 8, 32),      # ragged T
    (32, 512, 256, 2, 64),
    (200, 1024, 512, 4, 256),
    (4, 256, 64, 4, 256),      # single group per k-tile
]


def _data(T, d, dp):
    W = jnp.asarray(RNG.standard_normal((dp, d)).astype("float32"))
    D = jnp.asarray(np.exp(RNG.standard_normal(d) * 0.3).astype("float32"))
    x = jnp.asarray(RNG.standard_normal((T, d)).astype("float32"))
    return W, D, x


@pytest.mark.parametrize("T,d,dp,bits,g", SWEEP)
def test_ttq_quantize_kernel(T, d, dp, bits, g):
    W, D, _ = _data(T, d, dp)
    pk, S, Z = ops.ttq_quantize(W, D, bits=bits, group_size=g)
    pk_r, S_r, Z_r = ref.ttq_quantize_ref(W, D, bits=bits, group_size=g)
    u = np.asarray(unpack_bits(pk, d, bits))
    ur = np.asarray(unpack_bits(pk_r, d, bits))
    assert (u != ur).mean() < 2e-3          # boundary ties only
    assert np.abs(u.astype(int) - ur.astype(int)).max() <= 1
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(Z), np.asarray(Z_r), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("T,d,dp,bits,g", SWEEP)
def test_ttq_gemm_kernel(T, d, dp, bits, g):
    W, D, x = _data(T, d, dp)
    pk, S, Z = ref.ttq_quantize_ref(W, D, bits=bits, group_size=g)
    y = ops.ttq_gemm(x, pk, S, Z, dinv=1.0 / D, bits=bits, group_size=g)
    y_r = ref.ttq_gemm_ref(x, pk, S, Z, bits=bits, group_size=g, dinv=1.0 / D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ttq_gemm_dtypes(dtype):
    W, D, x = _data(8, 256, 128)
    x = x.astype(dtype)
    pk, S, Z = ref.ttq_quantize_ref(W, D, bits=4, group_size=32)
    y = ops.ttq_gemm(x, pk, S, Z, bits=4, group_size=32)
    y_r = ref.ttq_gemm_ref(x, pk, S, Z, bits=4, group_size=32)
    assert y.dtype == dtype
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_r.astype(dtype), np.float32),
                               rtol=2e-2, atol=1.0)


def test_gemm_matches_fp_matmul_closely():
    """8-bit quantized gemm ≈ the fp matmul it approximates."""
    W, D, x = _data(16, 512, 128)
    pk, S, Z = ops.ttq_quantize(W, D, bits=8, group_size=32)
    y = ops.ttq_gemm(x, pk, S, Z, dinv=1.0 / D, bits=8, group_size=32)
    y_fp = x @ W.T
    rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 1.2e-2, rel   # ~8-bit groupwise accuracy floor


def test_fallback_path_agrees():
    W, D, x = _data(8, 256, 64)
    pk, S, Z = ops.ttq_quantize(W, D, bits=4, group_size=32, use_pallas=False)
    y_p = ops.ttq_gemm(x, pk, S, Z, bits=4, group_size=32, use_pallas=True)
    y_f = ops.ttq_gemm(x, pk, S, Z, bits=4, group_size=32, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_f),
                               rtol=2e-5, atol=2e-4)
