"""Fault-injection robustness bench — the recovery-equality gates.

Every scenario drives the guarded TTQEngine through a seeded, deterministic
fault (``serving/faults.py``) and holds it to the ISSUE-9 acceptance bar:

  * **recovery equality** — requests the fault does not touch produce
    greedy tokens **bitwise identical** to a fault-free (or clean-twin)
    run.  For calibration poisoning the twin is a ``drop`` injector that
    skips the same update the guard quarantines — both runs fold the same
    statistics, so any token difference means poison leaked through;
  * **detection reconciliation** — the engine's guard counters
    (``calib_rejections`` / ``requant_rejections`` / ``lane_faults`` /
    ``deadline_expirations``) equal the number of faults the injector
    logged as fired.  A rejected calibration update must never reach a
    weight swap;
  * **zero steady-wave recompiles** — after a fault wave warms every
    program (including any degradation-ladder program), a clean wave on
    the same engine compiles nothing new.

Scenarios: NaN / outlier calibration stats (poisoned-prompt stand-ins),
requant-tree corruption (health gate + in-step retry), KV-pool exhaustion
(stolen blocks → bounded admission retries), a poisoned decode lane
(isolation with and without retry budget), and a virtual-clock deadline
expiry.  Pool/decode scenarios run NO_QUANT so lanes are batch-independent
and equality is exact by construction; calibration scenarios run the real
TTQ pipeline because the *weights* are the attack surface.

Run:  PYTHONPATH=src python benchmarks/bench_robustness.py [--fast]
Emits results/BENCH_robustness.json (picked up by benchmarks/report.py);
methodology in EXPERIMENTS.md §"Recovery-equality methodology".
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.core import NO_QUANT, ttq_policy
from repro.models import ModelConfig, lm
from repro.quant import GuardConfig
from repro.serving import (EngineConfig, Fault, FaultInjector, TTQEngine,
                           VirtualClock)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

CFG = ModelConfig(name="bench-robust", family="dense", n_layers=2,
                  d_model=64, n_heads=2, n_kv_heads=1, d_ff=128, vocab=128)
MAX_LEN = 128
TTQ = ttq_policy(bits=8, group_size=32, rank=0)
PARAMS = None            # initialized once in main()


def prompts_for(n: int):
    rng = np.random.default_rng(0)
    return [list(rng.integers(1, CFG.vocab, size=int(rng.integers(4, 12))))
            for _ in range(n)]


def make_engine(policy, faults=(), clock=None, **kw):
    inj = FaultInjector(faults, clock=clock)
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("decode_chunk", 2)
    return TTQEngine(CFG, PARAMS, policy, EngineConfig(**kw), faults=inj), inj


def run_wave(eng, prompts, max_new, deadlines=None):
    """Submit every prompt, drive to completion; returns outputs keyed by
    prompt index (GenResult: token list + unfinished/error flags)."""
    dls = deadlines or {}
    rids = [eng.submit(p, max_new=max_new, deadline_s=dls.get(i))
            for i, p in enumerate(prompts)]
    outs = eng.run_all()
    return {i: outs[r] for i, r in enumerate(rids)}


def equal_tokens(a, b, skip=()):
    return all(list(a[i]) == list(b[i]) for i in a if i not in skip)


def steady_recompiles(eng, prompts, max_new) -> int:
    """One clean wave on an already-warm engine; programs compiled by it."""
    warm = eng.compiled_programs
    run_wave(eng, prompts, max_new)
    return eng.compiled_programs - warm


# ---------------------------------------------------------------- scenarios


def scenario_calib_poison(kind: str, max_new: int):
    """Poisoned calibration statistics (``nan``/``inf``/``outlier``) vs the
    clean-twin ``drop`` injector that skips the same update.  The guard
    must quarantine exactly the injected update and the quantized weights
    — hence every token — must match the twin bitwise."""
    prompts = prompts_for(6)
    fault = [Fault("calib.stats", at=1, kind=kind)]
    twin = [Fault("calib.stats", at=1, kind="drop")]
    eng_f, inj_f = make_engine(TTQ, fault)
    eng_t, inj_t = make_engine(TTQ, twin)
    out_f = run_wave(eng_f, prompts, max_new)
    out_t = run_wave(eng_t, prompts, max_new)
    fired = sum(1 for s, _, _ in inj_f.fired if s == "calib.stats")
    row = {
        "scenario": f"calib-{kind}", "injected": fired,
        "calib_rejections": eng_f.calib_rejections,
        "quarantined": len(eng_f.quarantine),
        "requant_rejections": eng_f.requant_rejections,
        "tokens_equal": equal_tokens(out_f, out_t),
        "steady_new_programs": steady_recompiles(eng_f, prompts, max_new),
        "harness_errors": inj_f.errors + inj_t.errors,
    }
    ok = (row["tokens_equal"] and fired == 1
          and row["calib_rejections"] == fired
          and row["quarantined"] == fired
          and row["requant_rejections"] == 0
          and row["steady_new_programs"] == 0
          and not row["harness_errors"])
    return row, ok


def scenario_requant_corruption(max_new: int):
    """A corrupted candidate quantized tree (NaN scales) at the first
    requant dispatch.  The health gate must reject it, the in-step retry
    must rebuild a clean tree, and tokens must match a fault-free run."""
    prompts = prompts_for(4)
    eng_f, inj_f = make_engine(TTQ, [Fault("requant.tree", at=0,
                                           kind="nan-scale")])
    eng_b, _ = make_engine(TTQ)
    out_f = run_wave(eng_f, prompts, max_new)
    out_b = run_wave(eng_b, prompts, max_new)
    fired = sum(1 for s, _, _ in inj_f.fired if s == "requant.tree")
    row = {
        "scenario": "requant-corruption", "injected": fired,
        "requant_rejections": eng_f.requant_rejections,
        "n_requants": eng_f.n_requants,
        "tokens_equal": equal_tokens(out_f, out_b),
        "harness_errors": inj_f.errors,
    }
    ok = (row["tokens_equal"] and fired == 1
          and row["requant_rejections"] == fired
          and eng_f.n_requants == eng_b.n_requants
          and not row["harness_errors"])
    return row, ok


def scenario_pool_exhaustion(max_new: int):
    """Steal most free KV-pool blocks for a few engine steps: admissions
    hit MemoryError, the bounded retry loop (preempt → backoff → starve
    wait) rides it out, and once the blocks return every request finishes
    with tokens bitwise equal to the fault-free run (NO_QUANT — weights
    cannot drift, and preemption resume is token-exact)."""
    prompts = prompts_for(4)
    kw = dict(kv_dtype="int8", kv_paged=True, kv_block_size=16)
    # window sized to straddle the first lane turnover (~max_new/chunk
    # steps in), so mid-run admissions really do meet an exhausted pool
    eng_f, inj_f = make_engine(NO_QUANT, [Fault("pool.steal", at=1,
                                                magnitude=64,
                                                count=max_new // 2 + 4)],
                               **kw)
    eng_b, _ = make_engine(NO_QUANT, **kw)
    out_f = run_wave(eng_f, prompts, max_new)
    out_b = run_wave(eng_b, prompts, max_new)
    eng_f.allocator.assert_quiescent()
    row = {
        "scenario": "pool-exhaustion",
        "injected": sum(1 for s, _, _ in inj_f.fired if s == "pool.steal"),
        "preemptions": eng_f.preemptions,
        "admission_failures": eng_f.admission_failures,
        "all_finished": all(not out_f[i].unfinished for i in out_f),
        "tokens_equal": equal_tokens(out_f, out_b),
        "steady_new_programs": steady_recompiles(eng_f, prompts, max_new),
        "harness_errors": inj_f.errors,
    }
    ok = (row["tokens_equal"] and row["all_finished"]
          and row["admission_failures"] == 0
          and row["steady_new_programs"] == 0
          and not row["harness_errors"])
    return row, ok


def scenario_poison_lane(retries: int, max_new: int):
    """Non-finite logits on one lane.  With a retry budget the request
    replays from its original prompt and every output matches the
    fault-free run; with retries=0 it fails alone (``error`` set) while
    the other lanes stay bitwise identical."""
    prompts = prompts_for(3)
    gcfg = GuardConfig(max_retries=retries)
    eng_f, inj_f = make_engine(NO_QUANT, [Fault("decode.logits", at=0,
                                                rid=1, count=1)],
                               guard_cfg=gcfg)
    eng_b, _ = make_engine(NO_QUANT, guard_cfg=gcfg)
    out_f = run_wave(eng_f, prompts, max_new)
    out_b = run_wave(eng_b, prompts, max_new)
    fired = sum(1 for s, _, _ in inj_f.fired if s == "decode.logits")
    failed = [i for i in out_f if out_f[i].error]
    row = {
        "scenario": f"poison-lane-retries{retries}", "injected": fired,
        "lane_faults": eng_f.lane_faults, "failed": failed,
        "errors": {i: out_f[i].error for i in failed},
        "tokens_equal_unaffected": equal_tokens(out_f, out_b, skip=(1,)),
        "victim_recovered": list(out_f[1]) == list(out_b[1]),
        "harness_errors": inj_f.errors,
    }
    ok = (fired == 1 and row["lane_faults"] == fired
          and row["tokens_equal_unaffected"]
          and not row["harness_errors"])
    if retries > 0:
        ok = ok and row["victim_recovered"] and not failed
    else:
        ok = ok and failed == [1] \
            and row["errors"][1] == "non-finite logits"
    return row, ok


def scenario_deadline(max_new: int):
    """Virtual-clock deadline expiry: a skew fault jumps the clock past
    one request's budget mid-generation.  That request fails with
    ``error == "deadline"`` (partial output kept); the undeadlined lane
    matches the no-skew baseline bitwise."""
    prompts = prompts_for(2)
    deadlines = {1: 0.5}
    skew = [Fault("clock.skew", at=3, magnitude=1.0)]
    eng_f, inj_f = make_engine(NO_QUANT, skew, clock=VirtualClock())
    eng_b, _ = make_engine(NO_QUANT, clock=VirtualClock())
    out_f = run_wave(eng_f, prompts, max_new, deadlines=deadlines)
    out_b = run_wave(eng_b, prompts, max_new, deadlines=deadlines)
    row = {
        "scenario": "deadline-skew",
        "injected": sum(1 for s, _, _ in inj_f.fired if s == "clock.skew"),
        "deadline_expirations": eng_f.deadline_expirations,
        "expired_error": out_f[1].error,
        "partial_kept": len(out_f[1]) > 0,
        "tokens_equal_unaffected": equal_tokens(out_f, out_b, skip=(1,)),
        "harness_errors": inj_f.errors,
    }
    ok = (row["deadline_expirations"] == 1
          and row["expired_error"] == "deadline"
          and row["tokens_equal_unaffected"]
          and eng_b.deadline_expirations == 0
          and not row["harness_errors"])
    return row, ok


def main(fast: bool = False):
    global PARAMS
    PARAMS = lm.init_params(CFG, jax.random.PRNGKey(0))
    max_new = 12 if fast else 24
    scenarios = [
        lambda: scenario_calib_poison("nan", max_new),
        lambda: scenario_pool_exhaustion(max_new),
    ]
    if not fast:
        scenarios += [
            lambda: scenario_calib_poison("inf", max_new),
            lambda: scenario_calib_poison("outlier", max_new),
            lambda: scenario_requant_corruption(max_new),
            lambda: scenario_poison_lane(1, max_new),
            lambda: scenario_poison_lane(0, max_new),
            lambda: scenario_deadline(max_new),
        ]
    report = {"config": {"model": CFG.name, "max_new": max_new,
                         "fast": fast}, "rows": []}
    ok_all = True
    for fn in scenarios:
        row, ok = fn()
        row["pass"] = ok
        report["rows"].append(row)
        ok_all = ok_all and ok
        detail = {k: v for k, v in row.items()
                  if k not in ("scenario", "pass")}
        print(f"{row['scenario']}: {'PASS' if ok else 'FAIL'}  {detail}")
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_robustness.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")
    if not ok_all:
        raise SystemExit("bench_robustness acceptance FAILED")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: NaN-stats + pool-exhaustion only")
    main(fast=ap.parse_args().fast)
