"""Paper Table 2 — groupsize impact at 3-bit: RTN vs AWQ (shifted calib) vs
TTQ (r=16).  Claim: TTQ tolerates ~2× larger groups at iso-quality."""
from __future__ import annotations

from .common import (collect_stats, eval_batches, perplexity, quantize_with,
                     trained_model, ttq_perplexity)

BITS = 3
CALIB_DOMAIN = 2


def run(fast: bool = True):
    cfg, params = trained_model()
    ev = eval_batches(0, n=2 if fast else 4)
    cal = eval_batches(CALIB_DOMAIN, n=2 if fast else 4, seed0=888)
    calib = collect_stats(cfg, params, cal)
    groups = (8, 16, 32, 64, 128) if fast else (8, 16, 32, 64, 128, 256)
    rows = []
    for g in groups:
        rtn = perplexity(cfg, quantize_with(cfg, params, "rtn", BITS, g), ev)
        awq = perplexity(cfg, quantize_with(cfg, params, "awq", BITS, g,
                                            calib=calib), ev)
        ttq = ttq_perplexity(cfg, params, ev, BITS, g, rank=16)
        rows.append((g, rtn, awq, ttq))
    return rows


def main(fast: bool = True):
    rows = run(fast)
    print("# Table-2 analogue: groupsize sweep at 3-bit")
    print("groupsize,rtn_ppl,awq_ppl,ttq_r16_ppl")
    for g, r, a, t in rows:
        print(f"{g},{r:.3f},{a:.3f},{t:.3f}")
    return rows


if __name__ == "__main__":
    main()
