"""Paper Tables 4–8 / Appendix H — decode runtime on TPU v5e, derived from the
weight-traffic roofline (this container is CPU-only; wall-clock is not TPU
evidence, so we report the memory-bound projection the way Appendix H's GPU
tables report k-tokens/sec).

Decode of one token against the query projection (the paper's microbenchmark):
    fp16 : move d'·d·2 bytes
    TTQ4 : move d'·d/2 (packed) + S,Z (2·d'·d/g·4) + dinv d·4 bytes
    +r16 : + B,A fp16 bytes (the un-quantized low-rank factors)
tokens/sec = HBM_bw / bytes_moved (memory-bound decode, arithmetic intensity
≪ ridge point).  Also cross-checked against XLA's cost_analysis byte counts
for the jitted ttq path at each size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.analysis import HBM_BW

# Qwen3 dims (hidden → q-proj out = heads × 128)
QWEN3 = {
    "0.6B": (1024, 16 * 128), "1.7B": (2048, 16 * 128),
    "4B": (2560, 32 * 128), "8B": (4096, 32 * 128),
    "14B": (5120, 40 * 128), "32B": (5120, 64 * 128),
}
G = 32


def traffic_bytes(d, dp, mode, rank=16):
    if mode == "fp16":
        return d * dp * 2
    b = d * dp // 2 + 2 * (d * dp // G) * 4 + d * 4          # int4 + S,Z + dinv
    if mode == "ttq4_r16":
        b += (d + dp) * rank * 2
    return b


def measured_bytes(d, dp, mode):
    """XLA cost-analysis bytes for the actual jitted decode matmul."""
    x = jax.ShapeDtypeStruct((1, d), jnp.bfloat16)
    if mode == "fp16":
        W = jax.ShapeDtypeStruct((dp, d), jnp.bfloat16)
        fn = jax.jit(lambda xx, ww: xx @ ww.T)
        comp = fn.lower(x, W).compile()
    else:
        from repro.core.qdq import unpack_bits
        pk = jax.ShapeDtypeStruct((dp, d // 8), jnp.int32)
        S = jax.ShapeDtypeStruct((dp, d // G), jnp.float32)
        Z = jax.ShapeDtypeStruct((dp, d // G), jnp.float32)
        dinv = jax.ShapeDtypeStruct((d,), jnp.float32)

        def fn(xx, pk, S, Z, dinv):
            w = unpack_bits(pk, d, 4).astype(jnp.float32)
            w = w.reshape(dp, d // G, G) * S[..., None] + Z[..., None]
            return (xx * dinv) @ w.reshape(dp, d).T.astype(jnp.bfloat16)

        comp = jax.jit(fn).lower(x, pk, S, Z, dinv).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return float(ca.get("bytes accessed", 0.0))


def compile_count_probe():
    """jit-cache discipline for the ttq decode matmul: repeat calls at a
    seen shape must hit the cache (one program per shape, counted via
    ``_cache_size()`` — the same counter ``TTQEngine.compiled_programs``
    aggregates and tracecheck's TC2xx pass guards statically).  Returns
    (programs after 2×same + 1×new shape, expected)."""
    from repro.core.qdq import unpack_bits
    d, dp = QWEN3["0.6B"]

    @jax.jit
    def fn(xx, pk, S, Z, dinv):
        w = unpack_bits(pk, d, 4).astype(jnp.float32)
        w = w.reshape(dp, d // G, G) * S[..., None] + Z[..., None]
        return (xx * dinv) @ w.reshape(dp, d).T.astype(jnp.bfloat16)

    def args(rows):
        return (jnp.zeros((rows, d), jnp.bfloat16),
                jnp.zeros((dp, d // 8), jnp.int32),
                jnp.ones((dp, d // G), jnp.float32),
                jnp.zeros((dp, d // G), jnp.float32),
                jnp.ones((d,), jnp.float32))

    fn(*args(1))
    fn(*args(1))             # same shape: cache hit, no new program
    fn(*args(4))             # new batch shape: exactly one more
    return fn._cache_size(), 2


def run(fast: bool = True):
    rows = []
    for name, (d, dp) in QWEN3.items():
        fp = traffic_bytes(d, dp, "fp16")
        t0 = traffic_bytes(d, dp, "ttq4")
        t16 = traffic_bytes(d, dp, "ttq4_r16")
        ktoks = lambda b: HBM_BW / b / 1e3
        rows.append((name, ktoks(fp), ktoks(t0), ktoks(t16), fp / t0))
    return rows


def kv_context_rows(contexts=(4096, 8192, 16384, 32768)):
    """Whole-step decode roofline: weight term + KV-cache term at context S.

    The weight term uses the gemma-7b int4 tree; the cache term comes from
    ``bench_kvcache.cache_bytes_per_step`` — at 16k+ the cache dominates and
    the weight-only speedup (the table above) stops mattering (EXPERIMENTS.md
    §Roofline).
    """
    try:
        from .bench_kvcache import WEIGHT_BYTES_TTQ4, cache_bytes_per_step
    except ImportError:                      # run as a script, not a package
        from bench_kvcache import WEIGHT_BYTES_TTQ4, cache_bytes_per_step
    rows = []
    for S in contexts:
        tot = {kv: WEIGHT_BYTES_TTQ4 + cache_bytes_per_step(S, kv)
               for kv in ("bf16", "int8", "int4")}
        rows.append((S, {kv: HBM_BW / b for kv, b in tot.items()},
                     tot["bf16"] / tot["int8"]))
    return rows


def main(fast: bool = True):
    rows = run(fast)
    print("# Tables-4..8 analogue: v5e-projected decode k-tokens/s of the "
          "query projection (memory-bound roofline)")
    print("model,fp16_ktok_s,ttq4_ktok_s,ttq4_r16_ktok_s,speedup_ttq4_vs_fp16")
    for name, fp, t0, t16, sp in rows:
        print(f"qwen3-{name},{fp:.1f},{t0:.1f},{t16:.1f},{sp:.2f}x")
    print("# whole-step decode tok/s at context S (ttq4 weights + KV term, "
          "gemma-7b geometry — see bench_kvcache.py)")
    print("context,tok_s_kv_bf16,tok_s_kv_int8,tok_s_kv_int4,step_speedup_int8")
    for S, toks, sp in kv_context_rows():
        print(f"{S},{toks['bf16']:.1f},{toks['int8']:.1f},"
              f"{toks['int4']:.1f},{sp:.2f}x")
    # cross-check the traffic model against XLA byte counts on the largest dim
    d, dp = QWEN3["32B"]
    mfp = measured_bytes(d, dp, "fp16")
    mtq = measured_bytes(d, dp, "ttq4")
    print(f"xla_bytes_fp16_32B,{mfp:.0f}")
    print(f"xla_bytes_ttq4_32B,{mtq:.0f}")
    print(f"xla_speedup_32B,{mfp / mtq:.2f}x")
    got, want = compile_count_probe()
    print(f"jit_programs_after_2x_same_plus_1_new_shape,{got} (expect {want})")
    if got != want:
        raise SystemExit("bench_runtime jit-cache gate FAILED: repeated "
                         "same-shape calls recompiled the decode matmul")
    return rows


if __name__ == "__main__":
    main()
