"""Streaming SLO bench — chunked prefill vs monolithic under open-loop load.

The headline experiment (DESIGN.md §13): a long prompt arriving while
other streams are decoding.  Monolithic prefill stalls every running
lane for one giant dispatch — the stall lands in the victims' p99
inter-token latency (ITL).  Chunked prefill splits the ingestion into
``prefill_chunk``-sized dispatches interleaved with decode rounds, so
the worst-case stall shrinks to one chunk.

Three gates (CI runs ``--fast``):

  * **bitwise equality** — chunked and monolithic ingestion produce
    identical greedy tokens (dense bf16 and paged int8 probes; the full
    layout × precision × speculation matrix lives in
    tests/test_chunked_prefill.py);
  * **zero steady-state recompiles** — after a warm wave that has seen
    the same prompt lengths, the measured open-loop phase compiles no
    new XLA program (chunked ingestion adds one prefill program per
    distinct chunk *offset* — a bounded set, ≤ max_len/chunk — all
    warmed by one long prompt);
  * **transfer-guard** — with admission and ingestion quiesced, the
    remaining pure-decode loop runs under
    ``jax.transfer_guard("disallow")`` (prompt staging is host→device
    by nature, exactly like admission — EXPERIMENTS.md
    §"Transfer-guard methodology").

The latency phase is the paper scenario measured directly: two victim
streams decode from t=0 under open-loop Poisson background shorts
(arrivals never wait for the system — queueing is part of what's
measured), and the long prompt (4096 tokens; 512 under ``--fast``)
arrives once the victims are mid-stream.  The gated number is the
victims' own p99 ITL: with ~2·victim_new gaps, the one prefill-sized
stall per victim sits exactly in the top 1%, so p99 samples it rather
than diluting it (pooled whole-engine percentiles are also reported).
The ≥3x p99-ITL improvement is gated in ``--full`` runs only (timing
gates are advisory under ``--fast``, same policy as bench_engine).

Run:  PYTHONPATH=src python benchmarks/bench_serve_slo.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import numpy as np

from repro.core import NO_QUANT
from repro.models import ModelConfig, lm
from repro.serving import EngineConfig, TTQEngine

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

CFG = ModelConfig(name="bench-slo", family="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=96, vocab=128)
PARAMS = None


def _prompt(rng, n):
    return list(rng.integers(1, CFG.vocab, size=n))


def make_engine(chunked: bool, long_len: int, chunk: int, **kw):
    buckets = (16, 32, 64) + ((long_len,) if not chunked else ())
    ecfg = EngineConfig(max_slots=4, max_len=long_len + 128, decode_chunk=1,
                       temperature=0.0, recalibrate_tokens=10**9,
                       prompt_buckets=buckets,
                       prefill_chunk=chunk if chunked else 0,
                       **kw)
    return TTQEngine(CFG, PARAMS, NO_QUANT, ecfg)


# ----------------------------------------------------------------- equality


def equality_gate(long_len: int, chunk: int) -> dict:
    """Chunked vs monolithic greedy tokens, dense bf16 + paged int8."""
    rng = np.random.default_rng(0)
    prompts = [_prompt(rng, long_len), _prompt(rng, 24), _prompt(rng, 40)]
    row = {}
    for label, kw in (("dense-bf16", {}),
                      ("paged-int8", dict(kv_paged=True, kv_block_size=16,
                                          kv_dtype="int8"))):
        outs = []
        for chunked in (False, True):
            eng = make_engine(chunked, long_len, chunk, **kw)
            rids = [eng.submit(p, max_new=8) for p in prompts]
            res = eng.run_all()
            outs.append([list(res[r]) for r in rids])
            if eng.allocator is not None:
                eng.allocator.assert_quiescent()
        row[label] = outs[0] == outs[1]
    return row


# ------------------------------------------------------------ open-loop load


def poisson_schedule(rng, window_s: float, rate_hz: float):
    """Open-loop Poisson short arrivals (8–48 tokens): timestamps are
    fixed up front and never wait for the system — queueing is part of
    the measured system."""
    sched = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / rate_hz))
        if t >= window_s:
            break
        sched.append((t, _prompt(rng, int(rng.integers(8, 48)))))
    return sched


def _pct(xs, q):
    """Nearest-rank percentile: ceil(q*n)-th smallest.  The rank matters
    here — with 2 victims × victim_new tokens there are ~2·victim_new-2
    gaps and exactly 2 stall gaps (one per victim), and nearest-rank p99
    lands on the 2nd-largest of 198, i.e. the smaller stall.  A floor
    rule would land on the 3rd-largest and miss both."""
    xs = sorted(xs)
    return xs[min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))] \
        if xs else 0.0


def warm(eng, long_len: int):
    """Compile everything the open-loop phase can dispatch.  Prefill
    programs are keyed by (bucket, admission-group size), so warm every
    short bucket at group sizes 1..max_slots and the long prompt at 1–2
    (two simultaneous long admissions is already a tail event)."""
    rng = np.random.default_rng(9)
    for b in (16, 32, 64):
        for g in range(1, eng.ecfg.max_slots + 1):
            for _ in range(g):
                eng.submit(_prompt(rng, b - 1), max_new=2)
            eng.run_all()
    for g in (1, 2):
        for _ in range(g):
            eng.submit(_prompt(rng, long_len), max_new=2)
        eng.run_all()
    eng.scheduler.finished.clear()           # latency stats start clean


def latency_phase(chunked: bool, long_len: int, chunk: int, window_s: float,
                  rate_hz: float, victim_new: int) -> dict:
    """The headline scenario.  Two victim streams decode from t=0 under
    open-loop Poisson background shorts; once the victims are a quarter
    into their budget the long prompt arrives.  The victims' own p99 ITL
    is the gated number — monolithic ingestion puts one prefill-sized
    gap in each victim stream (top 1% of ~2·victim_new gaps, so p99
    samples it exactly); chunked ingestion caps the gap at one chunk.
    The measured window must compile nothing (warm() covers it)."""
    eng = make_engine(chunked, long_len, chunk)
    warm(eng, long_len)
    warm_programs = eng.compiled_programs

    rng = np.random.default_rng(2)
    victims = [eng.submit(_prompt(rng, 24), max_new=victim_new),
               eng.submit(_prompt(rng, 40), max_new=victim_new)]
    shorts = poisson_schedule(np.random.default_rng(3), window_s, rate_hz)
    long_prompt = _prompt(rng, long_len)
    long_rid = None
    sched = eng.scheduler
    t0 = time.monotonic()
    i = 0
    while (i < len(shorts) or sched.has_work() or sched.has_deferred_work()):
        now = time.monotonic() - t0
        while i < len(shorts) and shorts[i][0] <= now:
            eng.submit(shorts[i][1], max_new=8)
            i += 1
        if long_rid is None:
            v0 = next((r for r in eng.slot_req if r and r.rid == victims[0]),
                      None)
            if v0 is not None and len(v0.out) >= victim_new // 4:
                long_rid = eng.submit(long_prompt, max_new=8)  # mid-stream
        if sched.has_work() or sched.has_deferred_work():
            eng.step()
        elif i < len(shorts):
            time.sleep(min(0.002, max(0.0, shorts[i][0] - now)))

    fin = sched.finished
    gaps = [b - a for v in victims
            for a, b in zip(fin[v].tok_times, fin[v].tok_times[1:])]
    long_ts = fin[long_rid].tok_times if long_rid is not None else []
    lat = eng.latency_percentiles()            # engine-wide, informative
    lat.update(
        victim_itl_p50=_pct(gaps, 0.50), victim_itl_p99=_pct(gaps, 0.99),
        victim_gaps=len(gaps),
        long_ttft=(long_ts[0] - fin[long_rid].submit_t) if long_ts else None,
        steady_new_programs=eng.compiled_programs - warm_programs,
        requests=2 + len(shorts) + 1,
        prefill_chunks=eng.prefill_chunks)
    return lat


# ------------------------------------------------------------ transfer guard


def transfer_guard_probe(long_len: int, chunk: int) -> bool:
    """Quiesce ingestion, then run the remaining decode rounds under
    ``transfer_guard("disallow")`` — implicit transfers raise."""
    eng = make_engine(True, long_len, chunk)
    rng = np.random.default_rng(3)
    for n in (24, 40, long_len):
        eng.submit(_prompt(rng, n), max_new=12)
    sched = eng.scheduler
    while sched.queue or sched.prefilling:  # admission + chunk ingestion:
        eng.step()                          # host→device staging by nature
    try:
        with jax.transfer_guard("disallow"):
            while sched.has_work():
                if not eng.step():
                    break
        return True
    except Exception as e:                  # an implicit transfer raised
        print(f"transfer-guard probe tripped: {e}")
        return False


# --------------------------------------------------------------------- main


def main(fast: bool = False):
    global PARAMS
    PARAMS = lm.init_params(CFG, jax.random.PRNGKey(0))
    long_len = 512 if fast else 4096
    chunk = 64 if fast else 256
    window_s = 3.0 if fast else 10.0
    rate_hz = 4.0 if fast else 6.0
    victim_new = 100

    print(f"equality gate (long={long_len}, chunk={chunk}) ...")
    eq = equality_gate(long_len, chunk)
    print(f"  {eq}")

    rows = {}
    for label, chunked in (("unchunked", False), ("chunked", True)):
        print(f"open-loop load [{label}] ...")
        rows[label] = latency_phase(chunked, long_len, chunk, window_s,
                                    rate_hz, victim_new)
        r = rows[label]
        print(f"  victim itl p50/p99 {r['victim_itl_p50'] * 1e3:.1f}/"
              f"{r['victim_itl_p99'] * 1e3:.1f} ms "
              f"({r['victim_gaps']} gaps), long ttft "
              f"{(r['long_ttft'] or 0) * 1e3:.1f} ms, engine-wide ttft "
              f"p50/p99 {r['ttft_p50'] * 1e3:.1f}/"
              f"{r['ttft_p99'] * 1e3:.1f} ms, "
              f"{r['requests']} req, "
              f"{r['steady_new_programs']} new programs")

    itl_ratio = (rows["unchunked"]["victim_itl_p99"]
                 / max(rows["chunked"]["victim_itl_p99"], 1e-9))
    print(f"p99 ITL improvement: {itl_ratio:.2f}x "
          f"(gate ≥3x in --full; advisory under --fast)")

    guard_ok = transfer_guard_probe(long_len, chunk)

    report = {
        "config": {"model": CFG.name, "long_len": long_len, "chunk": chunk,
                   "window_s": window_s, "rate_hz": rate_hz,
                   "victim_new": victim_new, "fast": fast},
        "equality": eq,
        "latency": rows,
        "itl_p99_improvement": itl_ratio,
        "transfer_guard_ok": guard_ok,
    }
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_serve_slo.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")

    ok = (all(eq.values()) and guard_ok
          and rows["chunked"]["steady_new_programs"] == 0
          and rows["unchunked"]["steady_new_programs"] == 0)
    if not fast:
        ok = ok and itl_ratio >= 3.0
    if not ok:
        raise SystemExit("bench_serve_slo acceptance FAILED")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: 512-token long prompt, 3 s window; "
                         "equality/recompile/guard gates only (the 3x ITL "
                         "gate needs --full)")
    main(fast=ap.parse_args().fast)
