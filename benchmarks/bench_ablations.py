"""Appendix-E ablations — beyond the main tables:

1. alternating quantization-aware factorization (eq. 34-35) vs plain SVD
   (paper: "almost no gain" — verify),
2. quantized low-rank factors (A / B / both) vs fp factors,
3. AWQ statistic form: paper pseudo-code ('raw') vs Ledoit-Wolf 'blend',
   and the ℓ1 vs ℓ2 norm choice (paper App. F: ℓ1 "a terrible choice").
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (AWQConfig, QuantConfig, activation_diag,
                        alternating_refine, awq_qdq, svd_factors,
                        ttq_lowrank_qdq)
from repro.core.awq import awq_loss
from repro.core.lowrank import quantize_factors


def _setup(seed, dp=96, d=192, T=384):
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.standard_normal((dp, d)).astype("float32") * 0.05)
    chan = np.exp(rng.standard_normal(d) * 1.8).astype("float32")
    X = jnp.asarray(rng.standard_normal((T, d)).astype("float32") * chan)
    return W, X, jnp.mean(X ** 2, axis=0)


def run(fast: bool = True):
    qcfg = QuantConfig(bits=3, group_size=32, layout="row")
    trials = 3 if fast else 8
    agg: dict = {}
    for t in range(trials):
        W, X, Cd = _setup(100 + t)
        D = activation_diag(X)
        B, A = svd_factors(W, 16)
        rows = {
            "svd_factors": awq_loss(W, ttq_lowrank_qdq(W, B, A, D, qcfg), Cd),
        }
        Br, Ar = alternating_refine(W, D, qcfg, 16, iters=3)
        rows["alternating_refine"] = awq_loss(
            W, ttq_lowrank_qdq(W, Br, Ar, D, qcfg), Cd)
        for which in ("A", "B", "both"):
            qB, qA = quantize_factors(B, A, QuantConfig(bits=8, group_size=16),
                                      which)
            rows[f"quant_factor_{which}"] = awq_loss(
                W, ttq_lowrank_qdq(W, qB, qA, D, qcfg), Cd)
        for form, p in (("raw", 2.0), ("raw", 1.0), ("blend", 2.0)):
            Dv = activation_diag(X, AWQConfig(form=form, p=p))
            rows[f"awq_{form}_l{int(p)}"] = awq_loss(W, awq_qdq(W, Dv, qcfg), Cd)
        for k, v in rows.items():
            agg.setdefault(k, []).append(float(v))
    return {k: float(np.mean(v)) for k, v in agg.items()}


def main(fast: bool = True):
    out = run(fast)
    print("# Appendix-E/F ablations — activation-aware loss (lower = better)")
    print("variant,loss")
    for k, v in out.items():
        print(f"{k},{v:.2f}")
    base = out["svd_factors"]
    print(f"alternating_gain,{(base - out['alternating_refine']) / base:.3%}")
    return out


if __name__ == "__main__":
    main()
