"""§Roofline report — reads results/dryrun/*.json into the per-(arch × shape)
three-term table used in EXPERIMENTS.md. Run the dry-run first."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(mesh="single", quant="ttq4", opt=None):
    rows = []
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("mesh") != mesh:
            continue
        if r.get("kind") == "decode" and r.get("quant") != quant:
            continue
        if opt is not None and r.get("opt_level", 1) != opt:
            continue
        if opt is None and r.get("opt_level", 1) != 1:
            continue
        rows.append(r)
    return rows


def fmt_row(r):
    if "skipped" in r:
        return f"{r['arch']:24s} {r['shape']:12s} SKIP ({r['skipped'][:40]}…)"
    if "error" in r:
        return f"{r['arch']:24s} {r['shape']:12s} ERROR {r['error'][:50]}"
    rl = r["roofline"]
    dom = rl["dominant"]
    terms = (rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
    an = r.get("analytic", {})
    ideal = ""
    if an:
        ideal = (f" | ideal C={an['t_compute_s']:.1e} M={an['t_memory_s']:.1e}"
                 f" X={an['t_collective_s']:.1e}")
    ufr = rl.get("useful_flop_ratio", 0.0)
    return (f"{r['arch']:24s} {r['shape']:12s} "
            f"C={terms[0]:.2e} M={terms[1]:.2e} X={terms[2]:.2e} "
            f"dom={dom:10s} useful={ufr:.3f}{ideal}")


def main():
    for mesh in ("single", "multi"):
        rows = load(mesh)
        if not rows:
            continue
        print(f"== mesh: {mesh} (HLO-walker terms; 'ideal' = analytic "
              f"TPU lower bound, EXPERIMENTS.md §Roofline caveat) ==")
        for r in rows:
            print(fmt_row(r))
    return 0


if __name__ == "__main__":
    main()
