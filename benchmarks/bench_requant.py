"""Requantization dispatch — eager per-leaf vs fused single-dispatch, the
kernel-backed decode path, and the delta gate under a domain-shift stream.

TTQ's serving claim needs online requantization to be near-free (paper
eq. 3).  The eager driver (`quantize_params`) walks the tree leaf by leaf —
dozens of small device dispatches per requant that block the serving loop at
every recalibration.  `FusedRequantPlan` groups the quantizable weights into
(shape, bits, group) families and quantizes each family's stacked weights in
ONE jitted device program.  This bench measures, at bench-model scale:

  * ``requant``  — wall-time per whole-model requantization, eager vs fused
                   (acceptance: fused ≥ 5× faster — wall-clock-gated only in
                   the full run; ``--fast`` keeps the deterministic
                   dispatch-count check, mirroring bench_engine's policy for
                   shared CI runners) and the per-family dispatch count;
  * ``decode``   — engine decode tok/s with the Pallas ttq_gemm on vs off
                   over packed int4 weights (reported, not gated: this
                   container runs Pallas in interpret mode, so the kernel
                   path is an emulator here — the number that matters on
                   TPU is bytes moved, bench_runtime's table);
  * ``gate``     — drift-gate hit rate on a two-phase request stream: a
                   stable domain (gate should skip almost everything) that
                   shifts mid-stream (gate must wake the drifted layers).

Run:  PYTHONPATH=src python benchmarks/bench_requant.py [--fast]
Emits results/BENCH_requant.json; numbers land in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _block(tree):
    return jax.block_until_ready(tree)


def _timed(fn, reps: int):
    """min-of-reps: robust to CI-runner contention (latency, not throughput)."""
    fn()                                        # warm (jit compile)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_requant_latency(fast: bool):
    from repro.models import ModelConfig, lm
    from repro.models.config import HybridCfg
    from repro.quant import quantize_params, ttq_policy
    from repro.quant.api import FusedRequantPlan

    # hybrid (rec,rec,attn pattern): 19 distinct quantizable leaves — the
    # representative case for per-leaf dispatch overhead (a dense stack has
    # only 7 leaves, which under-counts what eager requantization costs on
    # the heterogeneous families)
    cfg = ModelConfig(name="bench-requant", family="hybrid", n_layers=6,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab=512, hybrid=HybridCfg())
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    _, _, stats = lm.prefill(cfg, params, {"tokens": toks}, max_len=40)
    count = float(toks.size)
    pol = ttq_policy(bits=4, group_size=32, rank=0)
    reps = 5 if fast else 10

    eager_s = _timed(lambda: _block(quantize_params(
        params, stats, pol, count=count)), reps)
    plan = FusedRequantPlan(params, stats, pol)
    fused_s = _timed(lambda: _block(plan.run(params, stats, count)), reps)
    row = {
        "model": cfg.name, "layers": plan.n_layers,
        "families": len(plan.families),
        "eager_ms": round(eager_s * 1e3, 2),
        "fused_ms": round(fused_s * 1e3, 2),
        "speedup": round(eager_s / fused_s, 2),
    }
    # deterministic structural acceptance (runs in --fast too): the fused
    # plan really is a handful of programs, not one per leaf
    assert len(plan.families) < plan.n_layers, \
        f"fused plan degenerated: {len(plan.families)} families for " \
        f"{plan.n_layers} leaves"
    print("mode,layers,dispatch_units,wall_ms")
    print(f"eager,{plan.n_layers},{plan.n_layers},{row['eager_ms']}")
    print(f"fused,{plan.n_layers},{len(plan.families)},{row['fused_ms']}")
    gated = "" if not fast else " (reported only under --fast)"
    print(f"requant speedup: {row['speedup']}x "
          f"({'PASS' if row['speedup'] >= 5 else 'FAIL'} >= 5x{gated})")
    return row


def bench_decode_kernels(fast: bool):
    from repro.models import ModelConfig, lm
    from repro.quant import ttq_policy
    from repro.serving import EngineConfig, TTQEngine

    cfg = ModelConfig(name="bench-decode", family="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
                      vocab=128)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    pol = ttq_policy(bits=4, group_size=32, rank=0, packed=True)
    max_new = 8 if fast else 24
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab, size=6)) for _ in range(2)]
    rows, streams = [], {}
    for use in (False, True):
        eng = TTQEngine(cfg, params, pol,
                        EngineConfig(max_slots=2, max_len=64, decode_chunk=4,
                                     use_kernels=use))
        for p in prompts:                       # warm wave: jit compiles
            eng.submit(p, max_new=max_new)
        eng.run_all()
        t0 = time.perf_counter()
        for p in prompts:
            eng.submit(p, max_new=max_new)
        out = eng.run_all()
        dt = time.perf_counter() - t0
        # both modes see the identical stats stream → identical quantized
        # weights → the token streams must match across kernel on/off
        streams[use] = sorted(map(tuple, out.values()))
        toks = sum(len(v) for v in out.values())
        rows.append({"kernels": use, "tokens": toks,
                     "tok_s": round(toks / dt, 1)})
        print(f"decode kernels={use}: {toks} tok, {toks / dt:.1f} tok/s"
              + ("  (interpret-mode Pallas: emulated, not TPU-speed)"
                 if use else ""))
    assert streams[True] == streams[False], \
        "kernel path diverged from the jnp fallback"
    return rows


def bench_drift_gate(fast: bool):
    from repro.models import ModelConfig, lm
    from repro.quant import QuantizedModel, ttq_policy

    cfg = ModelConfig(name="bench-gate", family="dense", n_layers=3,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab=256)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    n_phase = 4 if fast else 8
    threshold = 0.05

    def stats_for(seed, lo, hi):
        toks = jax.random.randint(jax.random.PRNGKey(seed), (2, 24), lo, hi)
        _, _, st = lm.prefill(cfg, params, {"tokens": toks}, max_len=32)
        return st

    qm = QuantizedModel(params, ttq_policy(bits=4, group_size=32, rank=0),
                        halflife=2.0)
    steps = []
    for i in range(2 * n_phase):
        shifted = i >= n_phase
        # phase A: broad-vocab domain; phase B: narrow degenerate domain
        st = stats_for(i, 200, 256) if shifted else stats_for(i, 1, 200)
        qm.calibrate(st, tokens=48.0)
        qm.requantize(threshold=threshold)
        steps.append({"step": i, "shifted": shifted,
                      "requant": qm.last_requant_layers,
                      "skipped": qm.last_skipped_layers})
    total = qm._plan.n_layers
    stable = steps[1:n_phase]                    # step 0 seeds the snapshot
    shift_step = steps[n_phase]
    stable_skip = sum(s["skipped"] for s in stable) / (len(stable) * total)
    print(f"gate threshold={threshold}: stable-domain skip rate "
          f"{stable_skip:.0%}, at shift {shift_step['requant']}/{total} "
          f"layers requantized")
    ok = stable_skip > 0 and shift_step["requant"] > 0
    print(f"gate acceptance: {'PASS' if ok else 'FAIL'} "
          f"(skips on stable domain, wakes on shift)")
    return {"threshold": threshold, "layers": total,
            "stable_skip_rate": round(stable_skip, 3),
            "shift_requant_layers": shift_step["requant"],
            "steps": steps, "ok": ok}


def main(fast: bool = False):
    report = {"requant": bench_requant_latency(fast),
              "decode": bench_decode_kernels(fast),
              "gate": bench_drift_gate(fast)}
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_requant.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")
    # wall-clock gate only at full scale — --fast (the CI smoke) keeps the
    # deterministic checks (dispatch-unit count, kernel-on/off token
    # equality, gate behavior); timing ratios on shared runners are flaky
    if not fast and report["requant"]["speedup"] < 5:
        raise SystemExit("bench_requant acceptance FAILED: fused < 5x eager")
    if not report["gate"]["ok"]:
        raise SystemExit("bench_requant acceptance FAILED: drift gate")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    a = ap.parse_args()
    main(fast=a.fast)
