"""KV-cache decode traffic + paged-pool capacity — bytes and concurrency.

At long contexts the decode step is memory-bound on the *cache*, not the
weights: every generated token reads the full K and V history of every
attention layer.  This bench reports, per cache dtype (bf16 / int8 / int4):

  * analytic bytes moved per decode step (codes + scales, all layers), and
    the reduction vs bf16 — the acceptance number is the int8 ratio at 8k;
  * the v5e roofline tokens/s projection (HBM_BW / bytes, the same
    memory-bound model as ``bench_runtime``), including the quantized-weight
    term so the totals compose;
  * **paged capacity** (DESIGN.md §8): on a mixed prompt-length workload
    (32–1024 at ``max_len=2048``) the dense slab reserves ``max_len`` rows
    per slot while the paged pool reserves only ``ceil((plen+max_new)/bs)``
    blocks per request — the table reports per-request footprint,
    utilization (useful rows / reserved rows — the dense slab's is its
    fragmentation problem), and effective concurrent requests per HBM byte.
    Acceptance: **≥ 2× requests/byte vs the dense slab**.  Bytes are
    *measured* from allocated ``lm.init_decode_state`` buffers (dense slab
    vs pool sized for equal concurrency), not just the analytic model.

Run:  PYTHONPATH=src python benchmarks/bench_kvcache.py [--fast]
Emits results/BENCH_kvcache.json; numbers land in EXPERIMENTS.md §Roofline
(decode-traffic table) and §Perf iteration 8.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kvquant import KVCacheConfig
from repro.launch.analysis import HBM_BW

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

# gemma-7b attention geometry (28L, MHA kv=16, head_dim 256) — the paper's
# long-context cell; per-(head, token) scales (group_size=0)
GEMMA = dict(n_layers=28, n_kv_heads=16, head_dim=256)
CONTEXTS = (4096, 8192, 16384, 32768)
MODES = ("bf16", "int8", "int4")
# int4 weights of the 8.5e9-param tree — the weight term at decode (so the
# table composes with bench_runtime's weight-only roofline)
WEIGHT_BYTES_TTQ4 = 8.5e9 * 0.5


def cache_bytes_per_step(S: int, mode: str, *, n_layers=None, n_kv_heads=None,
                         head_dim=None, batch: int = 1) -> float:
    """Bytes read by one decode step: K + V, all layers, all heads, S tokens."""
    g = GEMMA if n_layers is None else dict(n_layers=n_layers,
                                            n_kv_heads=n_kv_heads,
                                            head_dim=head_dim)
    per_row = KVCacheConfig(dtype=mode).bytes_per_token_head(g["head_dim"])
    return 2.0 * batch * g["n_layers"] * g["n_kv_heads"] * S * per_row


def _bench_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="bench", family="dense", n_layers=2,
                       d_model=4096, n_heads=16,
                       n_kv_heads=GEMMA["n_kv_heads"],
                       head_dim=GEMMA["head_dim"], d_ff=128, vocab=256)


def measured_state_bytes(S: int, mode: str, *, batch: int = 1,
                         num_blocks: int = 0, block_size: int = 16) -> float:
    """Allocate the REAL decode state via ``lm.init_decode_state`` (reduced
    depth, gemma head geometry) and count the cache leaves' device bytes.

    Every decode step streams a slot's whole cache once, so allocated bytes
    track bytes-moved.  This is a measurement of the shipped layout, not
    the analytic model: if the state tree carried bf16 anywhere it claims
    int8 — or the paged pool silently allocated the dense slab — this
    number catches it.  Scaled back to 28 layers for the table.
    ``num_blocks > 0`` allocates the paged layout instead of the slab.
    """
    from repro.models import lm
    cfg = _bench_cfg()
    kvcfg = KVCacheConfig(dtype=mode, paged=num_blocks > 0,
                          block_size=block_size)
    st = lm.init_decode_state(cfg, batch, S, kvcfg=kvcfg,
                              num_blocks=num_blocks)
    byts = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(st))
    return byts * GEMMA["n_layers"] / cfg.n_layers


# ---------------------------------------------------------------------------
# paged capacity: mixed prompt lengths, requests per HBM byte
# ---------------------------------------------------------------------------

def mixed_workload(n: int, lo: int = 32, hi: int = 1024, seed: int = 0):
    """Log-uniform prompt lengths in [lo, hi] — the heterogeneous-traffic
    regime TTQ targets (per-prompt adaptation implies per-prompt length)."""
    rng = np.random.default_rng(seed)
    return np.exp(rng.uniform(np.log(lo), np.log(hi), size=n)).astype(int)


def paged_capacity(mode: str, *, max_len: int = 2048, block_size: int = 16,
                   max_new: int = 128, n_req: int = 64, seed: int = 0):
    """Per-request reserved footprint, utilization, and requests/byte for
    the dense slab vs the paged pool on a mixed workload."""
    g = GEMMA
    row = (2.0 * g["n_layers"] * g["n_kv_heads"]
           * KVCacheConfig(dtype=mode).bytes_per_token_head(g["head_dim"]))
    plens = mixed_workload(n_req, seed=seed)
    used_rows = np.minimum(plens + max_new, max_len)            # rows touched
    dense_rows = np.full_like(used_rows, max_len)               # slab reserve
    paged_rows = (-(-used_rows // block_size)) * block_size     # block reserve
    dense_bytes = float(dense_rows.mean()) * row
    paged_bytes = float(paged_rows.mean()) * row
    return {
        "mode": mode,
        "avg_prompt": float(plens.mean()),
        "dense_req_MB": dense_bytes / 1e6,
        "paged_req_MB": paged_bytes / 1e6,
        "dense_utilization": float(used_rows.sum() / dense_rows.sum()),
        "paged_utilization": float(used_rows.sum() / paged_rows.sum()),
        "req_per_byte_gain": dense_bytes / paged_bytes,
    }


def run(fast: bool = True):
    rows = []
    for S in CONTEXTS:
        byts = {m: cache_bytes_per_step(S, m) for m in MODES}
        toks = {m: HBM_BW / (byts[m] + WEIGHT_BYTES_TTQ4) for m in MODES}
        rows.append((S, byts, toks))
    return rows


def main(fast: bool = True):
    rows = run(fast)
    report = {"traffic": [], "paged_capacity": [], "allocated": {}}
    print("# KV-cache decode traffic — gemma-7b geometry, batch=1, "
          "per-(head,token) scales")
    print("context,cache_GB_bf16,cache_GB_int8,cache_GB_int4,"
          "reduction_int8,reduction_int4,tok_s_bf16,tok_s_int8,tok_s_int4")
    for S, byts, toks in rows:
        report["traffic"].append({"context": S,
                                  **{f"GB_{m}": byts[m] / 1e9 for m in MODES}})
        print(f"{S},{byts['bf16']/1e9:.2f},{byts['int8']/1e9:.2f},"
              f"{byts['int4']/1e9:.2f},"
              f"{byts['bf16']/byts['int8']:.2f}x,"
              f"{byts['bf16']/byts['int4']:.2f}x,"
              f"{toks['bf16']:.1f},{toks['int8']:.1f},{toks['int4']:.1f}")
    red8 = rows[1][1]["bf16"] / rows[1][1]["int8"]
    print(f"acceptance: int8 vs bf16 bytes-moved at 8k = {red8:.2f}x "
          f"({'PASS' if red8 >= 1.5 else 'FAIL'} >= 1.5x)")
    # allocated-layout cross-check: real init_decode_state buffers (CPU-safe)
    S = 1024 if fast else 8192
    mbf = measured_state_bytes(S, "bf16")
    mi8 = measured_state_bytes(S, "int8")
    mi4 = measured_state_bytes(S, "int4")
    print(f"allocated_cache_GB_bf16_S{S},{mbf/1e9:.3f}")
    print(f"allocated_cache_GB_int8_S{S},{mi8/1e9:.3f}")
    print(f"allocated_cache_GB_int4_S{S},{mi4/1e9:.3f}")
    print(f"allocated_reduction_int8_S{S},{mbf / mi8:.2f}x")
    print(f"allocated_reduction_int4_S{S},{mbf / mi4:.2f}x")

    # ---- paged capacity: mixed prompts 32–1024 at max_len=2048 ----
    max_len, bs, max_new = 2048, 16, 128
    n_req = 32 if fast else 256
    print(f"\n# Paged pool capacity — mixed prompts 32-1024, "
          f"max_len={max_len}, block={bs}, max_new={max_new} "
          f"(reserved footprint per request; utilization = useful rows / "
          f"reserved rows)")
    print("mode,dense_MB_per_req,paged_MB_per_req,dense_util,paged_util,"
          "req_per_byte_gain")
    ok_cap = True
    for mode in MODES:
        c = paged_capacity(mode, max_len=max_len, block_size=bs,
                           max_new=max_new, n_req=n_req)
        report["paged_capacity"].append(c)
        print(f"{mode},{c['dense_req_MB']:.1f},{c['paged_req_MB']:.1f},"
              f"{c['dense_utilization']:.2f},{c['paged_utilization']:.2f},"
              f"{c['req_per_byte_gain']:.2f}x")
        ok_cap = ok_cap and c["req_per_byte_gain"] >= 2.0
    print(f"acceptance: effective concurrent requests per HBM byte "
          f"(paged vs dense slab) >= 2.0x "
          f"({'PASS' if ok_cap else 'FAIL'})")
    # measured from allocated buffers: a pool sized for the workload's
    # reserved blocks vs the dense slab at equal concurrency (reduced
    # geometry, int8, CPU-safe shapes)
    Sml, slots = (512, 4) if fast else (2048, 8)
    plens = mixed_workload(slots, lo=32, hi=Sml // 2)
    blocks = int(sum(-(-min(p + max_new, Sml) // bs) for p in plens)) + 1
    dense_b = measured_state_bytes(Sml, "int8", batch=slots)
    paged_b = measured_state_bytes(Sml, "int8", batch=slots,
                                   num_blocks=blocks, block_size=bs)
    report["allocated"] = {"max_len": Sml, "slots": slots,
                           "blocks": blocks,
                           "dense_GB": dense_b / 1e9,
                           "paged_GB": paged_b / 1e9,
                           "measured_gain": dense_b / paged_b}
    print(f"allocated_equal_concurrency_S{Sml}_B{slots}: dense "
          f"{dense_b/1e9:.3f} GB vs paged {paged_b/1e9:.3f} GB "
          f"({dense_b/paged_b:.2f}x measured)")
    report["acceptance"] = {"int8_reduction_8k": red8,
                            "req_per_byte_gain_ok": ok_cap}
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_kvcache.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")
    if not ok_cap:
        raise SystemExit("bench_kvcache paged-capacity acceptance FAILED")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    a = ap.parse_args()
    main(fast=a.fast)
