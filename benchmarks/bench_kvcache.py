"""KV-cache decode traffic — bytes-moved and tokens/s at 4k–32k contexts.

At long contexts the decode step is memory-bound on the *cache*, not the
weights: every generated token reads the full K and V history of every
attention layer.  This bench reports, per cache dtype (bf16 / int8 / int4):

  * analytic bytes moved per decode step (codes + scales, all layers), and
    the reduction vs bf16 — the acceptance number is the int8 ratio at 8k;
  * the v5e roofline tokens/s projection (HBM_BW / bytes, the same
    memory-bound model as ``bench_runtime``), including the quantized-weight
    term so the totals compose;
  * an XLA cost-analysis cross-check: the jitted fallback attention read's
    "bytes accessed" for bf16 vs int8 at one shape (the fused Pallas kernel
    moves the same cache bytes by construction — it reads codes+scales once).

Run:  PYTHONPATH=src python benchmarks/bench_kvcache.py [--fast]

Numbers land in EXPERIMENTS.md §Roofline (decode-traffic table).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core.kvquant import KVCacheConfig
from repro.launch.analysis import HBM_BW

# gemma-7b attention geometry (28L, MHA kv=16, head_dim 256) — the paper's
# long-context cell; per-(head, token) scales (group_size=0)
GEMMA = dict(n_layers=28, n_kv_heads=16, head_dim=256)
CONTEXTS = (4096, 8192, 16384, 32768)
MODES = ("bf16", "int8", "int4")
# int4 weights of the 8.5e9-param tree — the weight term at decode (so the
# table composes with bench_runtime's weight-only roofline)
WEIGHT_BYTES_TTQ4 = 8.5e9 * 0.5


def cache_bytes_per_step(S: int, mode: str, *, n_layers=None, n_kv_heads=None,
                         head_dim=None, batch: int = 1) -> float:
    """Bytes read by one decode step: K + V, all layers, all heads, S tokens."""
    g = GEMMA if n_layers is None else dict(n_layers=n_layers,
                                            n_kv_heads=n_kv_heads,
                                            head_dim=head_dim)
    per_row = KVCacheConfig(dtype=mode).bytes_per_token_head(g["head_dim"])
    return 2.0 * batch * g["n_layers"] * g["n_kv_heads"] * S * per_row


def measured_state_bytes(S: int, mode: str) -> float:
    """Allocate the REAL decode state via ``lm.init_decode_state`` (reduced
    depth, gemma head geometry) and count the cache leaves' device bytes.

    Every decode step streams the whole cache once, so allocated bytes ==
    bytes-moved per step.  This is a measurement of the shipped layout, not
    the analytic model: if the state tree carried bf16 anywhere it claims
    int8, this number catches it.  Scaled back to 28 layers for the table.
    """
    from repro.models import lm
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="bench", family="dense", n_layers=2,
                      d_model=4096, n_heads=16, n_kv_heads=GEMMA["n_kv_heads"],
                      head_dim=GEMMA["head_dim"], d_ff=128, vocab=256)
    st = lm.init_decode_state(cfg, 1, S, kvcfg=KVCacheConfig(dtype=mode))
    byts = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(st))
    return byts * GEMMA["n_layers"] / cfg.n_layers


def run(fast: bool = True):
    rows = []
    for S in CONTEXTS:
        byts = {m: cache_bytes_per_step(S, m) for m in MODES}
        toks = {m: HBM_BW / (byts[m] + WEIGHT_BYTES_TTQ4) for m in MODES}
        rows.append((S, byts, toks))
    return rows


def main(fast: bool = True):
    rows = run(fast)
    print("# KV-cache decode traffic — gemma-7b geometry, batch=1, "
          "per-(head,token) scales")
    print("context,cache_GB_bf16,cache_GB_int8,cache_GB_int4,"
          "reduction_int8,reduction_int4,tok_s_bf16,tok_s_int8,tok_s_int4")
    for S, byts, toks in rows:
        print(f"{S},{byts['bf16']/1e9:.2f},{byts['int8']/1e9:.2f},"
              f"{byts['int4']/1e9:.2f},"
              f"{byts['bf16']/byts['int8']:.2f}x,"
              f"{byts['bf16']/byts['int4']:.2f}x,"
              f"{toks['bf16']:.1f},{toks['int8']:.1f},{toks['int4']:.1f}")
    red8 = rows[1][1]["bf16"] / rows[1][1]["int8"]
    print(f"acceptance: int8 vs bf16 bytes-moved at 8k = {red8:.2f}x "
          f"({'PASS' if red8 >= 1.5 else 'FAIL'} >= 1.5x)")
    # allocated-layout cross-check: real init_decode_state buffers (CPU-safe)
    S = 1024 if fast else 8192
    mbf = measured_state_bytes(S, "bf16")
    mi8 = measured_state_bytes(S, "int8")
    mi4 = measured_state_bytes(S, "int4")
    print(f"allocated_cache_GB_bf16_S{S},{mbf/1e9:.3f}")
    print(f"allocated_cache_GB_int8_S{S},{mi8/1e9:.3f}")
    print(f"allocated_cache_GB_int4_S{S},{mi4/1e9:.3f}")
    print(f"allocated_reduction_int8_S{S},{mbf / mi8:.2f}x")
    print(f"allocated_reduction_int4_S{S},{mbf / mi4:.2f}x")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    a = ap.parse_args()
    main(fast=a.fast)
