"""Paper Table 3 — methods × bits, macro-averaged over domains, with AWQ's
calibration-domain sensitivity vs TTQ's invariance (the domain-shift claim).

Methods are resolved through the repro.quant registry; calibration state is
``CalibrationSession`` objects from :func:`benchmarks.common.collect_stats`.
The ``awq_mixed`` row demonstrates per-layer policy overrides: attention
projections one bit wider than the MLP base — mixed precision as policy, not
code.
"""
from __future__ import annotations

import numpy as np

from repro.quant import override

from .common import (CALIB_DOMAINS, EVAL_DOMAINS, collect_stats, eval_batches,
                     macro_avg, perplexity, quantize_with, trained_model,
                     ttq_perplexity)

G = 32


def run(fast: bool = True):
    cfg, params = trained_model()
    n_ev = 2 if fast else 4
    evs = {d: eval_batches(d, n=n_ev) for d in EVAL_DOMAINS}
    calibs = {c: collect_stats(cfg, params, eval_batches(c, n=n_ev, seed0=555))
              for c in CALIB_DOMAINS}
    bits_list = (2, 3, 4) if fast else (2, 3, 4, 5)
    per_dom: dict = {}
    for d in EVAL_DOMAINS:
        per_dom[("fp", 0, d)] = perplexity(cfg, params, evs[d])
    c_mix = CALIB_DOMAINS[0]
    for bits in bits_list:
        qp_rtn = quantize_with(cfg, params, "rtn", bits, G)
        for d in EVAL_DOMAINS:
            per_dom[("rtn", bits, d)] = perplexity(cfg, qp_rtn, evs[d])
        for c in CALIB_DOMAINS:
            qp = quantize_with(cfg, params, "awq", bits, G, calib=calibs[c])
            for d in EVAL_DOMAINS:
                per_dom[(f"awq_cal{c}", bits, d)] = perplexity(cfg, qp, evs[d])
        # mixed precision via overrides: attention +1 bit over the MLP base
        qp_mix = quantize_with(cfg, params, "awq", bits, G, calib=calibs[c_mix],
                               overrides=(override("*.mix.*", bits=bits + 1),))
        for d in EVAL_DOMAINS:
            per_dom[("awq_mixed", bits, d)] = perplexity(cfg, qp_mix, evs[d])
        for r in (0, 16):
            for d in EVAL_DOMAINS:
                per_dom[(f"ttq_r{r}", bits, d)] = ttq_perplexity(
                    cfg, params, evs[d], bits, G, rank=r)
    return bits_list, per_dom


def main(fast: bool = True):
    bits_list, per_dom = run(fast)
    methods = ["fp", "rtn"] + [f"awq_cal{c}" for c in CALIB_DOMAINS] + \
        ["awq_mixed", "ttq_r0", "ttq_r16"]

    def macro(m, b, doms):
        bb = 0 if m == "fp" else b
        return macro_avg([per_dom[(m, bb, d)] for d in doms])

    for doms, label in ((EVAL_DOMAINS, "all domains (incl. OOD dom 2 — noisy,"
                         " cf. paper's Gemma3/PTB note)"),
                        (EVAL_DOMAINS[:2], "in-support domains {0,1}")):
        print(f"# Table-3 analogue: macro-avg ppl, {label} (g={G})")
        print("method," + ",".join(f"{b}bit" for b in bits_list))
        for m in methods:
            print(m + "," + ",".join(f"{macro(m, b, doms):.3f}"
                                     for b in bits_list))
    # domain-shift sensitivity: spread of AWQ across calib sets
    for bits in bits_list:
        awqs = [macro(f"awq_cal{c}", bits, EVAL_DOMAINS[:2])
                for c in CALIB_DOMAINS]
        print(f"awq_calib_spread_{bits}bit,{max(awqs) - min(awqs):.3f}")
    return per_dom


if __name__ == "__main__":
    main()
