# One function per paper table. Prints CSV rows per section.
"""Benchmark driver — one section per paper table. ``--full`` widens sweeps."""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: calibration,groupsize,methods,runtime,"
                         "kvcache,engine,requant,overhead,serve_slo,"
                         "roofline")
    args = ap.parse_args()
    fast = not args.full
    only = set(args.only.split(",")) if args.only else None

    from . import (bench_ablations, bench_calibration, bench_engine,
                   bench_groupsize, bench_kvcache, bench_methods,
                   bench_overhead, bench_requant, bench_runtime,
                   bench_serve_slo, roofline)

    sections = [
        ("overhead", bench_overhead.main),        # cheap first
        ("runtime", bench_runtime.main),
        ("kvcache", bench_kvcache.main),
        ("engine", bench_engine.main),
        ("serve_slo", bench_serve_slo.main),
        ("requant", bench_requant.main),
        ("ablations", bench_ablations.main),
        ("calibration", bench_calibration.main),
        ("groupsize", bench_groupsize.main),
        ("methods", bench_methods.main),
    ]
    for name, fn in sections:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"\n===== bench:{name} =====")
        fn(fast)
        print(f"[{name}] {time.time() - t0:.1f}s")
    if only is None or "roofline" in only:
        print("\n===== bench:roofline (from dry-run cache) =====")
        roofline.main()


if __name__ == "__main__":
    main()
