"""Per-op attribution from a cached dry-run HLO: top contributors by HBM bytes
and by collective bytes — the §Perf profiling view (dry-run = the profile).

    PYTHONPATH=src python -m benchmarks.hlo_top results/dryrun/<cell>.hlo.zst
"""
import sys
from collections import defaultdict

import zstandard as zstd

from repro.launch.analysis import HloCost


def top(path: str, k: int = 14):
    with open(path, "rb") as f:
        text = zstd.ZstdDecompressor().decompress(f.read()).decode()
    hc = HloCost(text, collect=True)
    fl, by, coll = hc.cost()
    print(f"total: {fl/1e12:.2f} TFLOP, {by/1e9:.1f} GB hbm, "
          f"{sum(coll.values())/1e9:.1f} GB collective (per device)")
    groups = defaultdict(lambda: [0.0, 0.0, 0])
    for b, f, kind, snip in hc.attributions:
        key = (kind, snip.split(" stack_frame")[0][:110])
        groups[key][0] += b
        groups[key][1] += f
        groups[key][2] += 1
    print("\n-- top by HBM bytes --")
    for (kind, snip), (b, f, n) in sorted(groups.items(),
                                          key=lambda kv: -kv[1][0])[:k]:
        print(f"{b/1e9:9.2f} GB  {kind:14s} ×{n:<5d} {snip}")
    print("\n-- top by collective bytes --")
    cg = [(key, v) for key, v in groups.items() if key[0].startswith("coll:")]
    for (kind, snip), (b, f, n) in sorted(cg, key=lambda kv: -kv[1][0])[:k]:
        print(f"{b/1e9:9.2f} GB  {kind:14s} ×{n:<5d} {snip}")


if __name__ == "__main__":
    top(sys.argv[1], int(sys.argv[2]) if len(sys.argv) > 2 else 14)
