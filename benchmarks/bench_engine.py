"""Engine decode dispatch — host syncs per token and tokens/s vs baseline.

The seed engine dispatched ONE ``decode_step`` per Python iteration and
synced every generated token to the host per slot (``int(nxt[i])``) —
``slots`` blocking transfers per decode dispatch, so decode throughput was
gated by dispatch latency rather than by the kernels.  The scheduler/runner
split fuses K decode steps on device (``lm.decode_many``) and pulls one
(B, K) token block per chunk — ≤ 1/K transfers per token.

This bench drives both dispatch patterns over identical workloads at
1/4/8 slots and reports tokens/s and host-syncs-per-token:

  * ``baseline`` — the seed pattern, reproduced faithfully: one jitted
    ``decode_step`` per token + one per-active-slot ``int()`` sync;
  * ``fused`` — the TTQEngine, swept over ``decode_chunk`` K ∈ {1,2,4,8}.

Fusing is NOT free at every operating point: at 1 slot the fixed-K scan
overhead beats the dispatch saving and K=8 measured *slower* than the
per-token baseline (165 vs 724 tok/s in the PR-3 snapshot).  The sweep
finds the best K per slot count and the **crossover** — the smallest slot
count where fused-at-best-K beats the baseline.  The engine's
``pick_decode_chunk`` default (K=1 at 1 slot, K=8 beyond) is printed per
row, and the 1-slot per-token default is asserted structurally so the
regression cannot be silently reintroduced.

The model is deliberately tiny: the bench measures the *dispatch* path the
refactor moved on-device, not kernel throughput (that is bench_runtime /
bench_kvcache territory).  Each mode runs a warm-up wave first so jit
compilation is excluded — both patterns are timed steady-state, and two
runtime invariants are gated alongside the perf numbers (DESIGN.md
§"Static analysis & runtime invariants"): the timed wave must compile
ZERO new XLA programs (jit-cache counts per row), and a steady-state
decode loop must survive ``jax.transfer_guard("disallow")``.

Run:  PYTHONPATH=src python benchmarks/bench_engine.py [--fast]
Emits results/BENCH_engine.json (picked up by benchmarks/report.py);
numbers land in EXPERIMENTS.md §Perf.

``--mesh-shape 1,2,4`` runs the mesh-sharded serving sweep instead
(DESIGN.md §10, EXPERIMENTS.md §"Virtual-device methodology"): the parent
respawns itself once per mesh size under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the flag must be
set before the backend initializes, hence the subprocess) and gates
greedy-token equality across mesh sizes, zero steady-wave recompiles, and
per-device weight+KV bytes shrinking ≥1.8× at mesh=2.  Emits
results/BENCH_mesh.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import NO_QUANT, KVCacheConfig, QuantizedTensor
from repro.models import ModelConfig, lm
from repro.serving import EngineConfig, TTQEngine
from repro.serving.runner import _write_slots

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

CFG = ModelConfig(name="bench-engine", family="dense", n_layers=2,
                  d_model=64, n_heads=2, n_kv_heads=1, d_ff=128, vocab=128)
MAX_LEN = 128


def workload(slots: int):
    """One prompt per slot (all admitted up front — pure decode dispatch)."""
    rng = np.random.default_rng(0)
    return [list(rng.integers(1, CFG.vocab, size=int(rng.integers(4, 12))))
            for _ in range(slots)]


class Baseline:
    """The seed engine's dispatch pattern: one decode_step per token, one
    blocking ``int()`` host sync per active slot per token."""

    def __init__(self):
        self._decode = jax.jit(partial(lm.decode_step, CFG))
        self._prefill = jax.jit(partial(lm.prefill, CFG, collect_stats=False,
                                        full_logits=True),
                                static_argnames=("max_len",))

    @property
    def compiled_programs(self) -> int:
        return self._decode._cache_size() + self._prefill._cache_size()

    def run(self, params, prompts, max_new: int):
        B = len(prompts)
        state = lm.init_decode_state(CFG, B, MAX_LEN)
        pos = jnp.zeros((B,), jnp.int32)
        cur = jnp.zeros((B, 1), jnp.int32)
        outs = [[] for _ in range(B)]
        syncs = 0
        for i, p in enumerate(prompts):           # B=1 sequential prefills
            toks = jnp.asarray(p, jnp.int32)[None]
            lg, sstate, _ = self._prefill(params, {"tokens": toks},
                                          max_len=MAX_LEN)
            nxt = int(jnp.argmax(lg[0, len(p) - 1]))
            syncs += 1
            outs[i].append(nxt)
            state = _write_slots(state, sstate, [i])
            pos = pos.at[i].set(len(p))
            cur = cur.at[i, 0].set(nxt)
        live = list(range(B))
        while live:
            lg, state = self._decode(params, state, cur, pos)
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            pos = jnp.clip(pos + 1, 0, MAX_LEN - 1)
            cur = nxt[:, None]
            for i in list(live):
                outs[i].append(int(nxt[i]))       # per-slot host sync
                syncs += 1
                if len(outs[i]) >= max_new:
                    live.remove(i)
        return outs, syncs


class Fused:
    """The TTQEngine (scheduler/runner split, fused decode blocks)."""

    def __init__(self, slots: int, chunk: int):
        self.eng = TTQEngine(CFG, lm.init_params(CFG, jax.random.PRNGKey(0)),
                             NO_QUANT,
                             EngineConfig(max_slots=slots, max_len=MAX_LEN,
                                          decode_chunk=chunk))

    @property
    def compiled_programs(self) -> int:
        return self.eng.compiled_programs

    def run(self, params, prompts, max_new: int):
        self.eng.params = params                  # engine is reusable
        s0 = self.eng.host_syncs
        rids = [self.eng.submit(p, max_new=max_new) for p in prompts]
        outs = self.eng.run_all()
        return [list(outs[r]) for r in rids], self.eng.host_syncs - s0


def prefix_scenario(params, max_new: int):
    """Shared-system-prompt serving (paged pool, DESIGN.md §8): N requests
    share a ≥1-block prefix.  Reports prefill tokens dispatched cold
    (prefix_cache off) vs warm and the prefix hit rate; outputs must be
    identical — the savings are pure dispatch/FLOP removal."""
    sysp = list(np.random.default_rng(1).integers(1, CFG.vocab, size=48))
    prompts = [sysp + list(np.random.default_rng(10 + i).integers(
        1, CFG.vocab, size=6)) for i in range(4)]

    def serve(prefix_cache):
        pol = NO_QUANT.with_(kvcache=KVCacheConfig(dtype="int8", paged=True))
        eng = TTQEngine(CFG, params, pol,
                        EngineConfig(max_slots=2, max_len=MAX_LEN,
                                     prefix_cache=prefix_cache))
        rids = [eng.submit(p, max_new=max_new) for p in prompts]
        t0 = time.perf_counter()
        outs = eng.run_all()
        dt = time.perf_counter() - t0
        return [outs[r] for r in rids], eng, dt

    cold_out, cold_eng, _ = serve(False)
    warm_out, warm_eng, _ = serve(True)
    assert warm_out == cold_out, "prefix-cache hits changed the outputs"
    row = {
        "requests": len(prompts), "shared_prefix_tokens": len(sysp),
        "prefill_tokens_cold": cold_eng.prefill_tokens,
        "prefill_tokens_warm": warm_eng.prefill_tokens,
        "prefill_savings": 1.0 - (warm_eng.prefill_tokens
                                  / cold_eng.prefill_tokens),
        "prefix_hit_rate": warm_eng.prefix_hit_rate,
    }
    ok = row["prefix_hit_rate"] > 0 and \
        row["prefill_tokens_warm"] < row["prefill_tokens_cold"]
    print(f"prefix: {len(prompts)} reqs sharing {len(sysp)} tokens — "
          f"prefill tokens {row['prefill_tokens_cold']:.0f} → "
          f"{row['prefill_tokens_warm']:.0f} "
          f"({row['prefill_savings']:.0%} saved), hit rate "
          f"{row['prefix_hit_rate']:.2f}, outputs unchanged "
          f"({'PASS' if ok else 'FAIL'})")
    return row, ok


def timed(runner, params, prompts, max_new):
    """Warm wave (jit compiles), then the timed steady wave.  Also returns
    (programs after warm-up, programs compiled DURING the steady wave) from
    the runner's jit caches — the steady wave must compile nothing, or the
    timing is part compilation and the serving path has a recompile bug
    (tracecheck TC2xx's runtime counterpart)."""
    out = runner.run(params, prompts, max_new)    # warm wave: jit compiles
    warm_programs = runner.compiled_programs
    t0 = time.perf_counter()
    out = runner.run(params, prompts, max_new)
    dt = time.perf_counter() - t0
    return out, dt, warm_programs, runner.compiled_programs - warm_programs


def transfer_guard_probe(params, max_new: int):
    """Run a steady-state decode loop under ``jax.transfer_guard
    ("disallow")`` — any implicit host↔device transfer raises.  The same
    invariant tests/test_runtime_guards.py pins, probed here on the bench
    workload so perf runs carry the evidence (EXPERIMENTS.md
    §"Transfer-guard methodology")."""
    prompts = workload(2)
    eng = TTQEngine(CFG, params, NO_QUANT,
                    EngineConfig(max_slots=2, max_len=MAX_LEN,
                                 decode_chunk=4))
    for p in prompts:
        eng.submit(p, max_new=max_new)
    eng.step()                       # admission + first block: compiles here
    try:
        with jax.transfer_guard("disallow"):
            while eng.scheduler.has_work():
                if not eng.step():
                    break
        ok = True
    except Exception as e:           # an implicit transfer raised
        print(f"transfer-guard probe tripped: {e}")
        ok = False
    print(f"transfer_guard: steady-state decode loop implicit-transfer "
          f"free ({'PASS' if ok else 'FAIL'})")
    return ok


# -------------------------------------------------- self-speculative sweep

# dispatch-dominated CFG hides the draft/verify per-step cost asymmetry the
# sweep measures (a 64-wide model decodes at >600 tok/s on this container —
# pure dispatch), so the spec bench uses a model where per-step compute
# dominates dispatch overhead (still CI-sized: ~25 MB of bf16 weights)
SPEC_CFG = ModelConfig(name="bench-spec", family="dense", n_layers=4,
                       d_model=512, n_heads=8, n_kv_heads=4, d_ff=1536,
                       vocab=1024)


def _tree_stream_bytes(tree) -> int:
    """Weight bytes a decode step streams for this tree: packed codes at
    bits/8 per element (``wint`` storage is counted the same — packing is a
    storage choice, not extra traffic) plus the fp sidecars (scales, zeros,
    dinv, low-rank factors); fp leaves at their stored dtype.  Same byte
    convention as bench_runtime's roofline."""
    total = 0
    for leaf in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            total += leaf.out_features * leaf.in_features * leaf.bits // 8
            for side in (leaf.scale, leaf.zero, leaf.dinv, leaf.A, leaf.B):
                if side is not None:
                    total += side.size * side.dtype.itemsize
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total


def spec_sweep(ws, fast: bool):
    """Self-speculative decoding (DESIGN.md §11): acceptance × W × kv-dtype
    on the standard 4-slot workload.  Gates (ISSUE 8 acceptance):

      * greedy outputs bitwise-identical to the non-speculative engine at
        EVERY swept W and kv dtype (the verify tree decides every token);
      * zero steady-wave recompiles;
      * draft+verify requant plans compile ≤ 2× the programs of the
        single-tree plan;
      * byte-roofline speedup ≥ 1.3× at the best swept config — measured
        acceptance × the real draft/verify tree byte ratio,
        (1 + W·a) / (W·(draft_bytes/verify_bytes) + 1) — with the measured
        wall speedup reported beside it and floor-gated (≥ 0.8×: the spec
        path must never be catastrophically slower).  Wall and roofline
        diverge on THIS container because the jnp QDQ fallback dequantizes
        to f32 — a draft step streams/computes as much as a verify step, so
        CPU wall parity is expected (bench_kvcache reports the same
        analytic-vs-measured split for the KV path; see EXPERIMENTS.md
        §"Self-speculative methodology").

    Two verify precisions are swept, each against its own W=0 baseline:

      * ``int8 g32 r8`` verify with the paper-faithful ``int4`` companion
        draft (``policy.draft_variant()``) — exercises the dual-tree
        requant budget;
      * ``fp`` (NO_QUANT) verify with quantized ``int8``/``int4`` drafts —
        the quantized model speculating for its own full-precision self.
        On this container the fp (bf16) step costs ~2× a QDQ step (bf16
        matmuls have no native CPU BLAS path; QDQ dequantizes to f32 →
        fast f32 BLAS — EXPERIMENTS.md §"Self-speculative methodology"),
        so this is where the wall-clock win lives."""
    from repro.core import ttq_policy
    from repro.serving import pick_decode_chunk

    verifies = {
        "int8 g32 r8": (ttq_policy(bits=8, group_size=32, rank=8),
                        {"int4": None}),     # engine default: draft_variant()
        "fp": (NO_QUANT,
               {"int8": ttq_policy(bits=8, group_size=32, rank=0),
                "int4": ttq_policy(bits=4, group_size=32, rank=0)}),
    }
    kv_dtypes = ("bf16", "int8")
    max_new = 16 if fast else 48
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, SPEC_CFG.vocab,
                                 size=int(rng.integers(4, 12))))
               for _ in range(4)]
    params = lm.init_params(SPEC_CFG, jax.random.PRNGKey(0))

    def run(W, policy, draft, kvd):
        eng = TTQEngine(SPEC_CFG, params, policy,
                        EngineConfig(max_slots=4, max_len=MAX_LEN,
                                     decode_chunk=0,   # auto: baseline at its
                                     kv_dtype=kvd,     # best fused chunk
                                     speculate_k=W),
                        draft_policy=draft)

        def wave():
            rids = [eng.submit(p, max_new=max_new) for p in prompts]
            outs = eng.run_all()
            return [list(outs[r]) for r in rids]

        out = wave()                          # warm wave: jit compiles
        warm_programs = eng.compiled_programs
        t0 = time.perf_counter()
        steady = wave()
        dt = time.perf_counter() - t0
        assert steady == out, "steady wave diverged from the warm wave"
        return steady, dt, eng, eng.compiled_programs - warm_programs

    report = {"config": {"ws": list(ws), "kv_dtypes": list(kv_dtypes),
                         "max_new": max_new, "model": SPEC_CFG.name,
                         "verify_policies": list(verifies)}, "rows": []}
    ok_all = True
    print("verify,kv_dtype,draft,W,chunk,tokens,wall_s,tok_s,acceptance,"
          "roofline_x,steady_new_programs,tokens_equal")
    best = None               # by measured wall speedup
    best_roof = None          # by byte-roofline speedup
    ref_single_tree = None    # program count of ONE quantized tree's plan
    for vname, (policy, drafts) in verifies.items():
        for kvd in kv_dtypes:
            base_out, base_dt, base_eng, base_new = run(0, policy, None, kvd)
            n_tok = sum(len(o) for o in base_out)
            base_row = {"verify": vname, "kv_dtype": kvd, "draft": "-",
                        "W": 0, "chunk": base_eng.ecfg.decode_chunk,
                        "tokens": n_tok, "wall_s": round(base_dt, 4),
                        "tok_s": round(n_tok / base_dt, 1),
                        "acceptance": None, "steady_new_programs": base_new,
                        "tokens_equal": True}
            report["rows"].append(base_row)
            single = base_eng.qmodel.compiled_programs
            if single > 0 and ref_single_tree is None:
                ref_single_tree = single
            # ≤2× budget reference: the verify tree's own single-tree plan
            # when it quantizes, else one quantized tree's plan (an fp
            # verify compiles 0 — the draft-only plan must fit ONE tree)
            budget = 2 * single if single > 0 else ref_single_tree
            print(f"{vname},{kvd},-,0,{base_row['chunk']},{n_tok},"
                  f"{base_row['wall_s']},{base_row['tok_s']},-,{base_new},-")
            for dname, draft in drafts.items():
                for W in ws:
                    out, dt, eng, new = run(W, policy, draft, kvd)
                    equal = out == base_out
                    a = eng.spec_acceptance_rate
                    v_bytes = _tree_stream_bytes(eng.qmodel.decode_params)
                    d_bytes = _tree_stream_bytes(eng.qmodel.draft_params)
                    roofline = (1 + W * a) / (W * d_bytes / v_bytes + 1)
                    row = {"verify": vname, "kv_dtype": kvd, "draft": dname,
                           "W": W, "chunk": eng.ecfg.decode_chunk,
                           "tokens": n_tok, "wall_s": round(dt, 4),
                           "tok_s": round(n_tok / dt, 1),
                           "acceptance": round(a, 3),
                           "verify_mb": round(v_bytes / 2**20, 1),
                           "draft_mb": round(d_bytes / 2**20, 1),
                           "roofline_speedup": round(roofline, 3),
                           "requant_programs": eng.qmodel.compiled_programs,
                           "program_budget": budget,
                           "steady_new_programs": new,
                           "tokens_equal": equal}
                    report["rows"].append(row)
                    print(f"{vname},{kvd},{dname},{W},{row['chunk']},"
                          f"{n_tok},{row['wall_s']},{row['tok_s']},"
                          f"{row['acceptance']},{row['roofline_speedup']},"
                          f"{new},{equal}")
                    if not equal:
                        print(f"  FAIL: speculative outputs diverged "
                              f"(verify={vname} kv={kvd} draft={dname} "
                              f"W={W})")
                        ok_all = False
                    if new != 0:
                        print(f"  FAIL: steady wave compiled {new} "
                              f"program(s)")
                        ok_all = False
                    if budget is not None and \
                            row["requant_programs"] > budget:
                        print(f"  FAIL: requant programs "
                              f"{row['requant_programs']} > budget "
                              f"({budget})")
                        ok_all = False
                    speedup = row["tok_s"] / base_row["tok_s"]
                    if best is None or speedup > best["speedup"]:
                        best = dict(row, speedup=round(speedup, 3),
                                    base_tok_s=base_row["tok_s"])
                    if best_roof is None or \
                            roofline > best_roof["roofline_speedup"]:
                        best_roof = dict(row, speedup=round(speedup, 3),
                                         base_tok_s=base_row["tok_s"])
    report["best"] = best
    report["best_roofline"] = best_roof
    # timing gates only at full scale (tiny --fast workloads on shared
    # CI runners make timing flaky; CI keeps the equality/recompile gates)
    if not fast:
        ok_roof = best_roof is not None and \
            best_roof["roofline_speedup"] >= 1.3
        ok_wall = best is not None and best["speedup"] >= 0.8
        ok_all = ok_all and ok_roof and ok_wall
        print(f"acceptance: best roofline "
              f"(verify={best_roof['verify']} kv={best_roof['kv_dtype']} "
              f"draft={best_roof['draft']} W={best_roof['W']}) "
              f"{best_roof['roofline_speedup']:.2f}x "
              f"({'PASS' if ok_roof else 'FAIL'} >= 1.3x) at acceptance "
              f"{best_roof['acceptance']:.2f}; best measured wall "
              f"(verify={best['verify']} draft={best['draft']} "
              f"W={best['W']}) {best['speedup']:.2f}x "
              f"({'PASS' if ok_wall else 'FAIL'} >= 0.8x floor — CPU QDQ "
              f"wall parity expected, see EXPERIMENTS.md)")
    else:
        print(f"best speculation (verify={best['verify']} "
              f"kv={best['kv_dtype']} draft={best['draft']} W={best['W']}): "
              f"{best['speedup']:.2f}x wall, "
              f"{best_roof['roofline_speedup']:.2f}x roofline "
              f"(timing not gated under --fast)")
    # structural guard: speculation shrinks the window chunk, never the
    # 1-slot per-window default
    assert pick_decode_chunk(1, 4) == 1, "1-slot spec default regressed"
    assert pick_decode_chunk(4, 3) == 2, "4-slot spec chunk heuristic moved"
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_spec.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")
    if not ok_all:
        raise SystemExit("bench_engine speculation acceptance FAILED")
    return report


# --------------------------------------------------------------- mesh sweep

# bigger than CFG so sharded weight/KV shards dominate the replicated
# residue (norms, embeddings stay whole; the ≥1.8x byte gate needs the
# sharded fraction large) but still CI-sized
MESH_CFG = ModelConfig(name="bench-mesh", family="dense", n_layers=2,
                       d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                       vocab=512)


def _per_device_bytes(tree) -> int:
    """HBM-resident bytes per device: shard shape × itemsize per leaf (the
    sharding's shard_shape is exact — this is the quantity TP shrinks)."""
    tot = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "sharding") and hasattr(leaf, "dtype"):
            shard = leaf.sharding.shard_shape(leaf.shape)
            tot += int(np.prod(shard)) * leaf.dtype.itemsize
    return tot


def mesh_worker(n: int, fast: bool):
    """One mesh size, measured inside the 4-virtual-device subprocess.
    Prints a single ``MESHROW {json}`` line for the parent to collect."""
    from repro.core import ttq_policy
    from repro.launch.mesh import make_ctx, make_mesh

    pctx = make_ctx(make_mesh(1, n)) if n > 1 else None
    params = lm.init_params(MESH_CFG, jax.random.PRNGKey(0))
    eng = TTQEngine(MESH_CFG, params, ttq_policy(bits=4, group_size=32,
                                                 packed=True),
                    EngineConfig(max_slots=4, max_len=MAX_LEN, decode_chunk=4,
                                 kv_dtype="int8", kv_paged=True,
                                 kv_block_size=16, use_kernels=True),
                    pctx=pctx)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, MESH_CFG.vocab,
                                 size=int(rng.integers(6, 16))))
               for _ in range(4)]
    max_new = 8 if fast else 24

    def wave():
        rids = [eng.submit(p, max_new=max_new) for p in prompts]
        outs = eng.run_all()
        return [list(outs[r]) for r in rids]

    out = wave()                                  # warm wave: jit compiles
    warm_programs = eng.compiled_programs
    t0 = time.perf_counter()
    steady = wave()
    dt = time.perf_counter() - t0
    assert steady == out, "steady wave diverged from the warm wave"
    steady_new = eng.compiled_programs - warm_programs
    t0 = time.perf_counter()
    tree = eng.qmodel.requantize()                # full shard-local requant
    jax.block_until_ready(tree)
    requant_s = time.perf_counter() - t0
    n_tok = sum(len(o) for o in steady)
    row = {
        "mesh": n, "devices": jax.device_count(), "tokens": n_tok,
        "tok_s": round(n_tok / dt, 1), "wall_s": round(dt, 4),
        "weight_bytes_per_device": _per_device_bytes(eng.qmodel.decode_params),
        "kv_bytes_per_device": _per_device_bytes(eng.runner.state),
        "requant_wall_s": round(requant_s, 4),
        "requant_programs": eng.qmodel.compiled_programs,
        "steady_new_programs": steady_new, "outputs": steady,
    }
    print("MESHROW " + json.dumps(row))


def mesh_sweep(shapes, fast: bool):
    """Respawn one worker per mesh size on 4 virtual devices; gate equality,
    recompiles, and the per-device byte shrink; write BENCH_mesh.json."""
    import subprocess
    import sys

    env = dict(os.environ)
    kept = [f for f in env.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    kept.append("--xla_force_host_platform_device_count=4")
    env["XLA_FLAGS"] = " ".join(kept)
    rows = []
    for n in shapes:
        cmd = [sys.executable, os.path.abspath(__file__),
               "--mesh-worker", str(n)] + (["--fast"] if fast else [])
        r = subprocess.run(cmd, capture_output=True, text=True, env=env)
        if r.returncode != 0:
            raise SystemExit(f"mesh worker n={n} failed:\n{r.stdout}\n"
                             f"{r.stderr}")
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("MESHROW ")][-1]
        rows.append(json.loads(line[len("MESHROW "):]))
    ok_all = True
    outputs = {r["mesh"]: r.pop("outputs") for r in rows}
    by_mesh = {r["mesh"]: r for r in rows}
    print("mesh,tok_s,weight_MB_per_dev,kv_MB_per_dev,requant_s,"
          "steady_new_programs")
    for r in rows:
        print(f"{r['mesh']},{r['tok_s']},"
              f"{r['weight_bytes_per_device'] / 1e6:.3f},"
              f"{r['kv_bytes_per_device'] / 1e6:.3f},{r['requant_wall_s']},"
              f"{r['steady_new_programs']}")
        if r["steady_new_programs"] != 0:
            print(f"  FAIL mesh={r['mesh']}: steady wave compiled "
                  f"{r['steady_new_programs']} new program(s)")
            ok_all = False
    # token agreement is REPORTED, not gated, at bench scale: col-parallel
    # psum reorders bf16 partial sums (~ulp logit perturbations), so greedy
    # ties can flip on any sufficiently large vocab; the hard equality gate
    # lives in tests/test_mesh_serving.py on a model whose top-2 gaps clear
    # the reorder noise (EXPERIMENTS.md §"Virtual-device methodology")
    base = outputs[shapes[0]]
    agreement = {}
    for n in shapes[1:]:
        flat_b = [t for o in base for t in o]
        flat_n = [t for o in outputs[n] for t in o]
        same = sum(a == b for a, b in zip(flat_b, flat_n))
        agreement[n] = round(same / max(1, len(flat_b)), 3)
        if outputs[n] != base:
            print(f"  note mesh={n}: greedy tokens diverge from "
                  f"mesh={shapes[0]} (agreement {agreement[n]:.0%} — "
                  f"psum tie-breaks, see EXPERIMENTS.md)")
    shrink = None
    if 1 in by_mesh and 2 in by_mesh:
        tot = lambda r: (r["weight_bytes_per_device"]
                         + r["kv_bytes_per_device"])  # noqa: E731
        shrink = tot(by_mesh[1]) / tot(by_mesh[2])
        ok = shrink >= 1.8
        ok_all = ok_all and ok
        print(f"acceptance: per-device weight+KV bytes shrink {shrink:.2f}x "
              f"at mesh=2 ({'PASS' if ok else 'FAIL'} >= 1.8x), zero steady "
              f"recompiles; token agreement {agreement}")
    report = {"config": {"shapes": list(shapes), "model": MESH_CFG.name,
                         "virtual_devices": 4},
              "rows": rows, "byte_shrink_mesh2": shrink,
              "token_agreement": agreement,
              "outputs_equal": all(outputs[n] == base for n in shapes[1:])}
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_mesh.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")
    if not ok_all:
        raise SystemExit("bench_engine mesh acceptance FAILED")
    return report


def main(fast: bool = False, chunk: int = 0):
    """``chunk=0`` sweeps K per slot count; a nonzero K pins the sweep."""
    from repro.serving import pick_decode_chunk

    slot_counts = (1, 4) if fast else (1, 4, 8)
    chunks = (chunk,) if chunk else ((1, 8) if fast else (1, 2, 4, 8))
    max_new = 16 if fast else 64
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    report = {"config": {"chunks": list(chunks), "max_new": max_new,
                         "model": CFG.name}, "rows": []}
    best = {}
    print("slots,mode,chunk,tokens,wall_s,tok_s,host_syncs,syncs_per_token,"
          "programs,steady_new_programs")
    for slots in slot_counts:
        prompts = workload(slots)
        (base_out, base_syncs), base_dt, base_progs, base_new = timed(
            Baseline(), params, prompts, max_new)
        n_tok = sum(len(o) for o in base_out)
        rows = [{"slots": slots, "mode": "baseline", "chunk": 1,
                 "tokens": n_tok, "wall_s": round(base_dt, 4),
                 "tok_s": round(n_tok / base_dt, 1),
                 "host_syncs": base_syncs,
                 "syncs_per_token": round(base_syncs / n_tok, 3),
                 "programs": base_progs, "steady_new_programs": base_new}]
        for K in chunks:
            (fus_out, fus_syncs), fus_dt, fus_progs, fus_new = timed(
                Fused(slots, K), params, prompts, max_new)
            assert fus_out == base_out, \
                f"fused decode (K={K}) diverged from the per-token baseline"
            rows.append({"slots": slots, "mode": "fused", "chunk": K,
                         "tokens": n_tok, "wall_s": round(fus_dt, 4),
                         "tok_s": round(n_tok / fus_dt, 1),
                         "host_syncs": fus_syncs,
                         "syncs_per_token": round(fus_syncs / n_tok, 3),
                         "programs": fus_progs,
                         "steady_new_programs": fus_new})
        for r in rows:
            report["rows"].append(r)
            print(f"{r['slots']},{r['mode']},{r['chunk']},{r['tokens']},"
                  f"{r['wall_s']},{r['tok_s']},{r['host_syncs']},"
                  f"{r['syncs_per_token']},{r['programs']},"
                  f"{r['steady_new_programs']}")
        best[slots] = max((r for r in rows if r["mode"] == "fused"),
                          key=lambda r: r["tok_s"])

    # the headline finding: fused dispatch is a *batched-decode* win — find
    # the crossover slot count and check the shipped default sits beyond it
    crossover = None
    ok_all = True
    for slots in slot_counts:
        b = next(r for r in report["rows"]
                 if r["slots"] == slots and r["mode"] == "baseline")
        f = best[slots]
        speedup = f["tok_s"] / b["tok_s"]
        if crossover is None and speedup > 1.0:
            crossover = slots
        K = f["chunk"]
        budget = 1.0 / K + 1.0 / max_new + 0.01
        ok = f["syncs_per_token"] <= budget
        # the timed wave repeats the warm wave's shapes exactly — any new
        # program means the serving path recompiles in steady state
        stale = [r for r in report["rows"] if r["slots"] == slots
                 and r["steady_new_programs"] != 0]
        if stale:
            print(f"  steady-wave recompiles at slots={slots}: "
                  f"{[(r['mode'], r['chunk'], r['steady_new_programs']) for r in stale]}")
            ok = False
        if slots >= 4 and not fast:
            # wall-clock gate only at full scale — the --fast CI smoke keeps
            # the deterministic syncs/token check (tiny workloads on shared
            # runners make timing comparisons flaky)
            ok = ok and speedup > 1.0
        ok_all = ok_all and ok
        print(f"acceptance slots={slots}: best fused K={K} "
              f"{b['syncs_per_token']:.3f} → {f['syncs_per_token']:.3f} "
              f"syncs/token ({'PASS' if ok else 'FAIL'} <= {budget:.3f}), "
              f"tok/s {b['tok_s']:.0f} → {f['tok_s']:.0f} "
              f"({speedup:.2f}x), default K={pick_decode_chunk(slots)}")
    # structural guard on the shipped default: 1 slot must stay per-token
    # (the PR-3 regression: fixed-K fused decode lost to per-token there on
    # short budgets) and batched serving must fuse
    assert pick_decode_chunk(1) == 1, "1-slot default regressed to fused"
    assert pick_decode_chunk(4) > 1, "batched default regressed to per-token"
    report["best_chunk"] = {s_: best[s_]["chunk"] for s_ in slot_counts}
    report["default_chunk"] = {s_: pick_decode_chunk(s_)
                               for s_ in slot_counts}
    report["crossover_slots"] = crossover
    # shared-prefix prefill savings over the paged pool
    prefix_row, prefix_ok = prefix_scenario(params, max_new=8 if fast else 16)
    report["prefix"] = prefix_row
    ok_all = ok_all and prefix_ok
    # steady-state decode must be free of implicit host↔device transfers
    guard_ok = transfer_guard_probe(params, max_new=8 if fast else 16)
    report["transfer_guard_clean"] = guard_ok
    ok_all = ok_all and guard_ok
    print(f"crossover: fused-at-best-K beats baseline from {crossover} "
          f"slot(s) on this workload (max_new={max_new}); the engine "
          f"default keeps K=1 at 1 slot — the 1-slot win is "
          f"budget-dependent (short generations waste fixed-K steps, the "
          f"PR-3 regression) — and K=8 beyond")
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_engine.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")
    if not ok_all:
        raise SystemExit("bench_engine acceptance FAILED")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--chunk", type=int, default=0,
                    help="pin one decode_chunk instead of sweeping")
    ap.add_argument("--mesh-shape", default="",
                    help="comma list of model-mesh sizes (e.g. 1,2,4): run "
                         "the mesh-sharded serving sweep instead of the "
                         "dispatch bench (4 virtual CPU devices, "
                         "DESIGN.md §10)")
    ap.add_argument("--mesh-worker", type=int, default=0,
                    help=argparse.SUPPRESS)   # internal: one sweep child
    ap.add_argument("--speculate-k", default="",
                    help="comma list of draft-window sizes W (e.g. 2,3,4): "
                         "run the self-speculative decoding sweep "
                         "(acceptance × W × kv dtype, DESIGN.md §11) "
                         "instead of the dispatch bench")
    a = ap.parse_args()
    if a.mesh_worker:
        mesh_worker(a.mesh_worker, fast=a.fast)
    elif a.mesh_shape:
        mesh_sweep([int(s) for s in a.mesh_shape.split(",")], fast=a.fast)
    elif a.speculate_k:
        spec_sweep([int(s) for s in a.speculate_k.split(",")], fast=a.fast)
    else:
        main(fast=a.fast, chunk=a.chunk)
