"""Paper eq.(3) — online-quantization overhead fraction ρ = O[1/d' + 3/T].

Measured with XLA cost_analysis FLOPs of the actual jitted computations:
    overhead  = flops(stats D) + flops(scale+quantize W) + flops(prescale x)
    projection = flops(x @ Wᵀ)
ρ → 0 as d', T grow — the paper's negligible-overhead claim, verified on the
real compiled graphs rather than the analytic count alone.

Alongside the FLOP ratio, each case now reports the **measured wall-clock
latency** of one weight's online requantization (stats→D + scale+quantize,
jit-compiled, steady-state): FLOP ratios say the overhead vanishes
asymptotically, the milliseconds say what one recalibration actually costs
at each scale — the number `bench_requant.py` then drives down with the
fused whole-tree dispatch.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import AWQConfig, QuantConfig, activation_diag, awq_quantize


def _flops(fn, *sds):
    comp = jax.jit(fn).lower(*sds).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


def _wall_ms(fn, *args, reps: int = 5) -> float:
    jfn = jax.jit(fn)
    jax.block_until_ready(jfn(*args))           # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jfn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3


def measure(d: int, dp: int, T: int, g: int = 32):
    x = jax.ShapeDtypeStruct((T, d), jnp.float32)
    W = jax.ShapeDtypeStruct((dp, d), jnp.float32)
    D = jax.ShapeDtypeStruct((d,), jnp.float32)
    qcfg = QuantConfig(bits=4, group_size=g, layout="row")
    f_proj = _flops(lambda xx, ww: xx @ ww.T, x, W)
    f_stats = _flops(lambda xx: activation_diag(xx, AWQConfig()), x)
    f_quant = _flops(lambda ww, dd: awq_quantize(ww, dd, qcfg), W, D)
    f_scale = _flops(lambda xx, dd: xx * (1.0 / dd), x, D)
    rho = (f_stats + f_quant + f_scale) / max(f_proj, 1.0)
    rho_theory = 1.0 / dp + 3.0 / T

    # measured wall clock of one online requantization (stats→D, quantize)
    key = jax.random.PRNGKey(0)
    xv = jax.random.normal(key, (T, d), jnp.float32)
    Wv = jax.random.normal(jax.random.fold_in(key, 1), (dp, d), jnp.float32)

    def requant(xx, ww):
        dd = activation_diag(xx, AWQConfig())
        return awq_quantize(ww, dd, qcfg)

    wall = _wall_ms(requant, xv, Wv)
    return rho, rho_theory, f_proj, f_stats + f_quant + f_scale, wall


def run(fast: bool = True):
    cases = [(512, 512, 64), (1024, 1024, 256), (2048, 2048, 1024),
             (4096, 4096, 4096)]
    if not fast:
        cases += [(8192, 8192, 8192)]
    rows = []
    for d, dp, T in cases:
        rho, rho_t, fp, fo, wall = measure(d, dp, T)
        rows.append((d, dp, T, rho, rho_t, wall))
    return rows


def main(fast: bool = True):
    rows = run(fast)
    print("# eq.(3) analogue: measured online-quantization overhead fraction")
    print("d,dprime,T,rho_measured,rho_theory,requant_wall_ms")
    for d, dp, T, rho, rho_t, wall in rows:
        print(f"{d},{dp},{T},{rho:.5f},{rho_t:.5f},{wall:.2f}")
    assert rows[-1][3] < rows[0][3], "overhead must vanish with scale"
    return rows


if __name__ == "__main__":
    main()
