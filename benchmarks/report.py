"""Generate the EXPERIMENTS.md §Roofline + §Perf markdown tables from the
dry-run cache, plus a headline summary of the serving benchmark JSONs
(``results/BENCH_*.json``).  Absent JSONs WARN — they are produced by
separate bench runs that may not have happened on this checkout — the
report never crashes on a missing file.

    PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
HILL = [("granite_34b", "decode_32k"), ("gemma_7b", "decode_32k"),
        ("granite_34b", "train_4k")]


def _load(mesh, opt):
    out = {}
    for p in glob.glob(os.path.join(RESULTS, "*.json")):
        r = json.load(open(p))
        if r.get("mesh") != mesh or r.get("opt_level", 1) != opt:
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def _e(x):
    return f"{x:.2e}"


def roofline_table(mesh="single"):
    rows = _load(mesh, 1)
    print(f"\n### §Roofline — mesh {mesh} (per device per step; "
          "C/M/X = compute/memory/collective seconds)\n")
    print("| arch | shape | C | M (walker) | X | dominant | M (analytic) | "
          "useful FLOP ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for (a, s) in sorted(rows):
        r = rows[(a, s)]
        if "skipped" in r:
            print(f"| {a} | {s} | — | — | — | *skip: sub-quadratic-only "
                  f"shape* | — | — |")
            continue
        if "error" in r:
            print(f"| {a} | {s} | ERROR |")
            continue
        rl, an = r["roofline"], r.get("analytic", {})
        print(f"| {a} | {s} | {_e(rl['t_compute_s'])} | "
              f"{_e(rl['t_memory_s'])} | {_e(rl['t_collective_s'])} | "
              f"{rl['dominant']} | {_e(an.get('t_memory_s', 0))} | "
              f"{rl.get('useful_flop_ratio', 0):.3f} |")


def hillclimb_table():
    base = _load("single", 0)
    opt = _load("single", 1)
    print("\n### §Perf — hillclimbed cells, baseline (paper-faithful, opt0) "
          "vs optimized (opt1)\n")
    print("| cell | term | baseline | optimized | improvement |")
    print("|---|---|---|---|---|")
    for (a, s) in HILL:
        b, o = base.get((a, s)), opt.get((a, s))
        if not b or not o or "roofline" not in b or "roofline" not in o:
            continue
        for t, lbl in (("t_compute_s", "compute"), ("t_memory_s", "memory"),
                       ("t_collective_s", "collective")):
            bv, ov = b["roofline"][t], o["roofline"][t]
            gain = f"{bv/ov:.2f}×" if ov > 0 else "∞"
            print(f"| {a}/{s} | {lbl} | {_e(bv)} | {_e(ov)} | {gain} |")


def multi_pod_check():
    single = _load("single", 1)
    multi = _load("multi", 1)
    ok_s = sum(1 for r in single.values() if "roofline" in r)
    ok_m = sum(1 for r in multi.values() if "roofline" in r)
    sk_s = sum(1 for r in single.values() if "skipped" in r)
    sk_m = sum(1 for r in multi.values() if "skipped" in r)
    er = sum(1 for r in list(single.values()) + list(multi.values())
             if "error" in r)
    print(f"\n§Dry-run: single-pod {ok_s} compiled + {sk_s} skipped; "
          f"multi-pod {ok_m} compiled + {sk_m} skipped; {er} errors.")


def _bench(name):
    """Load one results/BENCH_*.json; warn (don't crash) when absent."""
    path = os.path.join(BENCH_DIR, name)
    if not os.path.exists(path):
        print(f"  warn: {name} absent — run its bench to regenerate "
              f"(benchmarks/README in EXPERIMENTS.md §Perf)")
        return None
    try:
        return json.load(open(path))
    except (json.JSONDecodeError, OSError) as e:
        print(f"  warn: {name} unreadable ({e})")
        return None


def bench_summary():
    """Headline numbers from the serving bench JSONs."""
    print("\n### §Perf — serving bench headlines (results/BENCH_*.json)\n")
    r = _bench("BENCH_engine.json")
    if r:
        print(f"engine: crossover {r.get('crossover_slots')} slot(s), "
              f"best chunk {r.get('best_chunk')}, prefix savings "
              f"{r.get('prefix', {}).get('prefill_savings', 0):.0%}")
    r = _bench("BENCH_kvcache.json")
    if r:
        rows = r.get("rows", [])
        print(f"kvcache: {len(rows)} rows "
              f"(dtypes × layouts; see EXPERIMENTS.md §Roofline)")
    r = _bench("BENCH_requant.json")
    if r:
        rows = r.get("rows", [])
        print(f"requant: {len(rows)} rows "
              f"(fused-plan cadence; see EXPERIMENTS.md §Perf)")
    r = _bench("BENCH_mesh.json")
    if r:
        print(f"mesh: byte shrink at mesh=2 "
              f"{r.get('byte_shrink_mesh2') or 0:.2f}x, token agreement "
              f"{r.get('token_agreement')}")
    r = _bench("BENCH_serve_slo.json")
    if r:
        lat = r.get("latency", {})
        ch = lat.get("chunked", {})
        print(f"serve_slo: p99 ITL improvement "
              f"{r.get('itl_p99_improvement', 0):.2f}x with a "
              f"{r.get('config', {}).get('long_len')}-token prompt "
              f"mid-stream (chunked victim p99 "
              f"{ch.get('victim_itl_p99', 0) * 1e3:.1f} ms), equality "
              f"{all(r.get('equality', {}).values())}, transfer-guard "
              f"{r.get('transfer_guard_ok')}")
    r = _bench("BENCH_spec.json")
    if r and r.get("best"):
        b = r["best"]
        br = r.get("best_roofline") or b
        print(f"speculate: best wall {b.get('speedup', 0):.2f}x "
              f"(verify={b.get('verify')} draft={b.get('draft')} "
              f"W={b.get('W')}), best roofline "
              f"{br.get('roofline_speedup', 0):.2f}x at acceptance "
              f"{br.get('acceptance')} (see EXPERIMENTS.md "
              f"§\"Self-speculative methodology\")")


def main():
    multi_pod_check()
    roofline_table("single")
    hillclimb_table()
    bench_summary()


if __name__ == "__main__":
    main()
