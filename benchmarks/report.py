"""Generate the EXPERIMENTS.md §Roofline + §Perf markdown tables from the
dry-run cache.

    PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
HILL = [("granite_34b", "decode_32k"), ("gemma_7b", "decode_32k"),
        ("granite_34b", "train_4k")]


def _load(mesh, opt):
    out = {}
    for p in glob.glob(os.path.join(RESULTS, "*.json")):
        r = json.load(open(p))
        if r.get("mesh") != mesh or r.get("opt_level", 1) != opt:
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def _e(x):
    return f"{x:.2e}"


def roofline_table(mesh="single"):
    rows = _load(mesh, 1)
    print(f"\n### §Roofline — mesh {mesh} (per device per step; "
          "C/M/X = compute/memory/collective seconds)\n")
    print("| arch | shape | C | M (walker) | X | dominant | M (analytic) | "
          "useful FLOP ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for (a, s) in sorted(rows):
        r = rows[(a, s)]
        if "skipped" in r:
            print(f"| {a} | {s} | — | — | — | *skip: sub-quadratic-only "
                  f"shape* | — | — |")
            continue
        if "error" in r:
            print(f"| {a} | {s} | ERROR |")
            continue
        rl, an = r["roofline"], r.get("analytic", {})
        print(f"| {a} | {s} | {_e(rl['t_compute_s'])} | "
              f"{_e(rl['t_memory_s'])} | {_e(rl['t_collective_s'])} | "
              f"{rl['dominant']} | {_e(an.get('t_memory_s', 0))} | "
              f"{rl.get('useful_flop_ratio', 0):.3f} |")


def hillclimb_table():
    base = _load("single", 0)
    opt = _load("single", 1)
    print("\n### §Perf — hillclimbed cells, baseline (paper-faithful, opt0) "
          "vs optimized (opt1)\n")
    print("| cell | term | baseline | optimized | improvement |")
    print("|---|---|---|---|---|")
    for (a, s) in HILL:
        b, o = base.get((a, s)), opt.get((a, s))
        if not b or not o or "roofline" not in b or "roofline" not in o:
            continue
        for t, lbl in (("t_compute_s", "compute"), ("t_memory_s", "memory"),
                       ("t_collective_s", "collective")):
            bv, ov = b["roofline"][t], o["roofline"][t]
            gain = f"{bv/ov:.2f}×" if ov > 0 else "∞"
            print(f"| {a}/{s} | {lbl} | {_e(bv)} | {_e(ov)} | {gain} |")


def multi_pod_check():
    single = _load("single", 1)
    multi = _load("multi", 1)
    ok_s = sum(1 for r in single.values() if "roofline" in r)
    ok_m = sum(1 for r in multi.values() if "roofline" in r)
    sk_s = sum(1 for r in single.values() if "skipped" in r)
    sk_m = sum(1 for r in multi.values() if "skipped" in r)
    er = sum(1 for r in list(single.values()) + list(multi.values())
             if "error" in r)
    print(f"\n§Dry-run: single-pod {ok_s} compiled + {sk_s} skipped; "
          f"multi-pod {ok_m} compiled + {sk_m} skipped; {er} errors.")


def main():
    multi_pod_check()
    roofline_table("single")
    hillclimb_table()


if __name__ == "__main__":
    main()
