"""Paper Table 1 — calibration-length sensitivity.

AWQ calibrated on a *shifted* domain with T ∈ {128 … 8192} tokens vs TTQ with
**zero** offline calibration (r=0 and r=16).  Metric: perplexity on the
in-domain eval set.  Reproduces the claim: TTQ ≥ best AWQ while AWQ degrades
as the calibration budget shrinks.
"""
from __future__ import annotations

from .common import (EVAL_DOMAINS, collect_stats, eval_batches, perplexity,
                     quantize_with, trained_model, ttq_perplexity)

BITS, G = 3, 32
CALIB_DOMAIN = 2       # ≠ eval domain 0 — the C4-calibration role


def run(fast: bool = True):
    cfg, params = trained_model()
    ev = eval_batches(0, n=2 if fast else 4)
    rows = []
    base = perplexity(cfg, params, ev)
    rows.append(("fp", 0, base))
    for r in (0, 16):
        ppl = ttq_perplexity(cfg, params, ev, BITS, G, rank=r)
        rows.append((f"ttq_r{r}", 0, ppl))
    budgets = (128, 512, 2048, 8192) if fast else (128, 256, 512, 1024, 2048,
                                                   4096, 8192)
    for T in budgets:
        n = max(1, T // (8 * 64))
        cal = eval_batches(CALIB_DOMAIN, n=n, batch=min(8, max(1, T // 64)),
                           seq=64, seed0=777)
        # trim to exactly T tokens worth of batches
        stats, count = collect_stats(cfg, params, cal)
        qp = quantize_with(cfg, params, "awq", BITS, G, calib=(stats, count))
        rows.append((f"awq_T{T}", T, perplexity(cfg, qp, ev)))
    return rows


def main(fast: bool = True):
    rows = run(fast)
    print("# Table-1 analogue: calibration length (bits=3, g=32, eval dom 0, "
          "calib dom 2)")
    print("method,calib_tokens,ppl")
    for name, T, ppl in rows:
        print(f"{name},{T},{ppl:.3f}")
    return rows


if __name__ == "__main__":
    main()
