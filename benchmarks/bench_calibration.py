"""Paper Table 1 — calibration-length sensitivity.

AWQ calibrated on a *shifted* domain with T ∈ {128 … 8192} tokens vs TTQ with
**zero** offline calibration (r=0 and r=16).  Metric: perplexity on the
in-domain eval set.  Reproduces the claim: TTQ ≥ best AWQ while AWQ degrades
as the calibration budget shrinks.

The calibration budgets are built *incrementally* by merging
``CalibrationSession`` chunks (the statistics are additive sufficient
statistics, so merge-of-chunks == one big session) — each budget reuses all
previous chunks' prefills instead of recomputing them.
"""
from __future__ import annotations

from repro.quant import CalibrationSession

from .common import (EVAL_DOMAINS, collect_stats, eval_batches, perplexity,
                     quantize_with, trained_model, ttq_perplexity)

BITS, G = 3, 32
CALIB_DOMAIN = 2       # ≠ eval domain 0 — the C4-calibration role


def run(fast: bool = True):
    cfg, params = trained_model()
    ev = eval_batches(0, n=2 if fast else 4)
    rows = []
    base = perplexity(cfg, params, ev)
    rows.append(("fp", 0, base))
    for r in (0, 16):
        ppl = ttq_perplexity(cfg, params, ev, BITS, G, rank=r)
        rows.append((f"ttq_r{r}", 0, ppl))
    budgets = (128, 512, 2048, 8192) if fast else (128, 256, 512, 1024, 2048,
                                                   4096, 8192)
    sess, done, batches_done = CalibrationSession(), 0, 0
    for T in budgets:
        # batches sized from the *remaining* budget so each row lands on
        # exactly T accumulated tokens; the seed base advances by batches
        # consumed so far (eval_batches strides its fold-in by i*131 — a
        # per-chunk stride would collide and re-sample merged batches)
        remaining = T - done
        batch = min(8, max(1, remaining // 64))
        n = max(1, remaining // (batch * 64))
        cal = eval_batches(CALIB_DOMAIN, n=n, batch=batch,
                           seq=64, seed0=777 + 131 * batches_done)
        batches_done += n
        sess = sess.merge(collect_stats(cfg, params, cal))   # grow the budget
        done = int(sess.count)
        qp = quantize_with(cfg, params, "awq", BITS, G, calib=sess)
        rows.append((f"awq_T{T}", done, perplexity(cfg, qp, ev)))
    return rows


def main(fast: bool = True):
    rows = run(fast)
    print("# Table-1 analogue: calibration length (bits=3, g=32, eval dom 0, "
          "calib dom 2)")
    print("method,calib_tokens,ppl")
    for name, T, ppl in rows:
        print(f"{name},{T},{ppl:.3f}")
    return rows


if __name__ == "__main__":
    main()
