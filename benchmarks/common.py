"""Shared benchmark substrate: a small trained LM + quantized-perplexity eval.

The paper's quality tables need a model whose activations carry real structure
(random weights have no outlier channels and near-uniform softmax).  We train
a compact LM in-framework on the synthetic multi-domain corpus (data/pipeline)
and cache it under results/bench_model/.  Domains play the WT2/PTB/C4 role:
the same architecture of experiment — calibrate on one, evaluate on another —
transfers.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import AWQConfig
from repro.data import DataConfig, make_domain, sample_batch, token_stream
from repro.models import ModelConfig, lm
from repro.quant import CalibrationSession, QuantizedModel, ttq_policy
from repro.training import TrainConfig, Trainer

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

BENCH_CFG = ModelConfig(name="bench-lm", family="dense", n_layers=4,
                        d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
                        vocab=256)
BENCH_DC = DataConfig(vocab=256, seq_len=64, batch=16, branch=6, seed=7)
TRAIN_DOMAIN = 0
EVAL_DOMAINS = (0, 1, 2)     # 0 = in-domain; 1, 2 = shifted (PTB/C4 role)
CALIB_DOMAINS = (1, 2, 3)


def trained_model(steps: int = 300, force: bool = False):
    """Train (or load cached) the benchmark LM. Returns (cfg, params)."""
    ckdir = os.path.join(RESULTS, "bench_model")
    mgr = CheckpointManager(ckdir, keep=1)
    tc = TrainConfig(n_microbatches=1, remat=False, total_steps=steps,
                     warmup=20, checkpoint_every=steps, checkpoint_dir=ckdir)
    # mixed-domain training so all eval domains are in-support but distinct
    def mixed():
        its = [token_stream(BENCH_DC, d) for d in (0, 1, 2, 3)]
        i = 0
        while True:
            yield next(its[i % 2])       # train mostly on domains 0/1
            i += 1
    tr = Trainer(BENCH_CFG, tc, mixed())
    if not force and tr.restore_if_available() and tr.step >= steps:
        return BENCH_CFG, tr.params
    tr.run(steps - tr.step)
    tr.ckpt.save(tr.step, {"opt": tr.opt_state})
    return BENCH_CFG, tr.params


def eval_batches(domain: int, n: int = 4, seq: int = 64, batch: int = 8,
                 seed0: int = 9000):
    spec = make_domain(BENCH_DC, domain)
    out = []
    for i in range(n):
        key = jax.random.fold_in(jax.random.PRNGKey(BENCH_DC.seed),
                                 seed0 + i * 131 + domain)
        out.append({"tokens": sample_batch(spec, key, batch, seq)})
    return out


def perplexity(cfg, params, batches) -> float:
    tot, cnt = 0.0, 0.0
    for b in batches:
        loss, aux = lm.loss_fn(cfg, params, b)
        tot += float(loss) * float(aux["tokens"])
        cnt += float(aux["tokens"])
    return float(np.exp(tot / cnt))


def collect_stats(cfg, params, batches) -> CalibrationSession:
    """Accumulate activation statistics over batches (offline calibration)."""
    sess = CalibrationSession()
    for b in batches:
        _, _, stats = lm.prefill(cfg, params, b, max_len=b["tokens"].shape[1],
                                 collect_stats=True)
        sess.update(stats, tokens=float(b["tokens"].size))
    return sess


def quantize_with(cfg, params, method: str, bits: int, group_size: int,
                  rank: int = 0, calib: CalibrationSession = None,
                  acfg: AWQConfig = AWQConfig(), overrides=()):
    """method: any registered quantizer name ('rtn' | 'awq' | 'ttq' | ...);
    stats-dependent methods need ``calib``.  Returns the quantized tree."""
    pol = ttq_policy(bits=bits, group_size=group_size, rank=rank,
                     packed=False, acfg=acfg).with_(method=method)
    if overrides:
        pol = pol.with_overrides(*overrides)
    qm = QuantizedModel(params, pol, acfg=acfg,
                        session=calib.snapshot() if calib is not None else None)
    qp = qm.requantize()
    if qp is None:
        raise ValueError(f"method {method!r} needs calibration statistics — "
                         "pass calib=collect_stats(...)")
    return qp


def ttq_perplexity(cfg, params, batches, bits, group_size, rank=0,
                   acfg: AWQConfig = AWQConfig()) -> float:
    """TTQ: re-quantize per incoming batch from that batch's own stats —
    zero offline calibration (the paper's test-time loop)."""
    pol = ttq_policy(bits=bits, group_size=group_size, rank=rank,
                     packed=False, acfg=acfg)
    qm = QuantizedModel(params, pol, acfg=acfg)   # low-rank factors: once
    tot, cnt = 0.0, 0.0
    for b in batches:
        qm.session = collect_stats(cfg, params, [b])
        qp = qm.requantize()
        loss, aux = lm.loss_fn(cfg, qp, b)
        tot += float(loss) * float(aux["tokens"])
        cnt += float(aux["tokens"])
    return float(np.exp(tot / cnt))


def macro_avg(vals):
    return float(np.mean(vals))
