#!/usr/bin/env python
"""Docs-link checker (CI step): fails if documentation drifts from code.

Validates two kinds of references:

1. markdown → file: every relative ``[text](path)`` link in the repo's
   ``*.md`` files resolves to an existing file (anchors/URLs are skipped);
2. source → docs sections: every EXPERIMENTS-/DESIGN-md section citation
   (the ``<doc>.md §<section>`` form, bare word or quoted) found in
   ``src``/``benchmarks``/``examples``/``tests`` resolves to a section
   heading of that document; numeric citations need a heading with that
   number prefix.

Usage:  python tools/check_docs_links.py   (exit 1 on any dangling ref)
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC_DIRS = ("src", "benchmarks", "examples", "tests", "tools")
MD_LINK = re.compile(r"\[[^\]]+\]\(([^)#\s]+)(?:#[^)]*)?\)")
# EXPERIMENTS.md §Roofline | DESIGN.md §"KV-cache layout" | DESIGN.md §4
CITE = re.compile(r"(EXPERIMENTS|DESIGN)\.md\s+§(?:\"([^\"]+)\"|(\w[\w-]*))")


def md_files():
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames
                       if d not in (".git", "__pycache__", ".github", "results")]
        for f in filenames:
            if f.endswith(".md"):
                yield os.path.join(dirpath, f)


def check_md_links(errors):
    for path in md_files():
        base = os.path.dirname(path)
        with open(path, encoding="utf-8") as f:
            for ln, line in enumerate(f, 1):
                for m in MD_LINK.finditer(line):
                    target = m.group(1)
                    if "://" in target or target.startswith("mailto:"):
                        continue
                    if not os.path.exists(os.path.join(base, target)):
                        errors.append(f"{os.path.relpath(path, ROOT)}:{ln}: "
                                      f"dangling link -> {target}")


def headings(doc):
    path = os.path.join(ROOT, doc)
    if not os.path.exists(path):
        return None
    with open(path, encoding="utf-8") as f:
        return [l.lstrip("#").strip() for l in f if l.startswith("#")]


def check_section_citations(errors):
    heads = {d: headings(f"{d}.md") for d in ("EXPERIMENTS", "DESIGN")}
    for sub in SRC_DIRS:
        for dirpath, dirnames, filenames in os.walk(os.path.join(ROOT, sub)):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fname in filenames:
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                with open(path, encoding="utf-8") as f:
                    # whole-file scan: the `\s+` crosses docstring line wraps
                    # ("EXPERIMENTS.md\n    §Roofline"), which a per-line
                    # scan would silently skip
                    content = f.read()
                for m in CITE.finditer(content):
                    ln = content.count("\n", 0, m.start()) + 1
                    doc, quoted, word = m.group(1), m.group(2), m.group(3)
                    # docstring wraps put newlines+indent inside quoted names
                    name = re.sub(r"\s+", " ", quoted or word)
                    hs = heads[doc]
                    if hs is None:
                        errors.append(f"{os.path.relpath(path, ROOT)}:"
                                      f"{ln}: cites missing {doc}.md")
                        continue
                    if word and word.isdigit():
                        ok = any(h.startswith(f"{word}.") for h in hs)
                    else:
                        ok = any(name.lower() in h.lower() for h in hs)
                    if not ok:
                        errors.append(
                            f"{os.path.relpath(path, ROOT)}:{ln}: "
                            f"dangling citation {doc}.md §{name}")


def main() -> int:
    errors: list = []
    check_md_links(errors)
    check_section_citations(errors)
    if errors:
        print("docs-link check FAILED:")
        for e in errors:
            print(f"  {e}")
        return 1
    print("docs-link check passed: all markdown links and §-citations resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
