#!/usr/bin/env python
"""Docs-link checker — thin shim over the ``tools.tracecheck`` docs pass.

The logic lives in ``tools/tracecheck/docs_links.py`` (rules TCDOC1/2);
CI runs the whole suite via ``python -m tools.tracecheck``.  This entry
point survives for muscle memory / older scripts.

Usage:  python tools/check_docs_links.py   (exit 1 on any dangling ref)
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from tools.tracecheck import docs_links  # noqa: E402


def main() -> int:
    errors = docs_links.check()
    if errors:
        print("docs-link check FAILED:")
        for e in errors:
            print(f"  {e.path}:{e.line}: {e.message}")
        return 1
    print("docs-link check passed: all markdown links and §-citations resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
