"""Repo tooling: ``tools.tracecheck`` (static analysis) and doc checkers."""
