"""kernel-contract pass (TC3xx): Pallas kernels keep their oracle contract.

The repo's kernel discipline (DESIGN.md §2): every ``pallas_call`` kernel
lives under a ``kernels/`` package, is *only* reached through a wrapper in
``kernels/ops.py`` that takes ``use_pallas`` and falls back to a pure-jnp
oracle in ``kernels/ref.py`` — so every code path runs everywhere and the
kernel is diffable against reference math.  Rules:

* TC301 — BlockSpec index-map arity must equal the grid rank (plus the
  ``num_scalar_prefetch`` offset for ``PrefetchScalarGridSpec``): a
  mismatched lambda fails only at trace time on the kernel path, which CI
  in interpret mode may not exercise with every config;
* TC302 — a public kernel entry (top-level def containing a
  ``pallas_call``) must be dispatched from an ``ops.py`` wrapper that has
  a ``use_pallas`` parameter (the escape hatch);
* TC303 — every ``ops.py`` wrapper with ``use_pallas`` must call into the
  ``ref`` module (the fallback must actually exist, not just the flag);
* TC304 — no ``astype(bfloat16/float16)`` literal inside ``kernels/``:
  a silent precision cast the jnp fallback won't replicate (the PR-4 bug
  class); casts to a dynamic ``x.dtype`` are fine;
* TC305 — ``dot_general``/``dot``/``matmul``/``einsum`` inside a kernel
  body must pin ``preferred_element_type`` (MXU accumulates in the output
  dtype otherwise — bf16 accumulation diverges from the f32 oracle).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from . import callgraph
from .core import Finding, Module, Repo


def _text(expr: ast.AST) -> Optional[str]:
    parts: List[str] = []
    node = expr
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _in_kernels_dir(mod: Module) -> bool:
    return "kernels" in mod.path.split("/")


def _is_pallas_call(node: ast.Call) -> bool:
    d = _text(node.func)
    return d is not None and d.split(".")[-1] == "pallas_call"


def _local_assigns(fn: ast.AST) -> Dict[str, ast.AST]:
    out: Dict[str, ast.AST] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out[t.id] = node.value
    return out


def _grid_rank(expr: ast.AST, local: Dict[str, ast.AST]) -> Optional[int]:
    if isinstance(expr, ast.Name) and expr.id in local:
        expr = local[expr.id]
    if isinstance(expr, (ast.Tuple, ast.List)):
        return len(expr.elts)
    return None


def _blockspecs(expr: ast.AST, local: Dict[str, ast.AST]) -> List[ast.Call]:
    """BlockSpec calls inside an in_specs/out_specs expression."""
    if isinstance(expr, ast.Name) and expr.id in local:
        expr = local[expr.id]
    out = []
    for n in ast.walk(expr):
        if isinstance(n, ast.Call):
            d = _text(n.func)
            if d and d.split(".")[-1] == "BlockSpec":
                out.append(n)
    return out


def _index_map_lambda(spec: ast.Call) -> Optional[ast.Lambda]:
    for a in list(spec.args) + [k.value for k in spec.keywords]:
        if isinstance(a, ast.Lambda):
            return a
    return None


def _kernel_fn_names(first_arg: ast.AST, local: Dict[str, ast.AST]
                     ) -> Set[str]:
    """Names of defs referenced by pallas_call's kernel argument, chasing
    one level of local assignment and ``partial`` wrapping."""
    out: Set[str] = set()
    seen = 0
    stack = [first_arg]
    while stack and seen < 50:
        seen += 1
        node = stack.pop()
        if isinstance(node, ast.Name):
            if node.id in local:
                stack.append(local[node.id])
            else:
                out.add(node.id)
        elif isinstance(node, ast.Call):
            stack.extend(node.args)
            stack.extend(k.value for k in node.keywords)
        elif isinstance(node, ast.Lambda):
            stack.append(node.body)
        elif isinstance(node, ast.Attribute):
            d = _text(node)
            if d:
                out.add(d.split(".")[-1])
    return out


_DOTS = {"dot_general", "dot", "matmul", "einsum"}


def check(repo: Repo) -> List[Finding]:
    cg = callgraph.build(repo)
    out: List[Finding] = []

    kernel_mods = [m for m in repo if _in_kernels_dir(m)]
    ops_mods = [m for m in kernel_mods
                if m.path.rsplit("/", 1)[-1] == "ops.py"]

    # ---- collect pallas_call sites, public entries, and kernel-body fns
    entries: Dict[str, callgraph.FuncInfo] = {}   # qualname -> entry def
    body_fns: Set[str] = set()                    # qualnames of kernel bodies
    for q, fi in cg.funcs.items():
        if not _in_kernels_dir(fi.module):
            continue
        base = fi.module.path.rsplit("/", 1)[-1]
        if base in ("ops.py", "ref.py", "__init__.py"):
            continue
        local = _local_assigns(fi.node)
        has_pc = False
        for node in ast.walk(fi.node):
            if not (isinstance(node, ast.Call) and _is_pallas_call(node)):
                continue
            has_pc = True
            # TC301: grid rank vs index-map arity
            rank: Optional[int] = None
            prefetch = 0
            specs: List[ast.Call] = []
            kw = {k.arg: k.value for k in node.keywords}
            if "grid" in kw:
                rank = _grid_rank(kw["grid"], local)
            gs = kw.get("grid_spec")
            if gs is not None:
                if isinstance(gs, ast.Name) and gs.id in local:
                    gs = local[gs.id]
                if isinstance(gs, ast.Call):
                    gkw = {k.arg: k.value for k in gs.keywords}
                    if "grid" in gkw:
                        rank = _grid_rank(gkw["grid"], local)
                    pf = gkw.get("num_scalar_prefetch")
                    if isinstance(pf, ast.Constant) and isinstance(
                            pf.value, int):
                        prefetch = pf.value
                    for key in ("in_specs", "out_specs"):
                        if key in gkw:
                            specs += _blockspecs(gkw[key], local)
            for key in ("in_specs", "out_specs"):
                if key in kw:
                    specs += _blockspecs(kw[key], local)
            if rank is not None:
                want = rank + prefetch
                for spec in specs:
                    lam = _index_map_lambda(spec)
                    if lam is None:
                        continue
                    arity = len(lam.args.args)
                    if arity != want:
                        out.append(Finding(
                            "TC301", fi.module.path, spec.lineno,
                            f"BlockSpec index map takes {arity} args but "
                            f"grid rank is {rank}"
                            + (f" + {prefetch} scalar-prefetch"
                               if prefetch else "")
                            + f" = {want} (in {q})"))
            # kernel body functions (for TC305)
            if node.args:
                names = _kernel_fn_names(node.args[0], local)
                for n in names:
                    fi2 = cg.resolve_func(f"{fi.module.name}.{n}")
                    if fi2 is not None:
                        body_fns.add(fi2.qualname)
        if has_pc and fi.class_name is None and "." not in \
                q[len(fi.module.name) + 1:]:
            entries[q] = fi

    # ---- ops.py wrappers: use_pallas param + ref fallback + dispatch map
    dispatched: Set[str] = set()
    for mod in ops_mods:
        for q, fi in cg.funcs.items():
            if fi.module is not mod:
                continue
            args = fi.node.args
            params = [p.arg for p in args.posonlyargs + args.args
                      + args.kwonlyargs]
            if "use_pallas" not in params:
                continue
            calls_ref = False
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                d = cg.dotted(mod, node.func)
                fi2 = cg.resolve_func(d)
                if fi2 is not None:
                    if fi2.qualname in entries:
                        dispatched.add(fi2.qualname)
                    if fi2.module.path.rsplit("/", 1)[-1] == "ref.py":
                        calls_ref = True
                elif d is not None and ".ref." in f".{d}":
                    calls_ref = True
            if not calls_ref:
                out.append(Finding(
                    "TC303", mod.path, fi.node.lineno,
                    f"ops wrapper {q.split('.')[-1]} has use_pallas but "
                    f"never calls a ref.py oracle — the escape hatch has "
                    f"no fallback"))

    # TC302: every public kernel entry must be dispatched from ops.py
    for q, fi in entries.items():
        if q not in dispatched:
            out.append(Finding(
                "TC302", fi.module.path, fi.node.lineno,
                f"pallas kernel entry {q.split('.')[-1]} is not dispatched "
                f"from any ops.py wrapper with use_pallas — callers can't "
                f"fall back to the oracle"))

    # ---- TC304 silent low-precision casts anywhere under kernels/
    for mod in kernel_mods:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype" and node.args):
                continue
            arg = node.args[0]
            target = None
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                target = arg.value
            else:
                d = _text(arg)
                if d:
                    target = d.split(".")[-1]
            if target in ("bfloat16", "float16", "fp16", "bf16"):
                out.append(Finding(
                    "TC304", mod.path, node.lineno,
                    f"silent astype({target}) in kernels/ — precision "
                    f"contract vs the jnp oracle; cast at the boundary "
                    f"with the caller's dtype instead"))

    # ---- TC305 unpinned accumulation dtype in kernel bodies
    for q in sorted(body_fns):
        fi = cg.funcs[q]
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            d = _text(node.func)
            if d is None or d.split(".")[-1] not in _DOTS:
                continue
            if not any(k.arg == "preferred_element_type"
                       for k in node.keywords):
                out.append(Finding(
                    "TC305", fi.module.path, node.lineno,
                    f"{d.split('.')[-1]} in kernel body "
                    f"{q.split('.')[-1]} without preferred_element_type — "
                    f"accumulation dtype follows inputs and diverges from "
                    f"the f32 oracle"))
    return out
