"""tracecheck — repo-specific static analysis for the TTQ serving stack.

Four AST passes over ``src/repro`` plus the docs-link checker, one entry
point (``python -m tools.tracecheck``), one baseline file
(``tools/tracecheck/baseline.toml``) for intentional exceptions:

* **host-sync** (TC1xx) — implicit device→host transfers on hot paths:
  ``.item()``, ``int()/float()/bool()`` on array values, ``np.asarray`` /
  ``jax.device_get`` in functions reachable from ``lm.decode_many`` or
  ``DeviceRunner``'s decode path, and Python ``if``/``while`` on
  tracer-typed values inside jitted/scanned bodies;
* **recompile-hazard** (TC2xx) — unhashable or non-frozen static args at
  jit callsites, ``static_argnames``/``static_argnums`` drift against the
  wrapped signature, mutable defaults in jitted signatures;
* **kernel-contract** (TC3xx) — every ``pallas_call`` kernel must be
  dispatched through an ``ops.py`` wrapper with a ``use_pallas`` escape
  hatch backed by a ``ref.py`` oracle; BlockSpec index maps must match the
  grid rank; no silent f32→bf16 casts; ``dot_general`` inside kernels must
  pin ``preferred_element_type``;
* **serving-invariant** (TC4xx) — no device allocation or block-table
  mutation outside ``DeviceRunner``/``BlockAllocator``, and the
  ``TTQEngine`` facade keeps its back-compat surface.

See DESIGN.md §"Static analysis & runtime invariants" for the pass
catalog and the baseline/suppression workflow.
"""
from .core import Finding, load_baseline, run, scan_paths  # noqa: F401

__all__ = ["Finding", "load_baseline", "run", "scan_paths"]
