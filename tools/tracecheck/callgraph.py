"""Best-effort call graph + name resolution over the parsed repo.

Static analysis of a jax codebase needs to see *through* the wrappers the
code actually uses — ``jax.jit(partial(lm.decode_many, cfg, ...))`` stored
on ``self._decode_jit``, ``lax.scan(step_fn, ...)``, decorator-jitted
defs — so this module builds:

* ``funcs``: every (possibly nested) ``def``, keyed by dotted qualname
  (``repro.serving.runner.DeviceRunner.decode_block``);
* ``edges``: call edges, including edges through ``jax.jit`` /
  ``functools.partial`` / ``jax.vmap`` / ``lax.scan`` / ``jax.checkpoint``
  arguments and through ``self.<attr>`` where ``<attr>`` was assigned a
  wrapped function in any method of the class;
* ``traced``: functions whose bodies run under trace (jit-decorated, or
  passed to jit/vmap/scan/pallas_call anywhere in the repo);
* ``classes``: dataclass registry with frozen-ness (for the
  recompile-hazard pass's static-arg checks).

Resolution is intentionally conservative: unknown names resolve to
``None`` and produce no edges/findings — the passes only act on what can
be proven from the AST.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .core import Module, Repo

# call wrappers whose function-valued arguments we follow
WRAPPERS = {
    "jax.jit", "jax.pmap", "jax.vmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.lax.scan", "jax.lax.fori_loop",
    "jax.lax.while_loop", "jax.lax.cond", "jax.lax.switch", "jax.lax.map",
    "functools.partial", "jax.experimental.pallas.pallas_call",
}
# wrappers that put their function argument under trace
TRACING = {
    "jax.jit", "jax.pmap", "jax.vmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.lax.scan", "jax.lax.fori_loop",
    "jax.lax.while_loop", "jax.lax.cond", "jax.lax.switch", "jax.lax.map",
    "jax.experimental.pallas.pallas_call",
}


@dataclass
class FuncInfo:
    qualname: str
    module: Module
    node: ast.AST                       # FunctionDef | AsyncFunctionDef
    class_name: Optional[str] = None    # enclosing class, if a method


@dataclass
class ClassInfo:
    qualname: str
    module: Module
    node: ast.ClassDef
    is_dataclass: bool = False
    frozen: bool = False


@dataclass
class CallGraph:
    repo: Repo
    funcs: Dict[str, FuncInfo] = field(default_factory=dict)
    classes: Dict[str, ClassInfo] = field(default_factory=dict)
    imports: Dict[str, Dict[str, str]] = field(default_factory=dict)
    edges: Dict[str, Set[str]] = field(default_factory=dict)
    traced: Set[str] = field(default_factory=set)
    # (module.Class, attr) -> function qualnames assigned to self.attr
    attr_funcs: Dict[Tuple[str, str], Set[str]] = field(default_factory=dict)

    # ------------------------------------------------------- name resolution

    def dotted(self, mod: Module, expr: ast.AST,
               self_class: Optional[str] = None) -> Optional[str]:
        """Resolve a Name/Attribute chain to a dotted path, through the
        module's import table.  ``self.x`` resolves against ``self_class``."""
        parts: List[str] = []
        node = expr
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        parts.reverse()
        head, rest = parts[0], parts[1:]
        table = self.imports.get(mod.name, {})
        if head == "self" and self_class:
            base = f"{mod.name}.{self_class}"
        elif head in table:
            base = table[head]
        else:
            base = f"{mod.name}.{head}" if self._local(mod, head) else head
        return ".".join([base] + rest)

    def _local(self, mod: Module, name: str) -> bool:
        for stmt in mod.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)) and stmt.name == name:
                return True
            if isinstance(stmt, ast.Assign):
                if any(isinstance(t, ast.Name) and t.id == name
                       for t in stmt.targets):
                    return True
        return False

    def resolve_func(self, dotted: Optional[str],
                     hops: int = 4) -> Optional[FuncInfo]:
        """Map a dotted path to a known def, chasing package re-exports
        (``repro.core.KVCacheConfig`` → ``repro.core.policy.KVCacheConfig``)."""
        for _ in range(hops):
            if dotted is None:
                return None
            if dotted in self.funcs:
                return self.funcs[dotted]
            # chase one re-export hop: longest module prefix whose import
            # table maps the next component
            nxt = self._chase(dotted)
            if nxt == dotted:
                return None
            dotted = nxt
        return None

    def resolve_class(self, dotted: Optional[str],
                      hops: int = 4) -> Optional[ClassInfo]:
        for _ in range(hops):
            if dotted is None:
                return None
            if dotted in self.classes:
                return self.classes[dotted]
            nxt = self._chase(dotted)
            if nxt == dotted:
                return None
            dotted = nxt
        return None

    def _chase(self, dotted: str) -> str:
        parts = dotted.split(".")
        for i in range(len(parts) - 1, 0, -1):
            prefix, head = ".".join(parts[:i]), parts[i]
            table = self.imports.get(prefix)
            if table and head in table:
                return ".".join([table[head]] + parts[i + 1:])
        return dotted

    # --------------------------------------------------------- reachability

    def reachable(self, roots: List[str]) -> Set[str]:
        seen: Set[str] = set()
        stack = [r for r in roots if r in self.funcs or r in self.edges]
        # allow class roots: "…DeviceRunner" pulls in every method
        for r in roots:
            seen.update(q for q in self.funcs if q.startswith(r + "."))
            if r in self.funcs:
                seen.add(r)
        stack = list(seen)
        while stack:
            cur = stack.pop()
            for nxt in self.edges.get(cur, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
        return seen


# ------------------------------------------------------------------ build

def _import_table(mod: Module) -> Dict[str, str]:
    table: Dict[str, str] = {}
    pkg = mod.name.rsplit(".", 1)[0] if "." in mod.name else ""
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                table[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            if node.level:                      # relative import
                base = mod.name
                # level 1 from a module == its package; each extra level
                # strips one more component
                for _ in range(node.level):
                    base = base.rsplit(".", 1)[0] if "." in base else ""
                if node.module:
                    base = f"{base}.{node.module}" if base else node.module
            else:
                base = node.module or ""
            for a in node.names:
                if a.name == "*":
                    continue
                table[a.asname or a.name] = (
                    f"{base}.{a.name}" if base else a.name)
    return table


def _is_dataclass_deco(deco: ast.AST) -> Tuple[bool, bool]:
    """(is_dataclass, frozen) for one decorator node."""
    name = None
    node = deco
    frozen = False
    if isinstance(node, ast.Call):
        for kw in node.keywords:
            if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                frozen = bool(kw.value.value)
        node = node.func
    if isinstance(node, ast.Attribute):
        name = node.attr
    elif isinstance(node, ast.Name):
        name = node.id
    return name == "dataclass", frozen


def _collect_defs(cg: CallGraph, mod: Module):
    def visit(body, prefix: str, class_name: Optional[str]):
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}.{node.name}"
                cg.funcs[q] = FuncInfo(q, mod, node, class_name)
                visit(node.body, q, class_name)
            elif isinstance(node, ast.ClassDef):
                q = f"{prefix}.{node.name}"
                is_dc = frozen = False
                for d in node.decorator_list:
                    dc, fr = _is_dataclass_deco(d)
                    is_dc, frozen = is_dc or dc, frozen or fr
                cg.classes[q] = ClassInfo(q, mod, node, is_dc, frozen)
                visit(node.body, q, node.name)
            elif isinstance(node, (ast.If, ast.Try, ast.With)):
                for sub in ast.iter_child_nodes(node):
                    if hasattr(sub, "body"):
                        visit(getattr(sub, "body"), prefix, class_name)

    visit(mod.tree.body, mod.name, None)


def _func_refs(cg: CallGraph, mod: Module, expr: ast.AST,
               self_class: Optional[str],
               scope_q: Optional[str] = None) -> Set[str]:
    """Function qualnames referenced by ``expr``, chasing wrapper calls
    (``jax.jit(partial(f, ...))`` yields ``f``).  ``scope_q`` lets bare
    names resolve to defs nested inside the referencing function (the
    ``lax.scan(step_fn, ...)`` idiom)."""
    out: Set[str] = set()
    if isinstance(expr, ast.Call):
        callee = cg.dotted(mod, expr.func, self_class)
        if callee is not None and _canon(callee) in WRAPPERS:
            for a in list(expr.args) + [k.value for k in expr.keywords]:
                out |= _func_refs(cg, mod, a, self_class, scope_q)
        return out
    if isinstance(expr, ast.Name) and scope_q is not None \
            and f"{scope_q}.{expr.id}" in cg.funcs:
        out.add(f"{scope_q}.{expr.id}")
        return out
    if isinstance(expr, (ast.Name, ast.Attribute)):
        d = cg.dotted(mod, expr, self_class)
        fi = cg.resolve_func(d)
        if fi is not None:
            out.add(fi.qualname)
    return out


def _canon(dotted: str) -> str:
    """Normalize common aliases (lax → jax.lax, partial → functools.partial,
    pl.pallas_call → …pallas.pallas_call)."""
    repl = {
        "lax.": "jax.lax.", "partial": "functools.partial",
        "jnp.": "jax.numpy.", "pl.": "jax.experimental.pallas.",
        "jax.experimental.pallas": "jax.experimental.pallas",
    }
    for k, v in repl.items():
        if k.endswith("."):
            if dotted.startswith(k):
                return v + dotted[len(k):]
        elif dotted == k:
            return v
    return dotted


def build(repo: Repo) -> CallGraph:
    cg = CallGraph(repo)
    for mod in repo:
        cg.imports[mod.name] = _import_table(mod)
        _collect_defs(cg, mod)

    # self.<attr> = <wrapped fn> assignments (any method of the class)
    for q, fi in cg.funcs.items():
        if fi.class_name is None:
            continue
        cls_q = q.rsplit(".", 1)[0]
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Assign):
                continue
            refs = _func_refs(cg, fi.module, node.value, fi.class_name)
            if not refs:
                continue
            for tgt in node.targets:
                if (isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"):
                    cg.attr_funcs.setdefault((cls_q, tgt.attr),
                                             set()).update(refs)

    # edges + traced set
    for q, fi in cg.funcs.items():
        edges = cg.edges.setdefault(q, set())
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Call):
                callee = cg.dotted(fi.module, node.func, fi.class_name)
                canon = _canon(callee) if callee else None
                if canon in WRAPPERS:
                    for a in (list(node.args)
                              + [k.value for k in node.keywords]):
                        refs = _func_refs(cg, fi.module, a, fi.class_name,
                                          scope_q=q)
                        edges |= refs
                        if canon in TRACING:
                            cg.traced |= refs
                    continue
                if (isinstance(node.func, ast.Name)
                        and f"{q}.{node.func.id}" in cg.funcs):
                    edges.add(f"{q}.{node.func.id}")
                    continue
                fi2 = cg.resolve_func(callee)
                if fi2 is not None:
                    edges.add(fi2.qualname)
                    continue
                # self.<attr>() through the attr-assignment table
                if (isinstance(node.func, ast.Attribute)
                        and isinstance(node.func.value, ast.Name)
                        and node.func.value.id == "self"
                        and fi.class_name is not None):
                    cls_q = f"{fi.module.name}.{fi.class_name}"
                    edges |= cg.attr_funcs.get((cls_q, node.func.attr), set())
        # decorators: @jax.jit / @partial(jax.jit, ...) put the def on trace
        deco_list = getattr(fi.node, "decorator_list", [])
        for d in deco_list:
            name = cg.dotted(fi.module, d.func if isinstance(d, ast.Call)
                             else d, fi.class_name)
            if name is not None and _canon(name) in TRACING:
                cg.traced.add(q)
            elif (isinstance(d, ast.Call)
                  and name is not None and _canon(name) == "functools.partial"
                  and d.args):
                inner = cg.dotted(fi.module, d.args[0], fi.class_name)
                if inner is not None and _canon(inner) in TRACING:
                    cg.traced.add(q)

    # traced-ness propagates into helpers called from traced functions: a
    # python `if` on a tracer is just as fatal two frames down
    frontier = list(cg.traced)
    while frontier:
        cur = frontier.pop()
        for nxt in cg.edges.get(cur, ()):
            if nxt not in cg.traced and nxt in cg.funcs:
                # only propagate within the scanned repo
                cg.traced.add(nxt)
                frontier.append(nxt)
    return cg
