"""host-sync pass (TC1xx): implicit device→host transfers on hot paths.

Hot = every function reachable from ``lm.decode_many`` or any
``DeviceRunner`` method (the per-token and per-admission device paths the
serving engine's host-syncs/token metric measures).  Rules:

* TC101 — ``.item()`` call in a hot function (each is one blocking sync);
* TC102 — ``int()``/``float()``/``bool()`` applied to an array-valued
  expression in a hot function;
* TC103 — ``jax.device_get`` in a hot function (the *designed* syncs — one
  per decode chunk, one per admission — live in the baseline);
* TC104 — ``np.asarray``/``np.array`` on an array value in a hot function
  (silent d2h copy; use an explicit ``jax.device_get`` if intended);
* TC105 — Python ``if``/``while`` on an array value inside traced code
  (jit-decorated defs, scan bodies, and helpers they call) — a
  ConcretizationError at best, a silent sync under eager fallback.

Array-valued-ness is a local taint: names assigned from ``jnp.*`` /
``jax.*`` / ``lax.*`` calls (and arithmetic/indexing thereof), minus
metadata reads (``.shape``/``.ndim``/``.dtype``/``len``).  Function
parameters are deliberately *not* tainted — config/static-arg branching
is ubiquitous and legitimate; the bug class this catches is branching on
*computed* device values.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence, Set

from . import callgraph
from .core import Finding, Repo

HOT_ROOTS = [
    "repro.models.lm.decode_many",
    "repro.serving.runner.DeviceRunner",
]

# attribute reads that leave the device-value world
_META_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes", "sharding"}
# methods that already ARE host syncs (flagged separately, not taint)
_HOST_METHODS = {"item", "tolist", "block_until_ready"}
_ARRAY_PREFIXES = ("jnp.", "jax.numpy.", "jax.lax.", "lax.", "jax.nn.",
                   "jax.random.")
_ARRAY_CALLS = {"jax.device_put", "jax.eval_shape"}


def _text_dotted(expr: ast.AST) -> Optional[str]:
    """Attribute chain exactly as written (no import resolution)."""
    parts: List[str] = []
    node = expr
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _is_array_call(expr: ast.Call) -> bool:
    d = _text_dotted(expr.func)
    if d is None:
        return False
    if d in _ARRAY_CALLS:
        return True
    if any(d.startswith(p) for p in _ARRAY_PREFIXES):
        tail = d.rsplit(".", 1)[-1]
        return tail not in _META_ATTRS
    return False


def expr_tainted(expr: ast.AST, tainted: Set[str]) -> bool:
    """Does ``expr`` evaluate to a device array, given tainted names?"""
    if isinstance(expr, ast.Name):
        return expr.id in tainted
    if isinstance(expr, ast.Call):
        if _is_array_call(expr):
            return True
        if isinstance(expr.func, ast.Attribute):
            # x.astype(...) / x.sum() on a tainted x stays tainted; x.item()
            # and friends leave the device
            if expr.func.attr in _HOST_METHODS | _META_ATTRS:
                return False
            return expr_tainted(expr.func.value, tainted)
        return False
    if isinstance(expr, ast.Attribute):
        if expr.attr in _META_ATTRS | _HOST_METHODS:
            return False
        return expr_tainted(expr.value, tainted)
    if isinstance(expr, ast.Subscript):
        return expr_tainted(expr.value, tainted)
    if isinstance(expr, ast.BinOp):
        return expr_tainted(expr.left, tainted) or expr_tainted(
            expr.right, tainted)
    if isinstance(expr, ast.UnaryOp):
        return expr_tainted(expr.operand, tainted)
    if isinstance(expr, ast.Compare):
        return expr_tainted(expr.left, tainted) or any(
            expr_tainted(c, tainted) for c in expr.comparators)
    if isinstance(expr, ast.BoolOp):
        return any(expr_tainted(v, tainted) for v in expr.values)
    if isinstance(expr, ast.IfExp):
        return expr_tainted(expr.body, tainted) or expr_tainted(
            expr.orelse, tainted)
    if isinstance(expr, (ast.Tuple, ast.List)):
        return any(expr_tainted(e, tainted) for e in expr.elts)
    return False


def _target_names(tgt: ast.AST) -> List[str]:
    if isinstance(tgt, ast.Name):
        return [tgt.id]
    if isinstance(tgt, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in tgt.elts:
            out.extend(_target_names(e))
        return out
    return []


def taint_names(fn: ast.AST) -> Set[str]:
    """Fixpoint over assignments: names holding device arrays."""
    tainted: Set[str] = set()
    for _ in range(4):
        changed = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                if expr_tainted(node.value, tainted):
                    for t in node.targets:
                        for n in _target_names(t):
                            if n not in tainted:
                                tainted.add(n)
                                changed = True
            elif isinstance(node, ast.AugAssign):
                if (isinstance(node.target, ast.Name)
                        and expr_tainted(node.value, tainted)
                        and node.target.id not in tainted):
                    tainted.add(node.target.id)
                    changed = True
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                if (isinstance(node.target, ast.Name)
                        and expr_tainted(node.value, tainted)
                        and node.target.id not in tainted):
                    tainted.add(node.target.id)
                    changed = True
        if not changed:
            break
    return tainted


def _own_body(fn: ast.AST):
    """Walk ``fn`` without descending into nested defs (they are separate
    FuncInfos with their own taint scope)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            stack.extend(ast.iter_child_nodes(node))


def _static_names(cg: callgraph.CallGraph, fi: callgraph.FuncInfo) -> Set[str]:
    """Names listed in static_argnames of the def's jit decorator(s)."""
    out: Set[str] = set()
    for d in getattr(fi.node, "decorator_list", []):
        if not isinstance(d, ast.Call):
            continue
        for kw in d.keywords:
            if kw.arg == "static_argnames":
                for n in ast.walk(kw.value):
                    if isinstance(n, ast.Constant) and isinstance(n.value,
                                                                  str):
                        out.add(n.value)
    return out


def check(repo: Repo, roots: Optional[Sequence[str]] = None) -> List[Finding]:
    cg = callgraph.build(repo)
    hot = cg.reachable(list(roots) if roots is not None else HOT_ROOTS)
    out: List[Finding] = []

    for q, fi in cg.funcs.items():
        in_hot = q in hot
        in_traced = q in cg.traced
        if not (in_hot or in_traced):
            continue
        tainted = taint_names(fi.node)
        static = _static_names(cg, fi)
        for node in _own_body(fi.node):
            if in_hot and isinstance(node, ast.Call):
                d = _text_dotted(node.func)
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "item" and not node.args):
                    out.append(Finding(
                        "TC101", fi.module.path, node.lineno,
                        f"`.item()` in hot function {q} — blocking "
                        f"device→host sync"))
                elif d in ("jax.device_get",):
                    out.append(Finding(
                        "TC103", fi.module.path, node.lineno,
                        f"jax.device_get in hot function {q} — every call "
                        f"is a blocking sync; baseline it if designed"))
                elif (d in ("np.asarray", "np.array", "numpy.asarray",
                            "numpy.array") and node.args
                      and expr_tainted(node.args[0], tainted)):
                    out.append(Finding(
                        "TC104", fi.module.path, node.lineno,
                        f"{d} on device value in hot function {q} — "
                        f"implicit d2h copy; use jax.device_get explicitly"))
                elif (isinstance(node.func, ast.Name)
                      and node.func.id in ("int", "float", "bool")
                      and node.args
                      and expr_tainted(node.args[0], tainted)):
                    out.append(Finding(
                        "TC102", fi.module.path, node.lineno,
                        f"{node.func.id}() on device value in hot function "
                        f"{q} — implicit blocking sync"))
            if in_traced and isinstance(node, (ast.If, ast.While)):
                test = node.test
                # exemptions: `is None`, isinstance, static_argnames
                if isinstance(test, ast.Compare) and any(
                        isinstance(op, (ast.Is, ast.IsNot))
                        for op in test.ops):
                    continue
                if (isinstance(test, ast.Call)
                        and isinstance(test.func, ast.Name)
                        and test.func.id in ("isinstance", "hasattr",
                                             "callable")):
                    continue
                names = {n.id for n in ast.walk(test)
                         if isinstance(n, ast.Name)}
                if names & static:
                    continue
                if expr_tainted(test, tainted):
                    kind = "if" if isinstance(node, ast.If) else "while"
                    out.append(Finding(
                        "TC105", fi.module.path, node.lineno,
                        f"Python `{kind}` on traced array value in {q} — "
                        f"use lax.cond/jnp.where (ConcretizationError "
                        f"under jit)"))
    return out
