"""serving-invariant pass (TC4xx): the engine split's ownership contract.

PR 3 split the engine into host policy (``Scheduler``) × device execution
(``DeviceRunner``); PR 5 added the paged pool with the "decode never
allocates" guarantee.  These are structural invariants the type system
can't express, so the analyzer pins them:

* TC401 — block-table state is mutated only inside ``runner.py`` (the
  device side) — a table write anywhere else can race the allocator's
  host bookkeeping;
* TC402 — no device-memory allocation (``jnp.zeros/…/asarray/stack``,
  ``jax.device_put``, ``init_decode_state``) in serving modules outside
  ``runner.py`` — host policy code must stay array-free so its cost
  model (pure Python) stays honest;
* TC403 — nothing reachable from the decode path calls
  ``BlockAllocator.allocate``/``_take`` or ``init_decode_state`` —
  admission reserves everything up front; decode is read-only on the
  block table;
* TC404 — the ``TTQEngine`` facade keeps its back-compat surface (the
  properties tests/benchmarks/examples consume) and
  ``serving/__init__`` keeps re-exporting the public names;
* TC405 — device placement and mesh construction stay funneled:
  ``jax.device_put`` / ``jax.make_mesh`` / ``jax.sharding.Mesh`` appear
  only under ``parallel/``, in ``launch/mesh.py`` or in
  ``serving/runner.py`` (repo-wide, call or argument position — passing
  ``jax.device_put`` to ``tree.map`` places arrays just the same).
  Scattered placement is how mixed-layout trees and silent resharding
  transfers creep in; the mesh-sharded engine relies on every array
  entering the device through one of these three doors.
* TC406 — no broad exception handlers (bare ``except``,
  ``except Exception``, ``except BaseException``) in serving hot paths.
  A swallowed error in the scheduler/runner/engine loop turns a crash
  into silent token corruption; fault *containment* is the job of the
  designated boundary module ``serving/faults.py`` (exempt by name) and
  of typed handlers (``except MemoryError`` stays legal).
* TC407 — no device dispatch or allocation from coroutine bodies in
  serving modules.  The async front end (``serving/server.py``,
  DESIGN.md §13) runs on the event-loop thread; every engine call
  (``submit``/``step``/``cancel``/…) and every ``jnp.``/``jax.``
  operation must happen on the dedicated worker thread.  An engine call
  inside an ``async def`` either blocks the loop for a whole device
  dispatch or races the worker thread on device state — both are bugs
  the type system can't see.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from . import callgraph
from .core import Finding, Module, Repo

_ALLOC_CALLS = {
    "jnp.zeros", "jnp.ones", "jnp.full", "jnp.empty", "jnp.arange",
    "jnp.asarray", "jnp.array", "jnp.stack", "jnp.concatenate",
    "jnp.zeros_like", "jnp.ones_like", "jax.device_put",
    "jax.numpy.zeros", "jax.numpy.asarray", "jax.numpy.stack",
}
_DECODE_ROOTS = [
    "repro.serving.runner.DeviceRunner.decode_block",
    "repro.models.lm.decode_many",
]
_ALLOCATOR_FNS = {
    "repro.serving.blocks.BlockAllocator.allocate",
    "repro.serving.blocks.BlockAllocator._take",
    "repro.models.lm.init_decode_state",
}

# TC405: placement/mesh primitives and the modules allowed to use them
_PLACEMENT_ATTRS = {"jax.device_put", "jax.make_mesh", "jax.sharding.Mesh"}

# TC407: engine entry points that dispatch device work (or mutate device
# state) — none may be called from a coroutine body in a serving module
_ENGINE_ENTRY = {
    "step", "run_all", "admit", "submit", "cancel", "decode_block",
    "admit_group", "prefill_chunk", "release_slots", "_requantize",
    "place_params", "calibrate", "requantize",
}


def _placement_allowed(path: str) -> bool:
    return ("/parallel/" in path or path.endswith("launch/mesh.py")
            or path.endswith("serving/runner.py"))


# the facade surface consumers (tests/benchmarks/examples) rely on
ENGINE_ATTRS = [
    "decode_params", "qparams", "n_requants", "lowrank_tree",
    "layers_requantized", "layers_skipped", "agg_stats", "stat_count",
    "admits_since_cal", "queue", "slot_req", "finished", "state", "pos",
    "cur_tok", "host_syncs", "allocator", "kv_pool_utilization",
    "prefix_hit_rate", "preemptions", "prefill_tokens",
    "calib_rejections", "quarantine", "requant_rejections", "lane_faults",
    "deadline_expirations", "admission_failures", "degrade_level",
    "submit", "cancel", "admit", "step", "run_all",
    "queue_depth", "queue_rejections", "prefill_chunks",
    "latency_percentiles", "set_stream_callbacks",
]
SERVING_EXPORTS = ["BlockAllocator", "DeviceRunner", "EngineConfig",
                   "Fault", "FaultInjector", "GenResult", "QueueFull",
                   "Request", "RequestFailed", "Scheduler", "TTQEngine",
                   "TTQServer", "VirtualClock"]


def _text(expr: ast.AST) -> Optional[str]:
    parts: List[str] = []
    node = expr
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _is_serving(mod: Module) -> bool:
    return "serving" in mod.path.split("/")


def _walk_own(fn: ast.AsyncFunctionDef):
    """Walk a coroutine's body without descending into nested ``def``s
    (a nested sync function may legitimately run on the worker thread;
    nested coroutines are visited by the module-level walk on their own)."""
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _touches_block_table(tgt: ast.AST) -> bool:
    for n in ast.walk(tgt):
        if isinstance(n, ast.Constant) and n.value == "block_table":
            return True
        if isinstance(n, ast.Attribute) and n.attr == "block_table":
            return True
    return False


def check(repo: Repo) -> List[Finding]:
    cg = callgraph.build(repo)
    out: List[Finding] = []

    serving_mods = [m for m in repo if _is_serving(m)]
    for mod in serving_mods:
        base = mod.path.rsplit("/", 1)[-1]
        if base in ("runner.py", "blocks.py"):
            continue
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                tgts = (node.targets if isinstance(node, ast.Assign)
                        else [node.target])
                for t in tgts:
                    if _touches_block_table(t):
                        out.append(Finding(
                            "TC401", mod.path, node.lineno,
                            f"block-table mutation outside runner.py "
                            f"({base}) — device block tables belong to "
                            f"DeviceRunner"))
            if isinstance(node, ast.Call):
                d = _text(node.func)
                if d in _ALLOC_CALLS:
                    out.append(Finding(
                        "TC402", mod.path, node.lineno,
                        f"device allocation `{d}` in serving module {base} "
                        f"— array staging belongs to DeviceRunner"))
                fi = cg.resolve_func(cg.dotted(mod, node.func))
                if (fi is not None
                        and fi.qualname.endswith(".init_decode_state")):
                    out.append(Finding(
                        "TC402", mod.path, node.lineno,
                        f"init_decode_state call in serving module {base} "
                        f"— decode state belongs to DeviceRunner"))

    # TC403: decode path never allocates pool state
    decode = cg.reachable(_DECODE_ROOTS)
    for q in sorted(decode):
        fi = cg.funcs.get(q)
        if fi is None:
            continue
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            d = cg.dotted(fi.module, node.func, fi.class_name)
            fi2 = cg.resolve_func(d)
            target = fi2.qualname if fi2 is not None else d
            if target in _ALLOCATOR_FNS:
                out.append(Finding(
                    "TC403", fi.module.path, node.lineno,
                    f"{target.split('.')[-1]} called from decode-reachable "
                    f"{q} — decode must never allocate (admission reserves "
                    f"up front)"))
            # self.allocator.allocate(...) textual form
            t = _text(node.func)
            if t and t.endswith("allocator.allocate"):
                out.append(Finding(
                    "TC403", fi.module.path, node.lineno,
                    f"allocator.allocate called from decode-reachable {q} "
                    f"— decode must never allocate"))

    # TC405: placement/mesh primitives only behind the three doors
    for mod in repo:
        if _placement_allowed(mod.path):
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Attribute):
                continue
            d = _text(node)
            if d in _PLACEMENT_ATTRS:
                out.append(Finding(
                    "TC405", mod.path, node.lineno,
                    f"`{d}` outside parallel/, launch/mesh.py, "
                    f"serving/runner.py — device placement and mesh "
                    f"construction are funneled (DESIGN.md §10)"))

    # TC406: broad exception handlers stay inside the fault boundary
    _BROAD = {"Exception", "BaseException"}
    for mod in serving_mods:
        if mod.path.endswith("faults.py"):   # the designated fault boundary
            continue
        base = mod.path.rsplit("/", 1)[-1]
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            t = node.type
            names = ([] if t is None
                     else [n for n in (getattr(e, "id", getattr(e, "attr",
                                                                None))
                           for e in (t.elts if isinstance(t, ast.Tuple)
                                     else [t])) if n])
            if t is None or any(n in _BROAD for n in names):
                what = "bare except" if t is None else \
                    f"except {'/'.join(n for n in names if n in _BROAD)}"
                out.append(Finding(
                    "TC406", mod.path, node.lineno,
                    f"{what} in serving module {base} — broad handlers "
                    f"mask corruption in the serving loop; contain faults "
                    f"in serving/faults.py or catch the specific error"))

    # TC407: coroutine bodies in serving modules stay device-free
    for mod in serving_mods:
        base = mod.path.rsplit("/", 1)[-1]
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, ast.AsyncFunctionDef):
                continue
            for node in _walk_own(fn):
                if not isinstance(node, ast.Call):
                    continue
                d = _text(node.func)
                if d is not None and (d.startswith("jnp.")
                                      or d.startswith("jax.")):
                    out.append(Finding(
                        "TC407", mod.path, node.lineno,
                        f"`{d}` inside coroutine `{fn.name}` ({base}) — "
                        f"device ops run on the engine worker thread, "
                        f"never the event loop"))
                elif (isinstance(node.func, ast.Attribute)
                        and node.func.attr in _ENGINE_ENTRY):
                    out.append(Finding(
                        "TC407", mod.path, node.lineno,
                        f"engine call `.{node.func.attr}(...)` inside "
                        f"coroutine `{fn.name}` ({base}) — engine entry "
                        f"points dispatch device work; hand the command "
                        f"to the worker thread instead"))

    # TC404: facade surface + package re-exports
    eng = cg.classes.get("repro.serving.engine.TTQEngine")
    if eng is not None:
        have = set()
        for node in eng.node.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                have.add(node.name)
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        have.add(t.id)
        init = cg.funcs.get("repro.serving.engine.TTQEngine.__init__")
        if init is not None:
            for node in ast.walk(init.node):
                if isinstance(node, ast.Assign):
                    for t in node.targets:
                        if (isinstance(t, ast.Attribute)
                                and isinstance(t.value, ast.Name)
                                and t.value.id == "self"):
                            have.add(t.attr)
        for a in ENGINE_ATTRS:
            if a not in have:
                out.append(Finding(
                    "TC404", eng.module.path, eng.node.lineno,
                    f"TTQEngine facade lost back-compat attr `{a}` — "
                    f"consumers (tests/benchmarks/examples) depend on it"))
    pkg = cg.repo.by_name.get("repro.serving")
    if pkg is not None:
        table = cg.imports.get("repro.serving", {})
        for name in SERVING_EXPORTS:
            if name not in table:
                out.append(Finding(
                    "TC404", pkg.path, 1,
                    f"repro.serving no longer re-exports `{name}`"))
    return out
