"""docs-links pass (TCDOC): markdown links and §-citations resolve.

The former ``tools/check_docs_links.py`` (which now shims to this module)
as a tracecheck pass, so CI runs one entry point:

* TCDOC1 — every relative ``[text](path)`` link in the repo's ``*.md``
  files resolves to an existing file (anchors/URLs skipped);
* TCDOC2 — every ``EXPERIMENTS.md §…`` / ``DESIGN.md §…`` citation in
  ``src``/``benchmarks``/``examples``/``tests``/``tools`` resolves to a
  section heading of that document (numeric citations need a heading with
  that number prefix).
"""
from __future__ import annotations

import os
import re
from typing import List, Optional

from .core import Finding, REPO_ROOT

SRC_DIRS = ("src", "benchmarks", "examples", "tests", "tools")
MD_LINK = re.compile(r"\[[^\]]+\]\(([^)#\s]+)(?:#[^)]*)?\)")
# EXPERIMENTS.md §Roofline | DESIGN.md §"KV-cache layout" | DESIGN.md §4
CITE = re.compile(r"(EXPERIMENTS|DESIGN)\.md\s+§(?:\"([^\"]+)\"|(\w[\w-]*))")


def _md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if d not in (".git", "__pycache__", ".github",
                                    "results")]
        for f in filenames:
            if f.endswith(".md"):
                yield os.path.join(dirpath, f)


def _headings(root: str, doc: str) -> Optional[List[str]]:
    path = os.path.join(root, doc)
    if not os.path.exists(path):
        return None
    with open(path, encoding="utf-8") as f:
        return [ln.lstrip("#").strip() for ln in f if ln.startswith("#")]


def check(root: str = REPO_ROOT) -> List[Finding]:
    out: List[Finding] = []
    for path in _md_files(root):
        base = os.path.dirname(path)
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as f:
            for ln, line in enumerate(f, 1):
                for m in MD_LINK.finditer(line):
                    target = m.group(1)
                    if "://" in target or target.startswith("mailto:"):
                        continue
                    if not os.path.exists(os.path.join(base, target)):
                        out.append(Finding("TCDOC1", rel, ln,
                                           f"dangling link -> {target}"))

    heads = {d: _headings(root, f"{d}.md") for d in ("EXPERIMENTS", "DESIGN")}
    for sub in SRC_DIRS:
        top = os.path.join(root, sub)
        if not os.path.isdir(top):
            continue
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fname in sorted(filenames):
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, root).replace(os.sep, "/")
                with open(path, encoding="utf-8") as f:
                    # whole-file scan: the `\s+` crosses docstring line
                    # wraps, which a per-line scan would silently skip
                    content = f.read()
                for m in CITE.finditer(content):
                    ln = content.count("\n", 0, m.start()) + 1
                    doc, quoted, word = m.group(1), m.group(2), m.group(3)
                    name = re.sub(r"\s+", " ", quoted or word)
                    hs = heads[doc]
                    if hs is None:
                        out.append(Finding("TCDOC2", rel, ln,
                                           f"cites missing {doc}.md"))
                        continue
                    if word and word.isdigit():
                        ok = any(h.startswith(f"{word}.") for h in hs)
                    else:
                        ok = any(name.lower() in h.lower() for h in hs)
                    if not ok:
                        out.append(Finding(
                            "TCDOC2", rel, ln,
                            f"dangling citation {doc}.md §{name}"))
    return out
