"""CLI: ``python -m tools.tracecheck [paths] [options]``.

Default paths: ``src/repro``.  Exit 1 iff non-baselined findings remain.

Options:
  --no-baseline     report baselined findings too (and fail on them)
  --no-docs         skip the docs-links pass
  --pass NAME       run only the named pass (repeatable): host-sync,
                    recompile-hazard, kernel-contract, serving-invariant,
                    docs-links
"""
from __future__ import annotations

import argparse
import sys

from .core import run


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m tools.tracecheck")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files/dirs to scan (default: src/repro)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore baseline.toml (report everything)")
    ap.add_argument("--no-docs", action="store_true",
                    help="skip the docs-links pass")
    ap.add_argument("--pass", dest="passes", action="append", default=None,
                    metavar="NAME", help="run only this pass (repeatable)")
    args = ap.parse_args(argv)

    paths = args.paths or ["src/repro"]
    new, old = run(paths, use_baseline=not args.no_baseline,
                   passes=args.passes, docs=not args.no_docs)
    for f in new:
        print(f)
    if new:
        print(f"\ntracecheck FAILED: {len(new)} finding(s)"
              + (f" ({len(old)} baselined)" if old else ""))
        print("fix, suppress with `# tracecheck: ok[RULE]`, or baseline "
              "in tools/tracecheck/baseline.toml with a reason")
        return 1
    print(f"tracecheck passed: 0 new findings ({len(old)} baselined)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
