"""tracecheck core: findings, baseline, suppression, pass registry, CLI.

A *finding* is ``(rule, path, line, message)``.  Two escape hatches:

* inline: append ``# tracecheck: ok[TC103]`` (comma-separate several rule
  ids) to the offending line — scoped, visible in review;
* baseline: a ``[[ignore]]`` table in ``baseline.toml`` with ``rule``,
  ``path`` and a one-line ``reason`` — for findings that are *designed*
  (e.g. the single host sync per decode chunk) rather than local quirks.

``run(paths)`` parses every ``*.py`` under the given paths once, hands the
parsed repo to each registered pass, then filters suppressed/baselined
findings.  Exit status is non-zero iff non-baselined findings remain.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
BASELINE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "baseline.toml")

_SUPPRESS = re.compile(r"#\s*tracecheck:\s*ok\[([^\]]+)\]")


@dataclass(frozen=True, order=True)
class Finding:
    """One analyzer hit.  ``path`` is repo-relative with ``/`` separators."""
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"


@dataclass
class Module:
    """A parsed source file: repo-relative path, dotted name, AST, lines."""
    path: str                  # repo-relative, "/"-separated
    name: str                  # dotted module name ("repro.serving.runner")
    tree: ast.Module
    lines: List[str]           # source lines (1-indexed via lines[i-1])

    def suppressed(self, line: int, rule: str) -> bool:
        if not 1 <= line <= len(self.lines):
            return False
        m = _SUPPRESS.search(self.lines[line - 1])
        return bool(m) and rule in {r.strip() for r in m.group(1).split(",")}


class Repo:
    """All parsed modules keyed by dotted name, plus path lookup."""

    def __init__(self, modules: Sequence[Module]):
        self.modules = list(modules)
        self.by_name: Dict[str, Module] = {m.name: m for m in modules}

    def __iter__(self):
        return iter(self.modules)


def _module_name(relpath: str) -> str:
    """Dotted module name for a repo-relative path (src/ layout aware)."""
    p = relpath.replace("\\", "/")
    for prefix in ("src/",):
        if p.startswith(prefix):
            p = p[len(prefix):]
            break
    if p.endswith(".py"):
        p = p[:-3]
    if p.endswith("/__init__"):
        p = p[: -len("/__init__")]
    return p.replace("/", ".")


def parse_paths(paths: Sequence[str], root: str = REPO_ROOT) -> Repo:
    """Parse every ``*.py`` under ``paths`` (files or directories)."""
    files: List[str] = []
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(ap):
            files.append(ap)
        else:
            for dirpath, dirnames, filenames in os.walk(ap):
                dirnames[:] = [d for d in dirnames if d != "__pycache__"]
                files.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames) if f.endswith(".py"))
    mods = []
    for f in sorted(set(files)):
        rel = os.path.relpath(f, root).replace(os.sep, "/")
        with open(f, encoding="utf-8") as fh:
            src = fh.read()
        try:
            tree = ast.parse(src, filename=rel)
        except SyntaxError as e:           # surface, don't crash the run
            mods.append(Module(rel, _module_name(rel),
                               ast.Module(body=[], type_ignores=[]),
                               src.splitlines()))
            tree = mods[-1].tree
            tree._tracecheck_syntax_error = e  # type: ignore[attr-defined]
            continue
        mods.append(Module(rel, _module_name(rel), tree, src.splitlines()))
    return Repo(mods)


# --------------------------------------------------------------- baseline

def load_baseline(path: str = BASELINE_PATH) -> List[dict]:
    """Read ``[[ignore]]`` entries.  Python 3.10 has no ``tomllib``, so this
    is a tolerant line parser for the flat subset the baseline uses:
    ``[[ignore]]`` headers followed by ``key = "value"`` lines."""
    try:
        import tomllib  # type: ignore[import-not-found]  # py311+
        with open(path, "rb") as f:
            return list(tomllib.load(f).get("ignore", []))
    except ImportError:
        pass
    except FileNotFoundError:
        return []
    entries: List[dict] = []
    cur: Optional[dict] = None
    try:
        with open(path, encoding="utf-8") as f:
            lines = f.readlines()
    except FileNotFoundError:
        return []
    for raw in lines:
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line == "[[ignore]]":
            cur = {}
            entries.append(cur)
            continue
        m = re.match(r'^(\w+)\s*=\s*"(.*)"\s*(?:#.*)?$', line)
        if m and cur is not None:
            cur[m.group(1)] = m.group(2)
    return entries


def baselined(finding: Finding, baseline: Iterable[dict]) -> bool:
    """A baseline entry matches on (rule, path) plus an optional
    ``contains`` message substring; line numbers are left out on purpose so
    unrelated edits to a file don't invalidate the entry."""
    return any(e.get("rule") == finding.rule and e.get("path") == finding.path
               and e.get("contains", "") in finding.message
               for e in baseline)


# --------------------------------------------------------------- registry

def all_passes():
    """(name, callable) for each analysis pass; callable(Repo) -> findings."""
    from . import hostsync, kernelcontract, recompile, serving
    return [
        ("host-sync", hostsync.check),
        ("recompile-hazard", recompile.check),
        ("kernel-contract", kernelcontract.check),
        ("serving-invariant", serving.check),
    ]


def scan_paths(paths: Sequence[str], root: str = REPO_ROOT,
               passes: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the AST passes over ``paths`` and return raw (unfiltered but
    suppression-aware) findings, sorted."""
    repo = parse_paths(paths, root)
    findings: List[Finding] = []
    for mod in repo:
        err = getattr(mod.tree, "_tracecheck_syntax_error", None)
        if err is not None:
            findings.append(Finding("TC000", mod.path, err.lineno or 1,
                                    f"syntax error: {err.msg}"))
    for name, fn in all_passes():
        if passes is not None and name not in passes:
            continue
        findings.extend(fn(repo))
    out = []
    for f in findings:
        mod = next((m for m in repo if m.path == f.path), None)
        if mod is not None and mod.suppressed(f.line, f.rule):
            continue
        out.append(f)
    return sorted(set(out))


def run(paths: Sequence[str], root: str = REPO_ROOT, use_baseline: bool = True,
        passes: Optional[Sequence[str]] = None, docs: bool = True,
        ) -> Tuple[List[Finding], List[Finding]]:
    """Full run: AST passes + docs-links.  Returns (new, baselined)."""
    findings = scan_paths(paths, root, passes)
    if docs and (passes is None or "docs-links" in passes):
        from . import docs_links
        findings.extend(docs_links.check(root))
        findings = sorted(set(findings))
    baseline = load_baseline() if use_baseline else []
    new = [f for f in findings if not baselined(f, baseline)]
    old = [f for f in findings if baselined(f, baseline)]
    return new, old
