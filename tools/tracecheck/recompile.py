"""recompile-hazard pass (TC2xx): things that silently explode jit caches.

Builds a registry of *jitted callables* — decorator-jitted defs
(``@jax.jit`` / ``@partial(jax.jit, static_argnames=…)``) and
``X = jax.jit(f, …)`` / ``self.X = jax.jit(partial(f, a, b), …)``
assignments — with each one's effective signature (partial-bound args
stripped) and static params.  Rules:

* TC201 — ``static_argnames``/``static_argnums`` drift: a static name not
  in the wrapped callable's remaining signature, or a num out of range
  (jax raises at call time; the analyzer catches it at review time);
* TC202 — mutable (``list``/``dict``/``set``) default in a jitted def's
  signature: the default's identity is the cache key, so a fresh literal
  per import/reload recompiles, and mutation invalidates silently;
* TC203 — unhashable literal (list/dict/set display) passed to a static
  param at a jit callsite: ``TypeError: unhashable`` at best, per-call
  recompile if wrapped in ``tuple(...)`` at each site;
* TC204 — non-frozen dataclass instance passed to a static param: Python
  hashes it by identity, so every construction is a cache miss.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from . import callgraph
from .core import Finding, Repo

_JIT_NAMES = {"jax.jit", "jit", "jax.pmap", "pmap"}
_PARTIAL = {"functools.partial", "partial"}


def _text(expr: ast.AST) -> Optional[str]:
    parts: List[str] = []
    node = expr
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _const_strs(expr: ast.AST) -> List[str]:
    out = []
    for n in ast.walk(expr):
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            out.append(n.value)
    return out


def _const_ints(expr: ast.AST) -> List[int]:
    out = []
    for n in ast.walk(expr):
        if isinstance(n, ast.Constant) and isinstance(n.value, int)\
                and not isinstance(n.value, bool):
            out.append(n.value)
    return out


@dataclass
class JitTarget:
    """One jitted callable and its effective (post-partial) signature."""
    static_names: Set[str]
    static_nums: List[int]
    params: Optional[List[str]]          # effective positional-or-kw names
    def_node: Optional[ast.AST]          # wrapped def, when resolved
    site_module: str
    site_line: int


def _def_params(fn: ast.AST) -> List[str]:
    a = fn.args
    return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]


def _unwrap_partial(cg: callgraph.CallGraph, mod, expr: ast.AST,
                    self_class: Optional[str]
                    ) -> Tuple[Optional[callgraph.FuncInfo], int, Set[str]]:
    """Resolve ``f`` / ``partial(f, a, kw=b)`` → (def, n_bound_pos, bound_kw).
    Nested partials accumulate."""
    bound_pos, bound_kw = 0, set()
    while isinstance(expr, ast.Call):
        name = _text(expr.func)
        if name is None or name.split(".")[-1] != "partial":
            break
        if not expr.args:
            return None, 0, set()
        bound_pos += len(expr.args) - 1
        bound_kw |= {k.arg for k in expr.keywords if k.arg}
        expr = expr.args[0]
    if isinstance(expr, (ast.Name, ast.Attribute)):
        fi = cg.resolve_func(cg.dotted(mod, expr, self_class))
        return fi, bound_pos, bound_kw
    return None, bound_pos, bound_kw


def _jit_call_info(cg: callgraph.CallGraph, mod, call: ast.Call,
                   self_class: Optional[str]) -> Optional[JitTarget]:
    """If ``call`` is ``jax.jit(f_expr, static_…=…)``, build its target."""
    name = _text(call.func)
    if name is None or name.split(".")[-1] not in ("jit", "pmap"):
        return None
    if not call.args:
        return None
    static_names: Set[str] = set()
    static_nums: List[int] = []
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            static_names |= set(_const_strs(kw.value))
        elif kw.arg == "static_argnums":
            static_nums += _const_ints(kw.value)
    fi, bound_pos, bound_kw = _unwrap_partial(cg, mod, call.args[0],
                                              self_class)
    params = None
    def_node = None
    if fi is not None:
        def_node = fi.node
        allp = _def_params(fi.node)
        params = [p for p in allp[bound_pos:] if p not in bound_kw]
    return JitTarget(static_names, static_nums, params, def_node,
                     mod.path, call.lineno)


def _decorated_jit(cg: callgraph.CallGraph, fi: callgraph.FuncInfo
                   ) -> Optional[JitTarget]:
    """JitTarget for ``@jax.jit`` / ``@partial(jax.jit, …)`` defs."""
    for d in getattr(fi.node, "decorator_list", []):
        names: Set[str] = set()
        nums: List[int] = []
        is_jit = False
        if isinstance(d, ast.Call):
            dn = _text(d.func)
            if dn and dn.split(".")[-1] == "partial" and d.args:
                inner = _text(d.args[0])
                if inner and inner.split(".")[-1] in ("jit", "pmap"):
                    is_jit = True
            elif dn and dn.split(".")[-1] in ("jit", "pmap"):
                is_jit = True
            if is_jit:
                for kw in d.keywords:
                    if kw.arg == "static_argnames":
                        names |= set(_const_strs(kw.value))
                    elif kw.arg == "static_argnums":
                        nums += _const_ints(kw.value)
        else:
            dn = _text(d)
            is_jit = bool(dn) and dn.split(".")[-1] in ("jit", "pmap")
        if is_jit:
            return JitTarget(names, nums, _def_params(fi.node), fi.node,
                             fi.module.path, fi.node.lineno)
    return None


_MUTABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
            ast.SetComp)


def check(repo: Repo) -> List[Finding]:
    cg = callgraph.build(repo)
    out: List[Finding] = []

    # registry: how callsites refer to jitted callables
    by_qualname: Dict[str, JitTarget] = {}        # decorated defs
    by_attr: Dict[Tuple[str, str], JitTarget] = {}  # (class_q, attr)
    by_global: Dict[str, JitTarget] = {}          # module-level assigns

    for q, fi in cg.funcs.items():
        jt = _decorated_jit(cg, fi)
        if jt is not None:
            by_qualname[q] = jt

    for mod in repo:
        for stmt in mod.tree.body:
            if isinstance(stmt, ast.Assign) and isinstance(stmt.value,
                                                           ast.Call):
                jt = _jit_call_info(cg, mod, stmt.value, None)
                if jt is None:
                    continue
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        by_global[f"{mod.name}.{t.id}"] = jt
    for q, fi in cg.funcs.items():
        if fi.class_name is None:
            continue
        cls_q = q.rsplit(".", 1)[0]
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                jt = _jit_call_info(cg, fi.module, node.value, fi.class_name)
                if jt is None:
                    continue
                for t in node.targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        by_attr[(cls_q, t.attr)] = jt

    # ---- TC201 drift + TC202 mutable defaults, per registered target
    seen_sites = set()
    for jt in (list(by_qualname.values()) + list(by_global.values())
               + list(by_attr.values())):
        key = (jt.site_module, jt.site_line)
        if key in seen_sites:
            continue
        seen_sites.add(key)
        if jt.params is not None:
            for n in sorted(jt.static_names):
                if n not in jt.params:
                    out.append(Finding(
                        "TC201", jt.site_module, jt.site_line,
                        f"static_argnames entry '{n}' not in the wrapped "
                        f"callable's remaining signature {jt.params}"))
            for i in jt.static_nums:
                if not -len(jt.params) <= i < len(jt.params):
                    out.append(Finding(
                        "TC201", jt.site_module, jt.site_line,
                        f"static_argnums entry {i} out of range for "
                        f"signature {jt.params}"))
        if jt.def_node is not None:
            a = jt.def_node.args
            for p, dflt in list(zip(reversed(a.args + a.posonlyargs),
                                    reversed(a.defaults))) + \
                    [(p, d) for p, d in zip(a.kwonlyargs, a.kw_defaults)
                     if d is not None]:
                if isinstance(dflt, _MUTABLE):
                    out.append(Finding(
                        "TC202", jt.site_module, dflt.lineno,
                        f"mutable default for '{p.arg}' in jitted "
                        f"signature — unhashable/identity-keyed cache "
                        f"entry"))

    # ---- TC203/TC204: callsite args bound to static params
    def static_params(jt: JitTarget) -> Set[str]:
        names = set(jt.static_names)
        if jt.params is not None:
            for i in jt.static_nums:
                if -len(jt.params) <= i < len(jt.params):
                    names.add(jt.params[i])
        return names

    def check_site(call: ast.Call, jt: JitTarget, mod, fn_q: str,
                   local_unfrozen: Dict[str, str]):
        statics = static_params(jt)
        if not statics:
            return
        bindings: List[Tuple[str, ast.AST]] = []
        if jt.params is not None:
            for i, a in enumerate(call.args):
                if i < len(jt.params):
                    bindings.append((jt.params[i], a))
        for kw in call.keywords:
            if kw.arg is not None:
                bindings.append((kw.arg, kw.value))
        for pname, val in bindings:
            if pname not in statics:
                continue
            if isinstance(val, _MUTABLE):
                out.append(Finding(
                    "TC203", mod.path, val.lineno,
                    f"unhashable {type(val).__name__.lower()} literal "
                    f"passed to static arg '{pname}' in {fn_q}"))
                continue
            ctor = None
            if isinstance(val, ast.Call):
                ctor = cg.resolve_class(cg.dotted(mod, val.func))
            elif isinstance(val, ast.Name) and val.id in local_unfrozen:
                ctor = cg.classes.get(local_unfrozen[val.id])
            if ctor is not None and ctor.is_dataclass and not ctor.frozen:
                out.append(Finding(
                    "TC204", mod.path, val.lineno,
                    f"non-frozen dataclass {ctor.qualname.split('.')[-1]} "
                    f"passed to static arg '{pname}' in {fn_q} — hashes "
                    f"by identity, every instance is a cache miss"))

    for q, fi in cg.funcs.items():
        # local names assigned from non-frozen dataclass ctors
        local_unfrozen: Dict[str, str] = {}
        for node in ast.walk(fi.node):
            if (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)):
                ci = cg.resolve_class(cg.dotted(fi.module, node.value.func,
                                                fi.class_name))
                if ci is not None and ci.is_dataclass and not ci.frozen:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            local_unfrozen[t.id] = ci.qualname
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            jt = None
            d = cg.dotted(fi.module, node.func, fi.class_name)
            if d is not None:
                fi2 = cg.resolve_func(d)
                if fi2 is not None and fi2.qualname in by_qualname:
                    jt = by_qualname[fi2.qualname]
                elif d in by_global:
                    jt = by_global[d]
                else:
                    chased = cg._chase(d)
                    if chased in by_global:
                        jt = by_global[chased]
            if (jt is None and isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "self"
                    and fi.class_name is not None):
                jt = by_attr.get((f"{fi.module.name}.{fi.class_name}",
                                  node.func.attr))
            if jt is not None:
                check_site(node, jt, fi.module, q, local_unfrozen)
    return out
